"""Batched serving example: single-tenant loop + multi-tenant routing.

    PYTHONPATH=src python examples/serve_batched.py

Part 1 serves a batch of 4 requests against the smoke-scale qwen2-7b
family config: one jitted prefill builds the KV cache for all requests at
once, then the decode step is reused per generated token (cache donated =
in-place). This is the serving shape the ``decode_32k`` / ``long_500k``
dry-run cells lower at production scale.

Part 2 is the multi-tenant shape (docs/serving.md): three adapter sets
registered in an ``AdapterStateCache`` LRU, six requests carrying adapter
handles, served in ONE grouped decode loop — and checked bitwise against
serving each tenant alone.
"""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config                      # noqa: E402
from repro.core import AdapterStateCache, DoRAConfig      # noqa: E402
from repro.launch.serve import (MultiTenantServer,        # noqa: E402
                                Request, generate)
from repro.launch.steps import StepConfig                 # noqa: E402
from repro.launch.train import build_state                # noqa: E402
from repro.obs import monotonic                     # noqa: E402


def main() -> None:
    mcfg = get_config("qwen2-7b", smoke=True)
    dcfg = DoRAConfig(rank=8, alpha=16.0, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, seed=0)

    batch, prompt_len, gen_len = 4, 24, 12
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, mcfg.vocab_size, (batch, prompt_len),
                           dtype=np.int32)

    t0 = monotonic()
    toks = generate(mcfg, params, adapters, scfg, prompts,
                    gen_len=gen_len, max_len=prompt_len + gen_len,
                    temperature=0.8, seed=42)
    dt = monotonic() - t0
    toks = np.asarray(toks)
    print(f"served {batch} requests x {gen_len} new tokens in {dt:.1f}s")
    for b in range(batch):
        gen = toks[b, prompt_len:].tolist()
        tail = toks[b, prompt_len - 3:prompt_len].tolist()
        print(f"  req{b}: prompt[-3:]={tail} -> generated {gen}")
    assert toks.shape == (batch, prompt_len + gen_len)
    # greedy decode twice == deterministic
    toks2 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                                gen_len=gen_len,
                                max_len=prompt_len + gen_len,
                                temperature=0.0))
    toks3 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                                gen_len=gen_len,
                                max_len=prompt_len + gen_len,
                                temperature=0.0))
    assert np.array_equal(toks2, toks3), "greedy decode must be deterministic"
    print("greedy decode deterministic: OK")

    # -- Part 2: multi-tenant routing over the adapter-state LRU ----------
    n_tenants, rows_per, P, G = 3, 2, 12, 6
    cache = AdapterStateCache.for_serving(mcfg, scfg)
    requests = []
    for t in range(n_tenants):
        _, ad_t, _ = build_state(mcfg, dcfg, seed=10 + t)
        cache.register(f"tenant-{t}", ad_t)
        for _ in range(rows_per):
            requests.append(Request(
                rng.integers(0, mcfg.vocab_size, P, dtype=np.int32),
                f"tenant-{t}"))
    server = MultiTenantServer(mcfg, scfg, params, cache=cache)
    t0 = monotonic()
    mixed = np.asarray(server.serve(requests, gen_len=G, max_len=P + G))
    dt = monotonic() - t0
    st = cache.stats()
    print(f"multi-tenant: {len(requests)} requests / {n_tenants} adapters "
          f"in ONE decode loop, {dt:.1f}s; cache {st.misses} misses -> "
          f"{st.hits} hits, {st.current_bytes} state bytes")
    # per-tenant sequential serving must agree bitwise (fp32 smoke config)
    for t in range(n_tenants):
        rows = [i for i, r in enumerate(requests)
                if r.adapter == f"tenant-{t}"]
        alone = np.asarray(generate(
            mcfg, params, cache.current_handle(f"tenant-{t}"), scfg,
            np.stack([np.asarray(requests[i].prompt) for i in rows]),
            gen_len=G, max_len=P + G, adapter_cache=cache))
        assert np.array_equal(alone, mixed[rows]), f"tenant {t} mismatch"
    print("mixed batch == per-tenant sequential: OK (bitwise)")


if __name__ == "__main__":
    main()
