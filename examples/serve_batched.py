"""Batched serving example: prefill + decode with a DoRA-adapted model.

    PYTHONPATH=src python examples/serve_batched.py

Serves a batch of 4 requests against the smoke-scale qwen2-7b family
config: one jitted prefill builds the KV cache for all requests at once,
then the decode step is reused per generated token (cache donated =
in-place). This is the serving shape the ``decode_32k`` / ``long_500k``
dry-run cells lower at production scale.
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config                      # noqa: E402
from repro.core import DoRAConfig                         # noqa: E402
from repro.launch.serve import generate                   # noqa: E402
from repro.launch.steps import StepConfig                 # noqa: E402
from repro.launch.train import build_state                # noqa: E402


def main() -> None:
    mcfg = get_config("qwen2-7b", smoke=True)
    dcfg = DoRAConfig(rank=8, alpha=16.0, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, seed=0)

    batch, prompt_len, gen_len = 4, 24, 12
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, mcfg.vocab_size, (batch, prompt_len),
                           dtype=np.int32)

    t0 = time.time()
    toks = generate(mcfg, params, adapters, scfg, prompts,
                    gen_len=gen_len, max_len=prompt_len + gen_len,
                    temperature=0.8, seed=42)
    dt = time.time() - t0
    toks = np.asarray(toks)
    print(f"served {batch} requests x {gen_len} new tokens in {dt:.1f}s")
    for b in range(batch):
        gen = toks[b, prompt_len:].tolist()
        print(f"  req{b}: prompt[-3:]={toks[b, prompt_len-3:prompt_len]"
              f".tolist()} -> generated {gen}")
    assert toks.shape == (batch, prompt_len + gen_len)
    # greedy decode twice == deterministic
    toks2 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                                gen_len=gen_len,
                                max_len=prompt_len + gen_len,
                                temperature=0.0))
    toks3 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                                gen_len=gen_len,
                                max_len=prompt_len + gen_len,
                                temperature=0.0))
    assert np.array_equal(toks2, toks3), "greedy decode must be deterministic"
    print("greedy decode deterministic: OK")


if __name__ == "__main__":
    main()
