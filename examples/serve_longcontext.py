"""Long-context serving example: a block-paged engine admits one LONG
prompt in chunks while short tenants keep streaming.

    PYTHONPATH=src python examples/serve_longcontext.py

The rectangular engine pays two costs for a long-context tenant: its
K/V cache reserves ``slots * max_len`` positions of HBM up front (every
slot pays for the longest request the engine might ever see), and its
monolithic prefill-into-slot processes the whole prompt in one device
call — a long prompt stalls every other tenant's decode for that whole
call. The paged engine (``DecodeEngine(..., paged=True)``) removes both:
K/V lives in a block pool sized to the traffic (blocks allocated as a
row's frontier crosses into them, freed at retirement), and admission
streams the prompt in fixed-size CHUNKS interleaved with decode ticks —
one chunk per tick, so the short tenants emit tokens on every tick of
the long admission.

This example is the smoke-scale version of the 8k-prompt scenario in
``docs/benchmarks.md`` (the smoke config's window is 64, so "long" is a
48-token prompt among 5-to-10-token neighbours — a 6-chunk admission;
the geometry, not the absolute length, is what the assertions lock):

  1. the long prompt admits over 6 chunked ticks and the short tenants
     stream at least one token on EVERY one of those ticks (chunked
     admission never stalls the batch);
  2. every stream — long and short — is bitwise the request served
     alone through ``generate()`` (the paged oracle contract
     ``tests/test_engine.py`` locks);
  3. the block pool's peak occupancy stays under the rectangular
     equivalent (``slots * max_blocks``) and drains to zero.
"""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config                      # noqa: E402
from repro.core import AdapterStateCache, DoRAConfig      # noqa: E402
from repro.launch.engine import DecodeEngine              # noqa: E402
from repro.launch.serve import generate                   # noqa: E402
from repro.launch.steps import StepConfig                 # noqa: E402
from repro.launch.train import build_state                # noqa: E402
from repro.obs import monotonic                     # noqa: E402


def main() -> None:
    mcfg = get_config("qwen2-7b", smoke=True)
    dcfg = DoRAConfig(rank=8, alpha=16.0, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, _, _ = build_state(mcfg, dcfg, seed=0)

    cache = AdapterStateCache.for_serving(mcfg, scfg)
    _, adapters, _ = build_state(mcfg, dcfg, seed=1)
    cache.register("tenant-0", adapters)

    # 3 slots, a 64-position window in 8-position blocks; the pool holds
    # 16 blocks — 2/3 of the 24 a rectangular cache would pin — because
    # only ONE tenant is ever long. Chunked prefill streams 8 prompt
    # tokens per tick.
    slots, max_len, block = 3, 64, 8
    n_blocks = 16
    engine = DecodeEngine(mcfg, scfg, params, slots=slots, max_len=max_len,
                          adapter_cache=cache, paged=True, block_size=block,
                          n_blocks=n_blocks, prefill_chunk=block)

    rng = np.random.default_rng(0)
    long_prompt = rng.integers(0, mcfg.vocab_size, 48, dtype=np.int32)
    # (arrival tick, prompt, budget): short tenants before, during and
    # after the long admission; the long prompt arrives at tick 1.
    trace = [(t, rng.integers(0, mcfg.vocab_size,
                              int(rng.integers(5, 11)), dtype=np.int32),
              int(rng.integers(4, 7)))
             for t in (0, 0, 2, 4, 6, 9, 12)]
    LONG_AT, LONG_BUDGET = 1, 6

    per_tick: dict[int, list[int]] = {}    # tick -> request ids that emitted
    budgets = {}

    t0 = monotonic()
    i, tick, long_rid = 0, 0, None
    while i < len(trace) or long_rid is None or engine.has_work():
        while i < len(trace) and trace[i][0] <= tick:
            budgets[engine.submit(trace[i][1], adapter="tenant-0",
                                  max_new_tokens=trace[i][2])] = trace[i][2]
            i += 1
        if long_rid is None and tick >= LONG_AT:
            long_rid = engine.submit(long_prompt, adapter="tenant-0",
                                     max_new_tokens=LONG_BUDGET)
            budgets[long_rid] = LONG_BUDGET
            print(f"tick {tick:>2}: long prompt (P=48) submitted -> "
                  f"{-(-49 // block)} blocks reserved, "
                  f"{-(-48 // block)} chunks to stream")
        engine.step(lambda rid, tok, _t=tick:
                    per_tick.setdefault(_t, []).append(rid))
        tick += 1
    dt = monotonic() - t0
    results = {r.request_id: r for r in engine.pop_results()}

    # 1. Chunked admission never stalled the batch: the long prompt took
    # several ticks to admit (6 chunks, one per tick), and the SHORT
    # tenants emitted tokens on every one of those ticks.
    first_long_tick = min(t for t, rids in per_tick.items()
                          if long_rid in rids)
    admission_ticks = range(LONG_AT, first_long_tick)
    assert len(admission_ticks) >= 5, (
        f"long admission finished suspiciously fast "
        f"(ticks {LONG_AT}..{first_long_tick})")
    for t in admission_ticks:
        assert any(r != long_rid for r in per_tick.get(t, ())), (
            f"tick {t}: no short-tenant token while the long prompt "
            f"was admitting — chunked admission stalled the batch")
    print(f"long admission spread over ticks "
          f"{LONG_AT}..{first_long_tick - 1}; short tenants streamed on "
          f"every one of them")

    # 2. Every stream — the long one included — is bitwise the request
    # served alone (short tenants are UNAFFECTED by the long neighbour).
    prompts = {long_rid: long_prompt}
    for j, (_, p, _) in enumerate(trace):
        # submission order: two shorts at tick 0, the long prompt at
        # tick 1 (long_rid == 2), then the remaining shorts
        prompts[j if j < 2 else j + 1] = p
    for rid, r in sorted(results.items()):
        p = prompts[rid]
        alone = np.asarray(generate(
            mcfg, params, cache.current_handle("tenant-0"), scfg,
            np.asarray(p)[None], gen_len=len(r.tokens), max_len=max_len,
            adapter_cache=cache))
        assert np.array_equal(r.tokens, alone[0, len(p):]), \
            f"req{rid} diverged from serving it alone"
    print(f"all {len(results)} streams (1 long + {len(trace)} short) == "
          f"served alone: OK")

    # 3. The pool never needed the rectangular reservation, and drained.
    ps = engine.pool_stats()
    rect_blocks = slots * ps["max_blocks"]
    assert ps["peak_used_blocks"] < rect_blocks, ps
    assert ps["used_blocks"] == 0 and ps["free_blocks"] == n_blocks, ps
    st = engine.stats()
    print(f"block pool: peak {ps['peak_used_blocks']}/{n_blocks} blocks "
          f"(rectangular would pin {rect_blocks}); drained to 0")
    print(f"served {st.admitted} requests in {dt:.1f}s, "
          f"{st.decode_steps} decode steps, occupancy "
          f"{st.mean_occupancy:.2f}")
    counts = engine.compile_counts()
    assert counts["prefill_chunk"] == 1 and counts["decode"] == {None: 1}, \
        counts
    print("compiled surface: 1 chunk-prefill + 1 decode "
          "(paging/joining never recompiled)")


if __name__ == "__main__":
    main()
