"""Cross-pod gradient compression under shard_map (DESIGN.md §5 demo).

Demonstrates the explicit data-parallel gradient sync with int8 +
error-feedback compression on the (simulated) DCN axis: 8 host-platform
devices form a (pod=2, data=4) mesh; per-device gradients psum in fp32
over the fast in-pod axis, then int8-compress for the slow cross-pod
reduce. Verifies (a) 4x payload reduction on the pod axis and (b) training
on compressed grads tracks the uncompressed run.

Run via its test (spawns a subprocess so the 8-device XLA flag does not
leak into other tests), or directly:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/grad_compression_dp.py
"""
import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, "src")

from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.compat.mesh import make_mesh, shard_map  # noqa: E402


def main() -> None:
    assert len(jax.devices()) >= 8, "needs 8 host-platform devices"
    mesh = make_mesh((2, 4), ("pod", "data"),
                     devices=jax.devices()[:8])

    d = 512
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(d,)) * 0.1)
    t = jnp.asarray(rng.normal(size=(d,)))

    def local_grad(w, x):
        # per-shard gradient of 0.5||x*(w - t)||^2 wrt w (toy)
        return jnp.mean(x, axis=0) * (w - t)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(("pod", "data"), None)),
        out_specs=(P(), P()), check_rep=False)
    def sync_grads(w, x):
        g = local_grad(w, x)
        # fast in-pod reduce (ICI): fp32
        g = jax.lax.pmean(g, "data")
        # slow cross-pod reduce (DCN): int8 payload + one fp32 scale per
        # pod; dequantize per-pod after the gather so the sum is exact in
        # the quantized values (payload on the wire stays int8 + scalar).
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        qs = jax.lax.all_gather(q, "pod")           # [npod, d] int8
        ss = jax.lax.all_gather(scale, "pod")       # [npod]
        g_hat = jnp.mean(qs.astype(jnp.float32) * ss[:, None], axis=0)
        err = g - g_hat  # residual (would feed error-feedback next step)
        return g_hat, jnp.sum(err * err)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(("pod", "data"), None)),
        out_specs=P(), check_rep=False)
    def sync_grads_fp32(w, x):
        return jax.lax.pmean(local_grad(w, x), ("pod", "data"))

    x = jnp.asarray(rng.normal(size=(16, d)) ** 2)  # positive weights
    g_q, err = jax.jit(sync_grads)(w, x)
    g_f = jax.jit(sync_grads_fp32)(w, x)
    rel = float(jnp.linalg.norm(g_q - g_f) / jnp.linalg.norm(g_f))
    print(f"int8-compressed cross-pod grad vs fp32: rel err {rel:.3e}")
    print(f"DCN payload: {d} B (int8) vs {4*d} B (fp32) -> 4.0x reduction")
    assert rel < 0.02, rel

    # SGD with compressed sync still converges on the toy objective.
    wq, wf = w, w
    for _ in range(200):
        gq, _ = jax.jit(sync_grads)(wq, x)
        wq = wq - 0.5 * gq
        wf = wf - 0.5 * jax.jit(sync_grads_fp32)(wf, x)
    dq = float(jnp.linalg.norm(wq - t))
    df = float(jnp.linalg.norm(wf - t))
    print(f"after 200 steps: |w-t| compressed {dq:.3e} vs fp32 {df:.3e}")
    assert dq < 0.05
    print("OK: compressed-gradient DP training matches fp32")


if __name__ == "__main__":
    main()
