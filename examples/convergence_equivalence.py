"""Convergence equivalence — paper §5.9 / Table 10, CPU-scale analogue.

Trains the same DoRA fine-tune twice per seed — once with the eager
(Tier-3) compose path, once with the fused Pallas kernels (interpret mode
executes the identical kernel arithmetic on CPU) — and reports per-step
loss deltas. The paper's claim: the fused kernels do not change training
dynamics (grand mean per-step |Δ| = 7.1e-4 over 2000 steps at bf16; we run
a reduced setting and expect deltas at the fp32 tolerance floor, since
interpret mode executes the same fp32 accumulation as the kernel).

    PYTHONPATH=src python examples/convergence_equivalence.py [--steps 60]
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import DoRAConfig                          # noqa: E402
from repro.data import DataConfig, SyntheticLMDataset      # noqa: E402
from repro.launch.steps import StepConfig, make_train_step  # noqa: E402
from repro.models import init_adapters, init_params        # noqa: E402
from repro.models.config import ModelConfig                # noqa: E402
from repro.optim import OptimizerConfig, adamw_init        # noqa: E402

MCFG = ModelConfig(
    name="conv-check", family="dense",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=2048, dtype=jnp.float32, remat="none")


def run_one(mode: str, seed: int, steps: int, ds, dcfg_kw) -> list[float]:
    dcfg = DoRAConfig(rank=16, alpha=32.0, mode=mode, **dcfg_kw)
    scfg = StepConfig(dora=dcfg, optim=OptimizerConfig(
        lr=1e-3, warmup_steps=5, total_steps=steps))
    key = jax.random.PRNGKey(seed)
    params = init_params(key, MCFG)
    adapters = init_adapters(jax.random.fold_in(key, 1), MCFG, params, dcfg)
    opt = adamw_init(adapters)
    step_fn = jax.jit(make_train_step(MCFG, scfg, None, batch=4, seq=64))
    losses = []
    for i in range(steps):
        b = ds.host_batch_np(i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        adapters, opt, m = step_fn(params, adapters, opt, batch)
        losses.append(float(m["loss"]))
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args()

    ds = SyntheticLMDataset(DataConfig(
        vocab_size=MCFG.vocab_size, seq_len=64, global_batch=4, seed=99))

    print(f"# eager vs fused(interpret) x {args.seeds} seeds x "
          f"{args.steps} steps ({MCFG.name})")
    all_means = []
    for seed in range(args.seeds):
        eager = run_one("eager", seed, args.steps, ds, {})
        fused = run_one("interpret", seed, args.steps, ds, {})
        d = np.abs(np.asarray(eager) - np.asarray(fused))
        all_means.append(d.mean())
        print(f"  seed {seed}: mean|Δ|={d.mean():.2e}  max|Δ|={d.max():.2e}"
              f"  final eager {eager[-1]:.4f} fused {fused[-1]:.4f} "
              f"(|Δ|={abs(eager[-1]-fused[-1]):.2e})")
    grand = float(np.mean(all_means))
    print(f"grand mean per-step |Δ| = {grand:.2e} "
          f"(paper Table 10 analogue: 7.1e-4 at bf16/2000 steps)")
    assert grand < 5e-3, "fused/eager training curves diverged"
    print("OK: fused kernels do not change training dynamics")


if __name__ == "__main__":
    main()
