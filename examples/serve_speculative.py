"""Speculative decoding example: draft cheap, verify once, rewind.

    PYTHONPATH=src python examples/serve_speculative.py

Requests arrive over time at a 3-slot engine running speculative decode
(k=3): each tick drafts k tokens per active row with the adapters
DISABLED (base matmuls only), verifies all k+1 positions in ONE batched
step through the full grouped-DoRA path, accepts each row's longest
matching draft prefix plus the verify's own next token, and rewinds the
row's per-row cache length to the accepted frontier. The adapter is
deliberately non-identity (random B), so the base-model drafter is
imperfect — some drafts are rejected — and the point of the demo is the
oracle: the streamed tokens are BITWISE the plain engine's greedy
streams anyway (``tests/test_engine.py`` locks this on single-device
and a 2-device mesh).
"""
import sys

import numpy as np

sys.path.insert(0, "src")

import jax                                                # noqa: E402

from repro.configs import get_config                      # noqa: E402
from repro.core import AdapterStateCache, DoRAConfig      # noqa: E402
from repro.launch.engine import DecodeEngine              # noqa: E402
from repro.launch.steps import StepConfig                 # noqa: E402
from repro.launch.train import build_state                # noqa: E402
from repro.obs import monotonic                     # noqa: E402

SPEC_K = 3


def imperfect_adapters(adapters, seed=7, scale=0.02):
    """Seed-built trees have B == 0 — the base drafter would then be
    EXACT and every draft would be accepted. Random-B adapters make the
    drafter genuinely speculative."""
    key = jax.random.PRNGKey(seed)
    cnt = [0]

    def f(path, leaf):
        cnt[0] += 1
        if "'B'" in "/".join(str(p) for p in path):
            return jax.random.normal(jax.random.fold_in(key, cnt[0]),
                                     leaf.shape, leaf.dtype) * scale
        return leaf
    return jax.tree_util.tree_map_with_path(f, adapters)


def drive(engine, trace):
    """Feed the arrival trace tick-by-tick; returns per-request streams
    in the exact order on_token emitted them."""
    streams: dict[int, list[int]] = {}

    def on_token(rid: int, tok: int) -> None:
        streams.setdefault(rid, []).append(tok)

    i, step = 0, 0
    while i < len(trace) or engine.has_work():
        while i < len(trace) and trace[i][0] <= step:
            engine.submit(trace[i][1], adapter="tenant-0",
                          max_new_tokens=trace[i][2], key_id=i)
            i += 1
        engine.step(on_token)
        step += 1
    return streams


def main() -> None:
    mcfg = get_config("qwen2-7b", smoke=True)
    dcfg = DoRAConfig(rank=8, alpha=16.0, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, _, _ = build_state(mcfg, dcfg, seed=0)

    cache = AdapterStateCache.for_serving(mcfg, scfg)
    _, adapters, _ = build_state(mcfg, dcfg, seed=1)
    cache.register("tenant-0", imperfect_adapters(adapters))

    slots, max_len = 3, 20
    rng = np.random.default_rng(0)
    trace = []
    t = 0
    for _ in range(8):
        t += int(rng.integers(0, 3))
        trace.append((t,
                      rng.integers(0, mcfg.vocab_size,
                                   int(rng.integers(4, 11)),
                                   dtype=np.int32),
                      int(rng.integers(3, 8))))

    spec = DecodeEngine(mcfg, scfg, params, slots=slots, max_len=max_len,
                        adapter_cache=cache, speculative_k=SPEC_K)
    plain = DecodeEngine(mcfg, scfg, params, slots=slots, max_len=max_len,
                         adapter_cache=cache)

    t0 = monotonic()
    spec_streams = drive(spec, trace)
    dt = monotonic() - t0
    plain_streams = drive(plain, trace)

    # The greedy oracle: speculative streams == plain streams,
    # token-for-token per request, at whatever the accept rate was.
    assert spec_streams == plain_streams, \
        "speculative streams diverged from plain greedy decode"

    st, ps = spec.stats(), plain.stats()
    full_steps = st.verify_steps + st.decode_steps
    print(f"served {st.admitted} requests in {dt:.1f}s: "
          f"{st.verify_steps} verify steps + {st.decode_steps} fallback "
          f"decode steps (plain engine: {ps.decode_steps} decode steps "
          f"for {ps.generated_tokens} tokens)")
    print(f"drafter: {st.accepted_drafts}/{st.draft_steps} drafts "
          f"accepted (imperfect on purpose)")
    assert 0 < st.accepted_drafts < st.draft_steps
    assert full_steps < ps.generated_tokens, \
        "speculative stopped beating one-full-forward-per-token"

    counts = spec.compile_counts()
    assert counts["draft"] == 1, counts
    assert counts["verify"] == {(None, SPEC_K + 1): 1}, counts
    print("compiled surface: 1 draft + 1 verify "
          "(join/leave never recompiled)")
    print("speculative streams == plain greedy streams: OK")


if __name__ == "__main__":
    main()
