"""Quickstart: adapt one linear layer with high-rank DoRA.

Shows the public API end to end on one weight matrix:
  1. init DoRA params (A, B, magnitude m = ||W||_row),
  2. the factored norm == the dense-materialization norm (but without the
     [d_out, d_in] product),
  3. a DoRA forward + a few gradient steps on a toy regression,
  4. the three-tier dispatch in action.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (DoRAConfig, dora_linear, init_dora_params,
                        norm_dense_ba)
from repro.core.factored_norm import factored_norm


def main():
    key = jax.random.PRNGKey(0)
    d_out, d_in, rank = 1024, 2048, 64

    cfg = DoRAConfig(rank=rank, alpha=128.0, mode="eager")
    W = jax.random.normal(key, (d_out, d_in), jnp.float32) * 0.02
    adapter = init_dora_params(jax.random.fold_in(key, 1), W, cfg)
    print(f"DoRA r={rank}: A {adapter['A'].shape}, B {adapter['B'].shape}, "
          f"m {adapter['m'].shape}, s={cfg.scaling:.3f} (rsLoRA)")

    # --- 2. factored norm vs dense reference --------------------------------
    # Perturb B so the norm is non-trivial (B=0 at init).
    adapter["B"] = 0.02 * jax.random.normal(jax.random.fold_in(key, 2),
                                            adapter["B"].shape)
    n_f = factored_norm(W, adapter["A"], adapter["B"], cfg.scaling)
    n_d = norm_dense_ba(W, adapter["A"], adapter["B"], cfg.scaling)
    print(f"factored vs dense norm: max |Δ| = "
          f"{float(jnp.max(jnp.abs(n_f - n_d))):.2e}  "
          f"(no [d_out, d_in] product materialized)")

    # --- 3. fit a toy target ------------------------------------------------
    x = jax.random.normal(jax.random.fold_in(key, 3), (256, d_in))
    y_target = jax.random.normal(jax.random.fold_in(key, 4), (256, d_out))

    @jax.jit
    def loss_fn(ad):
        y = dora_linear(x, W, ad, cfg, training=True)
        return jnp.mean((y - y_target) ** 2)

    lr = 1e-2
    ad = adapter
    for step in range(20):
        loss, g = jax.value_and_grad(loss_fn)(ad)
        ad = jax.tree.map(lambda p, gi: p - lr * gi, ad, g)
        if step % 5 == 0 or step == 19:
            print(f"  step {step:2d}  loss {float(loss):.4f}")

    # --- 4. dispatch tiers ---------------------------------------------------
    from repro.core import Tier, select_tier
    for rows, d in [(8192, 4096), (64, 512)]:
        t = select_tier(DoRAConfig(mode="auto"), training=True,
                        rows=rows, d_out=d)
        print(f"dispatch(rows={rows}, d_out={d}, backend="
              f"{jax.default_backend()}): {t.name}")
    print("on TPU the first shape takes FUSED_BWD (above the paper's "
          "crossover); on CPU everything falls back to EAGER")


if __name__ == "__main__":
    main()
