"""Continuous-batching serving example: requests join and leave a
RUNNING decode batch.

    PYTHONPATH=src python examples/serve_continuous.py

Eight mixed-length requests arrive over time (a deterministic
Poisson-ish trace) at a 3-slot engine: each is prefilled into a free row
of the live batch at its TRUE prompt length (per-row cache state, no
length bucketing), decodes alongside whatever else is running, and
retires individually — EOS, its own token budget, or the cache bound —
handing the row to the next waiting request. Tokens stream per request
as they are sampled, and every request's greedy output is checked
against serving it alone through ``generate()`` (the oracle contract
``tests/test_engine.py`` locks).
"""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config                      # noqa: E402
from repro.core import AdapterStateCache, DoRAConfig      # noqa: E402
from repro.launch.engine import DecodeEngine              # noqa: E402
from repro.launch.serve import generate                   # noqa: E402
from repro.launch.steps import StepConfig                 # noqa: E402
from repro.launch.train import build_state                # noqa: E402
from repro.obs import monotonic                     # noqa: E402


def main() -> None:
    mcfg = get_config("qwen2-7b", smoke=True)
    dcfg = DoRAConfig(rank=8, alpha=16.0, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, _, _ = build_state(mcfg, dcfg, seed=0)

    cache = AdapterStateCache.for_serving(mcfg, scfg)
    _, adapters, _ = build_state(mcfg, dcfg, seed=1)
    cache.register("tenant-0", adapters)

    slots, max_len = 3, 20
    rng = np.random.default_rng(0)
    # (arrival step, prompt, token budget) — mixed lengths on purpose
    trace = []
    t = 0
    for _ in range(8):
        t += int(rng.integers(0, 3))
        trace.append((t,
                      rng.integers(0, mcfg.vocab_size,
                                   int(rng.integers(4, 11)),
                                   dtype=np.int32),
                      int(rng.integers(3, 8))))

    engine = DecodeEngine(mcfg, scfg, params, slots=slots, max_len=max_len,
                          adapter_cache=cache)
    streamed: dict[int, list[int]] = {}

    def on_token(rid: int, tok: int) -> None:
        streamed.setdefault(rid, []).append(tok)

    t0 = monotonic()
    i, step = 0, 0
    while i < len(trace) or engine.has_work():
        while i < len(trace) and trace[i][0] <= step:
            engine.submit(trace[i][1], adapter="tenant-0",
                          max_new_tokens=trace[i][2])
            i += 1
        for r in engine.step(on_token):
            print(f"  step {step:>2}: req{r.request_id} retired "
                  f"({r.finish_reason}) -> {r.tokens.tolist()}")
        step += 1
    dt = monotonic() - t0

    st = engine.stats()
    print(f"served {st.admitted} mixed-length requests through {slots} "
          f"slots in {dt:.1f}s: {st.decode_steps} decode steps, mean "
          f"occupancy {st.mean_occupancy:.2f}, "
          f"{st.generated_tokens / dt:.1f} tok/s")
    counts = engine.compile_counts()
    assert counts["prefill_into_slot"] == 1, counts
    assert counts["decode"] == {None: 1}, counts
    print("compiled surface: 1 prefill-into-slot + 1 decode "
          "(join/leave never recompiled)")

    # Oracle: every request's tokens equal serving it alone.
    for r, (_, prompt, budget) in zip(engine.results(), trace):
        alone = np.asarray(generate(
            mcfg, params, cache.current_handle("tenant-0"), scfg,
            np.asarray(prompt)[None], gen_len=len(r.tokens),
            max_len=max_len, adapter_cache=cache))
        assert np.array_equal(r.tokens, alone[0, len(prompt):]), \
            f"req{r.request_id} diverged from serving it alone"
        assert streamed[r.request_id] == r.tokens.tolist()
    print("every mid-stream request == served alone: OK")


if __name__ == "__main__":
    main()
