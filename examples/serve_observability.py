"""Observability example: trace a live engine and PROVE it changed
nothing.

    PYTHONPATH=src python examples/serve_observability.py

Four requests run through a 2-slot engine; the last one arrives at
high priority while both slots are busy, so it PREEMPTS a running row
— the victim's lifecycle shows up on the timeline as two residency
spans with a queue-wait span between them. The same workload runs
twice, traced and untraced, and the example asserts the whole
observability contract end-to-end (docs/observability.md):

  1. observability is FREE: token streams bitwise identical, stats()
     and compile_counts() unchanged between the traced and untraced
     runs;
  2. the trace CONSERVES the lifecycle: one submitted + one terminal
     per request, ticks monotone, every resumed paired with a
     preceding preempted, token events == tokens delivered;
  3. the Chrome trace_event export loads as JSON with the expected
     span structure (drop the file on https://ui.perfetto.dev to see
     the timeline: slots are tracks, the queue is its own track);
  4. the Prometheus snapshot round-trips through parse_prometheus with
     the preemption counter and the TTFT histogram visible.
"""
import json
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config                      # noqa: E402
from repro.core import AdapterStateCache, DoRAConfig      # noqa: E402
from repro.launch.engine import DecodeEngine              # noqa: E402
from repro.launch.steps import StepConfig                 # noqa: E402
from repro.launch.train import build_state                # noqa: E402
from repro.obs import (TraceRecorder, engine_metrics,     # noqa: E402
                       lifecycle_latencies, parse_prometheus)


def drive(mcfg, scfg, params, adapters, prompts, trace):
    """One committed workload: 3 requests fill the queue and both
    slots, then a priority-5 arrival displaces a running row. A FRESH
    adapter cache per run so traced and untraced start identical."""
    cache = AdapterStateCache.for_serving(mcfg, scfg)
    cache.register("tenant-0", adapters)
    engine = DecodeEngine(mcfg, scfg, params, slots=2, max_len=16,
                          adapter_cache=cache, trace=trace)
    for i, p in enumerate(prompts[:3]):
        engine.submit(p, adapter="tenant-0", max_new_tokens=5, key_id=i)
    engine.step()
    engine.step()
    engine.submit(prompts[3], adapter="tenant-0", max_new_tokens=3,
                  key_id=3, priority=5)
    return engine, engine.run()


def main() -> None:
    mcfg = get_config("qwen2-7b", smoke=True)
    dcfg = DoRAConfig(rank=8, alpha=16.0, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, _, _ = build_state(mcfg, dcfg, seed=0)
    _, adapters, _ = build_state(mcfg, dcfg, seed=1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, mcfg.vocab_size, n, dtype=np.int32)
               for n in (6, 5, 7, 4)]

    rec = TraceRecorder()
    eng_on, traced = drive(mcfg, scfg, params, adapters, prompts, rec)
    eng_off, plain = drive(mcfg, scfg, params, adapters, prompts, None)

    # 1. Observability is FREE — the tracing contract.
    key = lambda rs: sorted(rs, key=lambda r: r.request_id)  # noqa: E731
    assert [r.tokens.tolist() for r in key(traced)] \
        == [r.tokens.tolist() for r in key(plain)], "streams diverged"
    assert eng_on.stats().as_dict() == eng_off.stats().as_dict()
    assert eng_on.compile_counts() == eng_off.compile_counts()
    st = eng_on.stats()
    assert st.preemptions == 1, "the workload must exercise preemption"
    print(f"invariance OK: {len(traced)} streams bitwise equal, stats + "
          f"compile counts unchanged ({len(rec)} events recorded, "
          f"{rec.dropped} dropped)")

    # 2. Lifecycle conservation over the whole trace.
    victim = None
    for rid in rec.request_ids():
        evs = rec.events(request_id=rid)
        names = [e.name for e in evs]
        assert names.count("submitted") == 1 and names[0] == "submitted"
        assert names.count("terminal") == 1 and names[-1] == "terminal"
        ticks = [e.tick for e in evs]
        assert ticks == sorted(ticks), f"r{rid}: ticks not monotone"
        n_pre, n_res = names.count("preempted"), names.count("resumed")
        assert n_res <= n_pre <= n_res + 1, f"r{rid}: unpaired resume"
        if n_pre:
            victim = rid
        r = next(x for x in traced if x.request_id == rid)
        n_tok = names.count("first_token") + names.count("token")
        assert n_tok == len(r.tokens), f"r{rid}: token events != tokens"
    assert victim is not None
    lat = lifecycle_latencies(rec)[victim]
    print(f"lifecycle conserved for {len(rec.request_ids())} requests; "
          f"victim r{victim} queue-wait {lat['queue_wait_ticks']} tick(s), "
          f"admit-to-retire {lat['admit_to_retire_ticks']} ticks across "
          f"the preemption")

    # 3. The Perfetto timeline: two residency spans for the victim.
    out_dir = tempfile.mkdtemp(prefix="repro_obs_")
    timeline = os.path.join(out_dir, "timeline.json")
    rec.to_chrome_trace(timeline)
    with open(timeline) as f:
        doc = json.load(f)
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    victim_spans = [e for e in spans if e["name"] == f"r{victim}"]
    queue_spans = [e for e in spans
                   if e["name"] == f"queued r{victim}"]
    assert len(victim_spans) == 2, "preemption must split the residency"
    assert len(queue_spans) == 2, "initial wait + re-queue after preempt"
    assert not [e for e in spans if e["name"].endswith("(open)")], \
        "all requests retired, no open spans"
    print(f"timeline OK: {len(spans)} spans ({len(victim_spans)} "
          f"residencies for the victim) -> {timeline} (load it in "
          f"https://ui.perfetto.dev)")

    # 4. The metrics surface, round-tripped.
    metrics = os.path.join(out_dir, "metrics.prom")
    engine_metrics(eng_on, rec).to_prometheus(metrics)
    parsed = parse_prometheus(open(metrics).read())
    assert parsed["repro_engine_preemptions_total"] == 1
    assert parsed["repro_engine_retired_total"] == len(traced)
    assert parsed["repro_ttft_ticks_count"] == len(traced)
    print(f"metrics OK: {len(parsed)} series -> {metrics} "
          f"(preemptions_total=1, ttft histogram over "
          f"{int(parsed['repro_ttft_ticks_count'])} requests)")


if __name__ == "__main__":
    main()
