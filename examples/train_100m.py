"""End-to-end driver: DoRA-fine-tune a ~100M-param transformer for a few
hundred steps on the synthetic pipeline, with checkpointing + resume.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

This is the deliverable-(b) end-to-end example: real model (~100M params:
12L x d512, GQA, SwiGLU), real data pipeline, AdamW over adapters only,
cosine schedule, checkpoint every 50 steps, auto-resume if re-launched.
The loss falling well below the unigram entropy of the synthetic stream
demonstrates the adapters are learning the stream's bigram structure
through frozen base weights.
"""
import argparse
import sys

import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.launch.train import train  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
import repro.configs as configs  # noqa: E402

# ~100M params: 12 x (4*512^2 + 3*512*1408) + 2*32768*512 ≈ 0.07B weights
M100 = ModelConfig(
    name="repro-100m", family="dense",
    num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
    d_ff=1408, vocab_size=32768, dtype=jnp.float32, remat="none")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args_in = ap.parse_args()

    # Register the 100M config under a temporary id so the standard driver
    # (the same one the TPU launch uses) can run it.
    import types
    mod = types.ModuleType("repro.configs._train100m")
    mod.CONFIG = M100
    mod.SMOKE = M100
    sys.modules["repro.configs._train100m"] = mod
    configs._MODULES["repro-100m"] = "_train100m"

    n = M100.count_params()
    print(f"model: {M100.name} ({n/1e6:.0f}M params), "
          f"steps={args_in.steps}, batch={args_in.batch}, "
          f"seq={args_in.seq}, rank={args_in.rank}")

    ns = argparse.Namespace(
        arch="repro-100m", smoke=False, steps=args_in.steps,
        batch=args_in.batch, seq=args_in.seq, rank=args_in.rank,
        alpha=2.0 * args_in.rank, dora_mode="auto", norm_impl="factored",
        lr=3e-3, warmup=20, clip_norm=1.0, loss_tokens=None, grad_accum=1,
        seed=0, data_seed=1234, ckpt_dir=args_in.ckpt_dir, ckpt_every=50,
        ckpt_keep=2, resume=True, heartbeat_dir="", log_every=10)
    out = train(ns)
    first, last = out["losses"][0], out["final_loss"]
    assert last < first, "loss did not decrease"
    print(f"OK: loss {first:.3f} -> {last:.3f} over {out['steps']} steps")


if __name__ == "__main__":
    main()
