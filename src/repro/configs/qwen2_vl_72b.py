"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE + dynamic resolution. [arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings [B, S, d_model]; the backbone (this config) is
the transformer with M-RoPE (sections 16/24/24 over the 64 rotary pairs).
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, pos_mode="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    attn_chunk=1024, frontend="patches",
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    qkv_bias=True, pos_mode="mrope", mrope_sections=(2, 3, 3),
    frontend="patches",
    dtype=jnp.float32,
)
