"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24, MHA) d_ff=6144
vocab=2048, decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

MusicGen uses a plain transformer decoder: LayerNorm, gelu MLP (no gating),
sinusoidal absolute positions. The EnCodec frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings (the sum
of the 4 codebook embeddings at each frame).
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    pos_mode="sinusoidal", mlp_kind="gelu", norm_kind="layer",
    attn_chunk=1024, frontend="audio_tokens",
)

SMOKE = ModelConfig(
    name="musicgen-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=64,
    pos_mode="sinusoidal", mlp_kind="gelu", norm_kind="layer",
    frontend="audio_tokens",
    dtype=jnp.float32,
)
