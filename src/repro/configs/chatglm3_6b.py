"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, 2d-RoPE (partial rotary: half the head dim), QKV bias.
[arXiv:2406.12793; hf]
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    qkv_bias=True, pos_mode="rope_partial", rotary_dim=64,
    attn_chunk=1024,
)

SMOKE = ModelConfig(
    name="chatglm3-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    qkv_bias=True, pos_mode="rope_partial", rotary_dim=8,
    dtype=jnp.float32,
)
