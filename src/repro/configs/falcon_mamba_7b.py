"""falcon-mamba-7b [ssm] — 64L d_model=4096, attention-free Mamba-1,
ssm_state=16, vocab=65024. [arXiv:2410.05355; unverified]

Pure Mamba-1 stack: each layer is norm → mamba → residual (no MLP sublayer,
d_ff = 0). d_inner = 2 × 4096 = 8192, dt_rank = 256.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm=True, ssm_state=16, ssm_conv=4, ssm_expand=2,
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256,
    ssm=True, ssm_state=4, ssm_conv=4, ssm_expand=2, ssm_chunk=32,
    dtype=jnp.float32,
)
