"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Per the assignment line: 16 routed experts, top-1 routing, every layer MoE.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    rope_theta=5e5,
    moe=True, num_experts=16, top_k=1, moe_d_ff=8192,
    attn_chunk=1024,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    moe=True, num_experts=4, top_k=1, moe_d_ff=128,
    capacity_factor=8.0,
    dtype=jnp.float32,
)
