"""Architecture registry: the 10 assigned configs (+ the paper's own
Qwen2-VL-7B proxy), each with a FULL config (exact published numbers, dry-run
only) and a SMOKE config (reduced, runs a real step on CPU)."""
from __future__ import annotations

import importlib

from repro.configs.shapes import (SHAPES, SMOKE_SHAPES, ShapeSpec,
                                  applicable_shapes)

ARCH_IDS = [
    "jamba-v0.1-52b",
    "qwen2-moe-a2.7b",
    "llama4-scout-17b-a16e",
    "falcon-mamba-7b",
    "chatglm3-6b",
    "phi4-mini-3.8b",
    "qwen3-32b",
    "qwen2-7b",
    "qwen2-vl-72b",
    "musicgen-medium",
]

EXTRA_IDS = ["qwen2-vl-7b"]  # paper-native proxy (benchmarks only)

_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "chatglm3-6b": "chatglm3_6b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen3-32b": "qwen3_32b",
    "qwen2-7b": "qwen2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "musicgen-medium": "musicgen_medium",
    "qwen2-vl-7b": "qwen2_vl_7b",
}


def get_config(arch: str, smoke: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


__all__ = ["ARCH_IDS", "EXTRA_IDS", "get_config", "SHAPES", "SMOKE_SHAPES",
           "ShapeSpec", "applicable_shapes"]
