"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer. 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
ssm_state=16. [arXiv:2403.19887; hf]

Jamba uses no explicit positional encoding (the Mamba layers carry position);
attention layers run NoPE. MoE hidden dim equals the dense MLP hidden dim.
Period = lcm(attn_period=8, moe_period=2) = 8: one attention layer at index 4
of every 8, MoE FFN on odd indices.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    pos_mode="none",
    moe=True, num_experts=16, top_k=2, moe_d_ff=14336, moe_period=2,
    ssm=True, ssm_state=16, ssm_conv=4, ssm_expand=2,
    attn_period=8, attn_index=4,
    attn_chunk=1024,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    pos_mode="none",
    moe=True, num_experts=4, top_k=2, moe_d_ff=128, moe_period=2,
    capacity_factor=8.0,
    ssm=True, ssm_state=4, ssm_conv=4, ssm_expand=2, ssm_chunk=32,
    attn_period=8, attn_index=4,
    dtype=jnp.float32,
)
