"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 + shared expert (4×1408 = 5632 hidden).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Qwen1.5-MoE details: QKV bias, top-4 of 60 routed experts with
norm_topk_prob=False (gate weights are raw softmax probs), one shared expert
of hidden 5632 scaled by a sigmoid gate.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    qkv_bias=True, rope_theta=1e6,
    moe=True, num_experts=60, top_k=4, moe_d_ff=1408,
    num_shared_experts=4, shared_d_ff=5632, renorm_topk=False,
    attn_chunk=1024,
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=32, vocab_size=256,
    qkv_bias=True,
    moe=True, num_experts=8, top_k=4, moe_d_ff=32,
    num_shared_experts=4, shared_d_ff=128, renorm_topk=False,
    capacity_factor=8.0,
    dtype=jnp.float32,
)
