"""Assigned input shapes (per-arch shape set for the LM-family pool).

  train_4k     seq 4096  × global_batch 256   — training step
  prefill_32k  seq 32768 × global_batch 32    — inference prefill
  decode_32k   seq 32768 × global_batch 128   — one-token decode, 32k cache
  long_500k    seq 524288 × global_batch 1    — long-context decode
                 (SSM/hybrid only; quadratic-attention archs skip — see
                  DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Reduced shapes for CPU smoke testing (same kinds, tiny sizes).
SMOKE_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 128, 2, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 128, 2, "decode"),
    "long_500k": ShapeSpec("long_500k", 256, 1, "decode"),
}


def applicable_shapes(mcfg) -> list[str]:
    """long_500k only runs for sub-quadratic (SSM/hybrid) families."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if mcfg.ssm or mcfg.attn_period:
        names.append("long_500k")
    return names
