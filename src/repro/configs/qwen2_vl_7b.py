"""qwen2-vl-7b — the paper's primary evaluation backbone (Qwen2-VL family,
§5.1 / App. D). Not part of the assigned pool; used by the convergence and
model-level benchmarks so the repro exercises the paper's own model shape.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064, M-RoPE, QKV bias.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, pos_mode="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    attn_chunk=1024, frontend="patches",
)

SMOKE = ModelConfig(
    name="qwen2-vl-7b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    qkv_bias=True, pos_mode="mrope", mrope_sections=(2, 3, 3),
    frontend="patches",
    dtype=jnp.float32,
)
