"""Bounded ring-buffer trace recorder for request-lifecycle events.

The recorder is the host half of the engine's observability contract:
:class:`repro.launch.engine.DecodeEngine` emits one :class:`TraceEvent`
per lifecycle transition (``submitted → queued → admitted →
chunk_prefill* → first_token → token* → {preempted, resumed}* →
terminal``) plus fault/ladder events (``fault``, ``quarantined``,
``spec_disabled``, ``spec_reenabled``, ``busy_rejected``, ``spill``,
``reload``), each stamped with the engine tick AND a monotonic wall
time (:func:`monotonic` = ``time.perf_counter`` — never ``time.time``,
which can step backwards under NTP).

The hard contract — observability is FREE and INVARIANT — lives in the
emit path: :meth:`TraceRecorder.emit` only ever receives host ints the
scheduler already maintains (slot indices, tick counters, token ids the
sampler has already fetched). It performs zero device fetches, so
tracing on vs. off leaves token streams bitwise identical and
``compile_counts()`` unchanged (asserted by tests/test_obs.py).

Storage is a bounded ring: past ``capacity`` events the OLDEST are
dropped and counted in :attr:`TraceRecorder.dropped` — a long-running
server never grows without bound, and the overflow is accounted, never
silent.

Exports: :meth:`TraceRecorder.to_jsonl` (one event per line, stable key
order) and :meth:`TraceRecorder.to_chrome_trace` (Chrome ``trace_event``
JSON — slots as tracks, requests as spans, token/fault instants —
loadable in Perfetto or ``chrome://tracing``).
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Any, Iterator

#: Monotonic wall-clock for latency deltas. ``time.perf_counter`` is
#: guaranteed monotone (``time.time`` is not: NTP steps can send it
#: backwards, producing negative "durations"). The ONE sanctioned
#: epoch-time user in the repo is the checkpoint heartbeat
#: (src/repro/checkpoint/fault.py), which other processes compare
#: against THEIR ``time.time()`` — see docs/observability.md.
monotonic = time.perf_counter

# Lifecycle event names, in legal emission order for one request.
# ``terminal`` carries ``reason=<one of engine FINISH_REASONS>`` — the
# event taxonomy mirrors the finish-reason taxonomy (docs/observability.md).
LIFECYCLE_EVENTS = ("submitted", "queued", "admitted", "chunk_prefill",
                    "first_token", "token", "preempted", "resumed",
                    "terminal")
# Out-of-band events: faults, degradation-ladder transitions, cache tier
# traffic. ``fault`` carries ``kind=<nan|evict|stale|slow>``.
AUX_EVENTS = ("fault", "quarantined", "spec_disabled", "spec_reenabled",
              "busy_rejected", "spill", "reload")
EVENT_NAMES = LIFECYCLE_EVENTS + AUX_EVENTS


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One structured lifecycle event.

    ``tick`` is the engine step counter at emission (deterministic —
    the gateable time domain); ``t_wall`` is :func:`monotonic` seconds
    (informational — varies run to run). ``request_id``/``slot`` are
    ``None`` for events not attached to a request / a slot.
    """
    name: str
    tick: int
    t_wall: float
    request_id: int | None = None
    slot: int | None = None
    data: dict[str, Any] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        d = {"name": self.name, "tick": self.tick, "t_wall": self.t_wall,
             "request_id": self.request_id, "slot": self.slot}
        if self.data:
            d["data"] = dict(self.data)
        return d


class TraceRecorder:
    """Bounded ring buffer of :class:`TraceEvent`.

    ``capacity`` bounds resident events; overflow drops the OLDEST and
    increments :attr:`dropped`. ``clock`` is injectable for tests (must
    be monotone); it defaults to :func:`monotonic`.
    """

    def __init__(self, capacity: int = 65536, *, clock=None):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} < 1")
        self.capacity = int(capacity)
        self._clock = clock or monotonic
        self._events: deque[TraceEvent] = deque(maxlen=self.capacity)
        self._emitted = 0
        self.t0 = self._clock()

    # -- recording ----------------------------------------------------------

    def emit(self, name: str, *, tick: int, request_id: int | None = None,
             slot: int | None = None, **data: Any) -> TraceEvent:
        """Append one event. Every argument is a host scalar the caller
        already holds — this method must never trigger a device fetch."""
        ev = TraceEvent(name=name, tick=int(tick),
                        t_wall=self._clock() - self.t0,
                        request_id=request_id, slot=slot, data=data)
        self._events.append(ev)
        self._emitted += 1
        return ev

    # -- accounting ---------------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(self._events)

    @property
    def emitted(self) -> int:
        """Total events ever emitted (resident + dropped)."""
        return self._emitted

    @property
    def dropped(self) -> int:
        """Events lost to ring overflow (oldest-first)."""
        return self._emitted - len(self._events)

    def events(self, name: str | None = None,
               request_id: int | None = None) -> list[TraceEvent]:
        """Resident events, optionally filtered by name and/or request."""
        return [e for e in self._events
                if (name is None or e.name == name)
                and (request_id is None or e.request_id == request_id)]

    def request_ids(self) -> list[int]:
        """Distinct request ids seen in resident events, sorted."""
        return sorted({e.request_id for e in self._events
                       if e.request_id is not None})

    # -- exporters ----------------------------------------------------------

    def to_jsonl(self, path: str | None = None) -> str:
        """One JSON object per line, oldest first. Returns the text;
        also writes it when ``path`` is given."""
        text = "\n".join(json.dumps(e.as_dict(), sort_keys=True)
                         for e in self._events)
        if text:
            text += "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_chrome_trace(self, path: str | None = None) -> dict:
        """Chrome ``trace_event`` JSON (Perfetto-loadable).

        Layout: pid 0 = the engine. Each SLOT is a track (tid = slot
        index) carrying one complete-event ("X") span per residency of
        a request on that slot (admitted/resumed → terminal/preempted),
        with token / chunk_prefill / first_token instants on the same
        track. The QUEUE is its own track carrying submitted→admitted
        wait spans. Fault/ladder events are instants on an "engine"
        track. Timestamps are ``t_wall`` microseconds.
        """
        evs = list(self._events)
        slots = sorted({e.slot for e in evs if e.slot is not None})
        queue_tid = (max(slots) + 1) if slots else 0
        engine_tid = queue_tid + 1
        us = 1e6

        out: list[dict] = [
            {"ph": "M", "pid": 0, "name": "process_name",
             "args": {"name": "repro.launch.engine"}},
            {"ph": "M", "pid": 0, "tid": queue_tid, "name": "thread_name",
             "args": {"name": "queue"}},
            {"ph": "M", "pid": 0, "tid": engine_tid, "name": "thread_name",
             "args": {"name": "engine"}},
        ]
        for s in slots:
            out.append({"ph": "M", "pid": 0, "tid": s,
                        "name": "thread_name",
                        "args": {"name": f"slot {s}"}})

        # Per-request state for span assembly.
        submitted: dict[int, TraceEvent] = {}
        seated: dict[int, TraceEvent] = {}     # admitted/resumed event
        for e in evs:
            rid = e.request_id
            args = {"tick": e.tick, **e.data}
            if rid is not None:
                args["request_id"] = rid
            if e.name == "submitted" and rid is not None:
                submitted[rid] = e
            elif e.name in ("admitted", "resumed") and rid is not None:
                if rid in submitted:        # queue-wait span closes
                    sub = submitted.pop(rid)
                    out.append({"ph": "X", "pid": 0, "tid": queue_tid,
                                "name": f"queued r{rid}",
                                "ts": sub.t_wall * us,
                                "dur": max(e.t_wall - sub.t_wall, 0.0) * us,
                                "args": {"request_id": rid,
                                         "ticks": e.tick - sub.tick}})
                seated[rid] = e
            elif e.name in ("terminal", "preempted") and rid is not None \
                    and rid in seated:
                seat = seated.pop(rid)
                tid = seat.slot if seat.slot is not None else engine_tid
                out.append({"ph": "X", "pid": 0, "tid": tid,
                            "name": f"r{rid}",
                            "ts": seat.t_wall * us,
                            "dur": max(e.t_wall - seat.t_wall, 0.0) * us,
                            "args": args})
                if e.name == "preempted":
                    submitted[rid] = e      # back to the queue track
            if e.name in ("token", "first_token", "chunk_prefill",
                          "fault", "quarantined", "spec_disabled",
                          "spec_reenabled", "busy_rejected", "spill",
                          "reload"):
                tid = (e.slot if e.slot is not None else engine_tid)
                out.append({"ph": "i", "pid": 0, "tid": tid,
                            "name": e.name, "ts": e.t_wall * us,
                            "s": "t", "args": args})
        # Requests still resident at export time: open spans closed at
        # the last event's timestamp so the timeline stays well-formed.
        t_end = evs[-1].t_wall * us if evs else 0.0
        for rid, seat in seated.items():
            tid = seat.slot if seat.slot is not None else engine_tid
            out.append({"ph": "X", "pid": 0, "tid": tid,
                        "name": f"r{rid} (open)", "ts": seat.t_wall * us,
                        "dur": max(t_end - seat.t_wall * us, 0.0),
                        "args": {"request_id": rid, "open": True}})

        doc = {"traceEvents": out, "displayTimeUnit": "ms",
               "otherData": {"emitted": self._emitted,
                             "dropped": self.dropped}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc
