"""Metrics registry: counters / gauges / fixed-bucket histograms with
Prometheus-text and JSON snapshot exporters.

Two time domains, deliberately separate (see docs/observability.md):

* **ticks** — engine step counts. Deterministic for a fixed arrival
  trace, so tick-domain metrics are GATEABLE (benchmarks/serve_bench.py
  commits them; scripts/check_bench_drift.py hard-fails on regression).
* **seconds** — :func:`repro.obs.monotonic` deltas. Informational only;
  they vary run to run and are never asserted on.

:func:`lifecycle_latencies` derives per-request latency from a
:class:`repro.obs.TraceRecorder` (TTFT, inter-token latency, queue
wait, admission-to-retire — each in both domains), and
:func:`engine_metrics` assembles the full registry for a live engine:
``EngineStats`` counters, ``CacheStats`` hit/spill/reload counters,
``pool_stats()`` block-pool occupancy gauges, compile counts, and the
derived latency histograms. Everything read is a host mirror — building
a snapshot performs zero device fetches.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Iterable, Mapping

from repro.obs.trace import TraceRecorder

# Fixed bucket edges. Ticks: powers of two out to one committed-trace
# horizon. Seconds: log-ish decades from 100us to 30s. Fixed (not
# adaptive) so two snapshots are always mergeable/comparable.
TICK_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)
SECONDS_BUCKETS = (1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 5.0, 30.0)


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integers stay integral."""
    if isinstance(v, bool):
        return "1" if v else "0"
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _label_str(labels: Mapping[str, str] | None) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    """Monotone counter (resets only with a new registry)."""

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment {n} < 0")
        self.value += n


class Gauge:
    """Point-in-time value."""

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram (cumulative on export, Prometheus-style).

    ``buckets`` are finite upper bounds; a ``+Inf`` bucket is implicit.
    """

    def __init__(self, buckets: Iterable[float]):
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(self.buckets) or not self.buckets:
            raise ValueError(f"bucket edges must be sorted/non-empty: "
                             f"{self.buckets}")
        self.counts = [0] * (len(self.buckets) + 1)   # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> list[tuple[float, int]]:
        """(upper_bound, cumulative_count) pairs ending at +Inf."""
        out, acc = [], 0
        for edge, c in zip(self.buckets, self.counts):
            acc += c
            out.append((edge, acc))
        out.append((math.inf, acc + self.counts[-1]))
        return out


@dataclasses.dataclass
class _Family:
    kind: str                 # "counter" | "gauge" | "histogram"
    help: str
    samples: dict             # frozenset(labels.items()) -> metric object
    label_maps: dict          # same key -> original labels dict


class MetricsRegistry:
    """Named metric families with label support and two exporters."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._families: dict[str, _Family] = {}

    def _get(self, name: str, kind: str, help: str,
             labels: Mapping[str, str] | None, factory):
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = _Family(kind, help, {}, {})
        elif fam.kind != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{fam.kind}, not {kind}")
        key = frozenset((labels or {}).items())
        if key not in fam.samples:
            fam.samples[key] = factory()
            fam.label_maps[key] = dict(labels or {})
        return fam.samples[key]

    def counter(self, name: str, help: str = "",
                labels: Mapping[str, str] | None = None) -> Counter:
        return self._get(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "",
              labels: Mapping[str, str] | None = None) -> Gauge:
        return self._get(name, "gauge", help, labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  labels: Mapping[str, str] | None = None,
                  buckets: Iterable[float] = TICK_BUCKETS) -> Histogram:
        return self._get(name, "histogram", help, labels,
                         lambda: Histogram(buckets))

    # -- exporters ----------------------------------------------------------

    def to_prometheus(self, path: str | None = None) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines: list[str] = []
        ns = self.namespace
        for name in sorted(self._families):
            fam = self._families[name]
            full = f"{ns}_{name}" if ns else name
            if fam.help:
                lines.append(f"# HELP {full} {fam.help}")
            lines.append(f"# TYPE {full} {fam.kind}")
            for key in sorted(fam.samples,
                              key=lambda k: sorted(fam.label_maps[k].items())):
                m = fam.samples[key]
                labels = fam.label_maps[key]
                if fam.kind == "histogram":
                    for edge, cum in m.cumulative():
                        le = dict(labels, le=_fmt(edge))
                        lines.append(
                            f"{full}_bucket{_label_str(le)} {cum}")
                    lines.append(
                        f"{full}_sum{_label_str(labels)} {_fmt(m.sum)}")
                    lines.append(
                        f"{full}_count{_label_str(labels)} {m.count}")
                else:
                    lines.append(
                        f"{full}{_label_str(labels)} {_fmt(m.value)}")
        text = "\n".join(lines) + ("\n" if lines else "")
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_json(self, path: str | None = None) -> dict:
        """JSON snapshot: {family: {kind, help, samples: [...]}}."""
        out: dict[str, Any] = {}
        for name in sorted(self._families):
            fam = self._families[name]
            samples = []
            for key in sorted(fam.samples,
                              key=lambda k: sorted(fam.label_maps[k].items())):
                m = fam.samples[key]
                s: dict[str, Any] = {"labels": fam.label_maps[key]}
                if fam.kind == "histogram":
                    s["sum"] = m.sum
                    s["count"] = m.count
                    s["buckets"] = [[("inf" if math.isinf(e) else e), c]
                                    for e, c in m.cumulative()]
                else:
                    s["value"] = m.value
                samples.append(s)
            out[name] = {"kind": fam.kind, "help": fam.help,
                         "samples": samples}
        if path is not None:
            with open(path, "w") as f:
                json.dump(out, f, indent=1, sort_keys=True)
        return out


def parse_prometheus(text: str) -> dict[str, float]:
    """Minimal parser for the exposition format this module writes:
    {sample_name_with_labels: value}. Used by smokes/tests to validate
    ``--metrics-out`` output round-trips."""
    out: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, _, value = line.rpartition(" ")
        if not name:
            raise ValueError(f"malformed sample line: {line!r}")
        out[name] = float(value)
    return out


def percentile(values: Iterable[float], q: float) -> float:
    """Deterministic nearest-rank percentile (q in [0, 100]) — the
    tick-domain percentile the bench gates use. Returns 0.0 on empty."""
    xs = sorted(values)
    if not xs:
        return 0.0
    if not 0 <= q <= 100:
        raise ValueError(f"q={q} outside [0, 100]")
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return float(xs[rank - 1])


# ---------------------------------------------------------------------------
# Derived per-request latency from a trace
# ---------------------------------------------------------------------------

def lifecycle_latencies(rec: TraceRecorder) -> dict[int, dict]:
    """Per-request latency derived from lifecycle events, in BOTH
    domains. For each request id seen in the trace::

        {"submitted_tick", "admitted_tick", "first_token_tick",
         "terminal_tick", "reason",
         "queue_wait_ticks",        # submitted -> first admission
         "ttft_ticks",              # submitted -> first_token
         "admit_to_retire_ticks",   # first admission -> terminal
         "itl_ticks": [...],        # successive token-emission gaps
         "queue_wait_s", "ttft_s", "admit_to_retire_s", "itl_s": [...]}

    Fields are ``None`` (lists empty) when the trace lacks the events —
    e.g. a queued-timeout request never admitted. Requests whose early
    events were dropped by ring overflow report what remains.
    """
    first: dict[int, dict[str, Any]] = {}
    tokens: dict[int, list] = {}
    for e in rec:
        if e.request_id is None:
            continue
        r = first.setdefault(e.request_id, {})
        if e.name in ("submitted", "admitted", "first_token", "terminal") \
                and e.name not in r:
            r[e.name] = e
        if e.name in ("first_token", "token"):
            tokens.setdefault(e.request_id, []).append(e)

    out: dict[int, dict] = {}
    for rid in sorted(first):
        r = first[rid]
        sub, adm = r.get("submitted"), r.get("admitted")
        ft, term = r.get("first_token"), r.get("terminal")

        def delta(a, b, attr):
            if a is None or b is None:
                return None
            return getattr(b, attr) - getattr(a, attr)

        toks = tokens.get(rid, [])
        out[rid] = {
            "submitted_tick": sub.tick if sub else None,
            "admitted_tick": adm.tick if adm else None,
            "first_token_tick": ft.tick if ft else None,
            "terminal_tick": term.tick if term else None,
            "reason": (term.data.get("reason") if term else None),
            "queue_wait_ticks": delta(sub, adm, "tick"),
            "ttft_ticks": delta(sub, ft, "tick"),
            "admit_to_retire_ticks": delta(adm, term, "tick"),
            "itl_ticks": [b.tick - a.tick
                          for a, b in zip(toks, toks[1:])],
            "queue_wait_s": delta(sub, adm, "t_wall"),
            "ttft_s": delta(sub, ft, "t_wall"),
            "admit_to_retire_s": delta(adm, term, "t_wall"),
            "itl_s": [b.t_wall - a.t_wall
                      for a, b in zip(toks, toks[1:])],
        }
    return out


def latency_metrics(rec: TraceRecorder,
                    registry: MetricsRegistry | None = None
                    ) -> MetricsRegistry:
    """Fill a registry with the derived latency histograms (both
    domains) plus terminal-reason counters and trace accounting."""
    reg = registry or MetricsRegistry()
    lat = lifecycle_latencies(rec)
    hists = (("queue_wait", "queue wait, submit to first admission"),
             ("ttft", "time to first token"),
             ("itl", "inter-token latency"),
             ("admit_to_retire", "first admission to terminal"))
    for stem, help in hists:
        ht = reg.histogram(f"{stem}_ticks", f"{help} (engine ticks)",
                           buckets=TICK_BUCKETS)
        hs = reg.histogram(f"{stem}_seconds", f"{help} (monotonic s)",
                           buckets=SECONDS_BUCKETS)
        for r in lat.values():
            if stem == "itl":
                for v in r["itl_ticks"]:
                    ht.observe(v)
                for v in r["itl_s"]:
                    hs.observe(v)
            else:
                if r[f"{stem}_ticks"] is not None:
                    ht.observe(r[f"{stem}_ticks"])
                if r[f"{stem}_s"] is not None:
                    hs.observe(r[f"{stem}_s"])
    for r in lat.values():
        if r["reason"] is not None:
            reg.counter("requests_finished_total",
                        "terminal events by finish reason",
                        labels={"reason": str(r["reason"])}).inc()
    reg.counter("trace_events_emitted_total",
                "events emitted to the trace ring").inc(rec.emitted)
    reg.counter("trace_events_dropped_total",
                "events lost to ring overflow").inc(rec.dropped)
    return reg


# ---------------------------------------------------------------------------
# Engine snapshot: wrap EngineStats / CacheStats / pool_stats
# ---------------------------------------------------------------------------

def engine_metrics(engine, recorder: TraceRecorder | None = None,
                   namespace: str = "repro") -> MetricsRegistry:
    """Full metrics snapshot for a live DecodeEngine (duck-typed — no
    engine import, so obs stays leaf-level). Reads only host mirrors:
    ``stats()``, ``compile_counts()``, the adapter cache's counters and
    ``pool_stats()`` are all plain-python state."""
    reg = MetricsRegistry(namespace)
    st = engine.stats()
    d = st.as_dict() if hasattr(st, "as_dict") else dict(st)
    gauges = {"slots"}
    for k, v in d.items():
        if v is None:
            continue
        if k in gauges:
            reg.gauge(f"engine_{k}", f"EngineStats.{k}").set(v)
        else:
            reg.counter(f"engine_{k}_total", f"EngineStats.{k}").inc(v)
    if hasattr(st, "mean_occupancy"):
        reg.gauge("engine_mean_occupancy",
                  "mean busy slots per decode step").set(st.mean_occupancy)

    counts = engine.compile_counts()
    for k, v in counts.items():
        if isinstance(v, dict):
            for sig, n in v.items():
                reg.counter("compiles_total", "compiled executables",
                            labels={"fn": k, "sig": str(sig)}).inc(n)
        else:
            reg.counter("compiles_total", "compiled executables",
                        labels={"fn": k, "sig": ""}).inc(v)

    cache = getattr(engine, "adapter_cache", None)
    if cache is not None and hasattr(cache, "stats"):
        cs = cache.stats().as_dict()
        cache_gauges = {"entries", "current_bytes", "max_bytes",
                        "thrashing", "host_entries", "host_bytes",
                        "host_max_bytes"}
        for k, v in cs.items():
            if v is None:
                continue
            if k in cache_gauges:
                reg.gauge(f"adapter_cache_{k}", f"CacheStats.{k}").set(v)
            else:
                reg.counter(f"adapter_cache_{k}_total",
                            f"CacheStats.{k}").inc(v)

    if getattr(engine, "_paged", False) and hasattr(engine, "pool_stats"):
        for k, v in engine.pool_stats().items():
            if k == "per_slot_blocks":
                for i, n in enumerate(v):
                    reg.gauge("pool_slot_blocks", "blocks owned per slot",
                              labels={"slot": str(i)}).set(n)
            else:
                reg.gauge(f"pool_{k}", f"pool_stats.{k}").set(v)

    if recorder is not None:
        latency_metrics(recorder, reg)
    return reg
