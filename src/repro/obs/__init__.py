"""Engine observability: request-lifecycle tracing, latency metrics,
and export surfaces (JSONL / Chrome trace / Prometheus text / JSON).

Contract (locked by tests/test_obs.py): observability is FREE and
INVARIANT — a :class:`TraceRecorder` threaded through
``DecodeEngine(trace=...)`` reads only host mirrors the scheduler
already maintains, so tracing on vs. off leaves token streams bitwise
identical, ``compile_counts()`` unchanged, and adds zero device
fetches. See docs/observability.md.
"""
from repro.obs.metrics import (SECONDS_BUCKETS, TICK_BUCKETS, Counter,
                               Gauge, Histogram, MetricsRegistry,
                               engine_metrics, latency_metrics,
                               lifecycle_latencies, parse_prometheus,
                               percentile)
from repro.obs.trace import (AUX_EVENTS, EVENT_NAMES, LIFECYCLE_EVENTS,
                             TraceEvent, TraceRecorder, monotonic)

__all__ = [
    "AUX_EVENTS", "Counter", "EVENT_NAMES", "Gauge", "Histogram",
    "LIFECYCLE_EVENTS", "MetricsRegistry", "SECONDS_BUCKETS",
    "TICK_BUCKETS", "TraceEvent", "TraceRecorder", "engine_metrics",
    "latency_metrics", "lifecycle_latencies", "monotonic",
    "parse_prometheus", "percentile",
]
