"""Continuous-batching decode engine: slot-scheduled serving with per-row
cache state.

The static serve loop (``repro.launch.serve``) retires a batch only when
EVERY row is done: a request that finishes early keeps burning its row,
and a waiting request cannot start until the whole batch drains. This
module adds the scheduler subsystem that keeps the decode batch full:

  - **slot table** — the decode batch is ``slots`` fixed rows over ONE
    persistent cache whose ``"len"`` is a per-row vector
    (``init_cache(..., row_lens=True)``): every row stands at its own
    position, attends under its own causal frontier, and writes its new
    K/V at its own depth;
  - **admission** — a waiting request is prefilled INTO a free row of the
    running batch (``make_prefill_into_slot_step``: slot and prompt
    length both traced, so joining never recompiles) while the other
    rows' state is untouched;
  - **retirement** — a row retires the moment its request finishes (EOS,
    its token budget, or the cache's ``max_len``); the freed slot admits
    the next queued request at the next engine step — no idle decode
    rows while work is waiting;
  - **fixed-shape steps** — the compiled surface is exactly one
    (prefill-into-slot, decode) pair per (slots, max_len,
    group-signature): join/leave traffic changes VALUES (slot index,
    per-row lengths, tokens), never shapes. The decode step's jaxpr
    contains zero ``dora_wnorm`` ops (the frozen-adapter serving state —
    which also carries the rsLoRA scale — does all norm work at
    precompute time, exactly as in the static path);
  - **per-slot adapters** — requests carry
    :class:`~repro.core.AdapterHandle`\\ s resolved through the PR-4
    :class:`~repro.core.AdapterStateCache` LRU. Slots whose handles
    coincide take the single-tenant bitwise path (``groups=None``);
    mixed-handle slot tables group contiguous same-handle runs through
    ``dora_linear_grouped`` (the PR-4 grouped gsB-folded compose, ≥2-row
    groups bitwise) with free slots absorbed into a neighbouring run;
  - **dynamic grouping** — with ``dynamic_grouping=True`` the static
    (start, size) signature gives way to a device-resident FLEET STACK
    of serving states indexed by a TRACED per-row int32 position
    (``batch_in["adapter_idx"]``): tenant churn — admissions,
    retirements, version bumps — changes VALUES, never the compile
    signature, so a fleet of thousands of adapters decodes through
    exactly ONE executable (``compile_counts()["decode"]`` has the
    single key ``"dynamic"``). Greedy dynamic streams are bitwise the
    static grouped streams AND per-tenant batched sequential serving
    (``select_tenant`` gathers after tenant-independent contractions;
    docs/serving.md).

With ``paged=True`` the rectangular per-row K/V gives way to a
block-paged cache: a per-layer block POOL plus a per-slot block TABLE
(``cache["pages"]``, a traced operand — paging never recompiles), blocks
allocated as a row's frontier crosses into them and freed at
retirement/preemption/speculative rewind, and prompts admitted
INCREMENTALLY in fixed-size chunks interleaved with decode ticks
(``make_prefill_chunk_step``). Greedy paged streams are bitwise the
rectangular streams; see ``docs/engine.md`` for the full contract and
the allocation/reclaim policy.

Scheduling is HOST logic over host mirrors (per-slot position/budget
counters): the engine never reads ``cache["len"]`` back from the device,
so the only per-step sync is the logits fetch that sampling needs anyway.
Scheduling is also deterministic and model-independent when no ``eos_id``
is set — ``benchmarks/serve_bench.py`` re-prices it analytically and
``scripts/check_bench_drift.py`` gates the result.

SSM/Mamba archs are rejected at construction (their states integrate
every processed token and cannot rewind to a slot's true prompt length);
MoE FFNs are rejected too (expert-capacity dispatch couples rows, so a
retired slot's garbage tokens could evict a live row's tokens from an
expert).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adapter import stack_adapter_states
from repro.core.adapter_cache import (AdapterHandle, AdapterStateCache,
                                      mesh_fingerprint)
from repro.launch.faults import FaultPlan
from repro.launch.steps import (StepConfig, make_decode_step,
                                make_draft_step,
                                make_prefill_chunk_step,
                                make_prefill_into_slot_step,
                                make_verify_step)
from repro.models import init_cache
from repro.models.config import ModelConfig
from repro.obs.trace import TraceRecorder

#: Every finish_reason a RequestResult can carry.
#:   eos           the request's eos_id was sampled
#:   length        the request's max_new_tokens budget ran out
#:   max_len       the CACHE bound ran out before the request's budget
#:   error         admission-time resolution failed (error_type/_message)
#:   timeout       deadline_ticks expired (queued or mid-decode); tokens
#:                 generated so far are delivered
#:   error_numeric the row's logits went non-finite and it was quarantined
FINISH_REASONS = ("eos", "length", "max_len", "error", "timeout",
                  "error_numeric")


class EngineBusy(RuntimeError):
    """Submit-time backpressure: the adapter-state cache is thrashing
    (every recent lookup an evicting miss) and admitting this cold
    request would stall the serve path on yet another full precompute.
    ``retry_after`` is the suggested backoff in engine ticks (the cache's
    thrash window — the window must see a non-evicting lookup before the
    signal clears)."""

    def __init__(self, message: str, retry_after: int = 1):
        super().__init__(message)
        self.retry_after = retry_after


@dataclasses.dataclass(frozen=True)
class EngineRequest:
    """One queued/running request (engine-internal; build via
    :meth:`DecodeEngine.submit`)."""
    request_id: int
    prompt: np.ndarray                 # int32 [P]
    adapter: AdapterHandle | None      # None = the engine's fixed adapters
    max_new_tokens: int
    eos_id: int | None = None
    key_id: int = 0                    # sample-key fold-in (see submit)
    state: Any = dataclasses.field(default=None, repr=False)
    #                                    serving tree pinned at submit: a
    #                                    tenant update() while this request
    #                                    waits in the queue must not change
    #                                    (or lose) the weights it serves with
    priority: int = 0                  # higher admits first / preempts lower
    deadline_step: int | None = None   # ABSOLUTE engine step (submit step +
    #                                    deadline_ticks); expired -> "timeout"
    # -- continuation bookkeeping (set by preemption, not by submit) --------
    prefix: np.ndarray | None = None   # tokens generated before preemption
    orig_prompt: np.ndarray | None = None   # prompt as originally submitted
    resume_cap: str | None = None      # finish_cap carried across preemption
    first_admitted: int | None = None  # step of the FIRST admission
    preempted: int = 0                 # times this request was preempted


@dataclasses.dataclass
class RequestResult:
    """Everything the engine produced for one request.

    Results are PICKLABLE: errors are carried as ``error_type`` (the
    exception class name) + ``error_message`` strings so a result can
    cross a process boundary or land in a structured log. The live
    exception — when the result was produced in THIS process — stays
    reachable behind the :attr:`error` debug accessor, which pickling
    drops."""
    request_id: int
    prompt: np.ndarray                 # int32 [P] (as submitted)
    tokens: np.ndarray                 # int32 [n] generated tokens
    finish_reason: str                 # one of FINISH_REASONS
    admitted_step: int                 # engine step the prefill ran in
    finished_step: int                 # engine step the last token landed
    error_type: str | None = None      # exception class name, iff "error"
    error_message: str | None = None   # str(exception), iff "error"
    preempted: int = 0                 # times the request was preempted

    @property
    def error(self) -> Exception | None:
        """The live exception behind an ``"error"`` result — debug only:
        present in the producing process, ``None`` after a pickle
        round-trip (``error_type``/``error_message`` survive)."""
        return getattr(self, "_live_error", None)

    def __getstate__(self):
        state = dict(self.__dict__)
        state.pop("_live_error", None)
        return state


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """Deterministic scheduling counters (point-in-time snapshot)."""
    slots: int
    steps: int                  # engine steps driven (incl. idle ones)
    decode_steps: int           # steps that ran the batched PLAIN decode
    prefills: int               # prefill-into-slot calls (= admissions)
    admitted: int
    retired: int
    generated_tokens: int       # sampled tokens (prefill + decode + verify)
    slot_steps: int             # sum over plain decode steps of active slots
    draft_steps: int = 0        # base-only draft forwards (speculative)
    verify_steps: int = 0       # full-DoRA k+1-window verifies (= spec ticks)
    accepted_drafts: int = 0    # draft tokens the verify accepted
    stack_inserts: int = 0      # fleet-stack state writes (dynamic grouping):
    #                             one per DISTINCT handle admission, zero per
    #                             token — the churn-cost counter the fleet
    #                             bench prices
    # -- robustness counters (all zero on a sunny-day run) ------------------
    preemptions: int = 0        # slots displaced by higher-priority requests
    timeouts: int = 0           # requests retired by deadline expiry
    quarantined: int = 0        # rows retired with non-finite logits
    busy_rejections: int = 0    # submits refused with EngineBusy (thrash)
    spec_disables: int = 0      # speculative ladder trips (accept collapse)
    spec_reenables: int = 0     # speculative re-enables after cooldown
    injected_nans: int = 0      # FaultPlan: logits rows poisoned
    forced_evictions: int = 0   # FaultPlan: cache invalidations fired
    stale_injected: int = 0     # FaultPlan: admissions handed stale handles
    slow_ticks: int = 0         # FaultPlan: straggler sleeps injected

    @property
    def mean_occupancy(self) -> float:
        """Active rows per decode step / slots — the fraction of decode
        row-work that produced a live request's token."""
        if self.decode_steps == 0:
            return 0.0
        return self.slot_steps / (self.decode_steps * self.slots)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["mean_occupancy"] = self.mean_occupancy
        return d


@dataclasses.dataclass
class _Slot:
    idx: int = -1                      # this slot's row index (fixed)
    req: EngineRequest | None = None
    handle: AdapterHandle | None = None
    state: Any = None                  # pinned serving tree for this row
    last_token: int = 0
    budget: int = 0                    # tokens still to sample
    finish_cap: str = "length"         # reason when the budget runs out
    generated: list = dataclasses.field(default_factory=list)
    admitted_step: int = 0
    pos: int = 0                       # host mirror of cache["len"][slot]:
    #                                    where this row's NEXT K/V write
    #                                    lands (speculative rewind target)
    n_prior: int = 0                   # tokens emitted in earlier legs of a
    #                                    preempted request: keeps the sample-
    #                                    key fold count (and so the
    #                                    temperature>0 stream) continuous
    #                                    across preempt/resume
    prefilling: bool = False           # paged chunked admission in flight:
    #                                    the slot holds a request but does
    #                                    not decode yet
    chunk_next: int = 0                # next chunk's start offset into the
    #                                    prompt while prefilling

    @property
    def occupied(self) -> bool:
        """The slot holds a request (decoding OR mid-admission)."""
        return self.req is not None

    @property
    def active(self) -> bool:
        """The slot decodes this tick (admission, if any, is complete)."""
        return self.req is not None and not self.prefilling


class DecodeEngine:
    """Slot-scheduled continuous-batching serving over one fixed-shape
    decode step.

    ``adapters`` is EITHER a single precomputed serving tree every
    request shares (single-tenant engine), OR ``None`` with an
    ``adapter_cache`` (:class:`~repro.core.AdapterStateCache`) — then
    every request carries an adapter id / handle resolved through the
    LRU at SUBMIT time. The resolved state is pinned on the request
    (and then on its slot) for the request's lifetime: a tenant
    ``update()`` mid-flight never swaps weights under a submitted
    request — whether it is already decoding or still waiting in the
    FIFO — and the NEXT submission picks up the new version.

    ``step()`` is one scheduler tick: retire-finished → admit-into-free
    (prefill + first token) → one batched decode for every active slot.
    ``run()`` drives until the queue and the slot table drain. Sampling
    is host-side (greedy at ``temperature=0.0``, else per-request keys —
    ``fold_in(fold_in(PRNGKey(seed), request_id), n_sampled)`` — so a
    request's sample stream is independent of what shares its batch).

    ``speculative_k > 0`` turns a tick into draft-then-verify: ``k``
    base-only draft forwards (adapter path short-circuited — zero
    ``dora_wnorm``, zero gsB work) propose tokens per row, ONE k+1-window
    forward through the full grouped DoRA path verifies them, each row
    accepts its longest matching prefix and rewinds ``cache["len"]`` to
    its accepted frontier (host mirrors — the engine still never reads
    ``len`` back from the device). Greedy speculative token streams are
    bitwise the plain greedy streams: the verify logits at every accepted
    position are the plain decode logits (same dense per-row-frontier
    attention math), so acceptance-by-argmax-match IS plain decode.
    Ticks fall back to plain decode when ``temperature > 0`` (rejection
    sampling not yet implemented) or when any active row's window would
    overflow ``max_len``.

    Fleet semantics (PR 9): ``dynamic_grouping=True`` (cache-routed
    engines only) replaces the static per-layout decode signatures with
    ONE traced executable — slots index a device-resident fleet stack of
    serving states by per-row int32 position, so admissions/retirements/
    version bumps never recompile (``compile_counts()["decode"]`` stays
    ``{"dynamic": 1}`` under arbitrary churn) at the cost of K× adapter-
    path FLOPs per decode (K = slots; the base matmul still dominates).
    Greedy dynamic streams are bitwise the static grouped streams and
    per-tenant batched sequential serving. ``max_active_per_adapter``
    caps how many slots one adapter id may hold simultaneously: excess
    requests wait in the queue (keeping their positions) so a hot
    tenant's burst cannot starve the fleet.

    Failure semantics (PR 7): requests may carry a ``priority`` (higher
    preempts lower when no slot is free — the victim re-queues as a
    continuation and resumes bitwise) and ``deadline_ticks`` (expiry
    retires the request with ``finish_reason="timeout"`` and its tokens
    so far); every tick's fetched logits pass a host-side non-finite
    guard that quarantines ONLY the poisoned row
    (``finish_reason="error_numeric"``) while its neighbours stay
    bitwise; speculative decode self-disables with hysteresis when the
    accept rate collapses; a thrashing adapter cache pushes back at
    submit time with :class:`EngineBusy`. All of it is driven
    deterministically by a :class:`~repro.launch.faults.FaultPlan`, and
    none of it adds executables: preempt/resume, quarantine and timeout
    reuse the same traced prefill/decode/verify steps
    (``compile_counts()`` is fault-invariant).

    Observability (PR 10): ``trace=`` takes a
    :class:`repro.obs.TraceRecorder`; the engine then emits one
    structured lifecycle event per transition (``submitted → queued →
    admitted → chunk_prefill* → first_token → token* → {preempted,
    resumed}* → terminal``) plus fault/ladder/cache events, each stamped
    with the engine tick and a monotonic wall time. The recorder reads
    ONLY host mirrors the scheduler already maintains — tracing on vs.
    off leaves streams bitwise identical, ``compile_counts()``
    unchanged, and adds zero device fetches (tests/test_obs.py;
    docs/observability.md).
    """

    def __init__(self, mcfg: ModelConfig, scfg: StepConfig, params, *,
                 slots: int, max_len: int, adapters=None,
                 adapter_cache: AdapterStateCache | None = None,
                 mesh=None, allow_miss: bool = True,
                 dynamic_grouping: bool = False,
                 max_active_per_adapter: int | None = None,
                 temperature: float = 0.0, seed: int = 0,
                 speculative_k: int = 0,
                 max_cached_steps: int = 16,
                 fault_plan: FaultPlan | None = None,
                 spec_accept_floor: float = 0.0,
                 spec_window: int = 4,
                 spec_reenable_after: int = 8,
                 paged: bool = False,
                 block_size: int | None = None,
                 n_blocks: int | None = None,
                 prefill_chunk: int | None = None,
                 trace: TraceRecorder | None = None):
        kinds = mcfg.layer_kinds()
        if any(k != "attn" for k in kinds):
            raise NotImplementedError(
                f"continuous batching requires attention-only caches: SSM "
                f"states integrate every processed token and cannot rewind "
                f"to a slot's true prompt length, so admission "
                f"(prefill-into-slot) and per-row retirement are "
                f"ill-defined (arch {mcfg.name!r} has layer kinds "
                f"{kinds})")
        if any(f == "moe" for f in mcfg.ffn_kinds()):
            raise NotImplementedError(
                f"continuous batching does not support MoE FFNs: expert-"
                f"capacity dispatch couples batch rows, so a retired "
                f"slot's garbage tokens could evict a live row's tokens "
                f"from an expert (arch {mcfg.name!r})")
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        if (adapters is None) == (adapter_cache is None):
            # Exactly one source of adapter state: mixing a fixed tree
            # with cache-routed requests would make a handle-less ACTIVE
            # slot indistinguishable from a free one in _slot_grouping —
            # its rows would silently decode under a neighbouring
            # tenant's adapters.
            raise ValueError(
                "DecodeEngine needs EITHER a fixed precomputed `adapters` "
                "tree (single-tenant) OR an `adapter_cache` to resolve "
                "per-request adapter handles against — not both, not "
                "neither")
        if adapter_cache is not None \
                and adapter_cache.sharding != mesh_fingerprint(mesh):
            raise ValueError(
                f"adapter cache is keyed for sharding "
                f"{adapter_cache.sharding} but the engine runs on mesh "
                f"{mesh_fingerprint(mesh)} — build the cache with "
                f"AdapterStateCache.for_serving(mcfg, scfg, mesh) for "
                f"THIS mesh")
        if dynamic_grouping and adapter_cache is None:
            raise ValueError(
                "dynamic_grouping=True requires an adapter_cache: the fleet "
                "stack is indexed by per-request adapter handles, which "
                "only cache-routed engines carry")
        if max_active_per_adapter is not None and max_active_per_adapter < 1:
            raise ValueError(
                f"max_active_per_adapter={max_active_per_adapter} < 1 would "
                f"make every adapter-carrying request permanently "
                f"inadmissible")
        self.mcfg = mcfg
        self.scfg = scfg
        self.params = params
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.mesh = mesh
        self.adapters = adapters
        self.adapter_cache = adapter_cache
        self.allow_miss = allow_miss
        self.temperature = float(temperature)
        self.seed = int(seed)
        if speculative_k < 0:
            raise ValueError(f"speculative_k={speculative_k} < 0")
        self.speculative_k = int(speculative_k)
        self.max_cached_steps = int(max_cached_steps)
        # -- robustness knobs ----------------------------------------------
        self.fault_plan = fault_plan
        if not 0.0 <= spec_accept_floor <= 1.0:
            raise ValueError(
                f"spec_accept_floor={spec_accept_floor} not in [0, 1]")
        self.spec_accept_floor = float(spec_accept_floor)
        self.spec_window = int(spec_window)
        self.spec_reenable_after = int(spec_reenable_after)
        # -- paged K/V knobs -----------------------------------------------
        self._paged = bool(paged)
        if not self._paged and (block_size is not None or n_blocks is not None
                                or prefill_chunk is not None):
            raise ValueError(
                "block_size / n_blocks / prefill_chunk require paged=True")
        if self._paged:
            if block_size is None:
                # Largest divisor of max_len up to 16: always valid, and
                # small enough that short tenants waste little slack.
                block_size = max(d for d in range(1, min(16, self.max_len) + 1)
                                 if self.max_len % d == 0)
            self._block_size = int(block_size)
            if self.max_len % self._block_size != 0:
                raise ValueError(
                    f"max_len={self.max_len} must be a multiple of "
                    f"block_size={self._block_size}")
            self._max_blocks = self.max_len // self._block_size
            if n_blocks is None:
                # Parity-safe default: enough blocks for every slot to
                # reach max_len (same HBM as the rectangular cache).
                # Pass a smaller pool to realise the paged memory win.
                n_blocks = self.slots * self._max_blocks
            self._n_blocks = int(n_blocks)
            if self._n_blocks < self._max_blocks:
                raise ValueError(
                    f"n_blocks={self._n_blocks} < max_blocks="
                    f"{self._max_blocks}: one slot alone must be able to "
                    f"grow to max_len, or the engine could deadlock with "
                    f"an admitted request it can never finish")
            self._chunk = int(prefill_chunk if prefill_chunk is not None
                              else self._block_size)
            if not 1 <= self._chunk <= self.max_len:
                raise ValueError(
                    f"prefill_chunk={self._chunk} not in [1, "
                    f"max_len={self.max_len}] (the chunk step's row writes "
                    f"must fit the logical window)")

        # Pin the persistent cache to the serving shardings (and the step
        # OUTPUT caches to the same layout): the cache round-trips through
        # every prefill/decode, and an unpinned layout would let GSPMD
        # re-lay it out after the first call — one spurious recompile per
        # step fn, breaking the one-executable-per-signature contract.
        self.cache = init_cache(
            mcfg, self.slots, self.max_len, row_lens=True,
            block_size=self._block_size if self._paged else None,
            n_blocks=self._n_blocks if self._paged else None)
        cache_out_sh = None
        if mesh is not None:
            from repro.launch import sharding as S
            c_sh = S.cache_sharding(
                mcfg, mesh, batch=self.slots,
                block_size=self._block_size if self._paged else None)
            self.cache = jax.device_put(self.cache, c_sh)
            cache_out_sh = c_sh
        self._prefill = jax.jit(
            make_prefill_into_slot_step(mcfg, scfg, mesh, seq=max_len),
            donate_argnums=(2,),
            out_shardings=(None, cache_out_sh))
        self._chunk_prefill = None
        if self._paged:
            self._chunk_prefill = jax.jit(
                make_prefill_chunk_step(mcfg, scfg, mesh, chunk=self._chunk),
                donate_argnums=(2,),
                out_shardings=(None, cache_out_sh))
        self._cache_out_sh = cache_out_sh
        # -- host mirror of the block pool (paged only) --------------------
        # The device never sees allocation logic: the engine owns the
        # free list and the per-slot block lists, mirrors them into the
        # int32 block table (cache["pages"]), and flushes the table as a
        # TRACED operand before any device step that reads the cache —
        # paging never recompiles anything.
        if self._paged:
            # pop() hands out ascending ids; freed blocks return LIFO.
            self._free: list[int] = list(range(self._n_blocks - 1, -1, -1))
            self._blocks: list[list[int]] = [[] for _ in range(self.slots)]
            self._pages_np = np.full((self.slots, self._max_blocks), -1,
                                     np.int32)
            self._pages_dirty = False
            self._peak_used = 0
        # Compiled decode steps per group signature (None = single
        # tenant). Same LRU discipline as MultiTenantServer._steps: each
        # entry pins a jitted executable.
        self._decodes: "OrderedDict[Any, Callable]" = OrderedDict()
        # Speculative executables: ONE adapter-free draft step (no group
        # signature — the draft never touches adapters) and one verify
        # step per (group signature, window) — window = k+1 is a SHAPE,
        # so each k the engine is driven at gets its own executable.
        self._draft: Callable | None = None
        self._verifies: "OrderedDict[Any, Callable]" = OrderedDict()
        # (slot-handle layout, groups, stacked tree) of the last decode —
        # re-stacked only when the layout changes, never per token.
        self._grouping_cache: tuple | None = None
        # -- dynamic fleet stack (dynamic_grouping=True) --------------------
        # K = slots stacked positions over the full serving-tree structure;
        # positions are handed out per DISTINCT handle (refcounted across
        # the slots sharing it) and recycled at last retirement. Occupied
        # slots ≤ slots, so distinct handles ≤ slots and _dyn_free can
        # never underflow at assignment time (the seating slot is still
        # free when its position is claimed).
        self._dynamic = bool(dynamic_grouping)
        self.max_active_per_adapter = (
            None if max_active_per_adapter is None
            else int(max_active_per_adapter))
        self._dyn_stack = None               # leaves [n_scan, K, ...]
        self._dyn_pos: dict[AdapterHandle, list] = {}   # handle→[pos, refs]
        self._dyn_free: list[int] = list(range(self.slots - 1, -1, -1))
        self._dyn_insert: Callable | None = None
        self._dyn_idx_np = np.zeros((self.slots,), np.int32)
        self._dyn_idx_cached = None          # device mirror of _dyn_idx_np
        self._stack_inserts = 0
        self._slots: list[_Slot] = [_Slot(idx=i) for i in range(self.slots)]
        self._queue: deque[EngineRequest] = deque()
        self._results: dict[int, RequestResult] = {}
        self._next_id = 0
        self._steps = 0
        self._decode_steps = 0
        self._prefills = 0
        self._admitted = 0
        self._retired = 0
        self._generated = 0
        self._slot_steps = 0
        self._draft_steps = 0
        self._verify_steps = 0
        self._accepted_drafts = 0
        # -- robustness state ----------------------------------------------
        self._preemptions = 0
        self._timeouts = 0
        self._quarantined = 0
        self._busy_rejections = 0
        self._spec_disables = 0
        self._spec_reenables = 0
        self._injected_nans = 0
        self._forced_evictions = 0
        self._stale_injected = 0
        self._slow_ticks = 0
        self._nan_tick: tuple = ()     # this tick's poisoned slots (faults)
        self._stale_pending = False    # next admission gets a stale handle
        self._spec_rates: list[float] = []   # recent per-tick accept rates
        self._spec_cooldown = 0        # plain ticks left before re-enable
        # -- observability (PR 10) -----------------------------------------
        # The recorder only ever receives host scalars the scheduler
        # already holds; a None trace makes every emit a single attribute
        # check. The adapter cache's spill/reload hook is claimed only
        # when tracing — an untraced engine leaves the cache untouched.
        self.trace = trace
        if trace is not None and adapter_cache is not None:
            adapter_cache.on_event = self._cache_event

    # -- observability -------------------------------------------------------

    def _emit(self, name: str, *, rid: int | None = None,
              slot: int | None = None, **data) -> None:
        """Record one lifecycle event (no-op untraced). Every argument
        must already be host state — this path adds zero device work."""
        if self.trace is not None:
            self.trace.emit(name, tick=self._steps, request_id=rid,
                            slot=slot, **data)

    def _cache_event(self, kind: str, key) -> None:
        """AdapterStateCache tier-traffic hook: ``spill`` / ``reload``
        events land on the engine's trace at the current tick."""
        self._emit(kind, adapter=key.adapter_id, version=key.version)

    # -- submission ---------------------------------------------------------

    def check_request(self, prompt, *,
                      adapter: AdapterHandle | str | None = None,
                      max_new_tokens: int):
        """Validate a request WITHOUT queuing it: raises exactly what
        :meth:`submit` would, and returns the (normalized prompt,
        resolved handle) pair it would queue. Batch front ends run this
        over EVERY request before the first submit — a bad request in
        the middle of a batch must fail the call, not strand the
        already-queued ones in the persistent engine."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        P = prompt.shape[0]
        if P < 1:
            raise ValueError("empty prompt")
        if P + 1 > self.max_len:
            raise ValueError(
                f"prompt length {P} leaves no room to generate within "
                f"max_len={self.max_len} (need P + 1 <= max_len)")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens={max_new_tokens} < 1")
        if adapter is None:
            if self.adapters is None:
                raise ValueError(
                    "this engine routes requests through an adapter cache; "
                    "every request must carry an adapter id or handle")
            handle = None
        else:
            if self.adapter_cache is None:
                raise ValueError(
                    "this engine serves one fixed adapter tree; requests "
                    "cannot carry adapter handles (construct the engine "
                    "with adapter_cache= to route per-request adapters)")
            handle = (adapter if isinstance(adapter, AdapterHandle)
                      else self.adapter_cache.current_handle(adapter))
            # Backpressure BEFORE the state resolution: when the LRU is
            # thrashing (every recent lookup an evicting miss), admitting
            # another COLD current-version request would stall the serve
            # path on yet one more full precompute — refuse it with a
            # retry hint instead. Stale/unregistered handles fall through
            # to get_state below so they keep raising their own errors.
            # SPILLED handles are exempt: a host-tier state costs one
            # host→device reload (queue latency), never a precompute, so
            # refusing it would turn the cheap case into a retry storm.
            if (self.adapter_cache.thrashing()
                    and not self.adapter_cache.is_resident(handle)
                    and not self.adapter_cache.is_spilled(handle)):
                try:
                    cur = self.adapter_cache.current_handle(
                        handle.adapter_id)
                except KeyError:
                    cur = None
                if cur == handle:
                    self._busy_rejections += 1
                    self._emit("busy_rejected", adapter=handle.adapter_id,
                               version=handle.version,
                               retry_after=self.adapter_cache.thrash_window)
                    raise EngineBusy(
                        f"adapter-state cache is thrashing (last "
                        f"{self.adapter_cache.thrash_window} lookups were "
                        f"all evicting misses) and "
                        f"{handle.adapter_id!r}@v{handle.version} is not "
                        f"resident — admitting it would evict yet another "
                        f"tenant; retry in ~"
                        f"{self.adapter_cache.thrash_window} ticks",
                        retry_after=self.adapter_cache.thrash_window)
            # Resolve the serving tree NOW: submit is the pin point, so
            # a stale handle — or a cold state under warm-only routing —
            # must fail here, before a batch front end queues anything,
            # not later at admission.
            self.adapter_cache.get_state(self.params, handle,
                                         allow_miss=self.allow_miss)
        return prompt, handle

    def submit(self, prompt, *, adapter: AdapterHandle | str | None = None,
               max_new_tokens: int, eos_id: int | None = None,
               key_id: int | None = None, priority: int = 0,
               deadline_ticks: int | None = None) -> int:
        """Queue one request; returns its request id. ``adapter``: an
        :class:`AdapterHandle`, a registered adapter id (resolved to the
        CURRENT version at submit time), or None when the engine serves a
        fixed adapter tree. The resolved serving tree is pinned on the
        request HERE: an :meth:`AdapterStateCache.update` issued while
        the request waits in the queue neither re-routes it to the new
        version nor errors it — it serves with the tree it was submitted
        against (so a stale handle or a cold warm-only state raises
        here, not at admission). ``key_id``: the fold-in for this request's
        temperature-sampling key stream (default: the request id, which
        monotonically increases on a persistent engine — batch-level
        callers wanting call-reproducible sampling pass the request's
        index within the batch, as ``EngineServer``/mixed-length
        ``serve()`` do).

        ``priority``: higher admits first and may PREEMPT a lower-priority
        active slot when no slot is free (the victim re-queues as a
        continuation — see :meth:`step`). ``deadline_ticks``: the request
        expires ``deadline_ticks`` engine steps from now — queued or
        mid-decode — retiring with ``finish_reason="timeout"`` and
        whatever tokens it generated."""
        if deadline_ticks is not None and deadline_ticks < 1:
            raise ValueError(f"deadline_ticks={deadline_ticks} < 1")
        prompt, handle = self.check_request(prompt, adapter=adapter,
                                            max_new_tokens=max_new_tokens)
        state = (self.adapters if handle is None
                 else self.adapter_cache.get_state(
                     self.params, handle, allow_miss=self.allow_miss))
        rid = self._next_id
        self._next_id += 1
        self._queue.append(EngineRequest(
            rid, prompt, handle, int(max_new_tokens), eos_id,
            key_id=rid if key_id is None else int(key_id), state=state,
            priority=int(priority),
            deadline_step=(None if deadline_ticks is None
                           else self._steps + int(deadline_ticks))))
        self._emit("submitted", rid=rid, prompt_len=int(prompt.shape[0]),
                   max_new_tokens=int(max_new_tokens),
                   adapter=(None if handle is None else handle.adapter_id),
                   priority=int(priority),
                   deadline_ticks=deadline_ticks)
        self._emit("queued", rid=rid, depth=len(self._queue))
        return rid

    # -- scheduling ---------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self._queue) or any(s.occupied for s in self._slots)

    def stats(self) -> EngineStats:
        return EngineStats(slots=self.slots, steps=self._steps,
                           decode_steps=self._decode_steps,
                           prefills=self._prefills,
                           admitted=self._admitted, retired=self._retired,
                           generated_tokens=self._generated,
                           slot_steps=self._slot_steps,
                           draft_steps=self._draft_steps,
                           verify_steps=self._verify_steps,
                           accepted_drafts=self._accepted_drafts,
                           stack_inserts=self._stack_inserts,
                           preemptions=self._preemptions,
                           timeouts=self._timeouts,
                           quarantined=self._quarantined,
                           busy_rejections=self._busy_rejections,
                           spec_disables=self._spec_disables,
                           spec_reenables=self._spec_reenables,
                           injected_nans=self._injected_nans,
                           forced_evictions=self._forced_evictions,
                           stale_injected=self._stale_injected,
                           slow_ticks=self._slow_ticks)

    def compile_counts(self) -> dict:
        """How many executables each step fn holds — the compile-count
        acceptance: after any join/leave trace this must be exactly 1 for
        the prefill, 1 per decode group-signature, 1 for the (adapter-
        free) draft, and 1 per (group-signature, window) verify. A
        dynamic-grouping engine has exactly ONE decode signature (the
        ``"dynamic"`` key) no matter the tenant mix, plus one traced
        ``adapter_insert`` executable for fleet-stack writes."""
        return {"prefill_into_slot": self._prefill._cache_size(),
                "adapter_insert": (0 if self._dyn_insert is None
                                   else self._dyn_insert._cache_size()),
                "prefill_chunk": (0 if self._chunk_prefill is None
                                  else self._chunk_prefill._cache_size()),
                "decode": {sig: fn._cache_size()
                           for sig, fn in self._decodes.items()},
                "draft": (0 if self._draft is None
                          else self._draft._cache_size()),
                "verify": {key: fn._cache_size()
                           for key, fn in self._verifies.items()}}

    # -- block pool (paged K/V) ---------------------------------------------

    def pool_stats(self) -> dict:
        """Host-mirror block-pool accounting (paged engines only): pool
        geometry, current and peak occupancy, and per-slot block counts.
        ``used_blocks == 0`` after the engine drains is the no-leak
        invariant the property suite exercises."""
        if not self._paged:
            raise ValueError("pool_stats() requires a paged engine "
                             "(construct with paged=True)")
        used = self._n_blocks - len(self._free)
        return {"block_size": self._block_size,
                "n_blocks": self._n_blocks,
                "max_blocks": self._max_blocks,
                "prefill_chunk": self._chunk,
                "free_blocks": len(self._free),
                "used_blocks": used,
                "peak_used_blocks": self._peak_used,
                "per_slot_blocks": [len(b) for b in self._blocks]}

    def _ensure_blocks(self, idx: int, upto_len: int) -> bool:
        """Grow slot ``idx``'s block list until it covers K/V positions
        [0, upto_len); False (with the partial growth kept — the blocks
        are reserved either way) when the pool runs dry."""
        need = -(-upto_len // self._block_size)
        blocks = self._blocks[idx]
        while len(blocks) < need:
            if not self._free:
                return False
            b = self._free.pop()
            self._pages_np[idx, len(blocks)] = b
            blocks.append(b)
            self._pages_dirty = True
        used = self._n_blocks - len(self._free)
        if used > self._peak_used:
            self._peak_used = used
        return True

    def _free_tail(self, idx: int, new_len: int) -> None:
        """Return every block of slot ``idx`` past position ``new_len``
        to the pool (a straddling block stays — it still holds live
        K/V). A freed block's stale content is harmless wherever it is
        reallocated: a slot only receives a new block when its frontier
        crosses INTO it, so every stale position sits at-or-beyond the
        new owner's causal frontier until overwritten."""
        keep = -(-new_len // self._block_size)
        blocks = self._blocks[idx]
        while len(blocks) > keep:
            b = blocks.pop()
            self._pages_np[idx, len(blocks)] = -1
            self._free.append(b)
            self._pages_dirty = True

    def _free_all(self, idx: int) -> None:
        self._free_tail(idx, 0)

    def _flush_pages(self) -> None:
        """Mirror the host block table into ``cache["pages"]``. A FRESH
        device array every time (the steps donate the cache); called
        before every device step that reads the cache, so allocation and
        freeing are visible exactly when they must be."""
        if not self._pages_dirty:
            return
        arr = jnp.asarray(np.array(self._pages_np))
        if self._cache_out_sh is not None:
            arr = jax.device_put(arr, self._cache_out_sh["pages"])
        cache = dict(self.cache)
        cache["pages"] = arr
        self.cache = cache
        self._pages_dirty = False

    def _block_victim(self) -> int | None:
        """Deterministic reclaim order under pool exhaustion: lowest
        priority first, most recently admitted among equals, highest
        slot index as the final tie-break."""
        occ = [i for i, s in enumerate(self._slots) if s.occupied]
        if not occ:
            return None
        return min(occ, key=lambda i: (self._slots[i].req.priority,
                                       -self._slots[i].admitted_step, -i))

    def _ensure_active_blocks(self, rows: list[int], extra: int
                              ) -> list[int]:
        """Allocate so every row in ``rows`` can write K/V positions
        pos..pos+extra-1 this tick. On pool exhaustion, reclaim by
        preempting :meth:`_block_victim` slots (their requests re-queue
        as continuations and resume bitwise) until the allocation fits.
        Returns the rows still active — a row preempted as its own
        victim drops out."""
        for i in rows:
            slot = self._slots[i]
            while slot.active and not self._ensure_blocks(i, slot.pos + extra):
                victim = self._block_victim()
                if victim is None:     # unreachable: row i itself is occupied
                    raise RuntimeError(
                        "paged block pool exhausted with nothing to preempt")
                self._preempt(victim)
        return [i for i in rows if self._slots[i].active]

    def _sample_rows(self, logits_rows, key_ids_and_counts) -> list[int]:
        """One token per row. Greedy is a host argmax over the
        already-fetched logits (zero device work); temperature>0 runs
        ONE vmapped categorical over the rows' per-request keys — a
        single device round trip per step, not one per active slot."""
        if self.temperature <= 0.0:
            return [int(np.argmax(row)) for row in logits_rows]
        keys = jnp.stack([
            jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.seed), kid), n)
            for kid, n in key_ids_and_counts])
        draws = jax.vmap(
            lambda k, row: jax.random.categorical(
                k, row / self.temperature)
        )(keys, jnp.asarray(np.stack(logits_rows)))
        return [int(t) for t in np.asarray(draws)]

    def _resolve_state(self, req: EngineRequest):
        if req.adapter is None:
            return self.adapters
        return self.adapter_cache.get_state(self.params, req.adapter,
                                            allow_miss=self.allow_miss)

    def _finish(self, slot: _Slot, reason: str) -> None:
        req = slot.req
        # A preempted-and-resumed request reports its ORIGINAL prompt and
        # the full token stream (earlier legs' prefix + this leg), and its
        # FIRST admission step — the continuation re-prefill is an engine
        # implementation detail the caller never sees.
        prefix = [] if req.prefix is None else list(req.prefix)
        self._results[req.request_id] = RequestResult(
            request_id=req.request_id,
            prompt=(req.prompt if req.orig_prompt is None
                    else req.orig_prompt),
            tokens=np.asarray(prefix + slot.generated, np.int32),
            finish_reason=reason,
            admitted_step=(slot.admitted_step if req.first_admitted is None
                           else req.first_admitted),
            finished_step=self._steps, preempted=req.preempted)
        self._emit("terminal", rid=req.request_id, slot=slot.idx,
                   reason=reason,
                   n_tokens=len(prefix) + len(slot.generated))
        if reason == "timeout":
            self._timeouts += 1
        elif reason == "error_numeric":
            self._quarantined += 1
        self._retired += 1
        if self._paged:
            self._free_all(slot.idx)
        if self._dynamic and slot.handle is not None:
            self._dyn_release(slot.handle)
        slot.req = None
        slot.handle = None
        slot.state = None
        slot.generated = []
        slot.prefilling = False

    def _note_token(self, slot: _Slot, tok: int, on_token) -> str | None:
        """Record one sampled token; returns the finish reason if the
        request is now done."""
        slot.generated.append(tok)
        slot.budget -= 1
        slot.last_token = tok
        self._generated += 1
        self._emit(("first_token"
                    if slot.n_prior + len(slot.generated) == 1
                    else "token"),
                   rid=slot.req.request_id, slot=slot.idx, token=tok)
        if on_token is not None:
            on_token(slot.req.request_id, tok)
        if slot.req.eos_id is not None and tok == slot.req.eos_id:
            return "eos"
        if slot.budget <= 0:
            return slot.finish_cap
        return None

    def _error_result(self, req: EngineRequest, e: Exception) -> None:
        res = RequestResult(
            request_id=req.request_id,
            prompt=(req.prompt if req.orig_prompt is None
                    else req.orig_prompt),
            tokens=np.asarray(
                [] if req.prefix is None else list(req.prefix), np.int32),
            finish_reason="error",
            admitted_step=(self._steps if req.first_admitted is None
                           else req.first_admitted),
            finished_step=self._steps, error_type=type(e).__name__,
            error_message=str(e), preempted=req.preempted)
        res._live_error = e
        self._results[req.request_id] = res
        self._emit("terminal", rid=req.request_id, reason="error",
                   error_type=type(e).__name__)

    def _timeout_queued(self, req: EngineRequest) -> None:
        """Retire a QUEUED request whose deadline expired: it never held
        (or no longer holds) a slot, so there is nothing to free — it
        just reports whatever earlier legs generated."""
        self._results[req.request_id] = RequestResult(
            request_id=req.request_id,
            prompt=(req.prompt if req.orig_prompt is None
                    else req.orig_prompt),
            tokens=np.asarray(
                [] if req.prefix is None else list(req.prefix), np.int32),
            finish_reason="timeout",
            admitted_step=(self._steps if req.first_admitted is None
                           else req.first_admitted),
            finished_step=self._steps, preempted=req.preempted)
        self._emit("terminal", rid=req.request_id, reason="timeout",
                   queued=True)
        self._timeouts += 1

    def _expire_deadlines(self) -> None:
        """Retire every request — queued or mid-decode — whose absolute
        deadline step has arrived, with ``finish_reason="timeout"``."""
        if any(r.deadline_step is not None and self._steps >= r.deadline_step
               for r in self._queue):
            keep: deque[EngineRequest] = deque()
            for req in self._queue:
                if (req.deadline_step is not None
                        and self._steps >= req.deadline_step):
                    self._timeout_queued(req)
                else:
                    keep.append(req)
            self._queue = keep
        for slot in self._slots:
            if (slot.occupied and slot.req.deadline_step is not None
                    and self._steps >= slot.req.deadline_step):
                self._finish(slot, "timeout")

    def _apply_tick_faults(self) -> None:
        """Consult the FaultPlan once at the top of the tick (no-op
        without a plan): straggler sleeps fire immediately, evictions hit
        the adapter cache, stale/NaN injections arm flags that the
        admission / sampling paths consume."""
        plan = self.fault_plan
        self._nan_tick = ()
        if plan is None:
            return
        d = plan.slow_at(self._steps)
        if d > 0:
            time.sleep(d)
            self._slow_ticks += 1
            self._emit("fault", kind="slow", seconds=d)
        if plan.evict_at(self._steps) and self.adapter_cache is not None:
            # Pinned slot/request states are untouched (containment); the
            # NEXT cold lookup pays a re-precompute — or errors, under
            # warm-only routing.
            self.adapter_cache.invalidate()
            self._forced_evictions += 1
            self._emit("fault", kind="evict")
        if plan.stale_at(self._steps):
            self._stale_pending = True
            self._emit("fault", kind="stale")
        self._nan_tick = plan.nan_slots(self._steps)
        if self._nan_tick:
            self._emit("fault", kind="nan",
                       slots=[(-1 if t is None else int(t))
                              for t in self._nan_tick])

    def _nan_targets(self, rows: list[int]) -> list[int]:
        """Which of ``rows`` this tick's plan poisons (None = all)."""
        if not self._nan_tick:
            return []
        if any(t is None for t in self._nan_tick):
            return list(rows)
        return [i for i in rows if i in self._nan_tick]

    def _poison(self, rows: list[int], logits_np: np.ndarray) -> np.ndarray:
        """Overwrite the planned rows with NaN on the host mirror.
        ``np.asarray`` of a jax array is read-only, so injection copies
        first; the no-fault path never copies."""
        targets = self._nan_targets(rows)
        if not targets:
            return logits_np
        logits_np = np.array(logits_np)
        for i in targets:
            logits_np[i] = np.nan
            self._injected_nans += 1
        return logits_np

    def _adapter_eligible(self, req: EngineRequest) -> bool:
        """Per-adapter admission rate limit (``max_active_per_adapter``):
        a request is held in the queue — WITHOUT losing its position —
        while its adapter already occupies that many slots, so one hot
        tenant's burst cannot monopolise the slot table and starve the
        fleet. No limit set (or a fixed-adapter engine): always True."""
        if self.max_active_per_adapter is None or req.adapter is None:
            return True
        n = sum(1 for s in self._slots
                if s.occupied and s.handle is not None
                and s.handle.adapter_id == req.adapter.adapter_id)
        return n < self.max_active_per_adapter

    def _pop_next(self) -> EngineRequest | None:
        """Pop the highest-priority ELIGIBLE queued request (earliest
        submitted among equals — all-default-priority queues stay exactly
        FIFO); None when every queued request is rate-limited by
        ``max_active_per_adapter`` (ineligible requests keep their queue
        positions)."""
        best = -1
        for j, r in enumerate(self._queue):
            if not self._adapter_eligible(r):
                continue
            if best < 0 or r.priority > self._queue[best].priority:
                best = j
        if best < 0:
            return None
        if best == 0:
            return self._queue.popleft()
        self._queue.rotate(-best)
        req = self._queue.popleft()
        self._queue.rotate(best)
        return req

    def _preempt(self, idx: int) -> None:
        """Displace slot ``idx``: re-queue its request as a CONTINUATION
        whose prompt is (prompt + generated-so-far) — re-admission
        re-prefills that through the traced prefill-into-slot, and the
        resumed stream is bitwise the uninterrupted one (the re-prefill's
        final-position logits ARE the plain decode logits at that
        frontier, and the sample-key fold count continues via n_prior).
        The continuation always fits: P' + budget' = P + budget <=
        max_len keeps room for every remaining token.

        A slot still MID-ADMISSION (paged chunked prefill) re-queues its
        request UNCHANGED — it has produced nothing yet, so there is no
        continuation to build — and returns its reserved blocks."""
        slot = self._slots[idx]
        req = slot.req
        self._emit("preempted", rid=req.request_id, slot=idx,
                   mid_admission=slot.prefilling,
                   n_generated=len(slot.generated))
        if slot.prefilling:
            self._queue.append(dataclasses.replace(
                req, preempted=req.preempted + 1))
            self._preemptions += 1
            self._free_all(idx)
            if self._dynamic and slot.handle is not None:
                self._dyn_release(slot.handle)
            slot.req = None
            slot.handle = None
            slot.state = None
            slot.generated = []
            slot.prefilling = False
            return
        gen = np.asarray(slot.generated, np.int32)
        self._queue.append(dataclasses.replace(
            req,
            prompt=np.concatenate([req.prompt, gen]),
            max_new_tokens=slot.budget,
            prefix=(gen if req.prefix is None
                    else np.concatenate([req.prefix, gen])),
            orig_prompt=(req.prompt if req.orig_prompt is None
                         else req.orig_prompt),
            resume_cap=slot.finish_cap,
            first_admitted=(slot.admitted_step if req.first_admitted is None
                            else req.first_admitted),
            preempted=req.preempted + 1))
        self._preemptions += 1
        if self._paged:
            self._free_all(idx)
        if self._dynamic and slot.handle is not None:
            self._dyn_release(slot.handle)
        slot.req = None
        slot.handle = None
        slot.state = None
        slot.generated = []

    def _admit_into(self, idx: int, slot: _Slot, req: EngineRequest,
                    on_token) -> bool:
        """One admission. Rectangular path: prefill INTO slot ``idx`` +
        first sampled token (a request whose budget is one token retires
        here without ever occupying a decode row). Paged path: SEAT the
        request (reserve blocks for the whole prompt + the first decode
        write) and mark the slot ``prefilling`` — the prompt streams in
        over :meth:`_chunk_tick` chunks, and the first token is sampled
        by the FINAL chunk. Returns False only when a paged admission is
        DEFERRED (the pool cannot hold the prompt right now; the request
        goes back to the queue head and this tick stops admitting)."""
        if self._stale_pending and req.adapter is not None:
            # Fault injection: hand the admission a handle whose version
            # the registry never issued, with the pinned state stripped —
            # the late-resolution path below then raises the cache's REAL
            # stale error (version mismatch), not a simulation of it.
            self._stale_pending = False
            self._stale_injected += 1
            req = dataclasses.replace(
                req, adapter=dataclasses.replace(
                    req.adapter, version=req.adapter.version + 1),
                state=None)
        try:
            # submit() pins the resolved tree on the request, so
            # normally this is a plain attribute read immune to
            # mid-queue cache churn; the late-resolution fallback
            # only fires for hand-built EngineRequests.
            state = (req.state if req.state is not None
                     else self._resolve_state(req))
        except Exception as e:
            # A failed LATE resolution must neither silently
            # lose the request nor wedge the FIFO behind it
            # forever: the request is finished with an errored
            # result and admission moves on to the next one.
            self._error_result(req, e)
            return True
        P = req.prompt.shape[0]
        if self._paged:
            # Admission-start gate: the WHOLE prompt (+ the first decode
            # write) is reserved up front, so chunked prefill can never
            # strand a half-admitted prompt on pool exhaustion. When the
            # pool cannot cover it, the request defers at the queue HEAD
            # (documented head-of-line policy: decode keeps running and
            # retirements will free blocks) rather than being skipped.
            need = -(-(P + 1) // self._block_size)
            if len(self._free) < need:
                self._queue.appendleft(req)
                return False
            slot.req = req
            slot.handle = req.adapter
            slot.state = state
            if self._dynamic:
                self._dyn_assign(idx, req.adapter, state)
            slot.admitted_step = self._steps
            slot.pos = 0
            slot.n_prior = (0 if req.prefix is None
                            else int(req.prefix.shape[0]))
            slot.generated = []
            slot.prefilling = True
            slot.chunk_next = 0
            self._ensure_blocks(idx, P + 1)
            self._emit("admitted", rid=req.request_id, slot=idx,
                       prompt_len=P, paged=True)
            if req.preempted:
                self._emit("resumed", rid=req.request_id, slot=idx,
                           attempt=req.preempted)
            return True
        if self._dynamic:
            # Claim the fleet-stack position BEFORE the prefill: a
            # budget-1 request that retires inside this admission still
            # releases a position it actually held.
            self._dyn_assign(idx, req.adapter, state)
        toks = np.zeros((1, self.max_len), np.int32)
        toks[0, :P] = req.prompt
        logits, self.cache = self._prefill(
            self.params, state, self.cache,
            {"tokens": jnp.asarray(toks),
             "prompt_len": jnp.asarray(P, jnp.int32),
             "slot": jnp.asarray(idx, jnp.int32)})
        self._prefills += 1
        self._admitted += 1
        slot.req = req
        slot.handle = req.adapter
        slot.state = state
        slot.admitted_step = self._steps
        self._emit("admitted", rid=req.request_id, slot=idx, prompt_len=P)
        if req.preempted:
            self._emit("resumed", rid=req.request_id, slot=idx,
                       attempt=req.preempted)
        slot.pos = P    # first decode K/V write lands at P
        slot.n_prior = 0 if req.prefix is None else int(req.prefix.shape[0])
        # Token budget: the request's own cap, or the cache bound
        # (P + budget - 1 decode writes must stay < max_len; the
        # last sampled token is never written back). A continuation
        # carries its ORIGINAL cap label (resume_cap): its shrunken
        # budget always fits the remaining room, so recomputing the
        # label here would misreport a capped request as "length".
        room = self.max_len - P
        slot.budget = min(req.max_new_tokens, room)
        slot.finish_cap = (req.resume_cap if req.resume_cap is not None
                           else ("length" if req.max_new_tokens <= room
                                 else "max_len"))
        row = np.asarray(logits)[0]
        if self._nan_targets([idx]):
            row = np.full_like(row, np.nan)
            self._injected_nans += 1
        if not np.isfinite(row).all():
            # Quarantine at admission: the prefill produced non-finite
            # logits for THIS row — retire it before it ever decodes.
            self._emit("quarantined", rid=req.request_id, slot=idx,
                       at="admission")
            self._finish(slot, "error_numeric")
            return True
        tok = self._sample_rows([row], [(req.key_id, slot.n_prior)])[0]
        reason = self._note_token(slot, tok, on_token)
        if reason is not None:
            self._finish(slot, reason)   # slot free again
        return True

    def _admit(self, on_token=None) -> None:
        """Fill free slots from the queue (highest priority first, FIFO
        among equals), then preempt: while a queued request outranks the
        lowest-priority OCCUPIED slot and no slot is free, that victim is
        displaced (re-queued as a continuation — a mid-admission slot
        re-queues its request unchanged) and the fill loop seats the
        outranking request in its row. Each preemption strictly raises
        the displaced slot's priority, so the loop terminates. A paged
        admission deferred on block exhaustion stops the whole tick's
        admitting (head-of-line)."""
        while True:
            for idx, slot in enumerate(self._slots):
                while not slot.occupied and self._queue:
                    req = self._pop_next()
                    if req is None:
                        break   # every queued request is rate-limited
                    if not self._admit_into(idx, slot, req, on_token):
                        return
            if not self._queue:
                return
            # Preemption considers ELIGIBLE queued requests only: a
            # rate-limited request must not displace anyone (it could
            # not be seated in the freed slot anyway).
            elig = [r.priority for r in self._queue
                    if self._adapter_eligible(r)]
            if not elig:
                return
            best = max(elig)
            occupied = [i for i, s in enumerate(self._slots) if s.occupied]
            if not occupied:
                return
            victim = min(occupied,
                         key=lambda i: (self._slots[i].req.priority, i))
            if best <= self._slots[victim].req.priority:
                return
            self._preempt(victim)

    # -- dynamic fleet stack (traced grouping) ------------------------------

    def _dyn_insert_fn(self):
        """ONE jitted fleet-stack writer: position traced, stack donated —
        admissions at every position share a single executable
        (``compile_counts()["adapter_insert"]``)."""
        if self._dyn_insert is None:
            def insert(stack, state, pos):
                def upd(big, leaf):
                    starts = (jnp.zeros((), jnp.int32), pos) + tuple(
                        jnp.zeros((), jnp.int32)
                        for _ in range(leaf.ndim - 1))
                    return jax.lax.dynamic_update_slice(
                        big, jnp.expand_dims(leaf, 1).astype(big.dtype),
                        starts)
                return jax.tree_util.tree_map(upd, stack, state)
            self._dyn_insert = jax.jit(insert, donate_argnums=(0,))
        return self._dyn_insert

    def _dyn_assign(self, idx: int, handle, state) -> None:
        """Give slot ``idx`` a fleet-stack position for ``handle``: slots
        sharing a handle share its position (refcounted), a NEW handle
        claims a free position and writes its serving tree there (the one
        churn-time device copy — decode ticks never restack). The stack
        is built lazily from the first state's leaf shapes (zeros rows:
        finite garbage nothing indexes)."""
        ent = self._dyn_pos.get(handle)
        if ent is not None:
            ent[1] += 1
        else:
            pos = self._dyn_free.pop()
            self._dyn_pos[handle] = ent = [pos, 1]
            if self._dyn_stack is None:
                self._dyn_stack = jax.tree_util.tree_map(
                    lambda l: jnp.zeros(
                        (l.shape[0], self.slots) + l.shape[1:], l.dtype),
                    state)
            self._dyn_stack = self._dyn_insert_fn()(
                self._dyn_stack, state, jnp.asarray(pos, jnp.int32))
            self._stack_inserts += 1
        self._dyn_idx_np[idx] = ent[0]
        self._dyn_idx_cached = None

    def _dyn_release(self, handle) -> None:
        """Drop one slot's claim on ``handle``'s position; the LAST claim
        recycles it (the stale stack row needs no zeroing — no live row's
        index points at it)."""
        ent = self._dyn_pos.get(handle)
        if ent is None:
            return
        ent[1] -= 1
        if ent[1] == 0:
            del self._dyn_pos[handle]
            self._dyn_free.append(ent[0])

    def _dyn_idx(self):
        """Device mirror of the per-slot position vector — the traced
        ``batch_in["adapter_idx"]`` operand; rebuilt only when an
        admission moved a slot's index, never per token. Free slots keep
        a stale (in-range) position: their rows decode garbage nothing
        reads, exactly like the static path's absorbed free slots."""
        if self._dyn_idx_cached is None:
            self._dyn_idx_cached = jnp.asarray(np.array(self._dyn_idx_np))
        return self._dyn_idx_cached

    def _slot_grouping(self):
        """(tenant_groups | None, adapter tree) for the CURRENT slot
        table. Free slots are absorbed into a neighbouring run (their
        rows decode garbage that nothing reads), so the signature only
        changes when the handle layout of OCCUPIED slots changes — a
        paged slot mid-chunked-admission already counts, so a prompt
        streaming in does not flap the signature when it joins decode —
        and the (groups, stacked-tree) pair is cached on that layout:
        re-stacking every tenant's full serving tree is a device-side
        copy that must happen per admission/retirement, not per sampled
        token."""
        if self.adapter_cache is None:
            return None, self.adapters
        if self._dynamic:
            # The signature is the CONSTANT "dynamic": churn moved values
            # (stack rows, index vector), never the trace.
            return "dynamic", self._dyn_stack
        layout = tuple((s.handle if s.occupied else None)
                       for s in self._slots)
        if self._grouping_cache is not None \
                and self._grouping_cache[0] == layout:
            return self._grouping_cache[1], self._grouping_cache[2]
        keys: list[Any] = list(layout)
        states = {s.handle: s.state for s in self._slots if s.occupied}
        # forward fill from the left, then leading Nones from the right
        last = None
        for i, k in enumerate(keys):
            if k is None:
                keys[i] = last
            else:
                last = k
        nxt = None
        for i in reversed(range(len(keys))):
            if keys[i] is None:
                keys[i] = nxt
            else:
                nxt = keys[i]
        if len(set(keys)) == 1:
            groups, adapters = None, states[keys[0]]
        else:
            runs: list[tuple[Any, int]] = []
            for k in keys:
                if runs and runs[-1][0] == k:
                    runs[-1] = (k, runs[-1][1] + 1)
                else:
                    runs.append((k, 1))
            groups, start = [], 0
            for _, n in runs:
                groups.append((start, n))
                start += n
            groups = tuple(groups)
            adapters = stack_adapter_states([states[k] for k, _ in runs],
                                            axis=1)
        self._grouping_cache = (layout, groups, adapters)
        return groups, adapters

    def _get_decode(self, groups):
        if groups in self._decodes:
            self._decodes.move_to_end(groups)
            return self._decodes[groups]
        dyn = groups == "dynamic"
        fn = jax.jit(make_decode_step(self.mcfg, self.scfg, self.mesh,
                                      batch=self.slots,
                                      tenant_groups=None if dyn else groups,
                                      dynamic_groups=dyn),
                     donate_argnums=(2,),
                     out_shardings=(None, self._cache_out_sh))
        self._decodes[groups] = fn
        while len(self._decodes) > self.max_cached_steps:
            self._decodes.popitem(last=False)
        return fn

    def _get_draft(self):
        if self._draft is None:
            self._draft = jax.jit(
                make_draft_step(self.mcfg, self.scfg, self.mesh,
                                batch=self.slots),
                donate_argnums=(1,),
                out_shardings=(None, self._cache_out_sh))
        return self._draft

    def _get_verify(self, groups, window: int):
        key = (groups, window)
        if key in self._verifies:
            self._verifies.move_to_end(key)
            return self._verifies[key]
        dyn = groups == "dynamic"
        fn = jax.jit(make_verify_step(self.mcfg, self.scfg, self.mesh,
                                      batch=self.slots, window=window,
                                      tenant_groups=None if dyn else groups,
                                      dynamic_groups=dyn),
                     donate_argnums=(2,),
                     out_shardings=(None, self._cache_out_sh))
        self._verifies[key] = fn
        while len(self._verifies) > self.max_cached_steps:
            self._verifies.popitem(last=False)
        return fn

    def _sync_len(self, lens: np.ndarray) -> None:
        """Overwrite ``cache["len"]`` with a host-built per-row vector —
        the speculative rewind. A FRESH device array every time: the
        steps donate the cache, so yesterday's ``len`` buffer may
        already be dead. Free rows get 0 (their buffer content is
        garbage either way — admission prefills the whole row)."""
        arr = jnp.asarray(np.asarray(lens, np.int32))
        if self._cache_out_sh is not None:
            arr = jax.device_put(arr, self._cache_out_sh["len"])
        cache = dict(self.cache)
        cache["len"] = arr.astype(cache["len"].dtype)
        self.cache = cache

    def _speculative_ok(self, active: list[int]) -> bool:
        """Whether THIS tick can draft-and-verify: greedy sampling only
        (rejection sampling for temperature>0 is future work) and every
        active row's k+1-window must fit under ``max_len`` — a clamped
        ``dynamic_update_slice`` would silently shift a row's writes.
        Rows with ≥ k remaining budget always fit (the admission budget
        keeps ``pos + budget <= max_len - 1``); a row at its max_len cap
        degrades the whole batch to plain decode for its last tokens.

        Degradation ladder: when the measured accept rate over the last
        ``spec_window`` speculative ticks collapses below
        ``spec_accept_floor`` (drafts are just burning forwards), the
        engine falls back to plain decode for ``spec_reenable_after``
        ticks, then retries — hysteresis, so a borderline adapter does
        not flap every tick."""
        if self.speculative_k <= 0 or self.temperature > 0.0:
            return False
        if self._spec_cooldown > 0:
            self._spec_cooldown -= 1
            if self._spec_cooldown == 0:
                self._spec_reenables += 1
                self._emit("spec_reenabled")
            return False
        k = self.speculative_k
        if not all(self._slots[i].pos + k + 1 <= self.max_len
                   for i in active):
            return False
        if self._paged:
            # A mid-admission slot degrades the tick to plain decode: the
            # draft loop would advance ITS device length k+1 positions
            # past the host chunk cursor, beyond what the next chunk
            # rewrites. And the whole k+1 window must be block-backed up
            # front — on exhaustion, fall back to plain decode (which
            # needs one block at most) instead of preempting for
            # speculation.
            if any(s.prefilling for s in self._slots):
                return False
            if not all(self._ensure_blocks(i, self._slots[i].pos + k + 1)
                       for i in active):
                return False
        return True

    def _quarantine(self, rows: list[int], logits_np: np.ndarray
                    ) -> tuple[list[int], np.ndarray]:
        """Per-row non-finite guard over the already-fetched host logits
        (zero extra device syncs): poisoned rows — injected or genuine —
        retire with ``finish_reason="error_numeric"``; the survivors'
        streams are untouched (attention and compose are row-local, so a
        quarantined neighbour never perturbs a live row's logits).
        Returns (surviving rows, possibly-poisoned logits)."""
        logits_np = self._poison(rows, logits_np)
        flat = logits_np.reshape(logits_np.shape[0], -1)
        bad = [i for i in rows if not np.isfinite(flat[i]).all()]
        for i in bad:
            self._emit("quarantined", rid=self._slots[i].req.request_id,
                       slot=i, at="decode")
            self._finish(self._slots[i], "error_numeric")
        if bad:
            rows = [i for i in rows if self._slots[i].active]
        return rows, logits_np

    def _chunk_tick(self, on_token) -> None:
        """Paged chunked admission: ONE prompt chunk per mid-admission
        slot per tick, through the traced batch-1 chunk step (slot,
        start, chunk length all traced — one executable total). Chunk
        starts are ``0, C, 2C, ...`` with the FINAL chunk re-anchored at
        ``P - C`` (when P > C): its window overlaps the previous chunk
        and rewrites those positions with bitwise-identical K/V, which
        keeps every start in-range for the clamping dynamic-slice write.
        The final chunk's last-position logits are the whole-prompt
        prefill logits bitwise (causal rows are independent, earlier
        chunks committed identical K/V), so the first token it samples —
        and the NaN quarantine guarding it — match the rectangular
        admission exactly.

        Between a slot's chunks, the batched decode advances EVERY row's
        device length by one and writes one garbage K/V row at the
        mid-admission slot's drifted frontier; the chunk step takes its
        start from the HOST mirror, and the drifted position always
        falls inside the NEXT chunk's window, so the garbage is
        overwritten before the final chunk reads it."""
        for idx, slot in enumerate(self._slots):
            if not slot.prefilling:
                continue
            req = slot.req
            P = req.prompt.shape[0]
            C = self._chunk
            final = P - slot.chunk_next <= C
            if final:
                c_len = min(P, C)
                start = P - c_len
            else:
                start, c_len = slot.chunk_next, C
            toks = np.zeros((1, C), np.int32)
            toks[0, :c_len] = req.prompt[start:start + c_len]
            self._emit("chunk_prefill", rid=req.request_id, slot=idx,
                       start=start, chunk_len=c_len, final=final)
            self._flush_pages()
            logits, self.cache = self._chunk_prefill(
                self.params, slot.state, self.cache,
                {"tokens": jnp.asarray(toks),
                 "slot": jnp.asarray(idx, jnp.int32),
                 "start": jnp.asarray(start, jnp.int32),
                 "chunk_len": jnp.asarray(c_len, jnp.int32)})
            if not final:
                slot.chunk_next = start + C
                continue
            # Final chunk: admission completes — the slot joins decode
            # THIS tick (a prompt that fits one chunk matches the
            # rectangular admission schedule exactly).
            slot.prefilling = False
            slot.pos = P
            self._prefills += 1
            self._admitted += 1
            room = self.max_len - P
            slot.budget = min(req.max_new_tokens, room)
            slot.finish_cap = (req.resume_cap if req.resume_cap is not None
                               else ("length" if req.max_new_tokens <= room
                                     else "max_len"))
            row = np.asarray(logits)[0]
            if self._nan_targets([idx]):
                row = np.full_like(row, np.nan)
                self._injected_nans += 1
            if not np.isfinite(row).all():
                self._emit("quarantined", rid=req.request_id, slot=idx,
                           at="admission")
                self._finish(slot, "error_numeric")
                continue
            tok = self._sample_rows([row], [(req.key_id, slot.n_prior)])[0]
            reason = self._note_token(slot, tok, on_token)
            if reason is not None:
                self._finish(slot, reason)

    def _decode_tick(self, active: list[int], on_token) -> None:
        """One plain batched decode over the active slots."""
        if self._paged:
            active = self._ensure_active_blocks(active, 1)
            if not active:
                return
            self._flush_pages()
        toks = np.zeros((self.slots, 1), np.int32)
        for i in active:
            toks[i, 0] = self._slots[i].last_token
        groups, adapters = self._slot_grouping()
        decode = self._get_decode(groups)
        batch_in = {"tokens": jnp.asarray(toks)}
        if groups == "dynamic":
            batch_in["adapter_idx"] = self._dyn_idx()
        logits, self.cache = decode(self.params, adapters, self.cache,
                                    batch_in)
        logits_np = np.asarray(logits)      # the sampling sync
        self._decode_steps += 1
        self._slot_steps += len(active)
        active, logits_np = self._quarantine(active, logits_np)
        toks_out = self._sample_rows(
            [logits_np[i] for i in active],
            [(self._slots[i].req.key_id,
              self._slots[i].n_prior + len(self._slots[i].generated))
             for i in active])
        for i, tok in zip(active, toks_out):
            slot = self._slots[i]
            slot.pos += 1               # this decode wrote K/V at pos
            reason = self._note_token(slot, tok, on_token)
            if reason is not None:
                self._finish(slot, reason)

    def _speculative_tick(self, active: list[int], on_token) -> None:
        """Draft k base-only tokens per row, verify the k+1 window in one
        full-DoRA forward, accept each row's longest matching prefix and
        rewind its cache length to the accepted frontier.

        Cache discipline: the drafts write BASE-path K/V at positions
        pos..pos+k-1; the verify then rewinds to pos and overwrites
        positions pos..pos+k with FULL-path K/V, so nothing base-flavored
        is ever attended to by a committed token. After acceptance each
        row rewinds to pos + emitted (the slot's next write position);
        rows beyond that frontier hold stale K/V that the per-row causal
        mask excludes until overwritten."""
        k = self.speculative_k
        base_len = np.zeros((self.slots,), np.int32)
        for i in active:
            base_len[i] = self._slots[i].pos
        cur = np.zeros((self.slots, 1), np.int32)
        for i in active:
            cur[i, 0] = self._slots[i].last_token

        # -- draft: k greedy base-only tokens per row -----------------------
        self._sync_len(base_len)
        if self._paged:
            self._flush_pages()   # _speculative_ok grew the k+1 window
        draft = self._get_draft()
        drafts = np.zeros((self.slots, k), np.int32)
        for j in range(k):
            logits, self.cache = draft(self.params, self.cache,
                                       {"tokens": jnp.asarray(cur)})
            lnp = np.asarray(logits)
            self._draft_steps += 1
            for i in active:
                t = int(np.argmax(lnp[i]))
                drafts[i, j] = t
                cur[i, 0] = t

        # -- verify: ONE grouped full-DoRA forward over [t0, q1..qk] --------
        self._sync_len(base_len)    # rewind over the drafts' len advance
        win = np.zeros((self.slots, k + 1), np.int32)
        for i in active:
            win[i, 0] = self._slots[i].last_token
            win[i, 1:] = drafts[i]
        groups, adapters = self._slot_grouping()
        verify = self._get_verify(groups, k + 1)
        batch_in = {"tokens": jnp.asarray(win)}
        if groups == "dynamic":
            batch_in["adapter_idx"] = self._dyn_idx()
        logits, self.cache = verify(self.params, adapters, self.cache,
                                    batch_in)
        logits_np = np.asarray(logits)       # [slots, k+1, V]
        self._verify_steps += 1
        # Quarantine BEFORE acceptance: a poisoned row emits nothing (its
        # verify window is garbage end to end) and its rewind target is 0
        # — the freed row's buffer is garbage either way.
        active, logits_np = self._quarantine(active, logits_np)

        # -- accept: longest matching prefix per row, then rewind -----------
        accepted_this = 0
        new_len = np.zeros((self.slots,), np.int32)
        for i in active:
            slot = self._slots[i]
            # true[j] = the token plain decode would emit after window
            # position j (valid as long as window[:j+1] matches the true
            # stream — which holds exactly up to the first draft miss).
            true = np.argmax(logits_np[i], axis=-1)
            a = 0
            while a < k and drafts[i, a] == true[a]:
                a += 1
            self._accepted_drafts += a
            accepted_this += a
            # emit true[0..a]: the a accepted drafts plus the verify's
            # own next token (a rejected draft's correction, or the
            # bonus token after a fully-accepted window).
            for tok in true[:a + 1]:
                slot.pos += 1
                reason = self._note_token(slot, int(tok), on_token)
                if reason is not None:
                    self._finish(slot, reason)
                    break
            if slot.active:
                new_len[i] = slot.pos
        if self._paged:
            # Speculative rewind frees the dead tail: blocks past each
            # surviving row's accepted frontier (allocated for the k+1
            # window) return to the pool; finished rows already freed
            # everything in _finish.
            for i in active:
                if self._slots[i].active:
                    self._free_tail(i, self._slots[i].pos)
        self._sync_len(new_len)

        # -- degradation ladder: track the accept rate ----------------------
        if active and self.spec_accept_floor > 0.0:
            self._spec_rates.append(accepted_this / (k * len(active)))
            if len(self._spec_rates) > self.spec_window:
                self._spec_rates.pop(0)
            if (len(self._spec_rates) == self.spec_window
                    and (sum(self._spec_rates) / self.spec_window)
                    < self.spec_accept_floor):
                self._spec_cooldown = self.spec_reenable_after
                self._spec_disables += 1
                self._emit("spec_disabled",
                           cooldown=self.spec_reenable_after)
                self._spec_rates.clear()

    def step(self, on_token=None) -> list[RequestResult]:
        """One scheduler tick: apply this tick's planned faults, expire
        deadlines, admit into free slots (preempting lower-priority rows
        when an outranking request is queued), then one batched decode —
        or draft/verify/rewind when ``speculative_k > 0`` — over every
        active slot. Returns the requests that FINISHED during this tick
        (also retrievable via :meth:`results`).
        ``on_token(request_id, token)`` streams every sampled token."""
        before = set(self._results)
        self._apply_tick_faults()
        self._expire_deadlines()
        self._admit(on_token)
        if self._paged:
            self._chunk_tick(on_token)
        active = [i for i, s in enumerate(self._slots) if s.active]
        if active:
            if self._speculative_ok(active):
                self._speculative_tick(active, on_token)
            else:
                self._decode_tick(active, on_token)
        self._nan_tick = ()
        self._steps += 1
        return [self._results[rid]
                for rid in sorted(set(self._results) - before)]

    def run(self, on_token=None) -> list[RequestResult]:
        """Drive :meth:`step` until the queue and slot table drain, then
        deliver (and DROP — the engine persists across calls, so results
        are handed over exactly once rather than retained forever) every
        undelivered finished result, ordered by request id."""
        while self.has_work():
            self.step(on_token)
        return self.pop_results()

    def results(self) -> list[RequestResult]:
        """Finished-but-undelivered results, oldest request first (kept
        until :meth:`run`/:meth:`pop_results` hands them over — a manual
        :meth:`step` driver should pop periodically, or the retained
        history grows with every request served)."""
        return [self._results[rid] for rid in sorted(self._results)]

    def pop_results(self) -> list[RequestResult]:
        """:meth:`results`, handing ownership over: the returned results
        are removed from the engine's retained set."""
        out = self.results()
        self._results.clear()
        return out
