"""End-to-end training driver.

Wires every substrate layer together: configs → model init → DoRA adapter
init → sharding (when a mesh is requested) → synthetic data pipeline with
prefetch → AdamW over adapters → checkpoint/auto-resume → preemption +
heartbeat fault-tolerance hooks.

Runs for real on CPU with a smoke config::

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 50 --batch 4 --seq 64

and is the same driver a TPU deployment launches per host (the mesh comes
from ``make_production_mesh``; per-host data sharding from
``jax.process_index()``).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointConfig, Heartbeat,
                              PreemptionHandler, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_config
from repro.core import DoRAConfig
from repro.data import DataConfig, make_train_iterator, prefetch
from repro.launch.steps import StepConfig, make_train_step
from repro.models import init_adapters, init_params
from repro.obs import monotonic
from repro.optim import OptimizerConfig, adamw_init


def build_state(mcfg, dcfg, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = init_params(key, mcfg)
    adapters = init_adapters(jax.random.fold_in(key, 1), mcfg, params, dcfg)
    opt_state = adamw_init(adapters)
    return params, adapters, opt_state


def train(args) -> dict:
    mcfg = get_config(args.arch, smoke=args.smoke)
    dcfg = DoRAConfig(rank=args.rank, alpha=args.alpha,
                      mode=args.dora_mode, norm_impl=args.norm_impl)
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=args.warmup,
                           total_steps=args.steps,
                           clip_norm=args.clip_norm)
    scfg = StepConfig(dora=dcfg, optim=ocfg,
                      loss_tokens=args.loss_tokens,
                      grad_accum=args.grad_accum)

    params, adapters, opt_state = build_state(mcfg, dcfg, args.seed)

    ckpt = CheckpointConfig(args.ckpt_dir, every_steps=args.ckpt_every,
                            keep=args.ckpt_keep)
    start_step = 0
    if args.resume:
        restored, step = restore_checkpoint(
            ckpt, {"adapters": adapters, "opt": opt_state})
        if restored is not None:
            adapters, opt_state = restored["adapters"], restored["opt"]
            start_step = step
            print(f"resumed from step {start_step}")

    dcfg_data = DataConfig(vocab_size=mcfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=args.data_seed)
    it = prefetch(make_train_iterator(
        dcfg_data, start_step=start_step,
        process_index=jax.process_index(),
        process_count=jax.process_count()), depth=2)

    step_fn = jax.jit(make_train_step(mcfg, scfg, None,
                                      batch=args.batch, seq=args.seq),
                      donate_argnums=(1, 2))

    hb = Heartbeat(args.heartbeat_dir, jax.process_index()) \
        if args.heartbeat_dir else None
    losses = []
    t_start = monotonic()
    with PreemptionHandler() as pre:
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            adapters, opt_state, metrics = step_fn(
                params, adapters, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if hb:
                hb.beat(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
            want_ckpt = ((step + 1) % args.ckpt_every == 0
                         or step == args.steps - 1)
            if pre.preempted:
                print(f"preemption signal at step {step}: saving + exiting")
                want_ckpt = True
            if want_ckpt and args.ckpt_dir:
                save_checkpoint(
                    ckpt, step + 1,
                    {"adapters": adapters, "opt": opt_state},
                    process_index=jax.process_index(),
                    process_count=jax.process_count(),
                    mesh_meta={"model": 1})
            if pre.preempted:
                break
    dt = monotonic() - t_start
    steps_done = len(losses)
    print(f"done: {steps_done} steps in {dt:.1f}s "
          f"({dt / max(steps_done, 1):.2f} s/step); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "steps": steps_done, "wall_s": dt}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--alpha", type=float, default=32.0)
    ap.add_argument("--dora-mode", default="auto",
                    choices=["auto", "eager", "fused", "interpret"])
    ap.add_argument("--norm-impl", default="factored",
                    choices=["factored", "dense_ba", "peft_eye"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--loss-tokens", type=int, default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=1234)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-keep", type=int, default=3)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--heartbeat-dir", default="")
    ap.add_argument("--log-every", type=int, default=10)
    train(ap.parse_args())


if __name__ == "__main__":
    main()
