"""Step functions (train / prefill / decode) + dry-run input specs.

``make_train_step`` / ``make_prefill_step`` / ``make_decode_step`` build
jit-able pure functions over (params, adapters, ...) with the sharding
rules from :mod:`repro.launch.sharding` attached via in/out_shardings.
``input_specs`` produces ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation) — the dry-run lowers
against these.

Under pjit, the gradient all-reduce over (pod, data), the factored-norm
partial-sum psums over the weight shard axis, and the sequence-parallel
collectives are all derived by the SPMD partitioner from the sharding
rules — the dry-run's compiled HLO is where we verify they are the ones
we designed for (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, SMOKE_SHAPES, get_config
from repro.compat import tree as ctree
from repro.core import DoRAConfig
from repro.models import (adapter_shapes, cache_shapes, forward,
                          param_shapes)
from repro.models.config import ModelConfig
from repro.launch import sharding as S
from repro.optim import OptimizerConfig, adamw_update

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class StepConfig:
    """Everything the step builders need beyond the model config."""
    dora: DoRAConfig = DoRAConfig(rank=384, alpha=192.0, mode="auto")
    optim: OptimizerConfig = OptimizerConfig()
    # paper §5.1: partial-sequence loss (1024 tokens) matches production
    # RLHF memory profiles and avoids the full-seq logit spike.
    loss_tokens: int | None = None
    grad_accum: int = 1


# ---------------------------------------------------------------------------
# Loss.
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    """Mean token NLL; fp32 logsumexp (V may be sharded — SPMD reduces)."""
    logits32 = logits.astype(_F32)
    lse = jax.scipy.special.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# Steps.
# ---------------------------------------------------------------------------

def make_train_step(mcfg: ModelConfig, scfg: StepConfig, mesh=None, *,
                    batch: int, seq: int):
    """(params, adapters, opt_state, batch) -> (adapters', opt_state',
    metrics). Frozen base params receive no gradient and no optimizer
    state."""
    constraint = (S.make_boundary_constraint(
        mesh, batch=batch, seq=seq,
        b_dout_axes=S.row_parallel_b_axes(mcfg, mesh))
        if mesh is not None else None)
    lt = scfg.loss_tokens

    def loss_fn(adapters, params, tokens_or_embeds, labels, is_embeds):
        kw = ({"embeds": tokens_or_embeds} if is_embeds
              else {"tokens": tokens_or_embeds})
        logits, _, aux = forward(
            mcfg, params, adapters, scfg.dora, training=True,
            boundary_constraint=constraint, loss_slice=lt, **kw)
        lbl = labels if lt is None or lt >= labels.shape[1] \
            else labels[:, -lt:]
        return cross_entropy(logits, lbl) + aux

    def train_step(params, adapters, opt_state, batch):
        is_embeds = "embeds" in batch
        x = batch["embeds"] if is_embeds else batch["tokens"]
        labels = batch["labels"]
        ga = scfg.grad_accum
        if ga <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                adapters, params, x, labels, is_embeds)
        else:
            # Gradient accumulation: scan over microbatches along batch
            # (paper model benches use ga=8). Keeps activation memory at
            # 1/ga with identical math.
            b = x.shape[0]
            assert b % ga == 0, (b, ga)
            xm = x.reshape((ga, b // ga) + x.shape[1:])
            lm_ = labels.reshape((ga, b // ga) + labels.shape[1:])

            def micro(carry, inp):
                xi, li = inp
                l, g = jax.value_and_grad(loss_fn)(
                    adapters, params, xi, li, is_embeds)
                loss_acc, g_acc = carry
                return (loss_acc + l,
                        ctree.map(jnp.add, g_acc, g)), None

            zeros = ctree.map(lambda a: jnp.zeros(a.shape, _F32),
                                 adapters)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), _F32), zeros), (xm, lm_))
            loss = loss / ga
            grads = ctree.map(lambda g: g / ga, grads)

        new_adapters, new_opt, stats = adamw_update(
            grads, opt_state, adapters, scfg.optim)
        metrics = {"loss": loss, **stats}
        return new_adapters, new_opt, metrics

    return train_step


def make_prefill_step(mcfg: ModelConfig, scfg: StepConfig, mesh=None, *,
                      batch: int, seq: int, padded: bool = False,
                      tenant_groups=None):
    """(params, adapters, batch) -> (last_logits [B, V], cache).

    Processes the full prompt and materializes the KV/SSM cache sized to
    ``seq`` (the serving runtime hands it to the decode step).

    ``padded=True``: shape-bucketed serving — the prompt arrives
    right-padded to ``seq`` and ``batch["prompt_len"]`` carries the TRUE
    prompt length P as an int32 scalar. P is traced, so ONE compiled
    prefill covers every P ≤ seq. The returned logits are gathered at
    position P-1 (the full-vocab head runs on exactly that one row, not
    the padded tail) and the cache length is REWOUND to P so the first
    decode token overwrites the first padded row — without the rewind,
    decode appends after the pad garbage. Only valid for attention caches
    (a rewound "len" masks the stale K/V rows via causality; an SSM state
    has already integrated the pad tokens and cannot rewind).

    ``tenant_groups``: multi-tenant serving — static (start, size) row
    blocks grouping the batch by adapter; the adapter tree must be the
    stacked folded serving state (see ``repro.launch.serve``)."""
    constraint = (S.make_boundary_constraint(
        mesh, batch=batch, seq=seq,
        b_dout_axes=S.row_parallel_b_axes(mcfg, mesh))
        if mesh is not None else None)
    if padded and any(k != "attn" for k in mcfg.layer_kinds()):
        raise ValueError(
            "padded prefill requires attention-only caches: SSM layer "
            "states integrate the padded tokens and cannot be rewound "
            f"(arch {mcfg.name!r} has {mcfg.layer_kinds()})")

    def prefill_step(params, adapters, batch_in):
        is_embeds = "embeds" in batch_in
        kw = ({"embeds": batch_in["embeds"]} if is_embeds
              else {"tokens": batch_in["tokens"]})
        from repro.models import init_cache
        cache = init_cache(mcfg, batch, seq)
        if padded:
            p_len = jnp.asarray(batch_in["prompt_len"], jnp.int32)
            kw["gather_position"] = p_len - 1
        else:
            kw["loss_slice"] = 1
        logits, new_cache, _ = forward(
            mcfg, params, adapters, scfg.dora, cache=cache, training=False,
            boundary_constraint=constraint, tenant_groups=tenant_groups,
            **kw)
        if padded and new_cache is not None:
            new_cache = dict(new_cache)
            new_cache["len"] = p_len.astype(new_cache["len"].dtype)
        return logits[:, -1], new_cache

    return prefill_step


def make_prefill_into_slot_step(mcfg: ModelConfig, scfg: StepConfig,
                                mesh=None, *, seq: int):
    """(params, adapters, cache, batch_in) -> (logits [1, V], cache').

    Continuous-batching admission (see :mod:`repro.launch.engine`):
    prefill ONE new request into row ``batch_in["slot"]`` of a RUNNING
    batch's cache while every other row's state is untouched. ``cache``
    must be a per-row-length cache (``init_cache(..., row_lens=True)``,
    ``"len"`` a [B] vector). ``batch_in``: the prompt right-padded to
    ``seq`` as ``"tokens"`` [1, seq], the true length as ``"prompt_len"``
    (int32 scalar) and the target row as ``"slot"`` (int32 scalar). Slot
    AND prompt_len are traced, so ONE compiled step serves every slot
    index and every prompt length — a request joining mid-decode never
    recompiles.

    The row itself runs the SAME padded batch=1 prefill the static path
    uses (``make_prefill_step(batch=1, padded=True)``), so the inserted
    K/V rows and the first-token logits are bitwise the ones a static
    serve of that request would produce; the row's cache length lands at
    the true P (``cache["len"][slot] = P``), so the first decoded token
    writes at position P.

    This step is ALSO the engine's preempt/resume primitive (PR 7): a
    preempted request re-queues with prompt' = prompt + generated-so-far,
    and re-admission simply prefills prompt' into whatever row frees up —
    no snapshotting of K/V, no extra executable. The resumed stream is
    bitwise the uninterrupted one because this prefill's final-position
    logits equal the plain decode logits at that frontier (same dense
    per-row-frontier attention), and prompt' + remaining budget always
    fits ``seq`` (the displaced budget shrinks exactly as the prompt
    grows).

    Attention-only archs: an SSM state integrates every processed token
    and cannot be rewound to a slot's true prompt length, so
    prefill-into-slot is ill-defined for Mamba/hybrid stacks (raises at
    build time — the engine surfaces this as its admission contract)."""
    kinds = mcfg.layer_kinds()
    if any(k != "attn" for k in kinds):
        raise NotImplementedError(
            f"continuous batching requires attention-only caches: SSM "
            f"states integrate every processed token and cannot rewind "
            f"to a slot's true prompt length, so prefill-into-slot is "
            f"ill-defined (arch {mcfg.name!r} has layer kinds {kinds})")
    row_prefill = make_prefill_step(mcfg, scfg, mesh, batch=1, seq=seq,
                                    padded=True)

    def prefill_into_slot(params, adapters, cache, batch_in):
        logits, row_cache = row_prefill(
            params, adapters, {"tokens": batch_in["tokens"],
                               "prompt_len": batch_in["prompt_len"]})
        slot = jnp.asarray(batch_in["slot"], jnp.int32)
        zero = jnp.zeros((), jnp.int32)

        def insert(big, row):
            # big [n_scan, B, T, H, hd]; row [n_scan, 1, T, H, hd] — the
            # single-row prefill result dropped into the slot's row.
            start = (zero, slot) + (zero,) * (big.ndim - 2)
            return jax.lax.dynamic_update_slice(
                big, row.astype(big.dtype), start)

        new_stack = ctree.map(insert, cache["stack"], row_cache["stack"])
        new_len = cache["len"].at[slot].set(
            jnp.asarray(batch_in["prompt_len"], cache["len"].dtype))
        return logits, {"stack": new_stack, "len": new_len}

    return prefill_into_slot


def make_prefill_chunk_step(mcfg: ModelConfig, scfg: StepConfig,
                            mesh=None, *, chunk: int):
    """(params, adapters, cache, batch_in) -> (logits [1, V], cache').

    CHUNKED admission for the PAGED continuous-batching engine (see
    :mod:`repro.launch.engine`): process ``chunk`` prompt tokens of one
    request into its slot's pages of a RUNNING batch's paged cache, so a
    long prompt is admitted incrementally — interleaved with decode ticks
    — instead of stalling the batch behind one monolithic prefill.

    ``cache`` is the engine's PAGED cache (block pools + ``"pages"``
    table + per-row ``"len"``). ``batch_in``: ``"tokens"`` [1, chunk]
    (the chunk's tokens, right-padded), ``"slot"`` / ``"start"`` /
    ``"chunk_len"`` int32 scalars — ALL traced, so ONE compiled step
    serves every slot, every chunk boundary and every ragged tail: the
    compile surface stays one (chunk-prefill, decode) pair per
    (slots, chunk, signature).

    ``start`` is the HOST's admission frontier for the slot, not the
    device ``len[slot]`` — decode ticks advance the whole [B] length
    vector (admitting rows included), so the device value drifts by one
    per interleaved tick; the chunk must write at the true prompt offset.
    The step runs the forward over a batch-1 VIEW (shared pools, the
    slot's page row, ``len=[start]``), then writes ``len[slot] =
    start + chunk_len`` back into the full vector. The final chunk's
    logits (gathered at ``chunk_len - 1``) are the first-token logits —
    bitwise the padded whole-prompt prefill's, because every q row of a
    causal forward depends only on positions ≤ its own, the gathered
    paged view has the SAME [max_len] reduction extent as the
    rectangular buffer, and masked/unallocated positions contribute
    exactly-0.0 softmax weight in both.

    Attention-only archs, like every continuous-batching step (SSM
    states cannot rewind / re-view)."""
    kinds = mcfg.layer_kinds()
    if any(k != "attn" for k in kinds):
        raise NotImplementedError(
            f"chunked prefill requires attention-only caches: SSM states "
            f"integrate every processed token and cannot be re-viewed at "
            f"a chunk boundary (arch {mcfg.name!r} has layer kinds "
            f"{kinds})")
    constraint = (S.make_boundary_constraint(
        mesh, batch=1, seq=chunk,
        b_dout_axes=S.row_parallel_b_axes(mcfg, mesh))
        if mesh is not None else None)

    def prefill_chunk(params, adapters, cache, batch_in):
        slot = jnp.asarray(batch_in["slot"], jnp.int32)
        start = jnp.asarray(batch_in["start"], jnp.int32)
        c_len = jnp.asarray(batch_in["chunk_len"], jnp.int32)
        view = {
            "stack": cache["stack"],              # shared block pools
            "len": jnp.reshape(start, (1,)),      # host frontier, not
                                                  # the drifted device len
            "pages": jax.lax.dynamic_slice_in_dim(cache["pages"], slot, 1,
                                                  axis=0),
        }
        logits, new_view, _ = forward(
            mcfg, params, adapters, scfg.dora, cache=view, training=False,
            boundary_constraint=constraint, tokens=batch_in["tokens"],
            gather_position=c_len - 1)
        new_len = cache["len"].at[slot].set(
            (start + c_len).astype(cache["len"].dtype))
        return logits[:, -1], {"stack": new_view["stack"],
                               "len": new_len,
                               "pages": cache["pages"]}

    return prefill_chunk


def make_precompute_step(mcfg: ModelConfig, scfg: StepConfig, mesh=None, *,
                         fold_gsb: bool = False):
    """(params, adapters) -> serving adapter tree (jit-able).

    Runs :func:`repro.core.precompute_adapter_state` once per frozen
    adapter set: every adapter leaf gains a cached ``"g"`` (and ``"gsB"``
    when folded) so the prefill/decode steps built below do ZERO
    factored-norm work per call — the whole O(d_out·d_in) norm moves out
    of the token loop. The act_dtype is pinned to the model dtype so the
    cached g is bitwise-identical to the one the uncached forward would
    compute. Invalidation: any training step on the adapters makes the
    returned tree stale; rebuild it (cheap — one norm per adapted layer)
    before serving the updated weights.

    ``mesh``: when set, the cached leaves are pinned to the serving
    shardings (``sharding.adapter_sharding(serving=True)``): ``g``
    congruent with ``m``, and the folded ``gsB`` row-sharded exactly like
    the raw ``B`` — so the broadcast-free decode compose consumes a
    correctly-sharded cached B instead of all-gathering it per token."""
    from repro.core import precompute_adapter_state

    serving_sh = (S.adapter_sharding(mcfg, scfg.dora, mesh, serving=True)
                  if mesh is not None else None)

    def constrain_tree(vals, sh):
        if isinstance(vals, dict):
            return {k: (constrain_tree(v, sh[k]) if k in sh else v)
                    for k, v in vals.items()}
        return jax.lax.with_sharding_constraint(vals, sh)

    def precompute_step(params, adapters):
        tree = precompute_adapter_state(params, adapters, scfg.dora,
                                        act_dtype=mcfg.dtype,
                                        fold_gsb=fold_gsb)
        if serving_sh is not None:
            tree = constrain_tree(tree, serving_sh)
        return tree

    return precompute_step


def make_decode_step(mcfg: ModelConfig, scfg: StepConfig, mesh=None, *,
                     batch: int, tenant_groups=None,
                     dynamic_groups: bool = False):
    """(params, adapters, cache, tokens [B,1]) -> (logits [B,V], cache').

    One new token against a pre-filled cache (the ``decode_*`` /
    ``long_*`` shapes lower THIS, not train_step). The cache's ``"len"``
    is either the scalar of the static serve loop or the [B] per-row
    length vector of the continuous-batching engine — the SAME builder
    compiles both (shape-keyed traces); with per-row lengths every slot
    attends/writes at its own position.

    ``tenant_groups``: multi-tenant serving — the decode batch's rows are
    grouped by adapter (static compile-time signature); the adapter tree
    must be the stacked folded serving state. The grouped step's jaxpr
    contains zero ``dora_wnorm``-tagged ops: a cache hit does no norm
    work (asserted in ``tests/test_serve_multitenant.py``).

    ``dynamic_groups``: fleet serving — each row's adapter is selected by
    the TRACED int32 per-row stack position ``batch_in["adapter_idx"]``
    ([B]) out of the K-stacked adapter tree, so tenant churn changes
    VALUES, never this step's compile signature: ONE decode executable
    serves every tenant mix (see ``repro.core.dora_linear_grouped``).
    Mutually exclusive with a static ``tenant_groups``."""
    if dynamic_groups and tenant_groups is not None:
        raise ValueError(
            "dynamic_groups=True takes the per-row adapter index from "
            "batch_in['adapter_idx']; a static tenant_groups signature "
            "cannot be given at the same time")

    def decode_step(params, adapters, cache, batch_in):
        is_embeds = "embeds" in batch_in
        kw = ({"embeds": batch_in["embeds"]} if is_embeds
              else {"tokens": batch_in["tokens"]})
        tg = (jnp.asarray(batch_in["adapter_idx"], jnp.int32)
              if dynamic_groups else tenant_groups)
        logits, new_cache, _ = forward(
            mcfg, params, adapters, scfg.dora, cache=cache,
            training=False, tenant_groups=tg, **kw)
        return logits[:, -1], new_cache

    return decode_step


def make_draft_step(mcfg: ModelConfig, scfg: StepConfig, mesh=None, *,
                    batch: int):
    """(params, cache, tokens [B,1]) -> (logits [B,V], cache').

    The speculative-draft step: one decode token through the BASE model
    only — the adapter tree is the empty dict, so every projection takes
    the ``maybe_dora`` base-matmul short-circuit. Zero ``dora_wnorm``
    work, zero gsB/grouped-adapter ops, and no adapter argument at all:
    one compiled executable serves every tenant mix (the draft is
    adapter-blind by design — the full grouped DoRA path only runs in the
    verify step). The cache contract is the decode step's: per-row
    ``"len"`` vector, each slot writes/attends at its own position.

    Draft K/V writes are base-path values at the drafted positions; the
    verify step re-writes those exact positions with full-path K/V, so
    nothing base-flavored survives into the committed cache (see
    ``launch/engine.py``)."""
    del mesh  # shardings are attached by the caller's jit, as for decode

    def draft_step(params, cache, batch_in):
        logits, new_cache, _ = forward(
            mcfg, params, {}, scfg.dora, cache=cache, training=False,
            tokens=batch_in["tokens"])
        return logits[:, -1], new_cache

    return draft_step


def make_verify_step(mcfg: ModelConfig, scfg: StepConfig, mesh=None, *,
                     batch: int, window: int, tenant_groups=None,
                     dynamic_groups: bool = False):
    """(params, adapters, cache, tokens [B,window]) ->
    (logits [B,window,V], cache').

    The speculative-verify step: score ``window`` = k+1 positions per row
    in ONE batched forward through the FULL grouped DoRA path — the same
    adapter compose (precomputed ``g``, folded ``gsB``, static tenant
    groups) the plain decode step runs, so greedy acceptance against
    these logits is bitwise the plain-decode token stream. Logits are
    returned for EVERY window position (no gather/loss_slice): position j
    scores the draft token at j+1 and supplies the correction token when
    the draft diverges.

    The cache write covers the whole window at each row's own frontier
    (per-row ``"len"`` + the per-row causal mask in
    ``models/layers.py``), overwriting the draft step's base-path K/V
    with full-path values. The ENGINE owns the rewind: it re-syncs
    ``"len"`` to each row's accepted frontier after this step (the step
    itself advances ``len`` by ``window`` like any forward).

    ``dynamic_groups``: as for :func:`make_decode_step` — per-row
    adapters from the traced ``batch_in["adapter_idx"]``, one verify
    executable per window across every tenant mix."""
    del mesh
    if dynamic_groups and tenant_groups is not None:
        raise ValueError(
            "dynamic_groups=True takes the per-row adapter index from "
            "batch_in['adapter_idx']; a static tenant_groups signature "
            "cannot be given at the same time")

    def verify_step(params, adapters, cache, batch_in):
        tg = (jnp.asarray(batch_in["adapter_idx"], jnp.int32)
              if dynamic_groups else tenant_groups)
        logits, new_cache, _ = forward(
            mcfg, params, adapters, scfg.dora, cache=cache,
            training=False, tenant_groups=tg,
            tokens=batch_in["tokens"])
        return logits, new_cache

    return verify_step


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStructs; nothing allocated).
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(mcfg: ModelConfig, *, batch: int, seq: int, kind: str):
    """Model-input ShapeDtypeStructs for one (arch, shape) cell.

    ``[vlm]``/``[audio]`` archs take precomputed patch/frame embeddings
    from the (stubbed) modality frontend; LM archs take token ids."""
    if kind == "decode":
        seq_in = 1
    else:
        seq_in = seq
    if mcfg.frontend:
        b = {"embeds": _sds((batch, seq_in, mcfg.d_model), mcfg.dtype)}
    else:
        b = {"tokens": _sds((batch, seq_in), jnp.int32)}
    if kind == "train":
        b["labels"] = _sds((batch, seq), jnp.int32)
    return b


def cell_specs(arch: str, shape_name: str, mesh, *, smoke: bool = False,
               scfg: StepConfig | None = None):
    """Everything the dry-run needs for one (arch × shape) cell:
    (step_fn, example_args, in_shardings, out_shardings placeholders).

    Returns a dict with keys: step, args, in_shardings, kind, mcfg.
    """
    mcfg = get_config(arch, smoke=smoke)
    shape = (SMOKE_SHAPES if smoke else SHAPES)[shape_name]
    scfg = scfg or StepConfig()
    B, T = shape.global_batch, shape.seq_len
    kind = shape.kind

    # NOTE (H2.4, refuted): chunk-local MoE dispatch (moe_seq_chunks=tp)
    # was measured to INCREASE collective time under GSPMD — the merged
    # (data x model) token dim is not localized by the partitioner and
    # the capacity buffers reshard anyway (EXPERIMENTS.md §Perf cell 2).
    # The mechanism stays available on ModelConfig for the shard_map
    # expert-parallel path; default off.

    p_sh = S.param_sharding(mcfg, mesh)
    a_sh = S.adapter_sharding(mcfg, scfg.dora, mesh)
    p_sds = param_shapes(mcfg)
    a_sds = adapter_shapes(mcfg, scfg.dora)
    b_sds = batch_specs(mcfg, batch=B, seq=T, kind=kind)
    b_sh = {k: (S.batch_sharding(mesh, batch=B) if v.ndim == 2
                else NamedSharding(mesh, S.activation_spec(
                    mesh, batch=B, seq=v.shape[1])))
            for k, v in b_sds.items()}

    if kind == "train":
        opt_sds = {
            "mu": ctree.map(
                lambda s: _sds(s.shape, _F32), a_sds),
            "nu": ctree.map(
                lambda s: _sds(s.shape, _F32), a_sds),
            "count": _sds((), jnp.int32),
        }
        opt_sh = S.opt_state_sharding(a_sh, mesh, a_sds)
        step = make_train_step(mcfg, scfg, mesh, batch=B, seq=T)
        args = (p_sds, a_sds, opt_sds, b_sds)
        in_sh = (p_sh, a_sh, opt_sh, b_sh)
        out_sh = (a_sh, opt_sh, None)
        donate = (1, 2)   # adapters, opt_state update in place
    elif kind == "prefill":
        step = make_prefill_step(mcfg, scfg, mesh, batch=B, seq=T)
        args = (p_sds, a_sds, b_sds)
        in_sh = (p_sh, a_sh, b_sh)
        c_sh = S.cache_sharding(mcfg, mesh, batch=B)
        out_sh = (None, c_sh)
        donate = ()
    else:  # decode
        c_sds = cache_shapes(mcfg, B, T)
        # the pre-filled cache: len == T - 1, one slot free for the token
        c_sh = S.cache_sharding(mcfg, mesh, batch=B)
        step = make_decode_step(mcfg, scfg, mesh, batch=B)
        args = (p_sds, a_sds, c_sds, b_sds)
        in_sh = (p_sh, a_sh, c_sh, b_sh)
        out_sh = (None, c_sh)
        donate = (2,)     # cache updated in place (as the serve loop does)
    return {"step": step, "args": args, "in_shardings": in_sh,
            "out_shardings": out_sh, "kind": kind, "mcfg": mcfg,
            "shape": shape, "donate": donate}
