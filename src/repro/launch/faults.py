"""Deterministic fault injection for the serving engine.

A :class:`FaultPlan` is a seeded, step-keyed schedule of faults that the
``DecodeEngine`` consults at the top of every ``step()``.  It is the serving
counterpart of ``repro.checkpoint.fault`` (PreemptionHandler / Heartbeat for
training jobs): instead of reacting to host signals, it *manufactures* the
hostile conditions — poisoned logits, cache evictions, stale adapter handles,
stalled ticks — so every containment path can be driven deterministically in
tests and smokes.

Fault kinds
-----------
``nan``
    Overwrite the sampled logits row for ``slot`` at ``step`` with NaN on the
    host mirror (after the device fetch, before sampling).  Exercises per-row
    quarantine: the poisoned row retires with ``finish_reason="error_numeric"``
    while co-resident rows stay bitwise identical to a fault-free run.
``evict``
    Invalidate every resident entry of the engine's ``AdapterStateCache`` at
    ``step``, forcing re-precompute (and, with ``allow_miss=False``, admission
    errors) on the next lookup.
``stale``
    The next admission at or after ``step`` is handed a handle whose version
    is behind the registry — the genuine ``AdapterCacheMiss`` stale path, not
    a simulation of it.
``slow``
    Sleep ``duration_s`` (capped) at the top of ``step`` — a straggler tick
    for deadline/timeout tests.

The module is numpy-only (no jax import) so plans can be built and inspected
anywhere, including in benchmark mirrors and docs blocks.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

__all__ = ["FaultEvent", "FaultPlan", "FAULT_KINDS"]

FAULT_KINDS = ("nan", "evict", "stale", "slow")

# Safety cap on injected straggler sleeps so a typo'd plan can't wedge CI.
MAX_SLOW_S = 0.25


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``step`` is the engine tick (``DecodeEngine._steps``) at which the fault
    fires.  ``slot`` targets a physical slot index for ``nan`` (``None`` means
    every active slot); it is ignored for the other kinds.  ``duration_s``
    only applies to ``slow``.
    """

    kind: str
    step: int
    slot: int | None = None
    duration_s: float = 0.05

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; want one of {FAULT_KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")


class FaultPlan:
    """An immutable, step-indexed schedule of :class:`FaultEvent`.

    Build one from explicit events, from the CLI mini-language via
    :meth:`parse` (``"nan@3:1,evict@5,stale@2,slow@4"``), or from a seed via
    :meth:`random`.  The engine consults :meth:`nan_slots` /
    :meth:`evict_at` / :meth:`stale_at` / :meth:`slow_at` once per tick.
    """

    def __init__(self, events=()):
        evs = tuple(sorted(events, key=lambda e: (e.step, FAULT_KINDS.index(e.kind), -1 if e.slot is None else e.slot)))
        self.events = evs
        by_step = defaultdict(list)
        for e in evs:
            by_step[e.step].append(e)
        self._by_step = dict(by_step)

    def __len__(self):
        return len(self.events)

    def __repr__(self):
        return f"FaultPlan({list(self.events)!r})"

    def __eq__(self, other):
        return isinstance(other, FaultPlan) and self.events == other.events

    def at(self, step):
        """All events scheduled for ``step`` (possibly empty)."""
        return tuple(self._by_step.get(step, ()))

    def nan_slots(self, step):
        """Slot indices poisoned at ``step``; ``None`` entries mean all active."""
        return tuple(e.slot for e in self.at(step) if e.kind == "nan")

    def evict_at(self, step):
        return any(e.kind == "evict" for e in self.at(step))

    def stale_at(self, step):
        return any(e.kind == "stale" for e in self.at(step))

    def slow_at(self, step):
        """Total (capped) injected sleep seconds for ``step``."""
        total = sum(e.duration_s for e in self.at(step) if e.kind == "slow")
        return min(total, MAX_SLOW_S)

    @property
    def last_step(self):
        return max((e.step for e in self.events), default=-1)

    @classmethod
    def parse(cls, spec):
        """Parse the CLI mini-language.

        ``spec`` is a comma-separated list of ``kind@step`` items; ``nan``
        accepts an optional ``:slot`` suffix (``nan@3:1`` poisons slot 1 at
        tick 3, ``nan@3`` poisons every active slot).  Whitespace is ignored.
        An empty/None spec yields an empty plan.
        """
        events = []
        for item in (spec or "").split(","):
            item = item.strip()
            if not item:
                continue
            try:
                kind, rest = item.split("@", 1)
            except ValueError:
                raise ValueError(f"bad fault item {item!r}: want kind@step[:slot]") from None
            kind = kind.strip()
            slot = None
            if ":" in rest:
                step_s, slot_s = rest.split(":", 1)
                slot = int(slot_s)
            else:
                step_s = rest
            events.append(FaultEvent(kind=kind, step=int(step_s), slot=slot))
        return cls(events)

    @classmethod
    def random(cls, seed, *, steps, slots, n_nan=1, n_evict=0, n_stale=0, n_slow=0):
        """A seeded random plan over ``steps`` ticks and ``slots`` slots.

        Deterministic: the same arguments always yield the same plan (used by
        the hypothesis property tests to pair a faulty run with its oracle).
        """
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_nan):
            events.append(
                FaultEvent("nan", step=int(rng.integers(0, steps)), slot=int(rng.integers(0, slots)))
            )
        for _ in range(n_evict):
            events.append(FaultEvent("evict", step=int(rng.integers(0, steps))))
        for _ in range(n_stale):
            events.append(FaultEvent("stale", step=int(rng.integers(0, steps))))
        for _ in range(n_slow):
            events.append(
                FaultEvent("slow", step=int(rng.integers(0, steps)), duration_s=float(rng.uniform(0.001, 0.01)))
            )
        return cls(events)
