"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the first two lines (jax locks device count on first init):
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json
import traceback

import jax

from repro.compat import xla as cxla
from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import StepConfig, cell_specs
from repro.obs import monotonic
from repro.roofline import HW, analyze_hlo_text, model_flops, roofline_terms


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str | None = None, scfg: StepConfig | None = None,
             verbose: bool = True, keep_hlo: bool = False) -> dict:
    """Lower + compile one cell on the production mesh; return the record
    (memory analysis, cost analysis, roofline terms)."""
    t0 = monotonic()
    mesh = make_production_mesh(multi_pod=multi_pod)
    scfg = scfg or StepConfig()
    cell = cell_specs(arch, shape_name, mesh, scfg=scfg)
    with mesh:
        jitted = jax.jit(cell["step"],
                         in_shardings=cell["in_shardings"],
                         out_shardings=cell["out_shardings"],
                         donate_argnums=cell["donate"])
        lowered = jitted.lower(*cell["args"])
        compiled = lowered.compile()
    t_compile = monotonic() - t0

    mem = compiled.memory_analysis()
    peak_bytes = cxla.peak_memory_bytes(compiled)
    cost = cxla.cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    ana = analyze_hlo_text(hlo)
    hw = HW()
    terms = roofline_terms(ana, hw)

    mcfg = cell["mcfg"]
    spec = cell["shape"]
    chips = mesh.devices.size
    tokens = spec.global_batch * (1 if spec.kind == "decode"
                                  else spec.seq_len)
    mf = model_flops(mcfg, tokens=tokens,
                     kind="train" if spec.kind == "train" else "serve")
    mf_per_chip = mf / chips

    record = {
        "arch": arch, "shape": shape_name, "kind": spec.kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "compile_s": round(t_compile, 1),
        "memory": {
            "peak_bytes": peak_bytes,
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            # donated args alias outputs — they are not double-counted
            "fits_16g": bool(peak_bytes
                             + mem.argument_size_in_bytes
                             - mem.alias_size_in_bytes < hw.hbm_bytes),
        },
        "xla_cost": {"flops": cost.get("flops"),
                     "bytes": cost.get("bytes accessed")},
        "hlo": {
            "flops_per_chip": ana.flops,
            "hbm_bytes_per_chip": ana.hbm_bytes,
            "link_bytes_per_chip": ana.link_bytes,
            "by_collective": ana.by_collective,
        },
        "roofline": terms,
        "model_flops_per_chip": mf_per_chip,
        "useful_fraction": (mf_per_chip / ana.flops) if ana.flops else None,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{record['mesh']}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1, default=float)
        if keep_hlo:
            with open(os.path.join(out_dir, tag + ".hlo"), "w") as f:
                f.write(hlo)
    if verbose:
        gib = 1 << 30
        print(f"[{record['mesh']}] {arch} x {shape_name}: compile "
              f"{t_compile:.0f}s | peak {record['memory']['peak_bytes']/gib:.2f}"
              f" GiB (args {record['memory']['argument_bytes']/gib:.2f}) | "
              f"compute {terms['compute_s']*1e3:.2f} ms, memory "
              f"{terms['memory_s']*1e3:.2f} ms, collective "
              f"{terms['collective_s']*1e3:.2f} ms -> {terms['dominant']}"
              f" | useful {record['useful_fraction'] and round(record['useful_fraction'], 3)}",
              flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        print(f"  cost_analysis: flops={cost.get('flops'):.3e} "
              f"bytes={cost.get('bytes accessed'):.3e}", flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None,
                    help="one shape name (default: all applicable)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--loss-tokens", type=int, default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--rank", type=int, default=384)
    ap.add_argument("--norm-impl", default="factored",
                    choices=["factored", "dense_ba", "peft_eye"])
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.core import DoRAConfig
    scfg = StepConfig(
        dora=DoRAConfig(rank=args.rank, alpha=args.rank / 2.0,
                        norm_impl=args.norm_impl, mode="auto"),
        loss_tokens=args.loss_tokens, grad_accum=args.grad_accum)

    archs = [args.arch] if args.arch else ARCH_IDS
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    n_ok = 0
    for arch in archs:
        mcfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else applicable_shapes(mcfg))
        for shape_name in shapes:
            for mp in meshes:
                tag = (f"{arch}_{shape_name}_"
                       f"{'2x16x16' if mp else '16x16'}")
                if args.skip_existing and os.path.exists(
                        os.path.join(args.out_dir, tag + ".json")):
                    print(f"skip {tag} (exists)", flush=True)
                    n_ok += 1
                    continue
                try:
                    run_cell(arch, shape_name, multi_pod=mp,
                             out_dir=args.out_dir, scfg=scfg,
                             keep_hlo=args.keep_hlo)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    failures.append((tag, repr(e)))
    print(f"\n=== dry-run: {n_ok} ok, {len(failures)} failed ===")
    for tag, err in failures:
        print(f"  FAIL {tag}: {err[:200]}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
