"""Sharding rules for the production mesh, with divisibility fallback.

Strategy (DESIGN.md §5):

  - **Base weights**: TP over ``model`` on the hidden/head dim + FSDP over
    (``pod``, ``data``) on the other dim. Frozen → no optimizer state, no
    gradient collectives for them.
  - **Adapters (A, B, m)**: sharded congruent with their base weight's TP
    axis only (B row-sharded when W is out-sharded; A col-sharded when W is
    in-sharded); never FSDP-sharded (they are small); DP-replicated so the
    adapter grad all-reduce is the only cross-pod gradient traffic.
  - **Batch**: sharded over (``pod``, ``data``).
  - **Activations**: sequence-sharded over ``model`` at scan-unit
    boundaries (sequence parallelism) so saved remat residuals scale with
    1/(dp·tp).
  - **Decode caches**: batch → (pod, data), KV seq → ``model``.

Every rule goes through :func:`pick_axes`, which drops to progressively
smaller axis sets (and finally replication) when a dim is not divisible —
e.g. qwen2-moe's 60 experts fall from (pod,data)=32 to pod=2; GQA KV
projections with kv_heads < 16 replicate over ``model`` (Megatron GQA
convention) instead of head-splitting. These fallbacks are exactly what the
multi-pod dry-run exercises.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import tree as ctree
from repro.core import DoRAConfig
from repro.core import sharding as _csh
from repro.models import lm as _lm
from repro.models.config import ModelConfig

# Role → ordered axis-set preferences (first divisible wins; tuples of mesh
# axes; missing axes are dropped for the single-pod mesh).
ROLE_PREFS: dict[str, tuple[tuple[str, ...], ...]] = {
    "tp": (("model",),),
    # Weight FSDP default is POD-ONLY (H1.3 — measured ~neutral on
    # collectives; the big ARs turned out to be TP row-parallel, not
    # FSDP): per-chip weights = total/(16 model x 2 pod), data axis
    # carries batch parallelism.
    "fsdp": (("pod",),),
    # Large-model FSDP (H3.5): models whose TP-sharded weights exceed the
    # per-chip budget (> ~6 GB at model=16) shard d_in over data too —
    # the 72B class cannot replicate weights within a pod.
    "fsdp_data": (("pod", "data"), ("data",), ("pod",)),
    # Weights with NO TP dim (e.g. llama4's 40 Q-heads on a 16-way model
    # axis) would otherwise replicate entirely; shard their d_out over
    # (pod, data) instead — GSPMD all-gathers the (small) weight before
    # the matmul, which costs ~weight-bytes/layer of link traffic instead
    # of activation-sized partial-sum all-reduces (H2.2).
    "fsdp_gather": (("pod", "data"), ("data",), ("pod",)),
    "expert": (("pod", "data"), ("data",), ("pod",)),
    "repl": (),
}

# Per-chip weight budget above which d_in FSDP extends to the data axis.
_FSDP_DATA_THRESHOLD_BYTES = 6 * 2**30

DP_AXES = ("pod", "data")


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def pick_axes(size: int, role: str, mesh, used: set[str]) -> Any:
    """First preference whose axes all exist, don't collide with ``used``,
    and whose product divides ``size``. None = replicate this dim."""
    for axes in ROLE_PREFS.get(role, ()):
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes or any(a in used for a in axes):
            continue
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        if size % prod == 0:
            used.update(axes)
            return axes if len(axes) > 1 else axes[0]
    return None


def spec_for(shape: tuple[int, ...], roles: tuple[str, ...], mesh) -> P:
    assert len(shape) == len(roles), (shape, roles)
    used: set[str] = set()
    return P(*(pick_axes(d, r, mesh, used) for d, r in zip(shape, roles)))


# ---------------------------------------------------------------------------
# Role tables. Keyed by leaf name; roles are per-dim, for the UNSTACKED
# shape (the stacked scan dim is prepended as "repl" automatically).
# ---------------------------------------------------------------------------

def _attn_tp_ok(mcfg: ModelConfig, mesh) -> tuple[bool, bool]:
    """(q sharded?, kv sharded?) — heads must split the model axis so the
    attention core stays head-aligned (Megatron GQA convention)."""
    tp = mesh.shape.get("model", 1)
    q_ok = mcfg.num_heads > 0 and mcfg.num_heads % tp == 0
    kv_ok = mcfg.num_kv_heads > 0 and mcfg.num_kv_heads % tp == 0
    return q_ok, kv_ok


def _fsdp_role(mcfg: ModelConfig, mesh) -> str:
    """'fsdp_data' for models whose TP-sharded weights exceed the
    per-chip budget; 'fsdp' (pod-only) otherwise."""
    tp = dict(mesh.shape).get("model", 1)
    per_chip = mcfg.count_params() * 2 / max(tp, 1)  # bf16
    return "fsdp_data" if per_chip > _FSDP_DATA_THRESHOLD_BYTES else "fsdp"


def leaf_roles(mcfg: ModelConfig, name: str, ndim: int, mesh) \
        -> tuple[str, ...]:
    """Per-dim sharding roles for a (non-stacked) param leaf."""
    q_ok, kv_ok = _attn_tp_ok(mcfg, mesh)
    fsdp = _fsdp_role(mcfg, mesh)
    table: dict[str, tuple[str, ...]] = {
        # embeddings / head: vocab TP (V-sharded logits → parallel CE loss),
        # FSDP on d_model.
        "embed": ("tp", fsdp),
        "head": ("tp", fsdp),
        # attention; non-TP-able projections get gather-FSDP on d_out
        "wq": ("tp" if q_ok else "fsdp_gather",
               fsdp if q_ok else "repl"),
        "wk": ("tp" if kv_ok else "fsdp_gather",
               fsdp if kv_ok else "repl"),
        "wv": ("tp" if kv_ok else "fsdp_gather",
               fsdp if kv_ok else "repl"),
        "wo": ((fsdp, "tp") if q_ok else ("fsdp_gather", "repl")),
        "wq_bias": ("tp" if q_ok else "repl",),
        "wk_bias": ("tp" if kv_ok else "repl",),
        "wv_bias": ("tp" if kv_ok else "repl",),
        # dense MLP
        "w_gate": ("tp", fsdp),
        "w_up": ("tp", fsdp),
        "w_down": (fsdp, "tp"),
        "w_up_bias": ("tp",),
        "w_down_bias": ("repl",),
        # MoE (stacked experts): expert dim FSDP-ish, hidden dim TP
        "router": ("repl", "repl"),
        "gate": ("expert", "tp", fsdp),
        "up": ("expert", "tp", fsdp),
        "down": ("expert", fsdp, "tp"),
        "shared_gate": ("repl", "repl"),
        # mamba: d_inner is the TP axis
        "in_proj": ("tp", fsdp),
        "out_proj": (fsdp, "tp"),
        "x_proj": ("repl", "tp"),
        "dt_proj": ("tp", "repl"),
        "dt_bias": ("tp",),
        "A_log": ("tp", "repl"),
        "skip_d": ("tp",),
        "conv_w": ("repl", "tp"),
        "conv_b": ("tp",),
    }
    if name in table:
        roles = table[name]
        assert len(roles) == ndim, (name, roles, ndim)
        return roles
    # norm scales, q_norm/k_norm, anything small: replicate.
    return ("repl",) * ndim


def param_sharding(mcfg: ModelConfig, mesh):
    """NamedSharding tree matching ``param_shapes(mcfg)``."""
    shapes = _lm.param_shapes(mcfg)

    def walk(tree, in_stack):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v, in_stack or k == "stack")
            else:
                nd = len(v.shape) - (1 if in_stack else 0)
                roles = leaf_roles(mcfg, k, nd, mesh)
                if in_stack:
                    roles = ("repl",) + roles
                out[k] = NamedSharding(mesh, spec_for(v.shape, roles, mesh))
        return out

    return walk(shapes, False)


def adapter_sharding(mcfg: ModelConfig, dcfg: DoRAConfig, mesh,
                     targets=_lm.DEFAULT_DORA_TARGETS, *,
                     serving: bool = False):
    """NamedSharding tree matching ``adapter_shapes``.

    Adapters shard CONGRUENT with their base weight on the matching dim
    (A col-sharded like W's d_in, B/m row-sharded like W's d_out); the
    rank dim replicates. At r = 384 on a 30-70B model the adapters are
    multi-GB, so — unlike low-rank LoRA — they cannot be DP-replicated on
    16 GB chips; the factored norm's distributed accumulation (DESIGN.md
    §5, the paper's FSDP2 future-work item) is what makes the d_in
    sharding of A/W work without an all-gather.

    ``serving=True`` additionally emits the frozen-adapter serving-state
    leaves written by ``precompute_adapter_state``: ``"g"`` [n_scan,
    d_out] shards like ``m`` (congruent with W's d_out), and ``"gsB"``
    [n_scan, d_out, r] shards like ``B`` — the folded cached B must land
    row-sharded exactly where the raw B lives, or the broadcast-free
    decode compose would all-gather it every token.
    """
    shapes = _lm.adapter_shapes(mcfg, dcfg, targets)

    def walk(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict) and "A" not in v:
                out[k] = walk(v)
                continue
            # v = {"A": sds, "B": sds, "m": sds}; base weight name = k
            roles = leaf_roles(mcfg, k, 2, mesh)
            out[k] = {
                # A [n_scan, r, d_in]: congruent with W's d_in
                "A": NamedSharding(mesh, spec_for(
                    v["A"].shape, ("repl", "repl", roles[-1]), mesh)),
                # B [n_scan, d_out, r]: congruent with W's d_out
                "B": NamedSharding(mesh, spec_for(
                    v["B"].shape, ("repl", roles[0], "repl"), mesh)),
                "m": NamedSharding(mesh, spec_for(
                    v["m"].shape, ("repl", roles[0]), mesh)),
            }
            if "base_sq" in v:  # H3.2 cached ||W||²_row: like m
                out[k]["base_sq"] = NamedSharding(mesh, spec_for(
                    v["base_sq"].shape, ("repl", roles[0]), mesh))
            if serving:
                out[k]["g"] = NamedSharding(mesh, spec_for(
                    v["m"].shape, ("repl", roles[0]), mesh))
                out[k]["gsB"] = NamedSharding(mesh, spec_for(
                    v["B"].shape, ("repl", roles[0], "repl"), mesh))
        return out

    return {"stack": walk(shapes["stack"])}


def fleet_stack_sharding(adapter_shardings, mesh):
    """Sharding tree for a device-resident FLEET STACK of serving states
    (dynamic grouped decode, see ``DecodeEngine(dynamic_grouping=True)``).

    The fleet stack holds K tenants' folded serving leaves stacked on a
    new axis 1 — ``[n_scan, K, ...]``, the ``stack_adapter_states(...,
    axis=1)`` layout — and is indexed per row by a TRACED int32 position,
    so the K axis must be REPLICATED: sharding it would turn the
    per-row ``take_along_axis`` gather into cross-device traffic on the
    decode hot path. Every other dim keeps the per-tenant serving
    sharding (A congruent with W's d_in, g/gsB row-sharded on d_out)
    unchanged — insert the tenant axis, touch nothing else."""
    def stackify(sh):
        spec = list(sh.spec)
        spec.insert(1, None)
        return NamedSharding(mesh, P(*spec))
    return ctree.map(stackify, adapter_shardings)


def opt_state_sharding(adapter_shardings, mesh, adapter_shapes=None):
    """AdamW moments: adapter sharding + ZeRO-1-style data-sharding.

    Moments are only touched elementwise in the update, never by a
    matmul, so they can shard over ``data`` even where the parameter
    cannot (H2.3): GSPMD reduce-scatters the incoming gradient and
    all-gathers the updated parameter — the ZeRO-1 schedule — trading
    ~param-bytes of link traffic per step for an 8x cut in fp32 moment
    memory. The largest still-replicated dim that divides the data axis
    takes the sharding.
    """
    data = dict(mesh.shape).get("data", 1)

    def shard_moment(sh, sds):
        if adapter_shapes is None or data <= 1:
            return sh
        spec = list(sh.spec) + [None] * (len(sds.shape) - len(sh.spec))
        used = set()
        for e in spec:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a:
                    used.add(a)
        if "data" in used:
            return sh
        cands = [(d, i) for i, (d, e) in enumerate(zip(sds.shape, spec))
                 if e is None and d % data == 0]
        if not cands:
            return sh
        _, i = max(cands)
        spec[i] = "data"
        return NamedSharding(mesh, P(*spec))

    if adapter_shapes is not None:
        moments = ctree.map(shard_moment, adapter_shardings,
                               adapter_shapes)
    else:
        moments = adapter_shardings
    return {
        "mu": moments,
        "nu": moments,
        "count": NamedSharding(mesh, P()),
    }


def _dp_entry(mesh, batch: int):
    """The batch-dim PartitionSpec entry: (pod, data) when divisible,
    replicated otherwise (e.g. long_500k's global_batch=1)."""
    dp = dp_axes(mesh)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if size == 0 or batch % size != 0:
        dp = ()
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def batch_spec(mesh, *, batch: int) -> P:
    return P(_dp_entry(mesh, batch), None)


def batch_sharding(mesh, *, batch: int):
    """tokens/labels [B, S]: batch over (pod, data) when divisible."""
    return NamedSharding(mesh, batch_spec(mesh, batch=batch))


def activation_spec(mesh, *, batch: int, seq: int) -> P:
    """[B, S, D] activations: batch over dp, sequence over model (SP)."""
    bdim = _dp_entry(mesh, batch)
    tp = dict(mesh.shape).get("model", 1)
    sdim = "model" if seq % tp == 0 and seq > 1 else None
    return P(bdim, sdim, None)


def row_parallel_b_axes(mcfg: ModelConfig, mesh) -> tuple[str, ...]:
    """Mesh axes FSDP-sharding the d_out of the ROW-PARALLEL adapted
    weights (wo / w_down — the only call sites that receive the boundary
    constraint). Their adapters' B/m shard d_out over these axes
    (``adapter_sharding`` uses the weight's dim-0 role), but the module
    output's feature dim does not — the ROADMAP ``b_spec`` gap. The axes
    are threaded into the compose plan (``ComposeSharding.b_dout_axes``)
    so the folded-gsB serving path declares B's true layout and the
    shard-local kernel path falls back cleanly instead of silently
    gathering at the shard_map boundary.

    Derived from each weight's ACTUAL dim-0 role (wo degrades to
    'fsdp_gather' when the heads don't divide the model axis — a
    different axis set than w_down's 'fsdp'). The one boundary-constraint
    plan is shared by both call sites, so when the two weights disagree
    the declaration is dropped entirely (legacy behavior, never a WRONG
    pin). Size-1 axes are dropped too (replication in disguise — they
    must not flip kernel expressibility)."""
    per_weight = []
    for name in ("wo", "w_down"):
        role = leaf_roles(mcfg, name, 2, mesh)[0]
        axes = pick_axes(mcfg.d_model, role, mesh, set())
        if axes is None:
            axes = ()
        elif not isinstance(axes, tuple):
            axes = (axes,)
        per_weight.append(tuple(a for a in axes if mesh.shape[a] > 1))
    if per_weight[0] != per_weight[1]:
        return ()
    return per_weight[0]


def make_boundary_constraint(mesh, *, batch: int, seq: int,
                             b_dout_axes: tuple[str, ...] = ()):
    """SP constraint for [B, S, D] activations; carries ``.heads`` — the
    head-parallel constraint for [B, S, H, hd] attention tensors (H3.4:
    forces the SP→head transition to all-to-all the small q/k/v instead
    of the fp32 score tiles) — and ``.plan``, the
    :class:`~repro.core.sharding.ComposeSharding` the adapted linears use
    to pin the rank-space LoRA intermediate and run the matmul-fused
    compose shard-local (no y_lora materialization under SPMD).
    ``b_dout_axes`` (usually :func:`row_parallel_b_axes`): extra FSDP axes
    on the constrained layers' B d_out, threaded into the plan."""
    spec = activation_spec(mesh, batch=batch, seq=seq)
    sharding = NamedSharding(mesh, spec)
    plan = _csh.plan_for_output(mesh, spec, b_dout_axes=tuple(b_dout_axes))

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, sharding)

    bdim = _dp_entry(mesh, batch)
    tp = dict(mesh.shape).get("model", 1)

    def heads(q):
        h_ax = "model" if q.shape[2] % tp == 0 and tp > 1 else None
        spec = P(bdim, None, h_ax, None)
        return jax.lax.with_sharding_constraint(
            q, NamedSharding(mesh, spec))

    constrain.heads = heads
    constrain.plan = plan
    return constrain


def cache_sharding(mcfg: ModelConfig, mesh, *, batch: int,
                   block_size: int | None = None):
    """Decode cache tree: KV [n_scan, B, T, Hkv, hd] — batch over dp, seq
    over model; mamba h [n_scan, B, di, n] — d_inner over model.

    ``block_size``: the PAGED cache layout — block pools
    [n_scan, n_blocks, bs, Hkv, hd] carry no batch dim (any pool block
    serves any row), so they never shard over dp; the in-block seq dim
    shards over model like the rectangular T dim when it divides. The
    ``"pages"`` table (and ``"len"``) are tiny host-mirrored int32 state:
    replicated."""
    b_ax = _dp_entry(mesh, batch)
    tp = dict(mesh.shape).get("model", 1)
    kinds = mcfg.layer_kinds()
    unit: dict[str, Any] = {}
    for i in range(mcfg.period):
        if kinds[i] == "attn":
            if block_size is not None:
                bs_ax = "model" if tp > 1 and block_size % tp == 0 \
                    else None
                kv = NamedSharding(mesh, P(None, None, bs_ax, None, None))
            else:
                kv = NamedSharding(mesh, P(None, b_ax, "model", None,
                                           None))
            unit[f"l{i}"] = {"k": kv, "v": kv}
        else:
            di_ok = mcfg.d_inner % tp == 0
            unit[f"l{i}"] = {
                "h": NamedSharding(
                    mesh, P(None, b_ax, "model" if di_ok else None, None)),
                "conv": NamedSharding(
                    mesh, P(None, b_ax, None, "model" if di_ok else None)),
            }
    out = {"stack": unit, "len": NamedSharding(mesh, P())}
    if block_size is not None:
        out["pages"] = NamedSharding(mesh, P())
    return out


def replicated(mesh):
    return NamedSharding(mesh, P())


def tree_replicated(tree, mesh):
    rep = replicated(mesh)
    return ctree.map(lambda _: rep, tree)
