"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benchmarks see the real single device.

Mesh layout: ``model`` (16) is the innermost axis — it stays inside one ICI
torus slice of a v5e pod; ``data`` (16) spans the pod; ``pod`` (2) crosses
pods over DCN. Batch shards over (pod, data); weights TP-shard over model and
FSDP-shard over (pod, data).
"""
from __future__ import annotations

import jax

from repro.compat import mesh as cmesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()[:ndev]  # single-pod = first 256 of the 512
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}, have {len(devices)} — "
            f"the dry-run must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"any jax import")
    return cmesh.make_mesh(shape, axes, devices=devices)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (uses however many devices exist)."""
    return cmesh.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The batch/FSDP axes present in this mesh ((pod, data) or (data,))."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, *names) -> int:
    s = 1
    for n in names:
        if n in mesh.axis_names:
            s *= mesh.shape[n]
    return s
