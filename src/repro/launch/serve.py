"""Serving driver: batched prefill + decode loop with a KV/SSM cache.

CPU-runnable with a smoke config::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --batch 2 --prompt-len 32 --gen-len 16

Implements the minimal production serving shape: one jitted prefill step
(prompt → cache + first logits) and one jitted decode step re-used per
token (the cache is donated, so decode runs in place). Sampling is
greedy/temperature on the host — the device step is exactly the
``serve_step`` the ``decode_*``/``long_*`` dry-run cells lower.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import DoRAConfig
from repro.launch.steps import StepConfig, make_decode_step, \
    make_prefill_step
from repro.launch.train import build_state


def generate(mcfg, params, adapters, scfg: StepConfig, prompts, *,
             gen_len: int, max_len: int, temperature: float = 0.0,
             seed: int = 0):
    """prompts: int32 [B, P]. Returns tokens [B, P+gen_len]."""
    B, P = prompts.shape
    prefill = jax.jit(make_prefill_step(mcfg, scfg, None, batch=B,
                                        seq=max_len))
    decode = jax.jit(make_decode_step(mcfg, scfg, None, batch=B),
                     donate_argnums=(2,))

    # Prefill writes the prompt into a max_len cache.
    pad = max_len - P
    toks = jnp.asarray(prompts, jnp.int32)
    logits, cache = prefill(params, adapters, {"tokens": toks})
    # forward() counted the padded rows too — rewind len to the true P.
    if pad:
        cache = dict(cache)

    key = jax.random.PRNGKey(seed)
    out = [toks]
    last = logits
    for i in range(gen_len):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        out.append(nxt)
        last, cache = decode(params, adapters, cache, {"tokens": nxt})
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=16.0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mcfg = get_config(args.arch, smoke=args.smoke)
    dcfg = DoRAConfig(rank=args.rank, alpha=args.alpha, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, args.seed)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, mcfg.vocab_size,
                           (args.batch, args.prompt_len), dtype=np.int32)
    max_len = args.prompt_len + args.gen_len

    t0 = time.time()
    toks = generate(mcfg, params, adapters, scfg, prompts,
                    gen_len=args.gen_len, max_len=max_len,
                    temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    toks = np.asarray(toks)
    print(f"generated [{toks.shape[0]}, {toks.shape[1]}] in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: ...{toks[b, args.prompt_len - 4:].tolist()}")


if __name__ == "__main__":
    main()
