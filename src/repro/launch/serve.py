"""Serving driver: batched prefill + decode loop with a KV/SSM cache.

CPU-runnable with a smoke config::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --batch 2 --prompt-len 32 --gen-len 16

Implements the minimal production serving shape: one jitted precompute of
the frozen-adapter state (w_norm/g cached once per adapter set — the
decode loop does zero factored-norm work per token), one jitted prefill
step (prompt → cache + first logits; right-padded to ``max_len`` on
attention-only archs so a single compiled prefill serves every prompt
length, with the cache length rewound to the true P) and one jitted decode
step re-used per token (the cache is donated, so decode runs in place).
Sampling is greedy/temperature on the host — the device step is exactly
the ``serve_step`` the ``decode_*``/``long_*`` dry-run cells lower.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import DoRAConfig
from repro.launch.steps import StepConfig, make_decode_step, \
    make_precompute_step, make_prefill_step
from repro.launch.train import build_state


def generate(mcfg, params, adapters, scfg: StepConfig, prompts, *,
             gen_len: int, max_len: int, temperature: float = 0.0,
             seed: int = 0, cache_adapters: bool = True,
             fold_gsb: bool = False, mesh=None):
    """prompts: int32 [B, P]. Returns tokens [B, P+gen_len].

    ``cache_adapters``: precompute the frozen-adapter serving state (cached
    g) before prefill — bitwise-identical tokens, no per-token norm work.
    ``fold_gsb``: additionally fold g·s into B (broadcast-free decode
    compose; last-ulp numerics difference, so off by default).
    ``mesh``: SPMD serving — the precompute pins the cached state to the
    serving shardings (gsB row-sharded like B) and prefill/decode attach
    the boundary constraints, so the sharded steps run the same
    matmul-fused compose as the single-device loop.
    """
    B, P = prompts.shape
    if max_len < P + gen_len:
        raise ValueError(f"max_len={max_len} < P+gen_len={P + gen_len}")
    if cache_adapters:
        adapters = jax.jit(make_precompute_step(
            mcfg, scfg, mesh, fold_gsb=fold_gsb))(params, adapters)

    # Padded prefill (attention-only archs): pad the prompt to max_len and
    # pass the true P as a traced scalar — ONE compiled prefill covers
    # every prompt length in the bucket; the step rewinds the cache length
    # to P. SSM states integrate every processed token and cannot rewind,
    # so hybrid/Mamba archs prefill at the exact P.
    can_pad = all(k == "attn" for k in mcfg.layer_kinds())
    pad = max_len - P if can_pad else 0
    prefill = jax.jit(make_prefill_step(
        mcfg, scfg, mesh, batch=B, seq=max_len, padded=bool(pad)))
    decode = jax.jit(make_decode_step(mcfg, scfg, mesh, batch=B),
                     donate_argnums=(2,))

    toks = jnp.asarray(prompts, jnp.int32)
    batch_in = {"tokens": toks}
    if pad:
        batch_in = {"tokens": jnp.pad(toks, ((0, 0), (0, pad))),
                    "prompt_len": jnp.asarray(P, jnp.int32)}
    logits, cache = prefill(params, adapters, batch_in)
    # The decode contract: the cache stands at exactly the true prompt
    # length, so the first generated token is written at position P.
    # (Hard errors, not asserts — the contract must survive python -O.)
    if int(cache["len"]) != P:
        raise RuntimeError(
            f"prefill left cache at {int(cache['len'])}, expected {P}")

    key = jax.random.PRNGKey(seed)
    out = [toks]
    last = logits
    for i in range(gen_len):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        out.append(nxt)
        last, cache = decode(params, adapters, cache, {"tokens": nxt})
        if i == 0 and int(cache["len"]) != P + 1:
            raise RuntimeError(
                f"decode wrote at {int(cache['len']) - 1}, expected {P}")
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=16.0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-adapter-cache", action="store_true",
                    help="skip the frozen-adapter precompute (recompute "
                         "the factored norm every step — debug only)")
    ap.add_argument("--fold-gsb", action="store_true",
                    help="fold g*s into B in the serving state "
                         "(broadcast-free decode compose)")
    args = ap.parse_args()

    mcfg = get_config(args.arch, smoke=args.smoke)
    dcfg = DoRAConfig(rank=args.rank, alpha=args.alpha, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, args.seed)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, mcfg.vocab_size,
                           (args.batch, args.prompt_len), dtype=np.int32)
    max_len = args.prompt_len + args.gen_len

    t0 = time.time()
    toks = generate(mcfg, params, adapters, scfg, prompts,
                    gen_len=args.gen_len, max_len=max_len,
                    temperature=args.temperature, seed=args.seed,
                    cache_adapters=not args.no_adapter_cache,
                    fold_gsb=args.fold_gsb)
    dt = time.time() - t0
    toks = np.asarray(toks)
    print(f"generated [{toks.shape[0]}, {toks.shape[1]}] in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: ...{toks[b, args.prompt_len - 4:].tolist()}")


if __name__ == "__main__":
    main()
