"""Serving driver: batched prefill + decode with per-request adapter routing.

CPU-runnable with a smoke config::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --batch 2 --prompt-len 32 --gen-len 16 [--tenants 3] [--continuous]

Implements the production serving shape (docs/serving.md):

  - **one jitted precompute per adapter set** — the frozen-adapter state
    (w_norm/g cached once; the decode loop does zero factored-norm work
    per token), held in an :class:`repro.core.AdapterStateCache` LRU keyed
    by (adapter id, version, dtype, sharding) with byte-bounded eviction;
  - **request-routed batches** — every request carries an adapter handle;
    :class:`MultiTenantServer` groups the batch's rows by adapter and
    serves heterogeneous-adapter batches in ONE prefill/decode step via
    the grouped gsB-folded compose (``repro.core.dora_linear_grouped``).
    Homogeneous batches take today's single-tenant path bitwise;
  - **shape-bucketed prefill** — one jitted prefill (prompt right-padded
    to ``max_len``, true P traced) serves every prompt length on
    attention-only archs, with the cache length rewound to P; one jitted
    decode step is re-used per token (cache donated = in place).

Sampling is greedy/temperature on the host — the device step is exactly
the ``serve_step`` the ``decode_*``/``long_*`` dry-run cells lower.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import DoRAConfig
from repro.core.adapter import stack_adapter_states
from repro.core.adapter_cache import (AdapterHandle, AdapterStateCache,
                                      mesh_fingerprint)
from repro.launch.steps import StepConfig, make_decode_step, \
    make_precompute_step, make_prefill_step
from repro.launch.train import build_state
# monotonic (time.perf_counter) for every wall-clock delta: time.time()
# can step backwards under NTP and is banned from latency math here
# (the one sanctioned epoch-time user is the checkpoint heartbeat).
from repro.obs import TraceRecorder, engine_metrics, monotonic


def _check_cache_mesh(cache: AdapterStateCache, mesh) -> None:
    """The cache keys states on the mesh they were pinned for — serving
    them under a DIFFERENT mesh would re-lay-out g/gsB every step, the
    exact per-token work the cache exists to remove. Refuse loudly."""
    want = mesh_fingerprint(mesh)
    if cache.sharding != want:
        raise ValueError(
            f"adapter cache is keyed for sharding {cache.sharding} but "
            f"serving runs on mesh {want} — build the cache with "
            f"AdapterStateCache.for_serving(mcfg, scfg, mesh) for THIS "
            f"mesh so cached states land pre-pinned to its shardings")


def _sample(last, temperature, key):
    if temperature > 0.0:
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(sub, last / temperature, axis=-1)
    else:
        nxt = jnp.argmax(last, axis=-1)
    return nxt.astype(jnp.int32)[:, None], key


def _contract_checks_enabled() -> bool:
    """The prefill/decode cache-length contract checks call ``int()`` on
    a device scalar — a host sync per prefill (and per first decode) that
    stalls the pipeline. They default OFF in the serving path; the
    contract itself stays hard-error (not assert) and is locked by tests,
    which force the checks on via ``check_contract=True``. Set
    ``REPRO_SERVE_DEBUG=1`` to re-enable them operationally."""
    return os.environ.get("REPRO_SERVE_DEBUG", "") not in ("", "0")


def _decode_loop(prefill, decode, params, adapters, toks, *, prompt_len,
                 gen_len, pad, temperature, seed, collect_logits=False,
                 check_contract: bool | None = None):
    """The shared prefill → sample → decode loop. Returns (tokens
    [B, P+gen_len], logits-per-sampled-token list or None).

    ``check_contract``: run the blocking cache-length contract checks
    (None = the ``REPRO_SERVE_DEBUG`` env switch; see
    :func:`_contract_checks_enabled`)."""
    check = (_contract_checks_enabled() if check_contract is None
             else check_contract)
    P = prompt_len
    batch_in = {"tokens": toks}
    if pad:
        batch_in = {"tokens": jnp.pad(toks, ((0, 0), (0, pad))),
                    "prompt_len": jnp.asarray(P, jnp.int32)}
    logits, cache = prefill(params, adapters, batch_in)
    # The decode contract: the cache stands at exactly the true prompt
    # length, so the first generated token is written at position P.
    # (Hard errors, not asserts — the contract must survive python -O —
    # but behind the debug switch: each int() is a device sync.)
    if check and int(cache["len"]) != P:
        raise RuntimeError(
            f"prefill left cache at {int(cache['len'])}, expected {P}")

    key = jax.random.PRNGKey(seed)
    out = [toks]
    steps_logits = [] if collect_logits else None
    last = logits
    for i in range(gen_len):
        if collect_logits:
            steps_logits.append(np.asarray(last))
        nxt, key = _sample(last, temperature, key)
        out.append(nxt)
        last, cache = decode(params, adapters, cache, {"tokens": nxt})
        if check and i == 0 and int(cache["len"]) != P + 1:
            raise RuntimeError(
                f"decode wrote at {int(cache['len']) - 1}, expected {P}")
    return jnp.concatenate(out, axis=1), steps_logits


def generate(mcfg, params, adapters, scfg: StepConfig, prompts, *,
             gen_len: int, max_len: int, temperature: float = 0.0,
             seed: int = 0, cache_adapters: bool = True,
             fold_gsb: bool = False, mesh=None, adapter_cache=None,
             allow_miss: bool = True, return_logits: bool = False,
             check_contract: bool | None = None):
    """prompts: int32 [B, P]. Returns tokens [B, P+gen_len] (or
    (tokens, per-step logits) when ``return_logits``).

    ``adapters`` is either an adapter tree (single-tenant, as before) or
    an :class:`~repro.core.AdapterHandle` resolved through
    ``adapter_cache`` (an :class:`~repro.core.AdapterStateCache`). A
    handle that misses the cache while ``allow_miss=False`` is rejected
    with an error naming the key fields — the guard against a caller
    swapping adapters without re-precomputing and silently serving stale
    logits. A stale handle (version behind the registry) is ALWAYS
    rejected.

    ``cache_adapters``: precompute the frozen-adapter serving state (cached
    g) before prefill — bitwise-identical tokens, no per-token norm work.
    ``fold_gsb``: additionally fold g·s into B (broadcast-free decode
    compose; last-ulp numerics difference, so off by default).
    ``mesh``: SPMD serving — the precompute pins the cached state to the
    serving shardings (gsB row-sharded like B) and prefill/decode attach
    the boundary constraints, so the sharded steps run the same
    matmul-fused compose as the single-device loop.
    """
    if isinstance(adapters, AdapterHandle):
        if adapter_cache is None:
            raise ValueError(
                f"generate() was handed the adapter handle {adapters} but "
                f"no adapter_cache to resolve it against")
        _check_cache_mesh(adapter_cache, mesh)
        adapters = adapter_cache.get_state(params, adapters,
                                           allow_miss=allow_miss)
    elif cache_adapters:
        adapters = jax.jit(make_precompute_step(
            mcfg, scfg, mesh, fold_gsb=fold_gsb))(params, adapters)

    B, P = prompts.shape
    if max_len < P + gen_len:
        raise ValueError(f"max_len={max_len} < P+gen_len={P + gen_len}")

    # Padded prefill (attention-only archs): pad the prompt to max_len and
    # pass the true P as a traced scalar — ONE compiled prefill covers
    # every prompt length in the bucket; the step rewinds the cache length
    # to P. SSM states integrate every processed token and cannot rewind,
    # so hybrid/Mamba archs prefill at the exact P.
    can_pad = all(k == "attn" for k in mcfg.layer_kinds())
    pad = max_len - P if can_pad else 0
    prefill = jax.jit(make_prefill_step(
        mcfg, scfg, mesh, batch=B, seq=max_len, padded=bool(pad)))
    decode = jax.jit(make_decode_step(mcfg, scfg, mesh, batch=B),
                     donate_argnums=(2,))
    toks = jnp.asarray(prompts, jnp.int32)
    tokens, logits = _decode_loop(
        prefill, decode, params, adapters, toks, prompt_len=P,
        gen_len=gen_len, pad=pad, temperature=temperature, seed=seed,
        collect_logits=return_logits, check_contract=check_contract)
    return (tokens, logits) if return_logits else tokens


# ---------------------------------------------------------------------------
# Multi-tenant request routing.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: a prompt row and the adapter it runs under
    (an :class:`AdapterHandle`, or a bare adapter-id string meaning "the
    current registered version")."""
    prompt: Any                        # int32 [P]
    adapter: AdapterHandle | str


class MultiTenantServer:
    """Request-routed serving over an :class:`AdapterStateCache`.

    ``serve(requests)`` resolves each request's adapter handle through the
    LRU (precomputing on a miss unless ``allow_miss=False``), sorts the
    batch's rows so same-adapter rows are contiguous, and runs ONE
    prefill + decode loop for the whole heterogeneous batch:

      - one distinct adapter → the single-tenant path, byte-for-byte
        today's serve loop (bitwise fast path);
      - K > 1 adapters → the per-tenant states are stacked leaf-wise
        ([n_scan, K, ...]) and the steps are compiled against the STATIC
        group signature ((start, size) per tenant); each group's rows run
        the same gsB-folded ops as the homogeneous path, so mixed batches
        are bitwise-equal (fp32) to per-tenant sequential serving for
        groups of ≥ 2 rows, and the grouped decode step's jaxpr has zero
        ``dora_wnorm`` ops (no norm work per token).

    Steps are cached per (batch, bucket, signature) — a new grouping
    signature compiles once, like a new prompt-length bucket.
    """

    def __init__(self, mcfg, scfg: StepConfig, params, *,
                 cache: AdapterStateCache, mesh=None,
                 max_cached_steps: int = 32, engine_slots: int = 8,
                 dynamic_grouping: bool = False,
                 max_active_per_adapter: int | None = None,
                 trace: TraceRecorder | None = None):
        _check_cache_mesh(cache, mesh)
        self.mcfg = mcfg
        self.scfg = scfg
        self.params = params
        self.cache = cache
        self.mesh = mesh
        # Observability pass-through: every engine this server builds
        # emits its lifecycle events into this one recorder (the static
        # batch path has no per-request scheduling to trace).
        self.trace = trace
        # Fleet knobs, threaded into every engine this server builds:
        # dynamic_grouping swaps the engine's static group signatures for
        # the traced fleet stack (one decode executable under churn);
        # max_active_per_adapter rate-limits slots per adapter id. The
        # STATIC batch path (same-length serve()) is unaffected — its
        # grouping is per-call, not per-engine.
        self.dynamic_grouping = bool(dynamic_grouping)
        self.max_active_per_adapter = max_active_per_adapter
        # Mixed-length batches route through a continuous-batching engine
        # with this FIXED slot count (requests beyond it queue and join
        # as rows retire) — decoupled from the batch size, so varying
        # batch sizes share one compiled (prefill, decode) pair and one
        # persistent per-row cache instead of one engine per size.
        self.engine_slots = int(engine_slots)
        # Compiled (prefill, decode) pairs per (batch, bucket, grouping
        # signature), LRU-bounded: churny request mixes produce many
        # signatures, and each entry pins two jitted executables — the
        # step cache must not grow unboundedly while the adapter states
        # one field away are carefully byte-bounded.
        self.max_cached_steps = max_cached_steps
        from collections import OrderedDict
        self._steps: "OrderedDict" = OrderedDict()
        # Continuous-batching engines for mixed-length batches, keyed by
        # (slots, max_len). Bounded far tighter than the step cache: each
        # entry pins a persistent [n_scan, slots, max_len, Hkv, hd] K/V
        # cache on device, not just compiled executables.
        self.max_cached_engines = 2
        self._engines: "OrderedDict" = OrderedDict()

    def _resolve(self, req: Request) -> AdapterHandle:
        if isinstance(req.adapter, AdapterHandle):
            return req.adapter
        return self.cache.current_handle(req.adapter)

    def _get_steps(self, *, batch: int, max_len: int, pad: bool,
                   groups):
        key = (batch, max_len, pad, groups)
        if key in self._steps:
            self._steps.move_to_end(key)
            return self._steps[key]
        prefill = jax.jit(make_prefill_step(
            self.mcfg, self.scfg, self.mesh, batch=batch, seq=max_len,
            padded=pad, tenant_groups=groups))
        decode = jax.jit(make_decode_step(
            self.mcfg, self.scfg, self.mesh, batch=batch,
            tenant_groups=groups), donate_argnums=(2,))
        self._steps[key] = (prefill, decode)
        while len(self._steps) > self.max_cached_steps:
            self._steps.popitem(last=False)
        return self._steps[key]

    def _get_engine(self, *, slots: int, max_len: int, temperature: float,
                    seed: int, allow_miss: bool, speculative_k: int = 0):
        from repro.launch.engine import DecodeEngine
        key = (slots, max_len)
        if key in self._engines:
            self._engines.move_to_end(key)
            eng = self._engines[key]
        else:
            eng = DecodeEngine(self.mcfg, self.scfg, self.params,
                               slots=slots, max_len=max_len,
                               adapter_cache=self.cache, mesh=self.mesh,
                               dynamic_grouping=self.dynamic_grouping,
                               max_active_per_adapter=(
                                   self.max_active_per_adapter),
                               trace=self.trace)
            self._engines[key] = eng
            while len(self._engines) > self.max_cached_engines:
                self._engines.popitem(last=False)
        eng.temperature = float(temperature)
        eng.seed = int(seed)
        eng.allow_miss = allow_miss
        eng.speculative_k = int(speculative_k)
        return eng

    def _serve_continuous(self, requests, prompts, *, gen_len, max_len,
                          temperature, seed, allow_miss,
                          speculative_k=0):
        """Mixed-length admission through the continuous-batching engine:
        every request is prefilled into a slot at its TRUE prompt length
        (per-row cache state), so no length bucketing is needed; batches
        larger than ``engine_slots`` queue and join as rows retire.
        Returns a list of 1-D [P_i + gen_len] arrays in request order.
        Sample keys fold in each request's index within THIS batch, so a
        repeated call with the same requests/temperature/seed reproduces
        its tokens even though the cached engine persists."""
        eng = self._get_engine(slots=self.engine_slots, max_len=max_len,
                               temperature=temperature, seed=seed,
                               allow_miss=allow_miss,
                               speculative_k=speculative_k)
        # Validate and resolve EVERY request before the first submit: a
        # bad one mid-batch (unregistered adapter id, empty prompt) must
        # fail this call, not strand already-queued requests in the
        # persistent cached engine.
        checked = [eng.check_request(p, adapter=self._resolve(r),
                                     max_new_tokens=gen_len)
                   for r, p in zip(requests, prompts)]
        rids = [eng.submit(p, adapter=h, max_new_tokens=gen_len, key_id=i)
                for i, (p, h) in enumerate(checked)]
        results = {res.request_id: res for res in eng.run()}
        for rid in rids:
            if results[rid].finish_reason == "error":
                # e.g. a stale/cold adapter handle at admission: surface
                # the original exception (the engine already dropped the
                # request with an errored result, so the persistent
                # engine is NOT wedged for the next call). Results carry
                # errors as strings (picklable); the live exception is
                # only present in the producing process.
                err = results[rid].error
                if err is None:
                    err = RuntimeError(f"{results[rid].error_type}: "
                                       f"{results[rid].error_message}")
                raise err
        return [np.concatenate([p, results[rid].tokens])
                for p, rid in zip(prompts, rids)]

    def serve(self, requests: Sequence[Request], *, gen_len: int,
              max_len: int, temperature: float = 0.0, seed: int = 0,
              allow_miss: bool = True, return_logits: bool = False,
              static: bool | None = None, speculative_k: int = 0,
              check_contract: bool | None = None):
        """Serve one batch. Returns tokens [B, P+gen_len] in REQUEST order
        (or (tokens, per-step logits) when ``return_logits``).

        Prompt lengths: same-length batches run the legacy STATIC path
        (one shared prefill, bitwise guarantees as documented).
        Mixed-length batches are admitted through the continuous-batching
        engine (``repro.launch.engine``) — per-row prefill at each
        request's true length, one fixed-shape decode — and return a LIST
        of 1-D [P_i + gen_len] token arrays in request order (ragged
        shapes don't stack). ``static=True`` forces the legacy path and
        keeps its same-length-bucket error; ``static=False`` forces the
        engine even for uniform lengths. ``return_logits`` is a
        static-path-only debugging hook.

        ``speculative_k > 0``: engine-path requests decode speculatively
        (k base-only drafts + one full-DoRA verify per tick; greedy
        streams stay bitwise the plain ones). A batched tick drafts one
        window shape, so k is a per-call scheduler knob, not a per-row
        one; temperature>0 calls silently fall back to plain decode (the
        engine's documented rejection-sampling gap)."""
        if not requests:
            raise ValueError("empty request batch")
        prompts = [np.asarray(r.prompt, np.int32) for r in requests]
        P = prompts[0].shape[-1]
        mixed = any(p.shape[-1] != P for p in prompts)
        if static is None:
            # speculative decode lives on the engine path (it needs the
            # rewindable per-row cache), so it routes uniform-length
            # batches there too.
            static = not mixed and not speculative_k
        if static and speculative_k:
            raise ValueError(
                "speculative_k requires the continuous-batching engine "
                "path (its rewindable per-row cache): serve with "
                "static=False/None, not static=True")
        if not static:
            if return_logits:
                raise ValueError(
                    "return_logits is only available on the static path "
                    "(the engine streams per-request tokens instead)")
            if check_contract:
                raise ValueError(
                    "check_contract is only meaningful on the static "
                    "path: the engine schedules on host mirrors and "
                    "never reads cache['len'] back, so there is no "
                    "blocking contract check to enable")
            if any(p.shape[-1] + gen_len > max_len for p in prompts):
                raise ValueError(
                    f"max_len={max_len} < P+gen_len="
                    f"{max(p.shape[-1] for p in prompts) + gen_len}")
            return self._serve_continuous(
                requests, prompts, gen_len=gen_len, max_len=max_len,
                temperature=temperature, seed=seed, allow_miss=allow_miss,
                speculative_k=speculative_k)
        if mixed:
            raise ValueError(
                f"all prompts in one batch must share a length bucket on "
                f"the legacy static path; got "
                f"{sorted({p.shape[-1] for p in prompts})} — serve with "
                f"static=None/False to admit mixed lengths through the "
                f"continuous-batching engine, or bucket requests by "
                f"prompt length before batching")
        if max_len < P + gen_len:
            raise ValueError(f"max_len={max_len} < P+gen_len={P + gen_len}")

        # Resolve handles (LRU hit / precompute-on-miss / reject), then
        # group rows by adapter: stable sort by first appearance, so
        # same-adapter rows are contiguous and the grouping signature is
        # deterministic in request order.
        handles = [self._resolve(r) for r in requests]
        order: dict[AdapterHandle, int] = {}
        for h in handles:
            order.setdefault(h, len(order))
        perm = sorted(range(len(requests)), key=lambda i: order[handles[i]])
        inv = np.argsort(perm)
        states = {h: self.cache.get_state(self.params, h,
                                          allow_miss=allow_miss)
                  for h in order}

        toks = jnp.asarray(np.stack([prompts[i] for i in perm]), jnp.int32)
        B = toks.shape[0]
        if len(order) == 1:
            adapters = next(iter(states.values()))
            groups = None          # single tenant: today's bitwise path
        else:
            adapters = stack_adapter_states(
                [states[h] for h in order], axis=1)
            sizes = [0] * len(order)
            for h in handles:
                sizes[order[h]] += 1
            groups, start = [], 0
            for n in sizes:
                groups.append((start, n))
                start += n
            groups = tuple(groups)

        can_pad = all(k == "attn" for k in self.mcfg.layer_kinds())
        pad = max_len - P if can_pad else 0
        prefill, decode = self._get_steps(batch=B, max_len=max_len,
                                          pad=bool(pad), groups=groups)
        tokens, logits = _decode_loop(
            prefill, decode, self.params, adapters, toks, prompt_len=P,
            gen_len=gen_len, pad=pad, temperature=temperature, seed=seed,
            collect_logits=return_logits, check_contract=check_contract)
        tokens = jnp.asarray(np.asarray(tokens)[inv])
        if return_logits:
            return tokens, [step[inv] for step in logits]
        return tokens


# ---------------------------------------------------------------------------
# Continuous-batching server (slot-scheduled; see repro.launch.engine).
# ---------------------------------------------------------------------------

class EngineServer:
    """Request-routed CONTINUOUS serving over one persistent
    :class:`~repro.launch.engine.DecodeEngine`.

    Where :class:`MultiTenantServer` serves one static batch at a time
    (every row enters and leaves together), ``EngineServer`` keeps a
    fixed slot table of ``slots`` decode rows alive across calls:
    ``run(requests)`` queues the requests (any mix of prompt lengths and
    adapters) and drives the engine until they drain — requests join a
    RUNNING batch through per-row prefill, retire individually on EOS /
    token budget / ``max_len``, and the freed rows admit whatever is
    waiting. The compiled surface stays one (prefill-into-slot, decode)
    pair per (slots, max_len, group-signature); per-slot adapter handles
    resolve through the same :class:`~repro.core.AdapterStateCache` LRU
    as the static server.
    """

    def __init__(self, mcfg, scfg: StepConfig, params, *,
                 cache: AdapterStateCache, slots: int, max_len: int,
                 mesh=None, temperature: float = 0.0, seed: int = 0,
                 allow_miss: bool = True, speculative_k: int = 0,
                 fault_plan=None, spec_accept_floor: float = 0.0,
                 paged: bool = False, block_size: int | None = None,
                 n_blocks: int | None = None,
                 prefill_chunk: int | None = None,
                 dynamic_grouping: bool = False,
                 max_active_per_adapter: int | None = None,
                 trace: TraceRecorder | None = None):
        from repro.launch.engine import DecodeEngine
        _check_cache_mesh(cache, mesh)
        self.cache = cache
        self.engine = DecodeEngine(mcfg, scfg, params, slots=slots,
                                   max_len=max_len, adapter_cache=cache,
                                   mesh=mesh, temperature=temperature,
                                   seed=seed, allow_miss=allow_miss,
                                   speculative_k=speculative_k,
                                   fault_plan=fault_plan,
                                   spec_accept_floor=spec_accept_floor,
                                   paged=paged, block_size=block_size,
                                   n_blocks=n_blocks,
                                   prefill_chunk=prefill_chunk,
                                   dynamic_grouping=dynamic_grouping,
                                   max_active_per_adapter=(
                                       max_active_per_adapter),
                                   trace=trace)

    def run(self, requests: Sequence[Request], *, gen_len: int,
            eos_id: int | None = None, on_token=None,
            speculative_k: int | None = None,
            deadline_ticks=None, priority=0):
        """Serve ``requests`` to completion through the slot table;
        returns a list of :class:`~repro.launch.engine.RequestResult` in
        request order (``result.tokens`` holds the generated tokens —
        possibly fewer than ``gen_len`` on EOS / ``max_len`` retirement;
        ``finish_reason == "error"`` with ``result.error`` set when a
        request's adapter failed to resolve at admission — the other
        requests still serve). ``on_token(request_id, token)`` streams
        tokens as they are sampled; the engine (``self.engine``) persists
        across calls, so throughput counters in ``self.engine.stats()``
        accumulate — sample keys fold in each request's index within THIS
        call, keeping temperature>0 runs call-reproducible.
        ``speculative_k``: override the engine's draft window for THIS
        call (0 = plain decode; None = keep the constructor's setting) —
        a batched tick has one window shape, so k is a call-level
        scheduler knob, not a per-row one.

        ``deadline_ticks`` / ``priority``: one scalar applied to every
        request, or a per-request sequence — see
        :meth:`~repro.launch.engine.DecodeEngine.submit` for the timeout
        and preemption semantics."""
        if not requests:
            raise ValueError("empty request batch")
        if speculative_k is not None:
            self.engine.speculative_k = int(speculative_k)

        def norm(v, name):
            if v is None or isinstance(v, (int, np.integer)):
                return [v] * len(requests)
            v = list(v)
            if len(v) != len(requests):
                raise ValueError(
                    f"{name} has {len(v)} entries for "
                    f"{len(requests)} requests")
            return v
        deadlines = norm(deadline_ticks, "deadline_ticks")
        priorities = norm(priority, "priority")
        # All-or-nothing submission: validate every request first, so a
        # bad one mid-batch cannot orphan earlier ones in the persistent
        # queue (they would steal slots from — and stream into — the
        # NEXT call).
        checked = [self.engine.check_request(r.prompt, adapter=r.adapter,
                                             max_new_tokens=gen_len)
                   for r in requests]
        rids = [self.engine.submit(p, adapter=h, max_new_tokens=gen_len,
                                   eos_id=eos_id, key_id=i,
                                   priority=int(priorities[i] or 0),
                                   deadline_ticks=deadlines[i])
                for i, (p, h) in enumerate(checked)]
        results = {res.request_id: res for res in self.engine.run(on_token)}
        return [results[rid] for rid in rids]


def _dump_obs(trace: TraceRecorder, engine, args) -> None:
    """Write the post-run observability artifacts requested on the CLI:
    ``--trace-out`` (JSONL if the path ends .jsonl, else Chrome
    trace_event) and ``--metrics-out`` (Prometheus text)."""
    if args.trace_out:
        if args.trace_out.endswith(".jsonl"):
            trace.to_jsonl(args.trace_out)
            kind = "jsonl"
        else:
            trace.to_chrome_trace(args.trace_out)
            kind = "chrome-trace"
        print(f"  obs: {len(trace)} events ({trace.dropped} dropped) -> "
              f"{args.trace_out} ({kind})")
    if args.metrics_out:
        # engine_metrics folds the trace-derived latency histograms in
        # when handed the recorder.
        engine_metrics(engine, trace).to_prometheus(args.metrics_out)
        print(f"  obs: metrics snapshot -> {args.metrics_out} "
              f"(prometheus text)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=16.0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-adapter-cache", action="store_true",
                    help="skip the frozen-adapter precompute (recompute "
                         "the factored norm every step — debug only)")
    ap.add_argument("--fold-gsb", action="store_true",
                    help="fold g*s into B in the serving state "
                         "(broadcast-free decode compose)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="N>1: multi-tenant demo — N adapter sets in one "
                         "LRU-cached batch, --batch rows EACH, served in "
                         "one grouped decode loop")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching demo: 2x--batch MIXED-length "
                         "requests through the slot-scheduled engine "
                         "(--batch slots; requests join/leave mid-decode)")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="with --continuous: draft K base-only tokens per "
                         "tick and verify them in one full-DoRA window; "
                         "asserts the greedy token streams match a plain "
                         "engine's bitwise")
    ap.add_argument("--inject", default="", metavar="SPEC",
                    help="with --continuous: deterministic fault plan, "
                         "e.g. 'nan@3' (poison every row's logits at tick "
                         "3), 'nan@3:1,evict@5,stale@2,slow@4' — see "
                         "repro.launch.faults.FaultPlan.parse")
    ap.add_argument("--deadline", type=int, default=0, metavar="N",
                    help="with --continuous: give every request a "
                         "deadline of N engine ticks (expired requests "
                         "retire with finish_reason='timeout')")
    ap.add_argument("--paged", action="store_true",
                    help="with --continuous: block-paged K/V cache + "
                         "chunked prefill (see docs/engine.md); asserts "
                         "the greedy token streams match a rectangular "
                         "engine's bitwise and the block pool drains")
    ap.add_argument("--block-size", type=int, default=0, metavar="B",
                    help="with --paged: K/V block size (0 = auto: the "
                         "largest divisor of max_len up to 16)")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="fleet-serving demo: N tenants over --batch "
                         "slots, a churny mixed-adapter trace through the "
                         "TRACED dynamic-grouping engine; asserts the "
                         "greedy streams match the static-signature "
                         "engine bitwise and that the dynamic decode "
                         "held exactly ONE executable")
    ap.add_argument("--priority", type=int, default=0, metavar="N",
                    help="with --continuous: submit the LAST request at "
                         "priority N — it admits ahead of the FIFO (and "
                         "would preempt a lower-priority active row if it "
                         "arrived mid-flight with every slot busy)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="with --continuous/--fleet: record the request "
                         "lifecycle and write it here — JSONL (one event "
                         "per line) when PATH ends in .jsonl, else a "
                         "Chrome trace_event timeline loadable in "
                         "Perfetto / chrome://tracing")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="with --continuous/--fleet: write an engine "
                         "metrics snapshot here — Prometheus text "
                         "exposition format (counters, gauges, and "
                         "tick/seconds latency histograms)")
    args = ap.parse_args()

    mcfg = get_config(args.arch, smoke=args.smoke)
    dcfg = DoRAConfig(rank=args.rank, alpha=args.alpha, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, args.seed)

    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen_len

    if args.fleet > 1:
        cache = AdapterStateCache.for_serving(mcfg, scfg)
        for t in range(args.fleet):
            _, ad_t, _ = build_state(mcfg, dcfg, args.seed + 1 + t)
            cache.register(f"tenant-{t}", ad_t)
        n_req = max(2 * args.batch, args.fleet)
        requests = [Request(rng.integers(
            0, mcfg.vocab_size,
            int(rng.integers(args.prompt_len // 2, args.prompt_len + 1)),
            dtype=np.int32), f"tenant-{int(rng.integers(args.fleet))}")
            for _ in range(n_req)]
        trace = (TraceRecorder()
                 if (args.trace_out or args.metrics_out) else None)
        dyn = EngineServer(mcfg, scfg, params, cache=cache,
                           slots=args.batch, max_len=max_len,
                           temperature=args.temperature, seed=args.seed,
                           dynamic_grouping=True, trace=trace)
        t0 = monotonic()
        results = dyn.run(requests, gen_len=args.gen_len)
        dt = monotonic() - t0
        st = dyn.engine.stats()
        counts = dyn.engine.compile_counts()
        assert counts["decode"] == {"dynamic": 1}, (
            f"dynamic decode grew extra executables: {counts['decode']}")
        assert counts["adapter_insert"] == 1, counts
        print(f"fleet: {n_req} requests x {args.fleet} tenants through "
              f"{args.batch} slots in {dt:.2f}s "
              f"({st.generated_tokens / dt:.1f} tok/s, "
              f"{st.stack_inserts} stack inserts, ONE dynamic decode "
              f"executable)")
        if args.temperature <= 0.0:
            # the fleet oracle: the same churny trace through a STATIC-
            # signature engine must stream bitwise-identical tokens —
            # while compiling one decode per distinct slot layout.
            static = EngineServer(mcfg, scfg, params, cache=cache,
                                  slots=args.batch, max_len=max_len,
                                  temperature=args.temperature,
                                  seed=args.seed)
            base = static.run(requests, gen_len=args.gen_len)
            for rs, rp in zip(results, base):
                assert rs.tokens.tolist() == rp.tokens.tolist(), (
                    rs.request_id, rs.tokens, rp.tokens)
            n_sigs = len(static.engine.compile_counts()["decode"])
            print(f"  dynamic greedy streams == static engine (oracle "
                  f"OK; static needed {n_sigs} decode signatures)")
        if trace is not None:
            _dump_obs(trace, dyn.engine, args)
        for r in results[:2]:
            print(f"  req{r.request_id}: P={len(r.prompt)} "
                  f"-> {r.tokens.tolist()} ({r.finish_reason})")
        return

    if args.continuous:
        from repro.launch.engine import FINISH_REASONS
        from repro.launch.faults import FaultPlan
        plan = FaultPlan.parse(args.inject) if args.inject else None
        faulty = plan is not None or args.deadline > 0 or args.priority > 0
        cache = AdapterStateCache.for_serving(mcfg, scfg)
        _, ad0, _ = build_state(mcfg, dcfg, args.seed + 1)
        cache.register("tenant-0", ad0)
        n_req = 2 * args.batch
        requests = [Request(rng.integers(
            0, mcfg.vocab_size,
            int(rng.integers(args.prompt_len // 2, args.prompt_len + 1)),
            dtype=np.int32), "tenant-0") for _ in range(n_req)]
        trace = (TraceRecorder()
                 if (args.trace_out or args.metrics_out) else None)
        server = EngineServer(mcfg, scfg, params, cache=cache,
                              slots=args.batch, max_len=max_len,
                              temperature=args.temperature, seed=args.seed,
                              speculative_k=args.speculative,
                              fault_plan=plan, paged=args.paged,
                              block_size=args.block_size or None,
                              trace=trace)
        t0 = monotonic()
        results = server.run(
            requests, gen_len=args.gen_len,
            deadline_ticks=args.deadline if args.deadline > 0 else None,
            priority=([0] * (n_req - 1) + [args.priority]
                      if args.priority > 0 else 0))
        dt = monotonic() - t0
        st = server.engine.stats()
        print(f"continuous: {n_req} mixed-length requests through "
              f"{args.batch} slots in {dt:.2f}s "
              f"({st.generated_tokens / dt:.1f} tok/s, "
              f"occupancy {st.mean_occupancy:.2f}, "
              f"{st.decode_steps} decode steps)")
        if faulty:
            # The fault-containment smoke: every request finishes exactly
            # once with a valid reason, the slot table drains, and the
            # ladder's counters are visible to the operator.
            hist: dict[str, int] = {}
            for r in results:
                hist[r.finish_reason] = hist.get(r.finish_reason, 0) + 1
            assert len(results) == n_req
            assert all(r.finish_reason in FINISH_REASONS for r in results)
            assert not server.engine.has_work(), "slot table did not drain"
            print(f"  faults: inject={args.inject or '-'} "
                  f"deadline={args.deadline or '-'} "
                  f"priority={args.priority or '-'} -> finish reasons "
                  f"{sorted(hist.items())}")
            print(f"  counters: timeouts={st.timeouts} "
                  f"quarantined={st.quarantined} "
                  f"preemptions={st.preemptions} "
                  f"injected_nans={st.injected_nans} "
                  f"forced_evictions={st.forced_evictions} "
                  f"stale_injected={st.stale_injected} "
                  f"slow_ticks={st.slow_ticks}")
        if args.paged:
            ps = server.engine.pool_stats()
            assert ps["used_blocks"] == 0, f"leaked blocks: {ps}"
            assert ps["per_slot_blocks"] == [0] * args.batch, ps
            counts = server.engine.compile_counts()
            assert counts["prefill_chunk"] == 1, counts
            print(f"  paged: block_size={ps['block_size']} "
                  f"n_blocks={ps['n_blocks']} "
                  f"chunk={ps['prefill_chunk']} "
                  f"peak_used={ps['peak_used_blocks']} blocks "
                  f"(pool drained)")
            if args.temperature <= 0.0 and not faulty:
                # the paged greedy oracle: the same requests through a
                # RECTANGULAR engine must stream bitwise-identical tokens.
                rect = EngineServer(mcfg, scfg, params, cache=cache,
                                    slots=args.batch, max_len=max_len,
                                    temperature=args.temperature,
                                    seed=args.seed)
                base = rect.run(requests, gen_len=args.gen_len)
                for rs, rp in zip(results, base):
                    assert rs.tokens.tolist() == rp.tokens.tolist(), (
                        rs.request_id, rs.tokens, rp.tokens)
                print("  paged greedy streams == rectangular engine "
                      "(oracle OK)")
        if args.speculative > 0 and args.temperature <= 0.0 and not faulty:
            # the greedy-oracle check: same requests through a PLAIN
            # engine must yield bitwise-identical token streams.
            plain = EngineServer(mcfg, scfg, params, cache=cache,
                                 slots=args.batch, max_len=max_len,
                                 temperature=args.temperature,
                                 seed=args.seed)
            base = plain.run(requests, gen_len=args.gen_len)
            for rs, rp in zip(results, base):
                assert rs.tokens.tolist() == rp.tokens.tolist(), (
                    rs.request_id, rs.tokens, rp.tokens)
            print(f"  speculative k={args.speculative}: "
                  f"{st.verify_steps} verify + {st.draft_steps} draft "
                  f"steps, {st.accepted_drafts} drafts accepted; greedy "
                  f"streams == plain engine (oracle OK)")
        if trace is not None:
            _dump_obs(trace, server.engine, args)
        for r in results[:2]:
            print(f"  req{r.request_id}: P={len(r.prompt)} "
                  f"-> {r.tokens.tolist()} ({r.finish_reason})")
        return

    if args.tenants > 1:
        cache = AdapterStateCache.for_serving(mcfg, scfg)
        requests = []
        for t in range(args.tenants):
            _, ad_t, _ = build_state(mcfg, dcfg, args.seed + t)
            cache.register(f"tenant-{t}", ad_t)
            for _ in range(args.batch):
                requests.append(Request(
                    rng.integers(0, mcfg.vocab_size, args.prompt_len,
                                 dtype=np.int32), f"tenant-{t}"))
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        t0 = monotonic()
        toks = np.asarray(server.serve(requests, gen_len=args.gen_len,
                                       max_len=max_len,
                                       temperature=args.temperature,
                                       seed=args.seed))
        dt = monotonic() - t0
        st = cache.stats()
        print(f"served {len(requests)} requests x {args.tenants} tenants "
              f"in {dt:.2f}s ({len(requests) * args.gen_len / dt:.1f} "
              f"tok/s); cache: {st.hits} hits / {st.misses} misses / "
              f"{st.current_bytes} state bytes")
        for b in range(min(len(requests), 2)):
            print(f"  req{b}: ...{toks[b, args.prompt_len - 4:].tolist()}")
        return

    prompts = rng.integers(0, mcfg.vocab_size,
                           (args.batch, args.prompt_len), dtype=np.int32)
    t0 = monotonic()
    toks = generate(mcfg, params, adapters, scfg, prompts,
                    gen_len=args.gen_len, max_len=max_len,
                    temperature=args.temperature, seed=args.seed,
                    cache_adapters=not args.no_adapter_cache,
                    fold_gsb=args.fold_gsb)
    dt = monotonic() - t0
    toks = np.asarray(toks)
    print(f"generated [{toks.shape[0]}, {toks.shape[1]}] in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: ...{toks[b, args.prompt_len - 4:].tolist()}")


if __name__ == "__main__":
    main()
