"""Serving driver: batched prefill + decode with per-request adapter routing.

CPU-runnable with a smoke config::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --batch 2 --prompt-len 32 --gen-len 16 [--tenants 3]

Implements the production serving shape (docs/serving.md):

  - **one jitted precompute per adapter set** — the frozen-adapter state
    (w_norm/g cached once; the decode loop does zero factored-norm work
    per token), held in an :class:`repro.core.AdapterStateCache` LRU keyed
    by (adapter id, version, dtype, sharding) with byte-bounded eviction;
  - **request-routed batches** — every request carries an adapter handle;
    :class:`MultiTenantServer` groups the batch's rows by adapter and
    serves heterogeneous-adapter batches in ONE prefill/decode step via
    the grouped gsB-folded compose (``repro.core.dora_linear_grouped``).
    Homogeneous batches take today's single-tenant path bitwise;
  - **shape-bucketed prefill** — one jitted prefill (prompt right-padded
    to ``max_len``, true P traced) serves every prompt length on
    attention-only archs, with the cache length rewound to P; one jitted
    decode step is re-used per token (cache donated = in place).

Sampling is greedy/temperature on the host — the device step is exactly
the ``serve_step`` the ``decode_*``/``long_*`` dry-run cells lower.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import DoRAConfig
from repro.core.adapter import stack_adapter_states
from repro.core.adapter_cache import (AdapterHandle, AdapterStateCache,
                                      mesh_fingerprint)
from repro.launch.steps import StepConfig, make_decode_step, \
    make_precompute_step, make_prefill_step
from repro.launch.train import build_state


def _check_cache_mesh(cache: AdapterStateCache, mesh) -> None:
    """The cache keys states on the mesh they were pinned for — serving
    them under a DIFFERENT mesh would re-lay-out g/gsB every step, the
    exact per-token work the cache exists to remove. Refuse loudly."""
    want = mesh_fingerprint(mesh)
    if cache.sharding != want:
        raise ValueError(
            f"adapter cache is keyed for sharding {cache.sharding} but "
            f"serving runs on mesh {want} — build the cache with "
            f"AdapterStateCache.for_serving(mcfg, scfg, mesh) for THIS "
            f"mesh so cached states land pre-pinned to its shardings")


def _sample(last, temperature, key):
    if temperature > 0.0:
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(sub, last / temperature, axis=-1)
    else:
        nxt = jnp.argmax(last, axis=-1)
    return nxt.astype(jnp.int32)[:, None], key


def _decode_loop(prefill, decode, params, adapters, toks, *, prompt_len,
                 gen_len, pad, temperature, seed, collect_logits=False):
    """The shared prefill → sample → decode loop. Returns (tokens
    [B, P+gen_len], logits-per-sampled-token list or None)."""
    P = prompt_len
    batch_in = {"tokens": toks}
    if pad:
        batch_in = {"tokens": jnp.pad(toks, ((0, 0), (0, pad))),
                    "prompt_len": jnp.asarray(P, jnp.int32)}
    logits, cache = prefill(params, adapters, batch_in)
    # The decode contract: the cache stands at exactly the true prompt
    # length, so the first generated token is written at position P.
    # (Hard errors, not asserts — the contract must survive python -O.)
    if int(cache["len"]) != P:
        raise RuntimeError(
            f"prefill left cache at {int(cache['len'])}, expected {P}")

    key = jax.random.PRNGKey(seed)
    out = [toks]
    steps_logits = [] if collect_logits else None
    last = logits
    for i in range(gen_len):
        if collect_logits:
            steps_logits.append(np.asarray(last))
        nxt, key = _sample(last, temperature, key)
        out.append(nxt)
        last, cache = decode(params, adapters, cache, {"tokens": nxt})
        if i == 0 and int(cache["len"]) != P + 1:
            raise RuntimeError(
                f"decode wrote at {int(cache['len']) - 1}, expected {P}")
    return jnp.concatenate(out, axis=1), steps_logits


def generate(mcfg, params, adapters, scfg: StepConfig, prompts, *,
             gen_len: int, max_len: int, temperature: float = 0.0,
             seed: int = 0, cache_adapters: bool = True,
             fold_gsb: bool = False, mesh=None, adapter_cache=None,
             allow_miss: bool = True, return_logits: bool = False):
    """prompts: int32 [B, P]. Returns tokens [B, P+gen_len] (or
    (tokens, per-step logits) when ``return_logits``).

    ``adapters`` is either an adapter tree (single-tenant, as before) or
    an :class:`~repro.core.AdapterHandle` resolved through
    ``adapter_cache`` (an :class:`~repro.core.AdapterStateCache`). A
    handle that misses the cache while ``allow_miss=False`` is rejected
    with an error naming the key fields — the guard against a caller
    swapping adapters without re-precomputing and silently serving stale
    logits. A stale handle (version behind the registry) is ALWAYS
    rejected.

    ``cache_adapters``: precompute the frozen-adapter serving state (cached
    g) before prefill — bitwise-identical tokens, no per-token norm work.
    ``fold_gsb``: additionally fold g·s into B (broadcast-free decode
    compose; last-ulp numerics difference, so off by default).
    ``mesh``: SPMD serving — the precompute pins the cached state to the
    serving shardings (gsB row-sharded like B) and prefill/decode attach
    the boundary constraints, so the sharded steps run the same
    matmul-fused compose as the single-device loop.
    """
    if isinstance(adapters, AdapterHandle):
        if adapter_cache is None:
            raise ValueError(
                f"generate() was handed the adapter handle {adapters} but "
                f"no adapter_cache to resolve it against")
        _check_cache_mesh(adapter_cache, mesh)
        adapters = adapter_cache.get_state(params, adapters,
                                           allow_miss=allow_miss)
    elif cache_adapters:
        adapters = jax.jit(make_precompute_step(
            mcfg, scfg, mesh, fold_gsb=fold_gsb))(params, adapters)

    B, P = prompts.shape
    if max_len < P + gen_len:
        raise ValueError(f"max_len={max_len} < P+gen_len={P + gen_len}")

    # Padded prefill (attention-only archs): pad the prompt to max_len and
    # pass the true P as a traced scalar — ONE compiled prefill covers
    # every prompt length in the bucket; the step rewinds the cache length
    # to P. SSM states integrate every processed token and cannot rewind,
    # so hybrid/Mamba archs prefill at the exact P.
    can_pad = all(k == "attn" for k in mcfg.layer_kinds())
    pad = max_len - P if can_pad else 0
    prefill = jax.jit(make_prefill_step(
        mcfg, scfg, mesh, batch=B, seq=max_len, padded=bool(pad)))
    decode = jax.jit(make_decode_step(mcfg, scfg, mesh, batch=B),
                     donate_argnums=(2,))
    toks = jnp.asarray(prompts, jnp.int32)
    tokens, logits = _decode_loop(
        prefill, decode, params, adapters, toks, prompt_len=P,
        gen_len=gen_len, pad=pad, temperature=temperature, seed=seed,
        collect_logits=return_logits)
    return (tokens, logits) if return_logits else tokens


# ---------------------------------------------------------------------------
# Multi-tenant request routing.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: a prompt row and the adapter it runs under
    (an :class:`AdapterHandle`, or a bare adapter-id string meaning "the
    current registered version")."""
    prompt: Any                        # int32 [P]
    adapter: AdapterHandle | str


class MultiTenantServer:
    """Request-routed serving over an :class:`AdapterStateCache`.

    ``serve(requests)`` resolves each request's adapter handle through the
    LRU (precomputing on a miss unless ``allow_miss=False``), sorts the
    batch's rows so same-adapter rows are contiguous, and runs ONE
    prefill + decode loop for the whole heterogeneous batch:

      - one distinct adapter → the single-tenant path, byte-for-byte
        today's serve loop (bitwise fast path);
      - K > 1 adapters → the per-tenant states are stacked leaf-wise
        ([n_scan, K, ...]) and the steps are compiled against the STATIC
        group signature ((start, size) per tenant); each group's rows run
        the same gsB-folded ops as the homogeneous path, so mixed batches
        are bitwise-equal (fp32) to per-tenant sequential serving for
        groups of ≥ 2 rows, and the grouped decode step's jaxpr has zero
        ``dora_wnorm`` ops (no norm work per token).

    Steps are cached per (batch, bucket, signature) — a new grouping
    signature compiles once, like a new prompt-length bucket.
    """

    def __init__(self, mcfg, scfg: StepConfig, params, *,
                 cache: AdapterStateCache, mesh=None,
                 max_cached_steps: int = 32):
        _check_cache_mesh(cache, mesh)
        self.mcfg = mcfg
        self.scfg = scfg
        self.params = params
        self.cache = cache
        self.mesh = mesh
        # Compiled (prefill, decode) pairs per (batch, bucket, grouping
        # signature), LRU-bounded: churny request mixes produce many
        # signatures, and each entry pins two jitted executables — the
        # step cache must not grow unboundedly while the adapter states
        # one field away are carefully byte-bounded.
        self.max_cached_steps = max_cached_steps
        from collections import OrderedDict
        self._steps: "OrderedDict" = OrderedDict()

    def _resolve(self, req: Request) -> AdapterHandle:
        if isinstance(req.adapter, AdapterHandle):
            return req.adapter
        return self.cache.current_handle(req.adapter)

    def _get_steps(self, *, batch: int, max_len: int, pad: bool,
                   groups):
        key = (batch, max_len, pad, groups)
        if key in self._steps:
            self._steps.move_to_end(key)
            return self._steps[key]
        prefill = jax.jit(make_prefill_step(
            self.mcfg, self.scfg, self.mesh, batch=batch, seq=max_len,
            padded=pad, tenant_groups=groups))
        decode = jax.jit(make_decode_step(
            self.mcfg, self.scfg, self.mesh, batch=batch,
            tenant_groups=groups), donate_argnums=(2,))
        self._steps[key] = (prefill, decode)
        while len(self._steps) > self.max_cached_steps:
            self._steps.popitem(last=False)
        return self._steps[key]

    def serve(self, requests: Sequence[Request], *, gen_len: int,
              max_len: int, temperature: float = 0.0, seed: int = 0,
              allow_miss: bool = True, return_logits: bool = False):
        """Serve one batch. Returns tokens [B, P+gen_len] in REQUEST order
        (or (tokens, per-step logits) when ``return_logits``)."""
        if not requests:
            raise ValueError("empty request batch")
        prompts = [np.asarray(r.prompt, np.int32) for r in requests]
        P = prompts[0].shape[-1]
        if any(p.shape[-1] != P for p in prompts):
            raise ValueError(
                f"all prompts in one batch must share a length bucket; got "
                f"{sorted({p.shape[-1] for p in prompts})} — bucket "
                f"requests by prompt length before batching")
        if max_len < P + gen_len:
            raise ValueError(f"max_len={max_len} < P+gen_len={P + gen_len}")

        # Resolve handles (LRU hit / precompute-on-miss / reject), then
        # group rows by adapter: stable sort by first appearance, so
        # same-adapter rows are contiguous and the grouping signature is
        # deterministic in request order.
        handles = [self._resolve(r) for r in requests]
        order: dict[AdapterHandle, int] = {}
        for h in handles:
            order.setdefault(h, len(order))
        perm = sorted(range(len(requests)), key=lambda i: order[handles[i]])
        inv = np.argsort(perm)
        states = {h: self.cache.get_state(self.params, h,
                                          allow_miss=allow_miss)
                  for h in order}

        toks = jnp.asarray(np.stack([prompts[i] for i in perm]), jnp.int32)
        B = toks.shape[0]
        if len(order) == 1:
            adapters = next(iter(states.values()))
            groups = None          # single tenant: today's bitwise path
        else:
            adapters = stack_adapter_states(
                [states[h] for h in order], axis=1)
            sizes = [0] * len(order)
            for h in handles:
                sizes[order[h]] += 1
            groups, start = [], 0
            for n in sizes:
                groups.append((start, n))
                start += n
            groups = tuple(groups)

        can_pad = all(k == "attn" for k in self.mcfg.layer_kinds())
        pad = max_len - P if can_pad else 0
        prefill, decode = self._get_steps(batch=B, max_len=max_len,
                                          pad=bool(pad), groups=groups)
        tokens, logits = _decode_loop(
            prefill, decode, self.params, adapters, toks, prompt_len=P,
            gen_len=gen_len, pad=pad, temperature=temperature, seed=seed,
            collect_logits=return_logits)
        tokens = jnp.asarray(np.asarray(tokens)[inv])
        if return_logits:
            return tokens, [step[inv] for step in logits]
        return tokens


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=16.0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-adapter-cache", action="store_true",
                    help="skip the frozen-adapter precompute (recompute "
                         "the factored norm every step — debug only)")
    ap.add_argument("--fold-gsb", action="store_true",
                    help="fold g*s into B in the serving state "
                         "(broadcast-free decode compose)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="N>1: multi-tenant demo — N adapter sets in one "
                         "LRU-cached batch, --batch rows EACH, served in "
                         "one grouped decode loop")
    args = ap.parse_args()

    mcfg = get_config(args.arch, smoke=args.smoke)
    dcfg = DoRAConfig(rank=args.rank, alpha=args.alpha, mode="auto")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, args.seed)

    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen_len

    if args.tenants > 1:
        cache = AdapterStateCache.for_serving(mcfg, scfg)
        requests = []
        for t in range(args.tenants):
            _, ad_t, _ = build_state(mcfg, dcfg, args.seed + t)
            cache.register(f"tenant-{t}", ad_t)
            for _ in range(args.batch):
                requests.append(Request(
                    rng.integers(0, mcfg.vocab_size, args.prompt_len,
                                 dtype=np.int32), f"tenant-{t}"))
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        t0 = time.time()
        toks = np.asarray(server.serve(requests, gen_len=args.gen_len,
                                       max_len=max_len,
                                       temperature=args.temperature,
                                       seed=args.seed))
        dt = time.time() - t0
        st = cache.stats()
        print(f"served {len(requests)} requests x {args.tenants} tenants "
              f"in {dt:.2f}s ({len(requests) * args.gen_len / dt:.1f} "
              f"tok/s); cache: {st.hits} hits / {st.misses} misses / "
              f"{st.current_bytes} state bytes")
        for b in range(min(len(requests), 2)):
            print(f"  req{b}: ...{toks[b, args.prompt_len - 4:].tolist()}")
        return

    prompts = rng.integers(0, mcfg.vocab_size,
                           (args.batch, args.prompt_len), dtype=np.int32)
    t0 = time.time()
    toks = generate(mcfg, params, adapters, scfg, prompts,
                    gen_len=args.gen_len, max_len=max_len,
                    temperature=args.temperature, seed=args.seed,
                    cache_adapters=not args.no_adapter_cache,
                    fold_gsb=args.fold_gsb)
    dt = time.time() - t0
    toks = np.asarray(toks)
    print(f"generated [{toks.shape[0]}, {toks.shape[1]}] in {dt:.2f}s "
          f"({args.batch * args.gen_len / dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: ...{toks[b, args.prompt_len - 4:].tolist()}")


if __name__ == "__main__":
    main()
