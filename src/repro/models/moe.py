"""Mixture-of-experts FFN: shared experts + routed top-k with capacity.

Dispatch strategy (TPU/GSPMD-friendly): tokens stay grouped by batch row, so
the scatter/gather that builds the per-expert capacity buffer has a leading
batch dimension sharded over (pod, data) — under SPMD both become fully local
(no cross-shard scatter). Expert weights are stacked [E, ...] and shard their
*hidden* dim over the model axis (MoE-TP): per-expert matmuls are einsums with
a contraction psum XLA inserts automatically, identical in shape to the dense
TP MLP. This avoids expert-parallel all-to-alls and works for expert counts
not divisible by the mesh (qwen2-moe's 60).

Buffer size is capacity-bound: cf * k * tokens * d_model — independent of E.
Dropped tokens (position >= capacity) contribute nothing (standard GShard
behaviour); the router can add a load-balance aux loss.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import DoRAConfig
from repro.models import layers as L
from repro.models.config import ModelConfig

_F32 = jnp.float32


def router_topk(x, w_router, cfg: ModelConfig):
    """x [G,S,D] → (weights [G,S,k] fp32, idx [G,S,k] int32, aux_loss)."""
    logits = (x.astype(_F32) @ w_router.astype(_F32).T)      # [G,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, cfg.top_k)          # [G,S,k]
    if cfg.renorm_topk:
        gate_w = gate_w / jnp.maximum(
            jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)
    aux = jnp.asarray(0.0, _F32)
    if cfg.router_aux_coef:
        # Switch-style load-balance loss: E * sum(f_e * p_e).
        E = cfg.num_experts
        me = jnp.mean(probs.reshape(-1, E), axis=0)
        ce = jnp.mean(
            (jax.nn.one_hot(gate_i.reshape(-1, cfg.top_k), E, dtype=_F32)
             .sum(axis=1)), axis=0) / cfg.top_k
        aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    return gate_w, gate_i, aux


def _dispatch_indices(gate_i, E: int, capacity: int):
    """Position of each (token, k) assignment within its expert's capacity
    buffer, via a cumsum over the flattened group sequence.

    gate_i: [G, N, k] int32 → (slot [G, N, k] int32 into [E*C], keep mask).
    """
    G, N, k = gate_i.shape
    flat = gate_i.reshape(G, N * k)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)          # [G, N*k, E]
    pos = jnp.cumsum(onehot, axis=1) - 1                       # pos in expert
    pos = jnp.take_along_axis(pos, flat[..., None], axis=2)[..., 0]
    keep = pos < capacity
    slot = flat * capacity + jnp.minimum(pos, capacity - 1)
    return slot.reshape(G, N, k), keep.reshape(G, N, k)


def _expert_ffn(buf, p, dora, dcfg, mcfg: ModelConfig, *, training):
    """buf [G, E, C, D] → [G, E, C, D] through stacked swiglu experts.

    Expert weights: gate/up [E, F, D], down [E, D, F]. DoRA adaptation of the
    routed experts (optional) vmaps the adapted linear over E.
    """
    act = jax.nn.silu if mcfg.mlp_kind == "swiglu" else jax.nn.gelu

    def dense():
        h = jnp.einsum("gecd,efd->gecf", buf, p["gate"])
        u = jnp.einsum("gecd,efd->gecf", buf, p["up"])
        h = act(h) * u
        return jnp.einsum("gecf,edf->gecd", h, p["down"])

    if dora is None:
        sg = jax.lax.stop_gradient
        p = {k: sg(v) for k, v in p.items()}
        return dense()

    # DoRA-adapted experts: vmap dora_linear over the expert dim.
    def one(bufe, we_gate, we_up, we_down, de):
        x = bufe  # [G*C? — here [G, C, D] after moveaxis]
        h = L.maybe_dora(x, we_gate, de.get("gate"), dcfg, training=training)
        u = L.maybe_dora(x, we_up, de.get("up"), dcfg, training=training)
        h = act(h) * u
        return L.maybe_dora(h, we_down, de.get("down"), dcfg,
                            training=training)

    bufE = jnp.moveaxis(buf, 1, 0)  # [E, G, C, D]
    outE = jax.vmap(one)(bufE, p["gate"], p["up"], p["down"], dora)
    return jnp.moveaxis(outE, 0, 1)


def moe_ffn(x, p, dora, mcfg: ModelConfig, dcfg: DoRAConfig | None, *,
            training: bool = True):
    """x [B, S, D] → (y [B, S, D], aux_loss).

    p: {"router": [E, D], "gate"/"up": [E, F, D], "down": [E, D, F],
        optional "shared": swiglu params, "shared_gate": [1, D]}.
    dora: {"shared": {...}, "experts": {...}} or None.

    ``mcfg.moe_seq_chunks = nc > 1`` (set by the launch layer to the
    sequence-parallel shard count) makes the dispatch CHUNK-LOCAL
    (H2.4): the sequence folds into nc groups aligned with the SP
    shards, so the capacity-buffer scatter/gather and their backward
    cotangent scatters never cross shards — the per-layer buffer-sized
    all-reduces over the model axis disappear. Capacity becomes
    per-chunk (cf·k·S_loc/E): statistically the same load, and
    boundary-local drops replace global ones (GShard semantics either
    way).
    """
    nc = mcfg.moe_seq_chunks
    if nc > 1 and x.shape[1] % nc == 0 and (x.shape[1] // nc) > 0:
        B0, S0, D0 = x.shape
        xc = x.reshape(B0 * nc, S0 // nc, D0)
        y, aux = moe_ffn(
            xc, p, dora, dataclasses.replace(mcfg, moe_seq_chunks=0),
            dcfg, training=training)
        return y.reshape(B0, S0, D0), aux

    G, S, D = x.shape
    E, k = mcfg.num_experts, mcfg.top_k
    dora = dora or {}

    gate_w, gate_i, aux = router_topk(x, jax.lax.stop_gradient(p["router"]),
                                      mcfg)
    capacity = max(int(mcfg.capacity_factor * k * S / E), 1)

    slot, keep = _dispatch_indices(gate_i, E, capacity)        # [G,S,k]
    # Scatter tokens into the capacity buffer [G, E*C, D]; dropped → zeros.
    # Dispatch stays in the activation dtype (bf16): every buffer slot
    # receives at most one token, so no accumulation precision is lost,
    # and the buffer-sized collectives halve (EXPERIMENTS.md §Perf H2.1).
    upd = jnp.where(keep[..., None], x[:, :, None, :],
                    jnp.zeros((), x.dtype))
    upd = upd.reshape(G, S * k, D)                             # [G, S*k, D]
    buf = jnp.zeros((G, E * capacity, D), x.dtype)
    buf = buf.at[jnp.arange(G)[:, None], slot.reshape(G, S * k)].add(
        upd, mode="drop")
    buf = buf.reshape(G, E, capacity, D)

    out_buf = _expert_ffn(buf, p, dora.get("experts"), dcfg, mcfg,
                          training=training)                   # [G,E,C,D]
    out_buf = out_buf.reshape(G, E * capacity, D)

    # Gather back and combine with gate weights. The combine runs in the
    # activation dtype end to end (H2.1b): an fp32 einsum here makes the
    # whole backward cotangent chain — including the buffer-sized scatter
    # all-reduces — fp32, doubling MoE collective bytes.
    picked = jnp.take_along_axis(
        out_buf, slot.reshape(G, S * k)[..., None], axis=1)    # [G,S*k,D]
    picked = picked.reshape(G, S, k, D)
    w = jnp.where(keep, gate_w, 0.0)
    y = jnp.einsum("gskd,gsk->gsd", picked, w.astype(x.dtype))

    if mcfg.num_shared_experts:
        sh = L.mlp_swiglu(x, p["shared"], dora.get("shared"), dcfg,
                          training=training)
        if "shared_gate" in p:
            sg = jax.nn.sigmoid(
                x.astype(_F32) @ jax.lax.stop_gradient(
                    p["shared_gate"]).astype(_F32).T)           # [G,S,1]
            sh = sh.astype(_F32) * sg
        y = y + sh.astype(_F32)
    return y.astype(x.dtype), aux
