"""Mamba-1 selective SSM block (falcon-mamba / Jamba mamba layers).

TPU adaptation notes:
  - The depthwise causal conv (k=4) is expressed as a sum of shifted slices
    (4 adds) instead of a grouped convolution — elementwise in d_inner, so it
    shards cleanly over the model axis.
  - The selective scan runs chunked: ``jax.lax.scan`` carries the [B, di, n]
    state across chunks of ``ssm_chunk`` tokens; within a chunk the linear
    recurrence h_t = a_t h_{t-1} + b_t is a ``jax.lax.associative_scan`` over
    the chunk (parallel prefix — maps to the VPU, avoids the [B,S,di,n]
    full-sequence materialization).
  - Everything between in_proj and out_proj is elementwise (or contracts only
    dt_rank/state dims), so d_inner is the natural TP axis: in_proj
    row-sharded, out_proj col-sharded (psum), scan state sharded on di.

Decode carries {"h": [B, di, n], "conv": [B, k-1, di]} per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import DoRAConfig
from repro.models import layers as L
from repro.models.config import ModelConfig

_F32 = jnp.float32


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv over seq via shifted adds.

    x [B, S, di]; w [k, di]; b [di]; cache [B, k-1, di] (decode) or None.
    Returns (y [B, S, di], new_cache [B, k-1, di]).
    """
    k = w.shape[0]
    if cache is None:
        ctx = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = jnp.zeros_like(x, dtype=_F32)
    for j in range(k):
        y = y + w[j].astype(_F32) * ctx[:, j:j + S].astype(_F32)
    y = y + b.astype(_F32)
    new_cache = ctx[:, -(k - 1):] if k > 1 else ctx[:, :0]
    return y.astype(x.dtype), new_cache


def _ssm_scan_fused(dt, dtx, Bm, Cm, A, h0, w: int):
    """Fused chunked selective scan: h_t = exp(dt_t A) ⊙ h_{t-1} +
    (dt_t x_t) ⊗ B_t;  y_t = Σ_n h_t C_t.

    dt, dtx: [B, S, di] fp32; Bm, Cm: [B, S, n] fp32; A: [di, n];
    h0: [B, di, n]. Returns (y [B, S, di], h_final).

    Traffic-optimal XLA formulation (EXPERIMENTS.md §Perf cell 1): a
    ``lax.scan`` over S/w chunks whose body runs w UNROLLED recurrence
    steps — one fusion that reads the [B, w, di] / [B, w, n] slices once,
    keeps h and the [B, di, n] discretized terms in registers, and writes
    y once. The full-sequence [B, S, di, n] tensors a/b are never
    materialized (the associative-scan formulation materialized them plus
    O(log chunk) tree levels of the same size — ~550x the per-tensor
    bytes in HBM traffic). This is the same schedule the Pallas
    selective-scan kernel pins on TPU (kernels/selective_scan.py); the
    XLA version keeps the dry-run honest on CPU.
    """
    B, S, di = dt.shape
    n = A.shape[1]
    if S == 1:  # decode fast path
        a = jnp.exp(dt[:, 0][..., None] * A)
        b = dtx[:, 0][..., None] * Bm[:, 0][:, None, :]
        h = a * h0 + b
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])
        return y[:, None], h

    nc = -(-S // w)
    pad = nc * w - S
    if pad:
        # dt=0 -> a=1 (h unchanged); dtx=0 -> b=0: pads are no-ops.
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dtx = jnp.pad(dtx, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, nc, w, *x.shape[2:]), 1, 0)

    def body(h, xs):
        dt_c, dtx_c, B_c, C_c = xs            # [B, w, di] / [B, w, n]
        ys = []
        for j in range(w):                     # unrolled: one XLA fusion
            a_j = jnp.exp(dt_c[:, j][..., None] * A)
            b_j = dtx_c[:, j][..., None] * B_c[:, j][:, None, :]
            h = a_j * h + b_j
            ys.append(jnp.einsum("bdn,bn->bd", h, C_c[:, j]))
        return h, jnp.stack(ys, axis=1)        # [B, w, di]

    h_f, yc = jax.lax.scan(
        body, h0, (to_chunks(dt), to_chunks(dtx), to_chunks(Bm),
                   to_chunks(Cm)))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, nc * w, di)
    return y[:, :S], h_f


def _ssm_scan(a, b, C, h0, chunk: int):
    """h_t = a_t ⊙ h_{t-1} + b_t;  y_t = Σ_n h_t[:, :, n] C_t[:, n].

    a, b: [B, S, di, n] fp32; C: [B, S, n] fp32; h0: [B, di, n] fp32.
    Chunked: lax.scan over S/chunk chunks, associative_scan inside.
    Returns (y [B, S, di] fp32, h_final [B, di, n]).

    NOTE: kept as the ``ssm_impl="assoc"`` baseline for the §Perf
    ablation; the default path is ``_ssm_scan_fused`` (see EXPERIMENTS.md
    §Perf cell 1 — this formulation's associative-scan tree costs ~550x
    the tensor bytes in HBM traffic under XLA's lowering).
    """
    B, S, di, n = a.shape
    if S == 1:  # decode fast path
        h = a[:, 0] * h0 + b[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, C[:, 0])
        return y[:, None], h

    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    ac = jnp.moveaxis(a.reshape(B, nc, chunk, di, n), 1, 0)
    bc = jnp.moveaxis(b.reshape(B, nc, chunk, di, n), 1, 0)
    Cc = jnp.moveaxis(C.reshape(B, nc, chunk, n), 1, 0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def body(h, inp):
        ai, bi, Ci = inp
        # fold the carried state into the first step: b'_0 = a_0 h0 + b_0
        bi = bi.at[:, 0].add(ai[:, 0] * h)
        _, hs = jax.lax.associative_scan(combine, (ai, bi), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hs, Ci)
        return hs[:, -1], y

    h_f, ys = jax.lax.scan(body, h0, (ac, bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * chunk, di)
    return y[:, :S], h_f


def mamba_block(x, p, dora, mcfg: ModelConfig, dcfg: DoRAConfig | None, *,
                cache=None, training: bool = True, constrain=None):
    """x [B, S, D] → (y [B, S, D], new_cache).

    p: {"in_proj": [2di, D], "conv_w": [k, di], "conv_b": [di],
        "x_proj": [dtr+2n, di], "dt_proj": [di, dtr], "dt_bias": [di],
        "A_log": [di, n], "skip_d": [di], "out_proj": [D, di]}.
    """
    B, S, D = x.shape
    di, n, dtr = mcfg.d_inner, mcfg.ssm_state, mcfg.dt_rank
    dora = dora or {}

    xz = L.maybe_dora(x, p["in_proj"], dora.get("in_proj"), dcfg,
                      training=training)                       # [B,S,2di]
    xi, z = jnp.split(xz, 2, axis=-1)

    conv_cache = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_conv(xi, jax.lax.stop_gradient(p["conv_w"]),
                                jax.lax.stop_gradient(p["conv_b"]),
                                conv_cache)
    xi = jax.nn.silu(xi)

    sg = jax.lax.stop_gradient
    bcdt = xi @ sg(p["x_proj"]).T                              # [B,S,dtr+2n]
    dt_in, Bm, Cm = jnp.split(bcdt.astype(_F32), [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ sg(p["dt_proj"]).astype(_F32).T
                         + sg(p["dt_bias"]).astype(_F32))      # [B,S,di]

    A = -jnp.exp(sg(p["A_log"]).astype(_F32))                  # [di, n]
    h0 = (cache["h"].astype(_F32) if cache is not None
          else jnp.zeros((B, di, n), _F32))
    if mcfg.ssm_impl == "assoc":
        a = jnp.exp(dt[..., None] * A)                         # [B,S,di,n]
        b = (dt * xi.astype(_F32))[..., None] * Bm[:, :, None, :]
        y, h_f = _ssm_scan(a, b, Cm, h0, mcfg.ssm_chunk)
    else:
        dtx = dt * xi.astype(_F32)                             # [B,S,di]
        y, h_f = _ssm_scan_fused(dt, dtx, Bm, Cm, A, h0,
                                 mcfg.ssm_unroll)
    y = y + sg(p["skip_d"]).astype(_F32) * xi.astype(_F32)
    y = y * jax.nn.silu(z.astype(_F32))
    y = y.astype(x.dtype)

    # row-parallel projection: constrain output to SP sharding (H1.4)
    out = L.maybe_dora(y, p["out_proj"], dora.get("out_proj"), dcfg,
                       training=training, constrain=constrain)
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_f.astype(cache["h"].dtype), "conv": new_conv}
    return out, new_cache
