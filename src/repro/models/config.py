"""Model configuration covering all 10 assigned architectures.

One dataclass describes dense GQA transformers, MoE (shared + routed top-k),
pure Mamba-1 stacks, hybrid mamba+attention interleaves (Jamba) and the
modality-frontend backbones (VLM patches / EnCodec audio tokens). The configs
in ``repro/configs`` instantiate it with the exact published numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                  # 0 for attention-free stacks
    num_kv_heads: int
    d_ff: int                       # dense-MLP hidden (0 = no MLP sublayer)
    vocab_size: int

    head_dim: int = 0               # 0 → d_model // num_heads
    # --- attention ---
    qk_norm: bool = False
    qkv_bias: bool = False
    pos_mode: str = "rope"          # rope | rope_partial | mrope | sinusoidal
    rope_theta: float = 10000.0
    rotary_dim: int = 0             # for rope_partial (ChatGLM 2d-RoPE)
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    attn_chunk: int | None = None   # online-softmax KV chunk (None = dense)
    # --- mlp ---
    mlp_kind: str = "swiglu"        # swiglu | gelu  (gelu = plain 2-mat MLP)
    norm_kind: str = "rms"          # rms | layer
    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0            # shared-expert hidden (qwen2-moe: 5632)
    moe_d_ff: int = 0               # routed-expert hidden
    moe_period: int = 1             # MoE FFN every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # >1: chunk-local MoE dispatch aligned with the SP shards (H2.4);
    # set by the launch layer to the model-axis size, 0/1 = global.
    moe_seq_chunks: int = 0
    renorm_topk: bool = True        # renormalize top-k gate weights
    router_aux_coef: float = 0.0    # load-balance aux loss coefficient
    # --- SSM (Mamba-1) ---
    ssm: bool = False
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                # 0 → ceil(d_model / 16)
    ssm_chunk: int = 256            # chunked-scan length (assoc impl)
    # "fused_chunk": w-unrolled recurrence per scan chunk, a/b computed on
    #   the fly (traffic-optimal; see EXPERIMENTS.md §Perf cell 1).
    # "assoc": full-S a/b materialization + associative_scan (baseline).
    ssm_impl: str = "fused_chunk"
    ssm_unroll: int = 16            # tokens per unrolled chunk (fused)
    # --- hybrid interleave (Jamba: attn every 8th layer, index 4) ---
    attn_period: int = 0            # 0 = not hybrid
    attn_index: int = 4
    # --- misc ---
    norm_eps: float = 1e-5
    frontend: str | None = None     # None | "patches" | "audio_tokens"
    dtype: Any = jnp.bfloat16
    # remat: "none" | "layer" (recompute layer internals, save boundaries)
    remat: str = "layer"

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if self.dt_rank == 0 and self.ssm:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))
        if self.moe and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived --
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kinds(self) -> list[str]:
        """Sequence-mixer kind per layer: 'attn' or 'mamba'."""
        if self.ssm and not self.attn_period:
            return ["mamba"] * self.num_layers
        if self.attn_period:
            return ["attn" if i % self.attn_period == self.attn_index
                    else "mamba" for i in range(self.num_layers)]
        return ["attn"] * self.num_layers

    def ffn_kinds(self) -> list[str]:
        """FFN kind per layer: 'moe' | 'mlp' | 'none'."""
        out = []
        for i in range(self.num_layers):
            if self.moe and i % self.moe_period == self.moe_period - 1:
                out.append("moe")
            elif self.d_ff > 0:
                out.append("mlp")
            else:
                out.append("none")
        return out

    @property
    def is_uniform(self) -> bool:
        """True when every layer is identical (single scan stack)."""
        return (len(set(self.layer_kinds())) == 1
                and len(set(self.ffn_kinds())) == 1)

    @property
    def period(self) -> int:
        """Length of the repeating layer pattern (scan unit)."""
        if self.is_uniform:
            return 1
        p = self.attn_period or 1
        if self.moe and self.moe_period > 1:
            import math
            p = p * self.moe_period // math.gcd(p, self.moe_period)
        assert self.num_layers % p == 0, (self.num_layers, p)
        return p

    def count_params(self) -> int:
        """Total parameter count (embeddings + head included)."""
        D, V = self.d_model, self.vocab_size
        total = 2 * V * D + D  # embed + head + final norm
        kinds = list(zip(self.layer_kinds(), self.ffn_kinds()))
        for lk, fk in kinds:
            total += D  # ln1
            if lk == "attn":
                total += (self.q_dim * D + 2 * self.kv_dim * D
                          + D * self.q_dim)
                if self.qkv_bias:
                    total += self.q_dim + 2 * self.kv_dim
                if self.qk_norm:
                    total += 2 * self.head_dim
            else:
                di, n, dtr = self.d_inner, self.ssm_state, self.dt_rank
                total += (2 * di * D + di * self.ssm_conv + di
                          + (dtr + 2 * n) * di + di * dtr + di
                          + di * n + di + D * di)
            if fk != "none":
                total += D  # ln2
            if fk == "mlp":
                total += (3 if self.mlp_kind == "swiglu" else 2) * self.d_ff * D
            elif fk == "moe":
                total += self.num_experts * (3 * self.moe_d_ff * D) \
                    + self.num_experts * D
                if self.num_shared_experts:
                    total += 3 * self.shared_d_ff * D + D
        return total

    def count_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.moe:
            return self.count_params()
        full = self.count_params()
        n_moe = sum(1 for k in self.ffn_kinds() if k == "moe")
        routed_all = n_moe * self.num_experts * 3 * self.moe_d_ff * self.d_model
        routed_active = n_moe * self.top_k * 3 * self.moe_d_ff * self.d_model
        return full - routed_all + routed_active
