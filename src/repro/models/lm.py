"""LM assembly: parameter specs/init, scan-over-layers forward, KV caches.

The layer stack is represented as ONE scan unit (the repeating layer pattern
— a single layer for uniform archs, 8 layers for Jamba's 1:7 interleave)
whose params are stacked along a leading scan dim. ``jax.lax.scan`` over the
stack keeps trace/compile size O(period), independent of depth — essential
for 64-80L configs lowered against 512 devices.

Adapters mirror the param tree: every adapted linear leaf holds
{"A","B","m"} stacked the same way, so the same scan slices both.

Caches: attention {"k","v"} [T]-indexed ring + mamba {"h","conv"} states,
stacked per scan unit; "len" is carried outside the scan — a scalar for
training/static serving, or a [B] per-row length vector for the
continuous-batching engine (``init_cache(row_lens=True)``), where every
batch row stands at its own position and requests join/leave mid-decode.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import tree as ctree
from repro.core import DoRAConfig
from repro.core.adapter import init_dora_params
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models.config import ModelConfig

_F32 = jnp.float32

DEFAULT_DORA_TARGETS = ("wq", "wk", "wv", "wo",
                        "w_gate", "w_up", "w_down",
                        "in_proj", "out_proj")


# ---------------------------------------------------------------------------
# Parameter spec construction. Leaves are (init_kind, shape) tuples turned
# into ShapeDtypeStructs (dry-run) or initialized arrays (smoke/train).
# ---------------------------------------------------------------------------

def _norm_spec(mcfg, D=None):
    D = D or mcfg.d_model
    s = {"scale": ("ones", (D,))}
    if mcfg.norm_kind == "layer":
        s["bias"] = ("zeros", (D,))
    return s


def _attn_spec(mcfg: ModelConfig):
    D, qd, kvd = mcfg.d_model, mcfg.q_dim, mcfg.kv_dim
    s = {"wq": ("linear", (qd, D)), "wk": ("linear", (kvd, D)),
         "wv": ("linear", (kvd, D)), "wo": ("linear", (D, qd))}
    if mcfg.qkv_bias:
        s["wq_bias"] = ("zeros", (qd,))
        s["wk_bias"] = ("zeros", (kvd,))
        s["wv_bias"] = ("zeros", (kvd,))
    if mcfg.qk_norm:
        s["q_norm"] = ("ones", (mcfg.head_dim,))
        s["k_norm"] = ("ones", (mcfg.head_dim,))
    return s


def _mamba_spec(mcfg: ModelConfig):
    D, di, n = mcfg.d_model, mcfg.d_inner, mcfg.ssm_state
    dtr, k = mcfg.dt_rank, mcfg.ssm_conv
    return {"in_proj": ("linear", (2 * di, D)),
            "conv_w": ("conv", (k, di)), "conv_b": ("zeros", (di,)),
            "x_proj": ("linear", (dtr + 2 * n, di)),
            "dt_proj": ("linear", (di, dtr)), "dt_bias": ("dt_bias", (di,)),
            "A_log": ("a_log", (di, n)), "skip_d": ("ones", (di,)),
            "out_proj": ("linear", (D, di))}


def _mlp_spec(mcfg: ModelConfig, ff: int):
    D = mcfg.d_model
    if mcfg.mlp_kind == "swiglu":
        return {"w_gate": ("linear", (ff, D)), "w_up": ("linear", (ff, D)),
                "w_down": ("linear", (D, ff))}
    return {"w_up": ("linear", (ff, D)), "w_up_bias": ("zeros", (ff,)),
            "w_down": ("linear", (D, ff)), "w_down_bias": ("zeros", (D,))}


def _moe_spec(mcfg: ModelConfig):
    D, E, F = mcfg.d_model, mcfg.num_experts, mcfg.moe_d_ff
    s = {"router": ("linear", (E, D)),
         "gate": ("linear3", (E, F, D)), "up": ("linear3", (E, F, D)),
         "down": ("linear3", (E, D, F))}
    if mcfg.num_shared_experts:
        s["shared"] = _mlp_spec(mcfg, mcfg.shared_d_ff)
        s["shared_gate"] = ("linear", (1, D))
    return s


def _layer_spec(mcfg: ModelConfig, kind: str, ffn: str):
    s: dict[str, Any] = {"ln1": _norm_spec(mcfg)}
    s["mixer"] = _attn_spec(mcfg) if kind == "attn" else _mamba_spec(mcfg)
    if ffn != "none":
        s["ln2"] = _norm_spec(mcfg)
        s["ffn"] = _moe_spec(mcfg) if ffn == "moe" else _mlp_spec(mcfg,
                                                                  mcfg.d_ff)
    return s


def unit_spec(mcfg: ModelConfig):
    """The repeating scan unit: {"l0": layer, ..., "l{p-1}": layer}."""
    kinds, ffns = mcfg.layer_kinds(), mcfg.ffn_kinds()
    p = mcfg.period
    return {f"l{i}": _layer_spec(mcfg, kinds[i], ffns[i]) for i in range(p)}


def model_spec(mcfg: ModelConfig):
    D, V = mcfg.d_model, mcfg.vocab_size
    return {"embed": ("embed", (V, D)),
            "stack": unit_spec(mcfg),
            "final_norm": _norm_spec(mcfg),
            "head": ("linear", (V, D))}


def _is_leaf_spec(x):
    return (isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], str))


def _map_spec(fn, spec):
    return ctree.map(fn, spec, is_leaf=_is_leaf_spec)


def param_shapes(mcfg: ModelConfig):
    """ShapeDtypeStruct tree — dry-run params, never allocated."""
    n_scan = mcfg.num_layers // mcfg.period

    def to_sds(leaf):
        kind, shape = leaf
        return jax.ShapeDtypeStruct(shape, mcfg.dtype)

    spec = model_spec(mcfg)
    out = {}
    for k, v in spec.items():
        if k == "stack":
            out[k] = _map_spec(
                lambda leaf: jax.ShapeDtypeStruct(
                    (n_scan,) + leaf[1], mcfg.dtype), v)
        else:
            out[k] = _map_spec(to_sds, v)
    return out


def _init_leaf(key, kind, shape, dtype):
    if kind in ("zeros",):
        return jnp.zeros(shape, dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    if kind == "dt_bias":
        # softplus(dt_bias) ≈ dt ∈ [1e-3, 1e-1] (mamba init)
        u = jax.random.uniform(key, shape, _F32,
                               math.log(1e-3), math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if kind == "a_log":
        di, n = shape
        return jnp.broadcast_to(
            jnp.log(jnp.arange(1, n + 1, dtype=_F32)), (di, n)).astype(dtype)
    if kind == "conv":
        k, di = shape
        bound = 1.0 / math.sqrt(k)
        return jax.random.uniform(key, shape, dtype, -bound, bound)
    if kind == "embed":
        return (0.02 * jax.random.normal(key, shape, _F32)).astype(dtype)
    # linear / linear3: fan-in scaled normal; d_in is the last dim.
    fan_in = shape[-1]
    w = jax.random.normal(key, shape, _F32) / math.sqrt(fan_in)
    return w.astype(dtype)


def init_params(key, mcfg: ModelConfig):
    n_scan = mcfg.num_layers // mcfg.period
    spec = model_spec(mcfg)
    _, treedef = ctree.flatten(spec, is_leaf=_is_leaf_spec)
    # Stable per-leaf keys via fold_in of the leaf index.
    paths = ctree.flatten_with_path(spec, is_leaf=_is_leaf_spec)[0]
    leaves = []
    for i, ((path, leaf)) in enumerate(paths):
        kind, shape = leaf
        in_stack = path and ctree.path_key(path[0]) == "stack"
        k = jax.random.fold_in(key, i)
        if in_stack:
            ks = jax.random.split(k, n_scan)
            leaves.append(jax.vmap(
                lambda kk: _init_leaf(kk, kind, shape, mcfg.dtype))(ks))
        else:
            leaves.append(_init_leaf(k, kind, shape, mcfg.dtype))
    return ctree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# DoRA adapter trees.
# ---------------------------------------------------------------------------

def _adapted_paths(mcfg: ModelConfig, targets):
    """Paths (tuples of keys) into the stack unit that get adapters, with
    their (d_out, d_in)."""
    out = []

    def walk(spec, path):
        for k, v in spec.items():
            if _is_leaf_spec(v):
                kind, shape = v
                if k in targets and kind == "linear" and len(shape) == 2:
                    out.append((path + (k,), shape))
            else:
                walk(v, path + (k,))

    walk(unit_spec(mcfg), ())
    return out


def adapter_shapes(mcfg: ModelConfig, dcfg: DoRAConfig,
                   targets=DEFAULT_DORA_TARGETS):
    """ShapeDtypeStruct tree of adapters (stacked over the scan dim)."""
    n_scan = mcfg.num_layers // mcfg.period
    r = dcfg.rank
    tree: dict[str, Any] = {}
    for path, (d_out, d_in) in _adapted_paths(mcfg, targets):
        node = tree
        for k in path[:-1]:
            node = node.setdefault(k, {})
        leaf = {
            "A": jax.ShapeDtypeStruct((n_scan, r, d_in), mcfg.dtype),
            "B": jax.ShapeDtypeStruct((n_scan, d_out, r), mcfg.dtype),
            "m": jax.ShapeDtypeStruct((n_scan, d_out), _F32),
        }
        if dcfg.cache_base_norm:
            leaf["base_sq"] = jax.ShapeDtypeStruct((n_scan, d_out), _F32)
        node[path[-1]] = leaf
    return {"stack": tree}


def init_adapters(key, mcfg: ModelConfig, params, dcfg: DoRAConfig,
                  targets=DEFAULT_DORA_TARGETS):
    """A ~ U(±1/√d_in), B = 0, m = ||W||_row (DoRA init) per layer slice."""
    tree: dict[str, Any] = {}
    for i, (path, _) in enumerate(_adapted_paths(mcfg, targets)):
        W = params["stack"]
        for k in path:
            W = W[k]                                  # [n_scan, d_out, d_in]
        k_i = jax.random.fold_in(key, i)
        leaf = init_dora_params(k_i, W, dcfg)         # vmapped over n_scan
        node = tree
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = leaf
    return {"stack": tree}


def adapter_param_count(mcfg: ModelConfig, dcfg: DoRAConfig,
                        targets=DEFAULT_DORA_TARGETS) -> int:
    n_scan = mcfg.num_layers // mcfg.period
    total = 0
    for _, (d_out, d_in) in _adapted_paths(mcfg, targets):
        total += n_scan * (dcfg.rank * d_in + d_out * dcfg.rank + d_out)
    return total


# ---------------------------------------------------------------------------
# Caches.
# ---------------------------------------------------------------------------

def cache_shapes(mcfg: ModelConfig, batch: int, max_len: int,
                 dtype=None, *, row_lens: bool = False,
                 block_size: int | None = None,
                 n_blocks: int | None = None):
    """ShapeDtypeStruct tree for the decode cache.

    ``row_lens=True``: continuous-batching cache — ``"len"`` is a ``[B]``
    int32 vector of per-row cache lengths instead of one scalar, so every
    slot of the batch stands at its own position (requests join/leave
    mid-decode; see ``repro.launch.engine``). The scalar form stays the
    default for training/static serving.

    ``block_size``: block-PAGED cache — per-layer K/V become a shared
    block pool ``[n_scan, n_blocks, block_size, Hkv, hd]`` (no batch
    dim), addressed through a per-row block table ``"pages"``
    ``[batch, max_len // block_size]`` int32 (``-1`` = unallocated, reads
    as zeros). ``n_blocks`` defaults to ``batch * max_len // block_size``
    (paged == rectangular bytes at full allocation; the engine sizes it
    smaller to realize the HBM win). Requires ``row_lens=True`` and an
    attention-only arch — paging is a serving-cache layout, and SSM
    states are O(1) per row, not positional."""
    dtype = dtype or mcfg.dtype
    n_scan = mcfg.num_layers // mcfg.period
    kinds = mcfg.layer_kinds()
    paged = block_size is not None
    if paged:
        if not row_lens:
            raise ValueError("paged cache requires row_lens=True "
                             "(per-row frontiers address the block table)")
        if max_len % block_size != 0:
            raise ValueError(f"max_len={max_len} must be a multiple of "
                             f"block_size={block_size}")
        if any(k != "attn" for k in kinds):
            raise ValueError(f"paged cache requires an attention-only "
                             f"arch; {mcfg.name!r} has {kinds}")
        max_blocks = max_len // block_size
        if n_blocks is None:
            n_blocks = batch * max_blocks
    unit: dict[str, Any] = {}
    for i in range(mcfg.period):
        if kinds[i] == "attn":
            kv_shape = ((n_scan, n_blocks, block_size, mcfg.num_kv_heads,
                         mcfg.head_dim) if paged else
                        (n_scan, batch, max_len, mcfg.num_kv_heads,
                         mcfg.head_dim))
            unit[f"l{i}"] = {
                "k": jax.ShapeDtypeStruct(kv_shape, dtype),
                "v": jax.ShapeDtypeStruct(kv_shape, dtype),
            }
        else:
            unit[f"l{i}"] = {
                "h": jax.ShapeDtypeStruct(
                    (n_scan, batch, mcfg.d_inner, mcfg.ssm_state), _F32),
                "conv": jax.ShapeDtypeStruct(
                    (n_scan, batch, mcfg.ssm_conv - 1, mcfg.d_inner), dtype),
            }
    out = {"stack": unit,
           "len": jax.ShapeDtypeStruct((batch,) if row_lens else (),
                                       jnp.int32)}
    if paged:
        out["pages"] = jax.ShapeDtypeStruct((batch, max_blocks), jnp.int32)
    return out


def init_cache(mcfg: ModelConfig, batch: int, max_len: int, dtype=None, *,
               row_lens: bool = False, block_size: int | None = None,
               n_blocks: int | None = None):
    shapes = cache_shapes(mcfg, batch, max_len, dtype, row_lens=row_lens,
                          block_size=block_size, n_blocks=n_blocks)
    cache = ctree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    if "pages" in cache:
        # -1 = unallocated: a zeroed table would alias every row to
        # block 0.
        cache["pages"] = jnp.full(shapes["pages"].shape, -1, jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------

def _apply_norm(x, p, mcfg: ModelConfig):
    if mcfg.norm_kind == "layer":
        x32 = x.astype(_F32)
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + mcfg.norm_eps)
        y = y * p["scale"].astype(_F32) + p["bias"].astype(_F32)
        return y.astype(x.dtype)
    return L.rms_norm(x, p["scale"], mcfg.norm_eps)


def _layer_apply(x, p, a, c, mcfg, dcfg, *, kind, ffn, positions, length,
                 training, constrain=None, tenant_groups=None, pages=None):
    """One layer: pre-norm mixer + pre-norm FFN, residual adds.

    c: None (no cache) or this layer's cache dict. Returns (x, new_cache,
    aux_loss). ``constrain`` pins the sublayer outputs to the
    sequence-parallel sharding so the row-parallel TP partial sums lower
    to reduce-scatter instead of all-reduce (EXPERIMENTS.md §Perf H1.4).
    ``tenant_groups``: multi-tenant serving — adapted linears apply the
    per-group folded adapter state (attention/dense-MLP archs only)."""
    aux = jnp.asarray(0.0, _F32)
    cst = constrain or (lambda t: t)
    if tenant_groups is not None and (kind != "attn" or ffn == "moe"):
        raise NotImplementedError(
            f"multi-tenant grouped serving supports attention + dense-MLP "
            f"layers only; arch {mcfg.name!r} has a "
            f"{'moe ffn' if ffn == 'moe' else kind} layer")
    h = _apply_norm(x, p["ln1"], mcfg)
    if kind == "attn":
        attn_cache = None
        if c is not None:
            attn_cache = {"k": c["k"], "v": c["v"], "len": length}
            if pages is not None:
                attn_cache["pages"] = pages
        y, new_c = L.attention(h, p["mixer"], (a or {}).get("mixer"), mcfg,
                               dcfg, positions=positions, cache=attn_cache,
                               training=training, constrain=constrain,
                               tenant_groups=tenant_groups)
        if new_c is not None:
            new_c = {"k": new_c["k"], "v": new_c["v"]}
    else:
        mcache = {"h": c["h"], "conv": c["conv"]} if c is not None else None
        y, new_c = M.mamba_block(h, p["mixer"], (a or {}).get("mixer"),
                                 mcfg, dcfg, cache=mcache,
                                 training=training, constrain=constrain)
    x = x + cst(y)
    if ffn != "none":
        h = _apply_norm(x, p["ln2"], mcfg)
        if ffn == "moe":
            y, aux = MOE.moe_ffn(h, p["ffn"], (a or {}).get("ffn"), mcfg,
                                 dcfg, training=training)
        elif mcfg.mlp_kind == "swiglu":
            y = L.mlp_swiglu(h, p["ffn"], (a or {}).get("ffn"), dcfg,
                             training=training, constrain=constrain,
                             tenant_groups=tenant_groups)
        else:
            d = (a or {}).get("ffn") or {}
            y = L.maybe_dora(h, p["ffn"]["w_up"], d.get("w_up"), dcfg,
                             bias=p["ffn"]["w_up_bias"], training=training,
                             tenant_groups=tenant_groups)
            y = jax.nn.gelu(y)
            y = L.maybe_dora(y, p["ffn"]["w_down"], d.get("w_down"), dcfg,
                             bias=p["ffn"]["w_down_bias"], training=training,
                             tenant_groups=tenant_groups)
        x = x + cst(y)
    return x, new_c, aux


def forward(mcfg: ModelConfig, params, adapters, dcfg: DoRAConfig | None,
            *, tokens=None, embeds=None, cache=None, positions=None,
            training: bool = True, boundary_constraint=None,
            loss_slice: int | None = None, gather_position=None,
            tenant_groups=None):
    """Returns (logits [B,S,V], new_cache, aux_loss).

    tokens [B,S] int32 OR embeds [B,S,D] (modality-frontend stubs feed
    precomputed patch/frame embeddings). cache: decode cache tree or None.

    ``boundary_constraint``: optional fn applied to the [B,S,D] activations
    at every scan-unit boundary — the hook the distribution layer uses to
    pin sequence-parallel sharding (saved remat residuals inherit it).
    ``loss_slice``: keep only the last N positions before the LM head
    (paper §5.1 partial-sequence loss — avoids the full-vocab logit spike).
    ``gather_position``: int32 scalar (traced OK) — keep ONLY this position
    before the final norm + LM head (logits come back [B, 1, V]); the
    shape-bucketed prefill uses it so the full-vocab head runs on exactly
    one row regardless of how much right-padding the bucket added.
    Overrides ``loss_slice``.
    ``tenant_groups``: multi-tenant serving — STATIC (start, size) row
    blocks grouping the batch by adapter, OR a TRACED int32 [B] array of
    per-row positions into the stacked tenant dim (dynamic fleet serving:
    tenant churn changes values, never the compile signature); either
    way ``adapters`` must be a stacked folded serving tree (leaves
    [n_scan, K, ...], see ``repro.core.stack_adapter_states``).
    Serving-only: requires ``training=False``.
    """
    if tenant_groups is not None and training:
        raise ValueError("tenant_groups is a serving-only path "
                         "(training=False required)")
    kinds, ffns = mcfg.layer_kinds(), mcfg.ffn_kinds()
    p = mcfg.period
    adapters = adapters or {}

    if embeds is None:
        emb = jax.lax.stop_gradient(params["embed"])
        x = jnp.take(emb, tokens, axis=0)
    else:
        x = embeds.astype(mcfg.dtype)
    B, S = x.shape[:2]

    length = cache["len"] if cache is not None else None
    if positions is None:
        pos_base = jnp.arange(S, dtype=jnp.int32)[None, :]
        if length is not None and getattr(length, "ndim", 0) == 1:
            # Continuous batching: per-row cache lengths [B] — every slot
            # positions its new tokens at its own depth.
            positions = pos_base + length[:, None].astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(
                pos_base if length is None else pos_base + length, (B, S))
    if mcfg.pos_mode == "sinusoidal":
        x = x + L.sinusoidal_embedding(positions, mcfg.d_model).astype(
            x.dtype)

    stack_p = params["stack"]
    stack_a = adapters.get("stack", {})
    stack_c = cache["stack"] if cache is not None else None
    # Paged serving cache: the per-row block table rides OUTSIDE the scan
    # (like "len") — one table addresses every layer's pool, and it is
    # read-only inside the forward (the engine owns allocation).
    pages = cache.get("pages") if cache is not None else None

    if boundary_constraint is not None:
        x = boundary_constraint(x)

    def unit_body(x, unit_p, unit_a, unit_c):
        aux_total = jnp.asarray(0.0, _F32)
        new_cs = {}
        for i in range(p):
            li = f"l{i}"
            c_i = unit_c[li] if unit_c is not None else None
            x, new_c, aux = _layer_apply(
                x, unit_p[li], unit_a.get(li), c_i, mcfg, dcfg,
                kind=kinds[i], ffn=ffns[i], positions=positions,
                length=length, training=training,
                constrain=boundary_constraint,
                tenant_groups=tenant_groups, pages=pages)
            if new_c is not None:
                new_cs[li] = new_c
            aux_total = aux_total + aux
        if boundary_constraint is not None:
            x = boundary_constraint(x)
        return x, new_cs, aux_total

    if mcfg.remat == "layer":
        unit_body = jax.checkpoint(
            unit_body,
            policy=jax.checkpoint_policies.save_only_these_names(
                "dora_wnorm"))

    if stack_c is None:
        def body(carry, xs):
            x, aux = carry
            unit_p, unit_a = xs
            x, _, aux_u = unit_body(x, unit_p, unit_a, None)
            return (x, aux + aux_u), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.asarray(0.0, _F32)),
                                   (stack_p, stack_a))
        new_cache = None
    else:
        def body(carry, xs):
            x, aux = carry
            unit_p, unit_a, unit_c = xs
            x, new_cs, aux_u = unit_body(x, unit_p, unit_a, unit_c)
            return (x, aux + aux_u), new_cs

        (x, aux), new_stack_c = jax.lax.scan(
            body, (x, jnp.asarray(0.0, _F32)), (stack_p, stack_a, stack_c))
        new_cache = {"stack": new_stack_c, "len": length + S}
        if pages is not None:
            new_cache["pages"] = pages

    if gather_position is not None:
        x = jax.lax.dynamic_slice_in_dim(x, gather_position, 1, axis=1)
    elif loss_slice is not None and loss_slice < x.shape[1]:
        x = x[:, -loss_slice:]
    x = _apply_norm(x, params["final_norm"], mcfg)
    head = jax.lax.stop_gradient(params["head"])
    logits = x @ head.T
    return logits, new_cache, aux
