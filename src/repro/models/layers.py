"""Shared NN layers: RMSNorm, RoPE variants, attention (GQA / qk-norm /
QKV-bias / M-RoPE / partial-rotary), SwiGLU MLP — with first-class DoRA
adaptation of every linear via ``maybe_dora``.

Weight convention follows the paper: [d_out, d_in], y = x @ Wᵀ, so the DoRA
row-norm is over dim 1.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import DoRAConfig
from repro.core.adapter import dora_linear
from repro.core.dispatch import plan_gather
from repro.kernels.paged_gather import (paged_gather, paged_gather_ref,
                                        paged_scatter)

_F32 = jnp.float32


def linear(x, w, bias=None):
    y = x @ w.T
    if bias is not None:
        y = y + bias
    return y


def maybe_dora(x, w, dora: dict | None, cfg: DoRAConfig | None, *,
               bias=None, training: bool = True, constrain=None,
               base_sq_cache=None, tenant_groups=None):
    """Adapted linear if a DoRA adapter is present, frozen linear otherwise.

    Base weights are *always* stop-gradiented here: in this framework the
    base model is frozen and only adapters train (PEFT semantics).
    ``constrain``: sharding for row-parallel outputs (H1.4) — a
    ``ComposeSharding`` plan or a plan-carrying/bare row-constraint
    callable; adapted linears pin the rank-space LoRA intermediate under
    it so the matmul-fused compose keeps firing under SPMD (no y_lora
    materialization — see ``repro.core.sharding``).
    ``base_sq_cache``: precomputed ||W||²_row (paper §2.3 future work —
    implemented here; see H3.2): skips the rank-independent base-norm
    term, the only part of the norm that re-reads W.
    ``tenant_groups``: multi-tenant serving — static (start, size) row
    blocks grouping the batch by adapter, with ``dora`` leaves carrying a
    leading tenant dim (see ``repro.core.dora_linear_grouped``). The base
    weight is shared across tenants, so the unadapted branch ignores it.
    """
    if dora is None:
        y = linear(x, jax.lax.stop_gradient(w), bias)
        return constrain(y) if constrain is not None else y
    return dora_linear(x, w, dora, cfg, bias=bias, training=training,
                       constrain=constrain, base_sq_cache=base_sq_cache,
                       tenant_groups=tenant_groups)


def rms_norm(x, scale, eps: float = 1e-5):
    x32 = x.astype(_F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(_F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard / partial / M-RoPE) + sinusoidal.
# ---------------------------------------------------------------------------

def _rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=_F32) / dim))


def rope_cos_sin(positions, dim: int, theta: float):
    """positions [..., S] int → cos/sin [..., S, dim//2] fp32."""
    freqs = _rope_freqs(dim, theta)
    angles = positions.astype(_F32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x [..., S, H, hd]; cos/sin [..., S, hd//2] (broadcast over heads).
    Rotates interleaved pairs (x_even, x_odd)."""
    x32 = x.astype(_F32)
    x1 = x32[..., 0::2]
    x2 = x32[..., 1::2]
    c = cos[..., None, :]
    s = sin[..., None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def apply_rope_partial(x, cos, sin, rotary_dim: int):
    """ChatGLM-style 2D/partial RoPE: rotate only the first ``rotary_dim``
    channels of each head; pass the rest through."""
    rot, rest = x[..., :rotary_dim], x[..., rotary_dim:]
    rot = apply_rope(rot, cos, sin)
    return jnp.concatenate([rot, rest], axis=-1)


def mrope_cos_sin(positions3, dim: int, theta: float,
                  sections: tuple[int, int, int]):
    """M-RoPE (Qwen2-VL): three position streams (t, h, w) each rotating a
    section of the head-dim pairs. positions3: [3, B, S].

    For pure-text (and our stub frontends) the three streams coincide and
    M-RoPE degenerates to standard RoPE — but the section plumbing is real.
    """
    assert sum(sections) == dim // 2, (sections, dim)
    cos_t, sin_t = rope_cos_sin(positions3[0], dim, theta)
    cos_h, sin_h = rope_cos_sin(positions3[1], dim, theta)
    cos_w, sin_w = rope_cos_sin(positions3[2], dim, theta)
    s0, s1, _ = sections
    pick = lambda t, h, w: jnp.concatenate(
        [t[..., :s0], h[..., s0:s0 + s1], w[..., s0 + s1:]], axis=-1)
    return pick(cos_t, cos_h, cos_w), pick(sin_t, sin_h, sin_w)


def sinusoidal_embedding(positions, dim: int):
    """MusicGen-style fixed sinusoidal embeddings added to the input."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=_F32) / half)
    angles = positions.astype(_F32)[..., None] * freqs
    return jnp.concatenate([jnp.cos(angles), jnp.sin(angles)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA) with optional qk-norm, bias, rope variants and KV cache.
# ---------------------------------------------------------------------------

def _causal_mask_bias(q_len: int, kv_len: int, offset, dtype):
    """Causal mask as an additive fp32 bias; ``offset`` = absolute position
    of the first query row (0 for training, cache length for decode) — a
    scalar, or a ``[B]`` vector of per-row cache lengths (continuous
    batching: every slot stands at its own position, so every row gets its
    own causal frontier). Returns ``[q_len, kv_len]`` for a scalar offset,
    ``[B, q_len, kv_len]`` for a vector one."""
    offset = jnp.asarray(offset)
    q_pos = offset[..., None] + jnp.arange(q_len)      # [q] or [B, q]
    k_pos = jnp.arange(kv_len)
    ok = k_pos <= q_pos[..., None]                     # [..., q, kv]
    return jnp.where(ok, 0.0, -1e30).astype(_F32)


def attention_core(q, k, v, *, offset=0, chunk: int | None = None):
    """Grouped-query attention core. q: [B,S,Hq,hd]; k/v: [B,T,Hkv,hd].
    KV heads are never materialized repeated: queries are reshaped to
    [B,S,Hkv,group,hd] and contracted against the shared KV head.

    ``chunk``: online-softmax over KV chunks (memory-efficient attention)
    for long sequences — the S×T score matrix is never materialized whole.
    """
    b, s, hq, hd = q.shape
    _, t, hkv, _ = k.shape
    group = hq // hkv
    # Mixed-precision attention (H3.2 cell 3): tensors stay in the input
    # dtype (bf16); every contraction accumulates in fp32
    # (preferred_element_type) and the softmax statistics are fp32 — the
    # flash-attention precision discipline. Materializing K/V/probs in
    # fp32 doubled the dominant HBM + all-to-all traffic of long-seq
    # cells.
    qg = q.reshape(b, s, hkv, group, hd)
    scale = 1.0 / math.sqrt(hd)

    if chunk is None or t <= chunk:
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                            preferred_element_type=_F32) * scale
        bias = _causal_mask_bias(s, t, offset, _F32)
        # scalar offset: one [s, t] mask for every row; per-row offsets
        # ([B]): a [B, s, t] mask broadcast over the (kv-head, group) dims.
        scores = scores + (bias[None, None, None] if bias.ndim == 2
                           else bias[:, None, None])
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(q.dtype), v,
                         preferred_element_type=_F32)
        return out.reshape(b, s, hq, hd).astype(q.dtype)

    # Online softmax over KV chunks (flash-style, lax.scan over chunks).
    if jnp.ndim(offset) != 0:
        raise NotImplementedError(
            "per-row cache offsets are only supported on the dense "
            "attention path (decode s==1 and short prefills); the chunked "
            "online-softmax scan assumes one causal frontier per batch")
    nchunks = -(-t // chunk)
    pad = nchunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, chunk, hkv, hd)
    vc = v.reshape(b, nchunks, chunk, hkv, hd)
    q_pos = offset + jnp.arange(s)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        kci, vci, ci = inp
        k_pos = ci * chunk + jnp.arange(chunk)
        valid = (k_pos[None, :] <= q_pos[:, None]) & (k_pos < t)[None, :]
        bias = jnp.where(valid, 0.0, -1e30).astype(_F32)
        sc = jnp.einsum("bskgh,btkh->bkgst", qg, kci,
                        preferred_element_type=_F32) * scale
        sc = sc + bias[None, None, None]
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p.astype(q.dtype), vci,
            preferred_element_type=_F32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, group, s), -jnp.inf, _F32)
    l0 = jnp.zeros((b, hkv, group, s), _F32)
    acc0 = jnp.zeros((b, hkv, group, s, hd), _F32)
    # Flash-style backward (H3.3): remat the chunk body so the backward
    # recomputes scores/probs per chunk from q/k instead of stacking the
    # [nchunks, ..., s, chunk] probs as scan residuals — the probs stack
    # was the single largest HBM item of long-sequence training cells.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, acc0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, hq, hd)
    return out.astype(q.dtype)


def attention(x, params, dora, mcfg, dcfg: DoRAConfig | None, *,
              positions, cache=None, training=True, constrain=None,
              tenant_groups=None):
    """Full attention block: QKV (DoRA-adapted), rope, core, O-proj.

    Returns (out, new_cache). ``cache`` = {"k","v","len"} for decode; when
    provided, new K/V rows are written at position ``len`` and attention
    runs over the cache prefix. ``tenant_groups``: multi-tenant serving —
    forwarded to every adapted projection (the attention core itself is
    row-local and adapter-free).
    """
    b, s, _ = x.shape
    hq, hkv, hd = mcfg.num_heads, mcfg.num_kv_heads, mcfg.head_dim

    def proj(name, d_out):
        w = params[name]
        bias = params.get(name + "_bias")
        return maybe_dora(x, w, (dora or {}).get(name), dcfg,
                          bias=bias, training=training,
                          tenant_groups=tenant_groups)

    q = proj("wq", hq * hd).reshape(b, s, hq, hd)
    k = proj("wk", hkv * hd).reshape(b, s, hkv, hd)
    v = proj("wv", hkv * hd).reshape(b, s, hkv, hd)

    if mcfg.qk_norm:
        q = rms_norm(q, params["q_norm"], mcfg.norm_eps)
        k = rms_norm(k, params["k_norm"], mcfg.norm_eps)

    if mcfg.pos_mode == "rope":
        cos, sin = rope_cos_sin(positions, hd, mcfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    elif mcfg.pos_mode == "rope_partial":
        rd = mcfg.rotary_dim
        cos, sin = rope_cos_sin(positions, rd, mcfg.rope_theta)
        q = apply_rope_partial(q, cos, sin, rd)
        k = apply_rope_partial(k, cos, sin, rd)
    elif mcfg.pos_mode == "mrope":
        pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        cos, sin = mrope_cos_sin(pos3, hd, mcfg.rope_theta,
                                 mcfg.mrope_sections)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    # "sinusoidal": absolute embeddings added at the input; nothing here.

    # NOTE (H3.4, refuted): pinning q/k/v to head-parallel sharding here
    # was measured to INCREASE collective time — with kv_heads < tp the
    # replicated K/V gradients partial-sum over the model axis and the
    # gathered-sequence backward adds a second reshard (EXPERIMENTS.md
    # §Perf cell 3). GSPMD's own choice (a2a on score tiles) is cheaper.

    if cache is None:
        out = attention_core(q, k, v, offset=0, chunk=mcfg.attn_chunk)
        new_cache = None
    else:
        pos = jnp.asarray(cache["len"])
        pages = cache.get("pages")
        if pages is not None and pos.ndim != 1:
            raise ValueError("paged K/V requires per-row lengths "
                             "(cache_shapes(..., row_lens=True))")
        if pages is not None:
            # Block-paged cache (launch/engine.py paged=True): gather the
            # per-layer block pools [n_blocks, bs, Hkv, hd] through the
            # per-row block table into the logical [B, max_len, Hkv, hd]
            # view, run the UNCHANGED per-row-frontier path below, and
            # scatter the written view back. Bitwise parity with the
            # rectangular cache is by construction: unallocated blocks
            # read as exact zeros, and every such position sits at/past
            # its row's causal frontier where the -1e30 bias already
            # drives the softmax weight to exactly 0.0. The table is a
            # traced operand — paging never recompiles.
            plan = plan_gather(dcfg, head_elems=hkv * hd)
            gather = (functools.partial(paged_gather,
                                        interpret=plan.interpret)
                      if plan.fused else paged_gather_ref)
            buf_k = gather(cache["k"], pages)
            buf_v = gather(cache["v"], pages)
        else:
            buf_k, buf_v = cache["k"], cache["v"]
        if pos.ndim == 1:
            # Continuous batching (launch/engine.py): "len" is a [B] vector
            # of per-row cache lengths — every slot writes its new K/V at
            # ITS OWN position, so requests at different depths share one
            # fixed-shape decode step.
            def _row_write(buf, new, p):
                zero = jnp.zeros((), p.dtype)
                return jax.lax.dynamic_update_slice(
                    buf, new, (p, zero, zero))

            ck = jax.vmap(_row_write)(
                buf_k, k.astype(buf_k.dtype), pos)
            cv = jax.vmap(_row_write)(
                buf_v, v.astype(buf_v.dtype), pos)
        else:
            zero = jnp.zeros((), pos.dtype)  # match index dtypes (x64-safe)
            ck = jax.lax.dynamic_update_slice(
                buf_k, k.astype(buf_k.dtype),
                (zero, pos, zero, zero))
            cv = jax.lax.dynamic_update_slice(
                buf_v, v.astype(buf_v.dtype),
                (zero, pos, zero, zero))
        # mask out unwritten cache rows via the causal offset: rows beyond
        # pos+s have k_pos > q_pos and are excluded by causality. Decode
        # (s == 1) always takes the dense-over-cache path: its score matrix
        # is [B, 1, Hq, T] — small — and chunking would only add scan steps.
        # Per-row offsets (pos.ndim == 1) also force the dense path: the
        # chunked scan assumes one causal frontier per batch, and every
        # per-row window (decode s==1, speculative verify s==k+1) is short.
        dense = s == 1 or pos.ndim == 1
        out = attention_core(q, ck, cv, offset=pos,
                             chunk=None if dense else mcfg.attn_chunk)
        if pages is not None:
            new_cache = {"k": paged_scatter(cache["k"], pages, ck),
                         "v": paged_scatter(cache["v"], pages, cv),
                         "len": pos + s}
        else:
            new_cache = {"k": ck, "v": cv, "len": pos + s}

    out = out.reshape(b, s, hq * hd)
    wo = params["wo"]
    # row-parallel projection: constrain output to SP sharding (H1.4)
    y = maybe_dora(out, wo, (dora or {}).get("wo"), dcfg,
                   training=training, constrain=constrain,
                   tenant_groups=tenant_groups)
    return y, new_cache


def mlp_swiglu(x, params, dora, dcfg: DoRAConfig | None, *, training=True,
               act=jax.nn.silu, constrain=None, tenant_groups=None):
    d = dora or {}
    gate = maybe_dora(x, params["w_gate"], d.get("w_gate"), dcfg,
                      training=training, tenant_groups=tenant_groups)
    up = maybe_dora(x, params["w_up"], d.get("w_up"), dcfg,
                    training=training, tenant_groups=tenant_groups)
    h = act(gate) * up
    # row-parallel projection: constrain output to SP sharding (H1.4)
    return maybe_dora(h, params["w_down"], d.get("w_down"), dcfg,
                      training=training, constrain=constrain,
                      tenant_groups=tenant_groups)
