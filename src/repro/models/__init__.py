"""Model zoo: dense/GQA, MoE, Mamba-1 SSM, hybrid stacks and modality
frontend stubs — every linear projection optionally DoRA-adapted."""
from repro.models.config import ModelConfig
from repro.models.lm import (
    forward, param_shapes, init_params, adapter_shapes, init_adapters,
    cache_shapes, init_cache, adapter_param_count, DEFAULT_DORA_TARGETS,
)

__all__ = [
    "ModelConfig", "forward", "param_shapes", "init_params",
    "adapter_shapes", "init_adapters", "cache_shapes", "init_cache",
    "adapter_param_count", "DEFAULT_DORA_TARGETS",
]
