"""Fused factored-norm kernel (paper §2, Algorithm 1) as a Pallas-TPU kernel.

Computes the two d_in-dependent factored-norm terms in a single VMEM-resident
pass over W:

    base_sq_j = Σ_k W_jk²                      (base term)
    cross_j   = Σ_l B_jl · U_jl,  U = W @ Aᵀ   (cross term)

Grid: (d_out tiles  ×  d_in chunks), with the chunk dimension sequential
("arbitrary") so the [1, block_rows] output blocks accumulate across chunk
steps — the TPU analogue of the paper's chunked fp32 accumulation, with the
chunk budget expressed as a BlockSpec instead of an allocator budget.

TPU-specific win vs. the eager factored path: W is read from HBM **once** for
both terms (the jnp path reads W twice — once for the row-square reduce, once
for the U matmul), and U_c lives only in VMEM/registers (never an HBM
round-trip). The Gram term G = A·Aᵀ and ba_sq = rowsum((B·G)⊙B) are O(r²)
and stay in jnp (they are rank-dependent but tiny: G ≤ 2.4 MB at r = 768).

The norm is detached (DoRA §4.3) so no backward kernel exists by design.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat.pallas import pl, tpu_compiler_params

_F32 = jnp.float32


def _norm_terms_kernel(w_ref, a_ref, b_ref, base_ref, cross_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        base_ref[...] = jnp.zeros_like(base_ref)
        cross_ref[...] = jnp.zeros_like(cross_ref)

    w = w_ref[...].astype(_F32)                    # [bm, bk]
    a = a_ref[...].astype(_F32)                    # [r, bk]
    b = b_ref[...].astype(_F32)                    # [bm, r]
    base_ref[...] += jnp.sum(w * w, axis=1)[None, :]
    u = jax.lax.dot_general(                       # U_c = W_c @ A_cᵀ  (MXU)
        w, a, (((1,), (1,)), ((), ())), preferred_element_type=_F32)
    cross_ref[...] += jnp.sum(b * u, axis=1)[None, :]


def norm_terms_pallas(W, A, B, *, block_rows: int, block_k: int,
                      interpret: bool = False):
    """Return (base_sq, cross) fp32 [d_out] for W [d_out, d_in], A [r, d_in],
    B [d_out, r]. d_out and d_in must be multiples of the block shape (the
    ops wrapper pads)."""
    d_out, d_in = W.shape
    r = A.shape[0]
    grid = (pl.cdiv(d_out, block_rows), pl.cdiv(d_in, block_k))
    out_shape = jax.ShapeDtypeStruct((1, d_out), _F32)
    base_sq, cross = pl.pallas_call(
        _norm_terms_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_k), lambda i, k: (i, k)),  # W
            pl.BlockSpec((r, block_k), lambda i, k: (0, k)),           # A
            pl.BlockSpec((block_rows, r), lambda i, k: (i, 0)),        # B
        ],
        out_specs=(
            pl.BlockSpec((1, block_rows), lambda i, k: (0, i)),
            pl.BlockSpec((1, block_rows), lambda i, k: (0, i)),
        ),
        out_shape=(out_shape, out_shape),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(W, A, B)
    return base_sq[0], cross[0]
