"""Pallas-TPU selective-scan kernel (Mamba-1 recurrence).

    h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + (dt_t·x_t) ⊗ B_t
    y_t = Σ_n h_t ⊙ C_t

The TPU adaptation of Mamba's hardware-aware scan: the recurrent state h
lives in VMEM scratch across sequence chunks; the discretized terms
a = exp(dt⊙A) and b = (dt·x)⊗B are computed in-register per token and
never touch HBM. Per-layer HBM traffic = read dt/dtx ([B,S,di]) + B/C
([B,S,n]) once + write y once — the roofline minimum — versus the
associative-scan XLA lowering's ~550x per-tensor traffic (EXPERIMENTS.md
§Perf cell 1).

Layout: the feature dim di is the 128-lane axis everywhere; the SSM state
dim n (=16) sits on sublanes, so h is carried as [n, block_di]. Grid =
(B, di_tiles, seq_chunks) with the chunk dim sequential ("arbitrary") —
for a fixed (batch, tile) the chunks iterate consecutively and the VMEM
scratch carries h; ``@pl.when(k == 0)`` reloads h0 at each new tile.

The within-chunk loop is a ``fori_loop`` over tokens: each step is a few
[n, block_di] VPU ops — exactly the unrolled-recurrence schedule the
``fused_chunk`` XLA path expresses, minus the loop-carry HBM round trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat.pallas import (pl, resolve_interpret, tpu_compiler_params,
                                 vmem)

_F32 = jnp.float32


def _scan_kernel(dt_ref, dtx_ref, b_ref, c_ref, at_ref, h0_ref,
                 y_ref, hout_ref, h_scr, *, chunk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        h_scr[...] = h0_ref[0]                      # [n, bd]

    at = at_ref[...]                                 # [n, bd]  (= A^T)

    def step(j, h):
        dt_j = dt_ref[0, j][None, :]                 # [1, bd]
        a_j = jnp.exp(dt_j * at)                     # [n, bd]
        b_j = dtx_ref[0, j][None, :] * b_ref[0, j][:, None]
        h = a_j * h + b_j
        y_ref[0, j] = jnp.sum(h * c_ref[0, j][:, None], axis=0)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h
    hout_ref[0] = h


def selective_scan_pallas(dt, dtx, Bm, Cm, A_t, h0_t, *,
                          block_di: int = 512, chunk: int = 64,
                          interpret: bool | None = None):
    """dt, dtx: [B, S, di]; Bm, Cm: [B, S, n]; A_t: [n, di];
    h0_t: [B, n, di] — all fp32, S % chunk == 0, di % block_di == 0.
    Returns (y [B, S, di], h_final [B, n, di])."""
    B, S, di = dt.shape
    n = A_t.shape[0]
    interpret = resolve_interpret(interpret)
    grid = (B, di // block_di, S // chunk)
    kern = functools.partial(_scan_kernel, chunk=chunk)
    y, h_f = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, i, k: (b, k, i)),
            pl.BlockSpec((1, chunk, block_di), lambda b, i, k: (b, k, i)),
            pl.BlockSpec((1, chunk, n), lambda b, i, k: (b, k, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, i, k: (b, k, 0)),
            pl.BlockSpec((n, block_di), lambda b, i, k: (0, i)),
            pl.BlockSpec((1, n, block_di), lambda b, i, k: (b, 0, i)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, block_di), lambda b, i, k: (b, k, i)),
            pl.BlockSpec((1, n, block_di), lambda b, i, k: (b, 0, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, S, di), _F32),
            jax.ShapeDtypeStruct((B, n, di), _F32),
        ),
        scratch_shapes=[vmem((n, block_di), _F32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dt, dtx, Bm, Cm, A_t, h0_t)
    return y, h_f
