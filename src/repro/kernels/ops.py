"""Jit'd public wrappers around the Pallas kernels.

  - ``fused_compose``: custom_vjp op — forward = fused compose kernel
    (optionally dual-output saving ``inner``), backward = fused backward
    kernel + deterministic jnp reduction for d_mag (paper §3.2).
  - ``fused_norm``: factored-norm terms kernel + jnp Gram term + assembly
    kernel; detached end-to-end (DoRA §4.3).

Both wrappers do the shape plumbing the paper's dispatch layer does on CUDA:
flatten leading dims, pad rows to the block shape, enforce the
d_out % 128 == 0 constraint (paper App. C), and accept an ``interpret`` flag
so the same kernels run on CPU for validation. ``interpret=None`` (default)
resolves through the capability probes: compiled on a TPU backend, the
Pallas interpreter anywhere else — so direct callers (tests, benchmarks)
never hardcode a host assumption.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat.pallas import resolve_interpret
from repro.kernels import dora_compose as _ck
from repro.kernels import factored_norm as _nk
from repro.kernels import norm_assembly as _ak

_F32 = jnp.float32


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pick_block_n(n: int, cap: int) -> int:
    """Largest multiple of 128 that divides n, at most cap."""
    if n % 128 != 0:
        raise ValueError(f"feature dim {n} not divisible by 128 "
                         "(paper App. C shape constraint)")
    for t in range(max(1, cap // 128), 0, -1):
        if n % (128 * t) == 0:
            return 128 * t
    return 128


def _pad_rows(x, bm: int):
    m = x.shape[0]
    pm = _round_up(m, bm)
    if pm == m:
        return x, m
    return jnp.pad(x, ((0, pm - m), (0, 0))), m


# ---------------------------------------------------------------------------
# Fused compose with custom VJP.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_compose(s: float, save_inner: bool, mag_grad: bool,
                  block_m: int, block_n: int, interpret: bool):
    def _prep(base, g):
        n = base.shape[-1]
        bn = pick_block_n(n, block_n)
        g32 = g.astype(_F32)
        gm1 = (g32 - 1.0).reshape(1, n)
        return bn, gm1, g32

    def _flatten(x):
        return x.reshape(-1, x.shape[-1])

    @jax.custom_vjp
    def compose(base, lora, g):
        out, _ = _fwd(base, lora, g)
        return out

    def _fwd(base, lora, g):
        shape = base.shape
        bn, gm1, g32 = _prep(base, g)
        b2, m = _pad_rows(_flatten(base), block_m)
        l2, _ = _pad_rows(_flatten(lora), block_m)
        bm = min(block_m, b2.shape[0])
        if save_inner and mag_grad:
            delta, inner = _ck.compose_fwd_pallas(
                b2, l2, gm1, s, save_inner=True,
                block_m=bm, block_n=bn, interpret=interpret)
            delta = delta[:m].reshape(shape)
            inner = inner[:m].reshape(shape)
            res = (g32, inner, None, None)
        else:
            delta = _ck.compose_fwd_pallas(
                b2, l2, gm1, s, save_inner=False,
                block_m=bm, block_n=bn, interpret=interpret)
            delta = delta[:m].reshape(shape)
            res = ((g32, None, base, lora) if mag_grad
                   else (g32, None, None, None))
        return delta, res

    def _bwd(res, dy):
        g32, inner, base, lora = res
        shape = dy.shape
        n = shape[-1]
        bn = pick_block_n(n, block_n)
        gm1 = (g32 - 1.0).reshape(1, n)
        gs = (g32 * s).reshape(1, n)
        dy2, m = _pad_rows(_flatten(dy), block_m)
        bm = min(block_m, dy2.shape[0])
        d_base, d_lora = _ck.compose_bwd_pallas(
            dy2, gm1, gs, block_m=bm, block_n=bn, interpret=interpret)
        d_base = d_base[:m].reshape(shape)
        d_lora = d_lora[:m].reshape(shape)
        if not mag_grad:
            d_g = jnp.zeros_like(g32)
        else:
            # d_g = Σ_rows dY ⊙ inner — separate deterministic reduction
            # (paper §3.2: .sum() instead of tl.atomic_add).
            if inner is None:
                inner32 = base.astype(_F32) + s * lora.astype(_F32)
            else:
                inner32 = inner.astype(_F32)
            d_g = jnp.sum(dy.astype(_F32) * inner32,
                          axis=tuple(range(dy.ndim - 1)))
        return d_base, d_lora, d_g

    def fwd(base, lora, g):
        return _fwd(base, lora, g)

    compose.defvjp(fwd, _bwd)
    return compose


def fused_compose(base, lora, g, s: float, *,
                  save_inner: bool = True,
                  mag_grad: bool = True,
                  block_m: int = 256, block_n: int = 1024,
                  interpret: bool | None = None):
    """delta = (g-1)⊙base + g⊙s⊙lora via the fused Pallas kernels.

    base/lora: [..., d_out] (input dtype); g: fp32 [d_out] (differentiable —
    carries the magnitude gradient unless ``mag_grad=False``, the paper's
    frozen-magnitude fast path that skips the ``inner`` save entirely).
    """
    fn = _make_compose(float(s), bool(save_inner), bool(mag_grad),
                       int(block_m), int(block_n),
                       resolve_interpret(interpret))
    return fn(base, lora, g)


# ---------------------------------------------------------------------------
# Matmul-fused compose with custom VJP: y_lora never reaches HBM.
# ---------------------------------------------------------------------------

def _pad_rank(x, rp: int):
    r = x.shape[-1]
    if rp == r:
        return x
    return jnp.pad(x, ((0, 0), (0, rp - r)))


@functools.lru_cache(maxsize=None)
def _make_compose_mm(s: float, mag_grad: bool, block_m: int, block_n: int,
                     interpret: bool):
    def _flatten(x):
        return x.reshape(-1, x.shape[-1])

    @jax.custom_vjp
    def compose(base, h, B, g):
        out, _ = fwd(base, h, B, g)
        return out

    def fwd(base, h, B, g):
        shape = base.shape
        n = shape[-1]
        r = B.shape[-1]
        bn = pick_block_n(n, block_n)
        rp = _round_up(r, 128)          # lane-width padding; zeros are inert
        g32 = g.astype(_F32)
        gm1 = (g32 - 1.0).reshape(1, n)
        b2, m = _pad_rows(_flatten(base), block_m)
        h2, _ = _pad_rows(_pad_rank(_flatten(h), rp), block_m)
        bm = min(block_m, b2.shape[0])
        delta = _ck.compose_mm_fwd_pallas(
            b2, h2, _pad_rank(B, rp), gm1, s,
            block_m=bm, block_n=bn, interpret=interpret)
        delta = delta[:m].reshape(shape)
        # Residuals are all tensors already live in the surrounding graph
        # (h is the x@Aᵀ activation, base is y_base) — unlike the Tier-1
        # dual-output path, nothing extra is materialized for the backward,
        # including the magnitude gradient (see _bwd).
        res = (g32, h, B, base if mag_grad else None)
        return delta, res

    def _bwd(res, dy):
        g32, h, B, base = res
        shape = dy.shape
        n = shape[-1]
        r = B.shape[-1]
        bn = pick_block_n(n, block_n)
        rp = _round_up(r, 128)
        gm1 = (g32 - 1.0).reshape(1, n)
        gs = (g32 * s).reshape(1, n)
        dy2, m = _pad_rows(_flatten(dy), block_m)
        bm = min(block_m, dy2.shape[0])
        d_base, d_h = _ck.compose_mm_bwd_pallas(
            dy2, _pad_rank(B, rp), gm1, gs,
            block_m=bm, block_n=bn, interpret=interpret)
        d_base = d_base[:m].reshape(shape)
        d_h = d_h[:m, :r].reshape(h.shape).astype(h.dtype)
        # d_B = (g·s) ⊙ (dYᵀ @ h): T is the one cross matmul the backward
        # cannot avoid (it also carries the lora half of d_g, so it is
        # computed once and reused — deterministic jnp reductions, paper
        # §3.2's .sum()-over-atomics choice).
        dy32 = _flatten(dy).astype(_F32)
        T = jax.lax.dot_general(
            dy32, _flatten(h).astype(_F32), (((0,), (0,)), ((), ())),
            preferred_element_type=_F32)                     # [n, r]
        d_B = ((g32 * s)[:, None] * T).astype(B.dtype)
        if not mag_grad:
            d_g = jnp.zeros_like(g32)
        else:
            # d_g = Σ_rows dY ⊙ (base + s·lora); the lora term contracts
            # through T: Σ_m dY⊙(hBᵀ) = rowsum(B ⊙ T).
            d_g = (jnp.sum(dy.astype(_F32) * base.astype(_F32),
                           axis=tuple(range(dy.ndim - 1)))
                   + s * jnp.sum(B.astype(_F32) * T, axis=1))
        return d_base, d_h, d_B, d_g

    compose.defvjp(fwd, _bwd)
    return compose


def fused_compose_mm(base, h, B, g, s: float, *,
                     mag_grad: bool = True,
                     block_m: int = 256, block_n: int = 1024,
                     interpret: bool | None = None):
    """delta = (g-1)⊙base + g⊙s⊙(h @ Bᵀ) with the up-projection fused.

    base: [..., d_out]; h = x@Aᵀ: [..., r]; B: [d_out, r]; g: fp32 [d_out].
    The [..., d_out] ``y_lora`` tensor is never materialized in HBM —
    forward reads (base, h, B) and writes delta only; backward reads dY
    once for both d_base and d_h (plus the unavoidable dYᵀ@h cross matmul
    for d_B / the magnitude gradient).
    """
    if base.shape[:-1] != h.shape[:-1]:
        raise ValueError(f"base leading dims {base.shape[:-1]} != h leading "
                         f"dims {h.shape[:-1]}")
    fn = _make_compose_mm(float(s), bool(mag_grad), int(block_m),
                          int(block_n), resolve_interpret(interpret))
    return fn(base, h, B, g)


# ---------------------------------------------------------------------------
# Fused factored norm.
# ---------------------------------------------------------------------------

def fused_norm(W, A, B, s: float, *,
               block_rows: int = 256, block_k: int = 512,
               interpret: bool | None = None, base_sq_cache=None):
    """Detached fp32 row-wise norm of W + s·B·A via the Pallas kernels."""
    interpret = resolve_interpret(interpret)
    W = jax.lax.stop_gradient(W)
    A = jax.lax.stop_gradient(A)
    B = jax.lax.stop_gradient(B)
    d_out, d_in = W.shape
    r = A.shape[0]
    br = pick_block_n(d_out, block_rows)  # d_out blocks: multiples of 128
    bk = min(block_k, _round_up(d_in, 128))
    # Zero-pad d_in to the chunk grid and r to the sublane size: zeros do not
    # perturb any of the accumulated terms.
    pk = _round_up(d_in, bk)
    pr = _round_up(r, 8)
    Wp = jnp.pad(W, ((0, 0), (0, pk - d_in))) if pk != d_in else W
    Ap = jnp.pad(A, ((0, pr - r), (0, pk - d_in)))
    Bp = jnp.pad(B, ((0, 0), (0, pr - r))) if pr != r else B
    if s == 0.0:
        if base_sq_cache is not None:
            return jnp.sqrt(jnp.maximum(base_sq_cache, 0.0))
        w32 = W.astype(_F32)
        return jnp.sqrt(jnp.maximum(jnp.sum(w32 * w32, axis=1), 0.0))
    base_sq, cross = _nk.norm_terms_pallas(
        Wp, Ap, Bp, block_rows=br, block_k=bk, interpret=interpret)
    if base_sq_cache is not None:
        base_sq = base_sq_cache
    A32 = A.astype(_F32)
    B32 = B.astype(_F32)
    G = A32 @ A32.T
    ba_sq = jnp.sum((B32 @ G) * B32, axis=1)
    return _ak.assemble_norm_pallas(base_sq, cross, ba_sq, s,
                                    interpret=interpret)
