"""Jit'd public wrappers around the Pallas kernels.

  - ``fused_compose``: custom_vjp op — forward = fused compose kernel
    (optionally dual-output saving ``inner``), backward = fused backward
    kernel + deterministic jnp reduction for d_mag (paper §3.2).
  - ``fused_norm``: factored-norm terms kernel + jnp Gram term + assembly
    kernel; detached end-to-end (DoRA §4.3).

Both wrappers do the shape plumbing the paper's dispatch layer does on CUDA:
flatten leading dims, pad rows to the block shape, enforce the
d_out % 128 == 0 constraint (paper App. C), and accept an ``interpret`` flag
so the same kernels run on CPU for validation. ``interpret=None`` (default)
resolves through the capability probes: compiled on a TPU backend, the
Pallas interpreter anywhere else — so direct callers (tests, benchmarks)
never hardcode a host assumption.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as _P

from repro.compat.pallas import resolve_interpret
from repro.kernels import dora_compose as _ck
from repro.kernels import factored_norm as _nk
from repro.kernels import norm_assembly as _ak

_F32 = jnp.float32


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# Single source of the feature-dim block rule (re-exported: direct
# callers and the factored-norm wrapper use it through this module).
pick_block_n = _ck.pick_block_n


def _pad_rows(x, bm: int):
    m = x.shape[0]
    pm = _round_up(m, bm)
    if pm == m:
        return x, m
    return jnp.pad(x, ((0, pm - m), (0, 0))), m


# ---------------------------------------------------------------------------
# Fused compose with custom VJP.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_compose(s: float, save_inner: bool, mag_grad: bool,
                  block_m: int, block_n: int, interpret: bool):
    def _prep(base, g):
        n = base.shape[-1]
        bn = pick_block_n(n, block_n)
        g32 = g.astype(_F32)
        gm1 = (g32 - 1.0).reshape(1, n)
        return bn, gm1, g32

    def _flatten(x):
        return x.reshape(-1, x.shape[-1])

    @jax.custom_vjp
    def compose(base, lora, g):
        out, _ = _fwd(base, lora, g)
        return out

    def _fwd(base, lora, g):
        shape = base.shape
        bn, gm1, g32 = _prep(base, g)
        b2, m = _pad_rows(_flatten(base), block_m)
        l2, _ = _pad_rows(_flatten(lora), block_m)
        bm = min(block_m, b2.shape[0])
        if save_inner and mag_grad:
            delta, inner = _ck.compose_fwd_pallas(
                b2, l2, gm1, s, save_inner=True,
                block_m=bm, block_n=bn, interpret=interpret)
            delta = delta[:m].reshape(shape)
            inner = inner[:m].reshape(shape)
            res = (g32, inner, None, None)
        else:
            delta = _ck.compose_fwd_pallas(
                b2, l2, gm1, s, save_inner=False,
                block_m=bm, block_n=bn, interpret=interpret)
            delta = delta[:m].reshape(shape)
            res = ((g32, None, base, lora) if mag_grad
                   else (g32, None, None, None))
        return delta, res

    def _bwd(res, dy):
        g32, inner, base, lora = res
        shape = dy.shape
        n = shape[-1]
        bn = pick_block_n(n, block_n)
        gm1 = (g32 - 1.0).reshape(1, n)
        gs = (g32 * s).reshape(1, n)
        dy2, m = _pad_rows(_flatten(dy), block_m)
        bm = min(block_m, dy2.shape[0])
        d_base, d_lora = _ck.compose_bwd_pallas(
            dy2, gm1, gs, block_m=bm, block_n=bn, interpret=interpret)
        d_base = d_base[:m].reshape(shape)
        d_lora = d_lora[:m].reshape(shape)
        if not mag_grad:
            d_g = jnp.zeros_like(g32)
        else:
            # d_g = Σ_rows dY ⊙ inner — separate deterministic reduction
            # (paper §3.2: .sum() instead of tl.atomic_add).
            if inner is None:
                inner32 = base.astype(_F32) + s * lora.astype(_F32)
            else:
                inner32 = inner.astype(_F32)
            d_g = jnp.sum(dy.astype(_F32) * inner32,
                          axis=tuple(range(dy.ndim - 1)))
        return d_base, d_lora, d_g

    def fwd(base, lora, g):
        return _fwd(base, lora, g)

    compose.defvjp(fwd, _bwd)
    return compose


def fused_compose(base, lora, g, s: float, *,
                  save_inner: bool = True,
                  mag_grad: bool = True,
                  block_m: int = 256, block_n: int = 1024,
                  interpret: bool | None = None):
    """delta = (g-1)⊙base + g⊙s⊙lora via the fused Pallas kernels.

    base/lora: [..., d_out] (input dtype); g: fp32 [d_out] (differentiable —
    carries the magnitude gradient unless ``mag_grad=False``, the paper's
    frozen-magnitude fast path that skips the ``inner`` save entirely).
    """
    fn = _make_compose(float(s), bool(save_inner), bool(mag_grad),
                       int(block_m), int(block_n),
                       resolve_interpret(interpret))
    return fn(base, lora, g)


# ---------------------------------------------------------------------------
# Matmul-fused compose with custom VJP: y_lora never reaches HBM.
# ---------------------------------------------------------------------------

def _pad_rank(x, rp: int):
    r = x.shape[-1]
    if rp == r:
        return x
    return jnp.pad(x, ((0, 0), (0, rp - r)))


@functools.lru_cache(maxsize=None)
def _make_compose_mm(s: float, mag_grad: bool, block_m: int, block_n: int,
                     interpret: bool):
    def _flatten(x):
        return x.reshape(-1, x.shape[-1])

    @jax.custom_vjp
    def compose(base, h, B, g):
        out, _ = fwd(base, h, B, g)
        return out

    def fwd(base, h, B, g):
        shape = base.shape
        n = shape[-1]
        r = B.shape[-1]
        bn = pick_block_n(n, block_n)
        rp = _round_up(r, 128)          # lane-width padding; zeros are inert
        g32 = g.astype(_F32)
        gm1 = (g32 - 1.0).reshape(1, n)
        b2, m = _pad_rows(_flatten(base), block_m)
        h2, _ = _pad_rows(_pad_rank(_flatten(h), rp), block_m)
        bm = min(block_m, b2.shape[0])
        delta = _ck.compose_mm_fwd_pallas(
            b2, h2, _pad_rank(B, rp), gm1, s,
            block_m=bm, block_n=bn, interpret=interpret)
        delta = delta[:m].reshape(shape)
        # Residuals are all tensors already live in the surrounding graph
        # (h is the x@Aᵀ activation, base is y_base) — unlike the Tier-1
        # dual-output path, nothing extra is materialized for the backward,
        # including the magnitude gradient (see _bwd).
        res = (g32, h, B, base if mag_grad else None)
        return delta, res

    def _bwd(res, dy):
        g32, h, B, base = res
        shape = dy.shape
        n = shape[-1]
        r = B.shape[-1]
        bn = pick_block_n(n, block_n)
        rp = _round_up(r, 128)
        gm1 = (g32 - 1.0).reshape(1, n)
        gs = (g32 * s).reshape(1, n)
        dy2, m = _pad_rows(_flatten(dy), block_m)
        bm = min(block_m, dy2.shape[0])
        d_base, d_h = _ck.compose_mm_bwd_pallas(
            dy2, _pad_rank(B, rp), gm1, gs,
            block_m=bm, block_n=bn, interpret=interpret)
        d_base = d_base[:m].reshape(shape)
        d_h = d_h[:m, :r].reshape(h.shape).astype(h.dtype)
        # d_B = (g·s) ⊙ (dYᵀ @ h): T is the one cross matmul the backward
        # cannot avoid (it also carries the lora half of d_g, so it is
        # computed once and reused — deterministic jnp reductions, paper
        # §3.2's .sum()-over-atomics choice).
        dy32 = _flatten(dy).astype(_F32)
        T = jax.lax.dot_general(
            dy32, _flatten(h).astype(_F32), (((0,), (0,)), ((), ())),
            preferred_element_type=_F32)                     # [n, r]
        d_B = ((g32 * s)[:, None] * T).astype(B.dtype)
        if not mag_grad:
            d_g = jnp.zeros_like(g32)
        else:
            # d_g = Σ_rows dY ⊙ (base + s·lora); the lora term contracts
            # through T: Σ_m dY⊙(hBᵀ) = rowsum(B ⊙ T).
            d_g = (jnp.sum(dy.astype(_F32) * base.astype(_F32),
                           axis=tuple(range(dy.ndim - 1)))
                   + s * jnp.sum(B.astype(_F32) * T, axis=1))
        return d_base, d_h, d_B, d_g

    compose.defvjp(fwd, _bwd)
    return compose


@functools.lru_cache(maxsize=None)
def _make_compose_mm_sharded(s: float, mag_grad: bool, block_m: int,
                             block_n: int, interpret: bool, mesh,
                             row_entry, dout_entry):
    """Shard-local matmul-fused compose: the same Pallas kernels as
    :func:`_make_compose_mm`, run per-device under shard_map with block
    specs derived from the mesh axis sizes (:func:`dora_compose.
    local_block_shape`). Forward is collective-free; the backward psums
    d_h over the d_out axes and d_B/d_g over the row axes (deterministic
    fp32 reductions, same .sum()-over-atomics discipline as the rest of
    the backward)."""
    from repro.compat.mesh import shard_map_unchecked
    from repro.core.sharding import _entry_axes

    row_axes = _entry_axes(row_entry)
    dout_axes = _entry_axes(dout_entry)
    p_mat = _P(row_entry, dout_entry)    # base / delta / dY  [M, N]
    p_h = _P(row_entry, None)            # h [M, rp] — rank replicated
    p_b = _P(dout_entry, None)           # B [N, rp]
    p_g = _P(dout_entry)                 # g [N]

    def _flatten(x):
        return x.reshape(-1, x.shape[-1])

    def _local_blocks(m_l: int, n_l: int):
        # Shards are already local here, so shard counts are 1.
        return _ck.local_block_shape(m_l, n_l, block_m=block_m,
                                     block_n=block_n)

    def _local_fwd(b2, h2, Bl, g32):
        m_l, n_l = b2.shape
        bm, bn = _local_blocks(m_l, n_l)
        gm1 = (g32 - 1.0).reshape(1, n_l)
        b2p, m = _pad_rows(b2, bm)
        h2p, _ = _pad_rows(h2, bm)
        delta = _ck.compose_mm_fwd_pallas(
            b2p, h2p, Bl, gm1, s, block_m=bm, block_n=bn,
            interpret=interpret)
        return delta[:m]

    def _local_bwd(dy, h2, Bl, g32, b2):
        m_l, n_l = dy.shape
        bm, bn = _local_blocks(m_l, n_l)
        gm1 = (g32 - 1.0).reshape(1, n_l)
        gs = (g32 * s).reshape(1, n_l)
        dy_p, m = _pad_rows(dy, bm)
        d_base, d_h = _ck.compose_mm_bwd_pallas(
            dy_p, Bl, gm1, gs, block_m=bm, block_n=bn, interpret=interpret)
        d_base, d_h = d_base[:m], d_h[:m]
        if dout_axes:
            d_h = jax.lax.psum(d_h, dout_axes)
        dy32 = dy.astype(_F32)
        T = jax.lax.dot_general(
            dy32, h2.astype(_F32), (((0,), (0,)), ((), ())),
            preferred_element_type=_F32)                     # [n_l, rp]
        if row_axes:
            T = jax.lax.psum(T, row_axes)
        d_B = (g32 * s)[:, None] * T
        if not mag_grad:
            return d_base, d_h, d_B, jnp.zeros_like(g32)
        d_g_base = jnp.sum(dy32 * b2.astype(_F32), axis=0)
        if row_axes:
            d_g_base = jax.lax.psum(d_g_base, row_axes)
        d_g = d_g_base + s * jnp.sum(Bl.astype(_F32) * T, axis=1)
        return d_base, d_h, d_B, d_g

    smap_fwd = shard_map_unchecked(
        _local_fwd, mesh, in_specs=(p_mat, p_h, p_b, p_g), out_specs=p_mat)
    smap_bwd = shard_map_unchecked(
        _local_bwd, mesh, in_specs=(p_mat, p_h, p_b, p_g, p_mat),
        out_specs=(p_mat, p_h, p_b, p_g))

    @jax.custom_vjp
    def compose(base, h, B, g):
        out, _ = fwd(base, h, B, g)
        return out

    def fwd(base, h, B, g):
        shape = base.shape
        r = B.shape[-1]
        rp = _round_up(r, 128)
        g32 = g.astype(_F32)
        delta2 = smap_fwd(_flatten(base),
                          _pad_rank(_flatten(h), rp),
                          _pad_rank(B, rp), g32)
        res = (g32, h, B, base if mag_grad else None)
        return delta2.reshape(shape), res

    def _bwd(res, dy):
        g32, h, B, base = res
        shape = dy.shape
        r = B.shape[-1]
        rp = _round_up(r, 128)
        dy2 = _flatten(dy)
        b2 = _flatten(base) if mag_grad else jnp.zeros_like(dy2)
        d_base, d_h, d_B, d_g = smap_bwd(
            dy2, _pad_rank(_flatten(h), rp), _pad_rank(B, rp), g32, b2)
        d_base = d_base.reshape(shape)
        d_h = d_h[:, :r].reshape(h.shape).astype(h.dtype)
        d_B = d_B[:, :r].astype(B.dtype)
        return d_base, d_h, d_B, d_g

    compose.defvjp(fwd, _bwd)
    return compose


def fused_compose_mm(base, h, B, g, s: float, *,
                     mag_grad: bool = True,
                     block_m: int = 256, block_n: int = 1024,
                     interpret: bool | None = None,
                     sharding=None):
    """delta = (g-1)⊙base + g⊙s⊙(h @ Bᵀ) with the up-projection fused.

    base: [..., d_out]; h = x@Aᵀ: [..., r]; B: [d_out, r]; g: fp32 [d_out].
    The [..., d_out] ``y_lora`` tensor is never materialized in HBM —
    forward reads (base, h, B) and writes delta only; backward reads dY
    once for both d_base and d_h (plus the unavoidable dYᵀ@h cross matmul
    for d_B / the magnitude gradient).

    ``sharding``: a :class:`repro.core.sharding.ComposeSharding` plan; when
    the operand shapes divide its mesh axes, the kernels run SHARD-LOCAL
    under shard_map (block specs derived from the local shard sizes) — the
    unsharded call is the trivial one-device instance. A plan the shapes
    cannot divide is dropped silently (the global-kernel path still
    computes the same values).
    """
    if base.shape[:-1] != h.shape[:-1]:
        raise ValueError(f"base leading dims {base.shape[:-1]} != h leading "
                         f"dims {h.shape[:-1]}")
    if sharding is not None and any(
            a not in sharding.dout_axes for a in sharding.b_dout_axes):
        # A B whose d_out carries FSDP axes beyond the output's own would
        # have to be all-gathered at the shard_map boundary to run the
        # kernel shard-local — refuse loudly instead of hiding the gather
        # (dispatch routes such plans to the materialized fallback; see
        # ComposeSharding.b_dout_axes).
        raise ValueError(
            f"fused_compose_mm cannot run shard-local with B sharded "
            f"beyond the output d_out: b_spec={sharding.b_spec} "
            f"(b_dout_axes={sharding.b_dout_axes}) vs output spec "
            f"{sharding.out_spec} — the plan is inexpressible; use the "
            f"materialized-lora route with the output constraint instead")
    if sharding is not None:
        rows = 1
        for d in base.shape[:-1]:
            rows *= d
        if (rows % max(sharding.row_shards, 1) == 0
                and sharding.kernel_expressible(base.shape[-1])):
            row_entry, dout_entry = sharding.flat2d()
            fn = _make_compose_mm_sharded(
                float(s), bool(mag_grad), int(block_m), int(block_n),
                resolve_interpret(interpret), sharding.mesh,
                row_entry, dout_entry)
            return fn(base, h, B, g)
    fn = _make_compose_mm(float(s), bool(mag_grad), int(block_m),
                          int(block_n), resolve_interpret(interpret))
    return fn(base, h, B, g)


# ---------------------------------------------------------------------------
# Fused factored norm.
# ---------------------------------------------------------------------------

def fused_norm(W, A, B, s: float, *,
               block_rows: int = 256, block_k: int = 512,
               interpret: bool | None = None, base_sq_cache=None):
    """Detached fp32 row-wise norm of W + s·B·A via the Pallas kernels."""
    interpret = resolve_interpret(interpret)
    W = jax.lax.stop_gradient(W)
    A = jax.lax.stop_gradient(A)
    B = jax.lax.stop_gradient(B)
    d_out, d_in = W.shape
    r = A.shape[0]
    br = pick_block_n(d_out, block_rows)  # d_out blocks: multiples of 128
    bk = min(block_k, _round_up(d_in, 128))
    # Zero-pad d_in to the chunk grid and r to the sublane size: zeros do not
    # perturb any of the accumulated terms.
    pk = _round_up(d_in, bk)
    pr = _round_up(r, 8)
    Wp = jnp.pad(W, ((0, 0), (0, pk - d_in))) if pk != d_in else W
    Ap = jnp.pad(A, ((0, pr - r), (0, pk - d_in)))
    Bp = jnp.pad(B, ((0, 0), (0, pr - r))) if pr != r else B
    if s == 0.0:
        if base_sq_cache is not None:
            return jnp.sqrt(jnp.maximum(base_sq_cache, 0.0))
        w32 = W.astype(_F32)
        return jnp.sqrt(jnp.maximum(jnp.sum(w32 * w32, axis=1), 0.0))
    base_sq, cross = _nk.norm_terms_pallas(
        Wp, Ap, Bp, block_rows=br, block_k=bk, interpret=interpret)
    if base_sq_cache is not None:
        base_sq = base_sq_cache
    A32 = A.astype(_F32)
    B32 = B.astype(_F32)
    G = A32 @ A32.T
    ba_sq = jnp.sum((B32 @ G) * B32, axis=1)
    return _ak.assemble_norm_pallas(base_sq, cross, ba_sq, s,
                                    interpret=interpret)
