"""Pallas TPU kernels for the DoRA hot spots (compose fwd/bwd, factored
norm, norm assembly) with jit wrappers (ops) and pure-jnp oracles (ref),
plus the paged K/V gather for the block-paged decode cache."""
from repro.kernels.ops import fused_compose, fused_norm
from repro.kernels.paged_gather import (paged_gather, paged_gather_ref,
                                        paged_scatter)

__all__ = ["fused_compose", "fused_norm", "paged_gather",
           "paged_gather_ref", "paged_scatter"]
