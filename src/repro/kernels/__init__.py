"""Pallas TPU kernels for the DoRA hot spots (compose fwd/bwd, factored
norm, norm assembly) with jit wrappers (ops) and pure-jnp oracles (ref)."""
from repro.kernels.ops import fused_compose, fused_norm

__all__ = ["fused_compose", "fused_norm"]
