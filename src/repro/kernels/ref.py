"""Pure-jnp oracles for every Pallas kernel in this package.

These are *independent* dense implementations (no factored algebra, no
chunking, no tiling) used by the allclose test sweeps and benchmarks:

  - ``ref_norm_terms`` / ``ref_norm``: dense fp32 row-norm of W + s·B·A.
  - ``ref_compose`` / ``ref_compose_dual``: fp32 stable compose.
  - ``ref_compose_bwd``: analytic cotangents of the compose.
  - ``ref_assemble``: Eq. 5 in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_F32 = jnp.float32


def ref_norm_terms(W, A, B):
    """Dense (base_sq, cross) fp32 [d_out] — oracle for norm_terms_pallas."""
    W32 = W.astype(_F32)
    BA = B.astype(_F32) @ A.astype(_F32)
    base_sq = jnp.sum(W32 * W32, axis=1)
    cross = jnp.sum(W32 * BA, axis=1)
    return base_sq, cross


def ref_norm(W, A, B, s: float):
    """Dense fp32 row-wise norm of W + s·B·A."""
    W32 = W.astype(_F32)
    BA = B.astype(_F32) @ A.astype(_F32)
    return jnp.linalg.norm(W32 + float(s) * BA, axis=1)


def ref_assemble(base_sq, cross, ba_sq, s: float):
    s = float(s)
    return jnp.sqrt(jnp.maximum(
        base_sq.astype(_F32) + (2.0 * s) * cross.astype(_F32)
        + (s * s) * ba_sq.astype(_F32), 0.0))


def ref_compose(base, lora, g, s: float):
    """Stable compose, fp32 intermediates, input-dtype output."""
    g32 = g.astype(_F32)
    t = jnp.asarray(float(s), _F32) * lora.astype(_F32)
    return ((g32 - 1.0) * base.astype(_F32) + g32 * t).astype(base.dtype)


def ref_compose_dual(base, lora, g, s: float):
    delta = ref_compose(base, lora, g, s)
    inner = (base.astype(_F32)
             + jnp.asarray(float(s), _F32) * lora.astype(_F32))
    return delta, inner.astype(base.dtype)


def ref_compose_mm(base, h, B, g, s: float):
    """Matmul-fused compose oracle: the lora product materialized densely in
    fp32, then the stable compose — what the fused kernel must match."""
    lora = jax.lax.dot_general(
        h.astype(_F32), B.astype(_F32), (((h.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=_F32)
    return ref_compose(base, lora, g, s)


def ref_compose_mm_fp64(base, h, B, g, s: float):
    """fp64 oracle for the matmul-fused compose (golden-tolerance tests)."""
    f64 = jnp.float64
    lora = h.astype(f64) @ B.astype(f64).T
    g64 = g.astype(f64)
    return (g64 - 1.0) * base.astype(f64) + g64 * (float(s) * lora)


def ref_compose_bwd(dy, base, lora, g, s: float):
    """Analytic cotangents: d_base = (g-1)·dY, d_lora = g·s·dY,
    d_g = Σ_rows dY ⊙ (s·lora + base)."""
    g32 = g.astype(_F32)
    dy32 = dy.astype(_F32)
    d_base = ((g32 - 1.0) * dy32).astype(dy.dtype)
    d_lora = ((g32 * float(s)) * dy32).astype(dy.dtype)
    inner = base.astype(_F32) + float(s) * lora.astype(_F32)
    d_g = jnp.sum(dy32 * inner, axis=tuple(range(dy.ndim - 1)))
    return d_base, d_lora, d_g
