"""Norm assembly kernel (paper §3.3, App. C kernel 3).

Fuses Eq. 5:  w_norm = sqrt(max(base_sq + two_s*cross + s2*ba_sq, 0))

over fp32 [d_out] vectors. The two scalars two_s = 2s and s2 = s² are
precomputed in fp64 and passed as compile-time constants. The paper's
store-reload barriers and inline-PTX ``sqrt.rn.f32`` exist to reproduce
PyTorch's separate-kernel evaluation order on CUDA; on TPU, XLA/Mosaic lowers
``jnp.sqrt`` on fp32 to the correctly-rounded op and the kernel expresses the
multiply-adds in the pinned order, so no equivalent hack is needed (see
DESIGN.md §2). max() propagates NaNs (IEEE 754, matching torch.clamp_min).

The magnitude division g = m / max(w_norm, eps) stays *outside* (paper §4) so
both norm paths share the same precision context.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat.pallas import pl

_F32 = jnp.float32


def _assembly_kernel(base_ref, cross_ref, ba_ref, out_ref,
                     *, two_s: float, s2: float):
    base = base_ref[...]
    # Pinned evaluation order: (base + two_s*cross) then (+ s2*ba).
    acc = base + jnp.asarray(two_s, _F32) * cross_ref[...]
    acc = acc + jnp.asarray(s2, _F32) * ba_ref[...]
    out_ref[...] = jnp.sqrt(jnp.maximum(acc, 0.0))


def assemble_norm_pallas(base_sq, cross, ba_sq, s: float, *,
                         block: int = 256, interpret: bool = False):
    """base_sq/cross/ba_sq: fp32 [d_out] → w_norm fp32 [d_out]."""
    (d_out,) = base_sq.shape
    # fp64 precompute of the scalars (paper App. C), then fp32 constants.
    s64 = float(s)
    kern = functools.partial(_assembly_kernel, two_s=2.0 * s64, s2=s64 * s64)
    vecs = [v.reshape(1, d_out) for v in (base_sq, cross, ba_sq)]
    block = min(block, d_out)
    spec = pl.BlockSpec((1, block), lambda i: (0, i))
    out = pl.pallas_call(
        kern,
        grid=(pl.cdiv(d_out, block),),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((1, d_out), _F32),
        interpret=interpret,
    )(*vecs)
    return out[0]
