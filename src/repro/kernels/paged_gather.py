"""Paged K/V gather for the block-paged decode cache.

The paged engine stores K/V in a per-layer block pool
``[n_blocks, block_size, Hkv, hd]`` with a per-slot block table
``pages [B, max_blocks]`` (int32 block ids, ``-1`` = unallocated). The
attention layer gathers the pool into the logical rectangular view
``[B, max_blocks * block_size, Hkv, hd]`` and then runs the UNCHANGED
per-row-frontier attention — bitwise parity with the rectangular cache is
by construction, because unallocated blocks read as exact zeros and every
position at or past a row's frontier is already masked to an exact 0.0
softmax weight by the causal bias.

Two tiers through :func:`repro.core.dispatch.plan_gather`:

  - ``paged_gather_ref`` — pure jnp (eager tier, and the oracle);
  - ``paged_gather`` — Pallas scalar-prefetch kernel: the block table is
    prefetched to SMEM and drives the pool BlockSpec index map, so each
    (row, table-slot) grid step DMAs exactly one ``[block_size, Hkv*hd]``
    block HBM→VMEM (unallocated slots clamp to block 0 and are zeroed in
    the body). Both tiers are pure copies + zero fill: bitwise identical.

The scatter back (:func:`paged_scatter`) is a jnp ``.at[].set`` on every
tier — XLA lowers it to an in-place dynamic-update when the pool is
donated, and the ``mode="drop"`` out-of-bounds rule gives the -1 → skip
semantics for free (all unallocated entries alias the same OOB id, so
``unique_indices`` must NOT be claimed).

The block table is a traced operand in both tiers: paging never
recompiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat.pallas import pl, pltpu, resolve_interpret


def paged_gather_ref(pool, pages):
    """Gather ``pool [n_blocks, bs, Hkv, hd]`` through ``pages
    [B, max_blocks]`` into the logical ``[B, max_blocks*bs, Hkv, hd]``
    view; unallocated (-1) blocks read as zeros."""
    n_blocks, bs, hkv, hd = pool.shape
    b, mb = pages.shape
    valid = pages >= 0
    blocks = pool[jnp.maximum(pages, 0)]           # [B, mb, bs, Hkv, hd]
    blocks = jnp.where(valid[..., None, None, None], blocks,
                       jnp.zeros((), pool.dtype))
    return blocks.reshape(b, mb * bs, hkv, hd)


@functools.lru_cache(maxsize=None)
def _make_gather(n_blocks: int, bs: int, hd_flat: int, b: int, mb: int,
                 dtype_name: str, interpret: bool):
    """One pallas_call per (pool geometry, table geometry, dtype): the
    table VALUES are traced (scalar-prefetch), so paging never
    recompiles."""

    def _kernel(pages_ref, pool_ref, out_ref):
        i = pl.program_id(0)
        j = pl.program_id(1)
        valid = pages_ref[i, j] >= 0
        out_ref[0, 0] = jnp.where(valid, pool_ref[0],
                                  jnp.zeros_like(pool_ref[0]))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, mb),
        in_specs=[
            # One pool block per grid step, chosen BY the prefetched
            # table; -1 clamps to block 0 (zeroed in the body).
            pl.BlockSpec((1, bs, hd_flat),
                         lambda i, j, pages: (jnp.maximum(pages[i, j], 0),
                                              0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bs, hd_flat),
                               lambda i, j, pages: (i, j, 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, mb, bs, hd_flat),
                                       jnp.dtype(dtype_name)),
        interpret=interpret,
    )


def paged_gather(pool, pages, *, interpret: bool | None = None):
    """Pallas tier of :func:`paged_gather_ref` (bitwise-identical: both
    tiers are copies + zero fill). Requires ``Hkv*hd % 128 == 0`` — the
    dispatch plan (:func:`repro.core.dispatch.plan_gather`) enforces it."""
    if pl is None or pltpu is None:  # pragma: no cover - pallas-free host
        return paged_gather_ref(pool, pages)
    interpret = resolve_interpret(interpret)
    n_blocks, bs, hkv, hd = pool.shape
    b, mb = pages.shape
    call = _make_gather(n_blocks, bs, hkv * hd, b, mb,
                        jnp.dtype(pool.dtype).name, interpret)
    out = call(pages.astype(jnp.int32), pool.reshape(n_blocks, bs,
                                                     hkv * hd))
    return out.reshape(b, mb * bs, hkv, hd)


def paged_scatter(pool, pages, values):
    """Write the logical ``values [B, max_blocks*bs, Hkv, hd]`` view back
    into ``pool`` through ``pages``; slices of unallocated (-1) blocks are
    dropped. Pure jnp on every tier (the scatter is a donate-friendly
    ``.at[].set`` that XLA updates in place)."""
    n_blocks, bs, hkv, hd = pool.shape
    b, mb = pages.shape
    vals = values.reshape(b * mb, bs, hkv, hd)
    # -1 → n_blocks: out of bounds, dropped. Every unallocated entry
    # aliases the SAME OOB id, so unique_indices would be a lie.
    ids = jnp.where(pages >= 0, pages, n_blocks).reshape(b * mb)
    return pool.at[ids].set(vals, mode="drop")
