"""Fused DoRA compose kernels (paper §3.1, §3.2) as Pallas-TPU kernels.

TPU adaptation of the paper's Triton kernels. The composition

    delta = (g - 1) ⊙ base + g ⊙ s ⊙ lora

is element-wise with a row-broadcast of g along the output feature dim. In
eager form it is four kernel launches / ~12 HBM passes; fused it is a single
pass: 2 tensor reads (base, lora) + small vector reads + 1 write. On TPU the
blocks are VMEM tiles shaped (block_rows, block_cols) with the lane dim a
multiple of 128.

The forward takes the fp32 *vector* gm1 = g - 1 instead of g: this pins the
stable form — (g - 1) is computed once in fp32 outside the kernel and never
reconstructed in low precision — and all paths share the canonical
evaluation order ``s * lora`` first, then ``g · (·)`` (paper §3.1). The
forward optionally dual-outputs ``inner = s*lora + base`` (paper §4 Tier 1),
the tensor saved for the magnitude gradient, eliminating the separate
forward-pass materialization.

The backward kernel emits d_lora = (g*s)*dY and d_base = (g-1)*dY in one pass
(paper §3.2). d_mag uses a separate jnp reduction — the exact analogue of the
paper's choice of a separate ``.sum()`` over ``tl.atomic_add`` (deterministic
reduction order).

Shape constraint (paper App. C): d_out must be divisible by 128; the ops
wrapper pads rows and enforces/falls back on the feature dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat.pallas import pl

_F32 = jnp.float32


def _fwd_kernel(base_ref, lora_ref, gm1_ref, delta_ref, *, s: float):
    b = base_ref[...].astype(_F32)
    l = lora_ref[...].astype(_F32)
    gm1 = gm1_ref[...].astype(_F32)        # (1, bn) broadcasts over rows
    t = jnp.asarray(s, _F32) * l           # canonical order: s*lora first
    delta_ref[...] = (gm1 * b + (gm1 + 1.0) * t).astype(delta_ref.dtype)


def _fwd_kernel_dual(base_ref, lora_ref, gm1_ref, delta_ref, inner_ref,
                     *, s: float):
    b = base_ref[...].astype(_F32)
    l = lora_ref[...].astype(_F32)
    gm1 = gm1_ref[...].astype(_F32)
    t = jnp.asarray(s, _F32) * l
    delta_ref[...] = (gm1 * b + (gm1 + 1.0) * t).astype(delta_ref.dtype)
    inner_ref[...] = (b + t).astype(inner_ref.dtype)


def _bwd_kernel(dy_ref, gm1_ref, gs_ref, dbase_ref, dlora_ref):
    dy = dy_ref[...].astype(_F32)
    gm1 = gm1_ref[...].astype(_F32)
    gs = gs_ref[...].astype(_F32)
    dbase_ref[...] = (gm1 * dy).astype(dbase_ref.dtype)
    dlora_ref[...] = (gs * dy).astype(dlora_ref.dtype)


def _row_specs(block_m: int, block_n: int):
    mat = pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))
    vec = pl.BlockSpec((1, block_n), lambda i, j: (0, j))
    return mat, vec


def compose_fwd_pallas(base, lora, gm1, s: float, *,
                       save_inner: bool,
                       block_m: int, block_n: int,
                       interpret: bool = False):
    """base, lora: [M, N]; gm1: fp32 [1, N]. Returns delta (+ inner)."""
    m, n = base.shape
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    mat, vec = _row_specs(block_m, block_n)
    out_shape = jax.ShapeDtypeStruct((m, n), base.dtype)
    if save_inner:
        kern = functools.partial(_fwd_kernel_dual, s=float(s))
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[mat, mat, vec],
            out_specs=(mat, mat),
            out_shape=(out_shape, out_shape),
            interpret=interpret,
        )(base, lora, gm1)
    kern = functools.partial(_fwd_kernel, s=float(s))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[mat, mat, vec],
        out_specs=mat,
        out_shape=out_shape,
        interpret=interpret,
    )(base, lora, gm1)


def compose_bwd_pallas(dy, gm1, gs, *, block_m: int, block_n: int,
                       interpret: bool = False):
    """dy: [M, N]; gm1, gs: fp32 [1, N]. Returns (d_base, d_lora) fused."""
    m, n = dy.shape
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    mat, vec = _row_specs(block_m, block_n)
    out_shape = jax.ShapeDtypeStruct((m, n), dy.dtype)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[mat, vec, vec],
        out_specs=(mat, mat),
        out_shape=(out_shape, out_shape),
        interpret=interpret,
    )(dy, gm1, gs)
