"""Fused DoRA compose kernels (paper §3.1, §3.2) as Pallas-TPU kernels.

TPU adaptation of the paper's Triton kernels. The composition

    delta = (g - 1) ⊙ base + g ⊙ s ⊙ lora

is element-wise with a row-broadcast of g along the output feature dim. In
eager form it is four kernel launches / ~12 HBM passes; fused it is a single
pass: 2 tensor reads (base, lora) + small vector reads + 1 write. On TPU the
blocks are VMEM tiles shaped (block_rows, block_cols) with the lane dim a
multiple of 128.

The forward takes the fp32 *vector* gm1 = g - 1 instead of g: this pins the
stable form — (g - 1) is computed once in fp32 outside the kernel and never
reconstructed in low precision — and all paths share the canonical
evaluation order ``s * lora`` first, then ``g · (·)`` (paper §3.1). The
forward optionally dual-outputs ``inner = s*lora + base`` (paper §4 Tier 1),
the tensor saved for the magnitude gradient, eliminating the separate
forward-pass materialization.

The backward kernel emits d_lora = (g*s)*dY and d_base = (g-1)*dY in one pass
(paper §3.2). d_mag uses a separate jnp reduction — the exact analogue of the
paper's choice of a separate ``.sum()`` over ``tl.atomic_add`` (deterministic
reduction order).

Shape constraint (paper App. C): d_out must be divisible by 128; the ops
wrapper pads rows and enforces/falls back on the feature dim.

Matmul-fused variant (one fusion deeper than the paper): the forward takes
``h = x @ Aᵀ [M, r]`` and ``B [d_out, r]`` instead of the materialized
``lora = h @ Bᵀ`` — the LoRA up-projection runs on the MXU inside the same
pass that composes the delta, so the ``[M, d_out]`` ``lora`` tensor is never
written to (or re-read from) HBM: 3 full-matrix passes become 2. The matching
backward emits ``d_h = (g·s)·dY @ B`` fused with ``d_base = (g-1)·dY`` in a
single pass over dY, accumulating the ``[bm, r]`` d_h tile across the
sequential d_out-chunk grid dimension (same accumulation pattern as the
factored-norm kernel). r is zero-padded to the 128-lane width by the ops
wrapper; zero columns perturb neither contraction.

Under SPMD the same kernels run SHARD-LOCAL inside shard_map (the ops
wrapper takes a ``ComposeSharding`` plan): each device composes its
``[rows_local, d_out_local]`` tile from a rank-replicated ``h`` shard, with
block specs derived from the mesh axis sizes via :func:`local_block_shape`
(row-sharded d_out shrinks block_n to the local shard; r stays replicated).
The forward needs no collectives; the backward psums the accumulated d_h
tile over the d_out axes — the one collective a contraction over a sharded
d_out cannot avoid — and d_B/d_g over the row axes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat.pallas import pl, tpu_compiler_params
from repro.core.config import shrink_block_rows

_F32 = jnp.float32


def pick_block_n(n: int, cap: int) -> int:
    """Largest multiple of 128 (the lane width) that divides n, at most
    cap — the feature-dim block every compose/norm grid uses."""
    if n % 128 != 0:
        raise ValueError(f"feature dim {n} not divisible by 128 "
                         "(paper App. C shape constraint)")
    for t in range(max(1, cap // 128), 0, -1):
        if n % (128 * t) == 0:
            return 128 * t
    return 128


def _fwd_kernel(base_ref, lora_ref, gm1_ref, delta_ref, *, s: float):
    b = base_ref[...].astype(_F32)
    l = lora_ref[...].astype(_F32)
    gm1 = gm1_ref[...].astype(_F32)        # (1, bn) broadcasts over rows
    t = jnp.asarray(s, _F32) * l           # canonical order: s*lora first
    delta_ref[...] = (gm1 * b + (gm1 + 1.0) * t).astype(delta_ref.dtype)


def _fwd_kernel_dual(base_ref, lora_ref, gm1_ref, delta_ref, inner_ref,
                     *, s: float):
    b = base_ref[...].astype(_F32)
    l = lora_ref[...].astype(_F32)
    gm1 = gm1_ref[...].astype(_F32)
    t = jnp.asarray(s, _F32) * l
    delta_ref[...] = (gm1 * b + (gm1 + 1.0) * t).astype(delta_ref.dtype)
    inner_ref[...] = (b + t).astype(inner_ref.dtype)


def _bwd_kernel(dy_ref, gm1_ref, gs_ref, dbase_ref, dlora_ref):
    dy = dy_ref[...].astype(_F32)
    gm1 = gm1_ref[...].astype(_F32)
    gs = gs_ref[...].astype(_F32)
    dbase_ref[...] = (gm1 * dy).astype(dbase_ref.dtype)
    dlora_ref[...] = (gs * dy).astype(dlora_ref.dtype)


def _row_specs(block_m: int, block_n: int):
    mat = pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))
    vec = pl.BlockSpec((1, block_n), lambda i, j: (0, j))
    return mat, vec


def compose_fwd_pallas(base, lora, gm1, s: float, *,
                       save_inner: bool,
                       block_m: int, block_n: int,
                       interpret: bool = False):
    """base, lora: [M, N]; gm1: fp32 [1, N]. Returns delta (+ inner)."""
    m, n = base.shape
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    mat, vec = _row_specs(block_m, block_n)
    out_shape = jax.ShapeDtypeStruct((m, n), base.dtype)
    if save_inner:
        kern = functools.partial(_fwd_kernel_dual, s=float(s))
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[mat, mat, vec],
            out_specs=(mat, mat),
            out_shape=(out_shape, out_shape),
            interpret=interpret,
        )(base, lora, gm1)
    kern = functools.partial(_fwd_kernel, s=float(s))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[mat, mat, vec],
        out_specs=mat,
        out_shape=out_shape,
        interpret=interpret,
    )(base, lora, gm1)


def compose_bwd_pallas(dy, gm1, gs, *, block_m: int, block_n: int,
                       interpret: bool = False):
    """dy: [M, N]; gm1, gs: fp32 [1, N]. Returns (d_base, d_lora) fused."""
    m, n = dy.shape
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    mat, vec = _row_specs(block_m, block_n)
    out_shape = jax.ShapeDtypeStruct((m, n), dy.dtype)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[mat, vec, vec],
        out_specs=(mat, mat),
        out_shape=(out_shape, out_shape),
        interpret=interpret,
    )(dy, gm1, gs)


# ---------------------------------------------------------------------------
# Matmul-fused compose: the LoRA up-projection h @ Bᵀ never leaves VMEM.
# ---------------------------------------------------------------------------

def local_block_shape(m: int, n: int, *, row_shards: int = 1,
                      dout_shards: int = 1, block_m: int = 256,
                      block_n: int = 1024) -> tuple[int, int]:
    """Block specs for a shard-local kernel invocation, derived from the
    mesh axis sizes: the grid tiles the LOCAL ``[m/row_shards,
    n/dout_shards]`` shard, so the caps shrink to the shard before the
    usual largest-divisible-multiple-of-128 (lanes) / row rules apply.
    ``row_shards``/``dout_shards`` are the products of the mesh axis sizes
    sharding the row and feature dims (1 = unsharded — the trivial mesh).

    Shares one derivation with the dispatch crossover and the bench bytes
    model: the row rule is :func:`repro.core.config.shrink_block_rows`
    (the same one ``DoRAConfig.resolve_mm_block_rows`` applies) and the
    feature rule is :func:`pick_block_n` — so the crossover guard, the
    kernel, and the bench all price the same tiles.
    """
    if n % dout_shards != 0 or (n // dout_shards) % 128 != 0:
        raise ValueError(
            f"d_out={n} over {dout_shards} shards breaks the 128-lane "
            f"block constraint (paper App. C, applied per shard)")
    n_local = n // dout_shards
    m_local = -(-m // row_shards)
    return (shrink_block_rows(block_m, m_local),
            pick_block_n(n_local, block_n))


def _mm_fwd_kernel(base_ref, h_ref, b_ref, gm1_ref, delta_ref, *, s: float):
    b = base_ref[...].astype(_F32)                 # [bm, bn]
    h = h_ref[...].astype(_F32)                    # [bm, rp]
    bm_ = b_ref[...].astype(_F32)                  # [bn, rp]
    gm1 = gm1_ref[...].astype(_F32)                # (1, bn)
    lora = jax.lax.dot_general(                    # h @ B_tileᵀ on the MXU
        h, bm_, (((1,), (1,)), ((), ())), preferred_element_type=_F32)
    t = jnp.asarray(s, _F32) * lora                # canonical order (§3.1)
    delta_ref[...] = (gm1 * b + (gm1 + 1.0) * t).astype(delta_ref.dtype)


def _mm_bwd_kernel(dy_ref, b_ref, gm1_ref, gs_ref, dbase_ref, dh_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        dh_ref[...] = jnp.zeros_like(dh_ref)

    dy = dy_ref[...].astype(_F32)                  # [bm, bn]
    gm1 = gm1_ref[...].astype(_F32)                # (1, bn)
    gs = gs_ref[...].astype(_F32)                  # (1, bn)
    dbase_ref[...] = (gm1 * dy).astype(dbase_ref.dtype)
    t = gs * dy                                    # (g·s)·dY tile
    dh_ref[...] += jax.lax.dot_general(            # accumulate over d_out
        t, b_ref[...].astype(_F32), (((1,), (0,)), ((), ())),
        preferred_element_type=_F32)


def compose_mm_fwd_pallas(base, h, B, gm1, s: float, *,
                          block_m: int, block_n: int,
                          interpret: bool = False):
    """base: [M, N]; h: [M, rp]; B: [N, rp]; gm1: fp32 [1, N].

    Returns delta [M, N] = (g-1)⊙base + g⊙s⊙(h @ Bᵀ) with the up-projection
    computed per-tile in VMEM. rp (the padded rank) must be a lane multiple;
    callers pad through the ops wrapper.
    """
    m, n = base.shape
    rp = h.shape[1]
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    mat, vec = _row_specs(block_m, block_n)
    return pl.pallas_call(
        functools.partial(_mm_fwd_kernel, s=float(s)),
        grid=grid,
        in_specs=[
            mat,                                            # base (i, j)
            pl.BlockSpec((block_m, rp), lambda i, j: (i, 0)),   # h (i, ·)
            pl.BlockSpec((block_n, rp), lambda i, j: (j, 0)),   # B (j, ·)
            vec,                                            # gm1 (·, j)
        ],
        out_specs=mat,
        out_shape=jax.ShapeDtypeStruct((m, n), base.dtype),
        interpret=interpret,
    )(base, h, B, gm1)


def compose_mm_bwd_pallas(dy, B, gm1, gs, *, block_m: int, block_n: int,
                          interpret: bool = False):
    """dy: [M, N]; B: [N, rp]; gm1, gs: fp32 [1, N].

    Returns (d_base [M, N], d_h fp32 [M, rp]) in ONE pass over dY: the d_h
    tile accumulates across the sequential d_out-chunk grid dimension
    (paper §3.2 extended one matmul deeper — dY is read once for both
    cotangents instead of once for d_base and once for the d_lora @ B
    matmul).
    """
    m, n = dy.shape
    rp = B.shape[1]
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    mat, vec = _row_specs(block_m, block_n)
    return pl.pallas_call(
        _mm_bwd_kernel,
        grid=grid,
        in_specs=[
            mat,                                            # dy (i, j)
            pl.BlockSpec((block_n, rp), lambda i, j: (j, 0)),   # B (j, ·)
            vec, vec,                                       # gm1, gs (·, j)
        ],
        out_specs=(
            mat,                                            # d_base (i, j)
            pl.BlockSpec((block_m, rp), lambda i, j: (i, 0)),   # d_h (i, ·)
        ),
        out_shape=(jax.ShapeDtypeStruct((m, n), dy.dtype),
                   jax.ShapeDtypeStruct((m, rp), _F32)),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(dy, B, gm1, gs)
