"""Core DoRA library — the paper's contribution as composable JAX modules."""
from repro.core.config import DoRAConfig
from repro.core.adapter import (
    dora_linear, dora_linear_grouped, dora_linear_stacked,
    init_dora_params, compute_weight_norm, compose_delta,
    compose_delta_factored, precompute_adapter_state,
    invalidate_adapter_state, stack_adapter_states,
)
from repro.core.adapter_cache import (
    AdapterCacheMiss, AdapterHandle, AdapterKey, AdapterStateCache,
    CacheStats,
)
# NOTE: the factored_norm *function* is deliberately not re-exported at
# package level — it would shadow the repro.core.factored_norm submodule.
from repro.core.factored_norm import (
    factored_norm_terms, factored_norm_sharded,
    assemble_norm, norm_peft_eye, norm_dense_ba, dtype_eps,
)
from repro.core.compose import (
    compose_stable, compose_naive, magnitude_scale, select_tenant,
)
from repro.core.dispatch import Tier, select_tier

__all__ = [
    "DoRAConfig", "dora_linear", "dora_linear_grouped",
    "dora_linear_stacked", "init_dora_params",
    "compute_weight_norm", "compose_delta", "compose_delta_factored",
    "precompute_adapter_state", "invalidate_adapter_state",
    "stack_adapter_states",
    "AdapterCacheMiss", "AdapterHandle", "AdapterKey", "AdapterStateCache",
    "CacheStats",
    "factored_norm_terms", "factored_norm_sharded", "assemble_norm",
    "norm_peft_eye", "norm_dense_ba", "dtype_eps", "compose_stable",
    "compose_naive", "magnitude_scale", "select_tenant", "Tier",
    "select_tier",
]
