"""Multi-tenant adapter-state cache: an LRU of precomputed serving states.

The frozen-adapter serving state (:func:`repro.core.precompute_adapter_
state`) makes decode do zero factored-norm work per token — but it is
computed for ONE adapter set. A multi-tenant server swaps adapter sets per
request, so this module keeps an LRU of precomputed states keyed by
:class:`AdapterKey` — (adapter id, version, activation dtype, gsB folding,
sharding fingerprint) — with explicit byte accounting (``max_bytes``
eviction over the full resident state trees) and hit/miss/evict counters
surfaced as a :class:`CacheStats` struct.

Why those key fields (see PAPERS.md): the rsLoRA scaling ``s`` interacts
with the rank and is folded into both the norm and ``gsB`` — it rides in
via the precompute fn's ``DoRAConfig``, so one cache is bound to one
config; the activation dtype picks the ``eps`` the cached ``g`` was
computed with (a state precomputed for the wrong dtype is NOT bitwise);
the sharding fingerprint pins which mesh the cached leaves were laid out
for (EDoRA-style cheap re-derivation makes eviction-and-recompute an
acceptable miss path, so we never serve a state pinned for the wrong
mesh).

Versioning composes with the training contract: ``register``/``update``
strip any serving leaves via :func:`repro.core.invalidate_adapter_state`
(so the registry always holds the raw trainable tree), ``update`` bumps
the version and drops every cached state of older versions, and a request
carrying a stale :class:`AdapterHandle` is ALWAYS rejected with an error
naming the key fields — the failure mode this subsystem exists to kill is
a caller swapping adapters without re-precomputing and silently serving
wrong logits.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Any, Callable

import numpy as np

from repro.core.adapter import invalidate_adapter_state


@dataclasses.dataclass(frozen=True)
class AdapterHandle:
    """What a request carries: which adapter set, at which version."""
    adapter_id: str
    version: int


@dataclasses.dataclass(frozen=True)
class AdapterKey:
    """Full LRU key for one precomputed serving state."""
    adapter_id: str
    version: int
    act_dtype: str
    fold_gsb: bool
    sharding: Any = None          # hashable mesh fingerprint or None

    def describe(self) -> str:
        return (f"adapter_id={self.adapter_id!r} version={self.version} "
                f"act_dtype={self.act_dtype} fold_gsb={self.fold_gsb} "
                f"sharding={self.sharding}")


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Point-in-time counters (returned by :meth:`AdapterStateCache.stats`;
    the cache keeps mutating its own tallies)."""
    hits: int
    misses: int
    evictions: int
    invalidations: int
    entries: int
    current_bytes: int
    max_bytes: int | None
    thrashing: bool = False    # every recent lookup was an evicting miss
    # -- host spill tier (all zero without host_max_bytes) ------------------
    host_entries: int = 0      # states resident in the host tier
    host_bytes: int = 0        # bytes the host tier holds
    host_max_bytes: int | None = None
    spills: int = 0            # device evictions preserved to host
    reloads: int = 0           # host states promoted back to device
    host_drops: int = 0        # states dropped from the host tier (true loss)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class AdapterCacheMiss(LookupError):
    """A request's adapter state is not servable from the cache. ``key``
    carries the full :class:`AdapterKey`; the message names every field so
    the operator can see exactly which precompute is missing."""

    def __init__(self, message: str, key: AdapterKey):
        super().__init__(message)
        self.key = key


def mesh_fingerprint(mesh) -> tuple | None:
    """Hashable identity of a mesh's layout (axis names x sizes) — enough
    to distinguish states pinned to different serving shardings."""
    if mesh is None:
        return None
    shape = dict(mesh.shape)
    return tuple((a, shape[a]) for a in mesh.axis_names)


def _tree_to_host(tree):
    """Move a device state tree to host RAM: (numpy tree, shardings tree).
    The shardings are captured leaf-wise so a reload can ``device_put``
    each buffer back exactly where the precompute had pinned it."""
    import jax
    host = jax.tree_util.tree_map(lambda l: np.asarray(jax.device_get(l)),
                                  tree)
    shardings = jax.tree_util.tree_map(
        lambda l: getattr(l, "sharding", None), tree)
    return host, shardings


def _tree_to_device(host_tree, sh_tree):
    """Inverse of :func:`_tree_to_host`: bitwise the original state (a
    device_get/device_put round trip never rewrites bits)."""
    import jax

    def put(a, s):
        return jax.device_put(a, s) if s is not None else jax.device_put(a)

    return jax.tree_util.tree_map(put, host_tree, sh_tree)


def serving_state_nbytes(tree) -> int:
    """Bytes a cached serving tree HOLDS: every array leaf, raw adapter
    weights included. A jitted precompute returns fresh device buffers
    for A/B/m too (jit outputs never alias their inputs), so counting
    only the ``g``/``gsB`` leaves would understate resident memory ~3x
    and fire ``max_bytes`` eviction far too late."""
    total = 0
    if isinstance(tree, dict):
        for v in tree.values():
            if isinstance(v, dict):
                total += serving_state_nbytes(v)
            elif hasattr(v, "shape"):
                total += int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
    return total


class AdapterStateCache:
    """LRU of precomputed per-tenant serving states with byte accounting.

    ``precompute(params, raw_adapters) -> serving tree`` is the (usually
    jitted) state builder — :func:`repro.launch.steps.make_precompute_step`
    for model-level trees, or a thin ``precompute_adapter_state`` wrapper
    in unit tests. One compiled precompute is reused across tenants (same
    tree shapes → one trace), and a mesh-aware precompute lands the cached
    ``g``/``gsB`` pre-pinned to the serving shardings, so a cache hit hands
    decode a correctly-placed state with zero per-request layout work.

    ``max_bytes`` bounds the bytes of the cached state trees (every
    leaf — the jitted precompute materializes fresh A/B/m buffers
    alongside ``g``/``gsB``, so the whole tree is resident memory); the
    least-recently-used states are evicted past it. A single state larger
    than the whole budget is kept (serving must proceed) and everything
    else is evicted around it.

    ``host_max_bytes`` turns the single-tier LRU into a TIERED cache: a
    device-HBM LRU over a host-RAM spill tier. Device eviction then
    SPILLS the state to host (``jax.device_get``, shardings captured)
    instead of dropping it, a later lookup RELOADS it (``device_put``
    back under the captured shardings — bitwise the original precompute,
    at host-copy cost instead of a full recompute), and only host-tier
    overflow truly drops a state (``host_drops``). A spilled state never
    raises :class:`AdapterCacheMiss` under warm-only routing
    (``allow_miss=False``) — spilled-but-registered is servable — and a
    reload is NOT an evicting miss for :meth:`thrashing`: backpressure
    (:class:`repro.launch.engine.EngineBusy`) is reserved for handles
    that would pay a full precompute. Every state is resident in exactly
    ONE tier (spill moves it, reload moves it back); version bumps and
    :meth:`invalidate` clear BOTH tiers.
    """

    def __init__(self, precompute: Callable[[Any, Any], Any], *,
                 max_bytes: int | None = None,
                 host_max_bytes: int | None = None,
                 act_dtype: Any = np.float32,
                 fold_gsb: bool = True,
                 sharding: Any = None,
                 thrash_window: int = 4):
        self._precompute = precompute
        self.max_bytes = max_bytes
        self.host_max_bytes = host_max_bytes
        self.act_dtype = np.dtype(act_dtype).name
        self.fold_gsb = bool(fold_gsb)
        self.sharding = sharding
        if thrash_window < 1:
            raise ValueError(f"thrash_window={thrash_window} < 1")
        self.thrash_window = int(thrash_window)
        self._registry: dict[str, tuple[int, Any]] = {}
        self._lru: "OrderedDict[AdapterKey, tuple[Any, int]]" = OrderedDict()
        # Host spill tier: key -> (host numpy tree, captured shardings
        # tree, nbytes). LRU-ordered; only populated when host_max_bytes
        # is set.
        self._host: "OrderedDict[AdapterKey, tuple[Any, Any, int]]" = \
            OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._invalidations = 0
        self._current_bytes = 0
        self._host_bytes = 0
        self._spills = 0
        self._reloads = 0
        self._host_drops = 0
        # Observability hook: ``on_event(kind, key)`` fires on tier
        # traffic ("spill" / "reload"). The engine claims it when built
        # with a trace recorder (repro.obs) — it must stay cheap and
        # must never raise; None (the default) costs one attribute read.
        self.on_event: Callable[[str, AdapterKey], None] | None = None
        # Sliding window over the last `thrash_window` lookups: True iff
        # the lookup was a miss whose insertion evicted someone. All-True
        # (with a full window) = the working set cannot fit — every
        # admission pays a full precompute AND kills a neighbour's state.
        self._recent_evicting: deque[bool] = deque(maxlen=self.thrash_window)

    # -- construction -------------------------------------------------------

    @classmethod
    def for_serving(cls, mcfg, scfg, mesh=None, *, max_bytes=None,
                    host_max_bytes=None,
                    fold_gsb: bool = True) -> "AdapterStateCache":
        """Model-level cache: precompute = jitted ``make_precompute_step``
        (mesh-aware — cached leaves land pinned to the serving shardings),
        act_dtype = the model dtype, key fingerprint = the mesh layout."""
        import jax
        from repro.launch.steps import make_precompute_step
        fn = jax.jit(make_precompute_step(mcfg, scfg, mesh,
                                          fold_gsb=fold_gsb))
        return cls(fn, max_bytes=max_bytes, host_max_bytes=host_max_bytes,
                   act_dtype=mcfg.dtype,
                   fold_gsb=fold_gsb, sharding=mesh_fingerprint(mesh))

    # -- registry (raw trainable trees + versions) --------------------------

    def register(self, adapter_id: str, adapters) -> AdapterHandle:
        """Register a NEW adapter set at version 0. Serving leaves are
        stripped (``invalidate_adapter_state``): the registry always holds
        the raw trainable tree; states are (re)derived through the cache."""
        if adapter_id in self._registry:
            raise ValueError(
                f"adapter_id {adapter_id!r} is already registered "
                f"(version {self._registry[adapter_id][0]}); use "
                f"update() to publish new weights")
        self._registry[adapter_id] = (0, invalidate_adapter_state(adapters))
        return AdapterHandle(adapter_id, 0)

    def update(self, adapter_id: str, adapters) -> AdapterHandle:
        """Publish updated weights for a registered adapter set: bumps the
        version and drops every cached state of older versions — the LRU
        face of the training invalidation contract (any update to A/B/m
        invalidates the precomputed state)."""
        if adapter_id not in self._registry:
            raise KeyError(f"adapter_id {adapter_id!r} is not registered")
        version = self._registry[adapter_id][0] + 1
        self._registry[adapter_id] = (version,
                                      invalidate_adapter_state(adapters))
        self.invalidate(adapter_id)
        return AdapterHandle(adapter_id, version)

    def current_handle(self, adapter_id: str) -> AdapterHandle:
        if adapter_id not in self._registry:
            raise KeyError(f"adapter_id {adapter_id!r} is not registered")
        return AdapterHandle(adapter_id, self._registry[adapter_id][0])

    def adapters(self, adapter_id: str):
        """The registered raw (trainable) tree at the current version."""
        return self._registry[adapter_id][1]

    # -- the LRU ------------------------------------------------------------

    def make_key(self, handle: AdapterHandle) -> AdapterKey:
        return AdapterKey(handle.adapter_id, handle.version,
                          self.act_dtype, self.fold_gsb, self.sharding)

    def get_state(self, params, handle: AdapterHandle, *,
                  allow_miss: bool = True):
        """The precomputed serving tree for ``handle``.

        A stale handle (version != the registered current version) is
        ALWAYS an error — precomputing from the current raw tree would
        serve different weights than the caller asked for. A current
        handle whose state is not cached is a miss: recomputed and
        inserted when ``allow_miss`` (evicting LRU states past
        ``max_bytes``), or :class:`AdapterCacheMiss` naming every key
        field when the caller demanded warm-only serving.
        """
        if handle.adapter_id not in self._registry:
            raise AdapterCacheMiss(
                f"adapter_id {handle.adapter_id!r} is not registered with "
                f"this cache (key: {self.make_key(handle).describe()}); "
                f"register(adapter_id, adapters) first",
                self.make_key(handle))
        current, raw = self._registry[handle.adapter_id]
        if handle.version != current:
            raise AdapterCacheMiss(
                f"stale adapter handle: request pinned "
                f"{self.make_key(handle).describe()} but the registered "
                f"version is {current} — the adapter was updated after "
                f"this handle was issued; re-resolve with "
                f"current_handle({handle.adapter_id!r}) (a stale state "
                f"would silently serve the wrong weights)",
                self.make_key(handle))
        key = self.make_key(handle)
        if key in self._lru:
            self._lru.move_to_end(key)
            self._hits += 1
            self._recent_evicting.append(False)
            return self._lru[key][0]
        if key in self._host:
            # Spilled-but-registered: promote back to the device tier at
            # host-copy cost — NEVER an AdapterCacheMiss (warm-only
            # routing included), never a full precompute, and not an
            # evicting miss for the thrash window (the insertion may
            # still spill a neighbour, but THIS lookup paid no norm
            # work).
            host_tree, sh_tree, nbytes = self._host.pop(key)
            self._host_bytes -= nbytes
            state = _tree_to_device(host_tree, sh_tree)
            self._reloads += 1
            if self.on_event is not None:
                self.on_event("reload", key)
            self._lru[key] = (state, nbytes)
            self._current_bytes += nbytes
            self._evict_over_budget()
            self._recent_evicting.append(False)
            return state
        if not allow_miss:
            raise AdapterCacheMiss(
                f"adapter state not precomputed and allow_miss=False: "
                f"{key.describe()} — warm the cache with "
                f"get_state(params, handle) (or precompute at publish "
                f"time) before serving with warm-only routing",
                key)
        self._misses += 1
        state = self._precompute(params, raw)
        nbytes = serving_state_nbytes(state)
        self._lru[key] = (state, nbytes)
        self._current_bytes += nbytes
        ev_before = self._evictions
        self._evict_over_budget()
        self._recent_evicting.append(self._evictions > ev_before)
        return state

    def _evict_over_budget(self) -> None:
        if self.max_bytes is None:
            return
        while self._current_bytes > self.max_bytes and len(self._lru) > 1:
            key, (state, nbytes) = self._lru.popitem(last=False)
            self._current_bytes -= nbytes
            self._evictions += 1
            if self.host_max_bytes is not None:
                # Spill instead of drop: the state moves (never copies —
                # exactly one tier holds it) to host RAM with its device
                # shardings captured, so a reload lands bitwise-identical
                # and correctly placed.
                host_tree, sh_tree = _tree_to_host(state)
                self._host[key] = (host_tree, sh_tree, nbytes)
                self._host_bytes += nbytes
                self._spills += 1
                if self.on_event is not None:
                    self.on_event("spill", key)
                self._shrink_host_tier()

    def _shrink_host_tier(self) -> None:
        while (self._host_bytes > self.host_max_bytes
               and len(self._host) > 1):
            _, (_, _, nbytes) = self._host.popitem(last=False)
            self._host_bytes -= nbytes
            self._host_drops += 1

    def invalidate(self, adapter_id: str | None = None) -> int:
        """Drop cached states (all of one adapter's versions, or the whole
        cache) from BOTH tiers — a stale spilled state must never be
        reloadable after a version bump. The registry (raw trees) is
        untouched. Returns the number of states dropped."""
        doomed = [k for k in self._lru
                  if adapter_id is None or k.adapter_id == adapter_id]
        for k in doomed:
            _, nbytes = self._lru.pop(k)
            self._current_bytes -= nbytes
        doomed_host = [k for k in self._host
                       if adapter_id is None or k.adapter_id == adapter_id]
        for k in doomed_host:
            _, _, nbytes = self._host.pop(k)
            self._host_bytes -= nbytes
        doomed += doomed_host
        self._invalidations += len(doomed)
        # An explicit drop (publish, operator action, fault injection) is
        # not thrash: the next few lookups will miss because WE removed
        # the states, not because the working set outgrew the budget.
        self._recent_evicting.clear()
        return len(doomed)

    def is_resident(self, handle: AdapterHandle) -> bool:
        """Whether ``handle``'s state is servable from the LRU right now
        (no staleness check, no LRU-order side effects)."""
        return self.make_key(handle) in self._lru

    def is_spilled(self, handle: AdapterHandle) -> bool:
        """Whether ``handle``'s state sits in the host spill tier: not
        device-resident, but servable at host-copy cost (a reload, not a
        precompute) — the backpressure exemption
        (:class:`repro.launch.engine.EngineBusy` never refuses a spilled
        handle). Always False without a host tier."""
        return self.make_key(handle) in self._host

    def thrashing(self) -> bool:
        """True when the last ``thrash_window`` lookups were ALL evicting
        misses — the working set cannot fit ``max_bytes``, so every
        admission pays a full precompute and evicts a neighbour. The
        serving layer uses this for submit-time backpressure
        (:class:`repro.launch.engine.EngineBusy`) instead of letting the
        serve path stall on back-to-back precomputes."""
        return (len(self._recent_evicting) == self.thrash_window
                and all(self._recent_evicting))

    def cached_keys(self) -> tuple[AdapterKey, ...]:
        """LRU order, least recently used first (eviction order)."""
        return tuple(self._lru.keys())

    def spilled_keys(self) -> tuple[AdapterKey, ...]:
        """Host-tier keys, least recently spilled first (drop order)."""
        return tuple(self._host.keys())

    def stats(self) -> CacheStats:
        return CacheStats(hits=self._hits, misses=self._misses,
                          evictions=self._evictions,
                          invalidations=self._invalidations,
                          entries=len(self._lru),
                          current_bytes=self._current_bytes,
                          max_bytes=self.max_bytes,
                          thrashing=self.thrashing(),
                          host_entries=len(self._host),
                          host_bytes=self._host_bytes,
                          host_max_bytes=self.host_max_bytes,
                          spills=self._spills,
                          reloads=self._reloads,
                          host_drops=self._host_drops)
