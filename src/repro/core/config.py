"""DoRA adapter configuration (paper §1, §4, App. B).

The config mirrors the paper's runtime knobs:
  - rank / alpha / rsLoRA scaling (``s`` appears in all three factored-norm
    terms, paper §7),
  - three-tier dispatch controls (mode, crossover thresholds),
  - norm implementation selector (factored vs. the two baselines the paper
    benchmarks against: dense ``B@A`` and PEFT's identity-matrix pattern),
  - chunk budget for the fp32 norm accumulation (paper default 256 MB),
  - ``save_inner`` — Tier-1 dual-output that saves ``inner = s*lora + base``
    for the magnitude gradient (skipped when the magnitude is frozen).
"""
from __future__ import annotations

import dataclasses
import math
import os


def _env_flag(name: str) -> str | None:
    v = os.environ.get(name)
    return v if v not in (None, "") else None


def shrink_block_rows(block_m: int, rows: int | None) -> int:
    """Decode-aware row-tile shrink: the block never exceeds the
    sublane-rounded row count, so small-M grids pad to the next multiple
    of 8 rows instead of a full tile. The ONE source of this rule — the
    config resolver, the shard-local kernel wrapper
    (``kernels.dora_compose.local_block_shape``) and the bench bytes
    model all derive their block_m through it."""
    if rows is None:
        return block_m
    return min(block_m, max(8, (rows + 7) // 8 * 8))


# Tier names (dispatch-table keys) → config modes. "tpu"/"pallas"/"fused"
# all mean the compiled-kernel path; dispatch degrades it to the
# interpreter on non-TPU hosts.
_TIER_ALIASES = {"tpu": "fused", "pallas": "fused", "fused": "fused",
                 "interpret": "interpret", "eager": "eager"}


def _normalize_tier(tier: str) -> str | None:
    return _TIER_ALIASES.get(tier.strip().lower())


@dataclasses.dataclass(frozen=True)
class DoRAConfig:
    """Configuration for DoRA adaptation of a linear layer family."""

    rank: int = 384
    alpha: float = 192.0
    rslora: bool = True

    # --- dispatch (paper §4, Table 2) ---
    # "auto": pallas on TPU above crossover, eager otherwise.
    # "fused": force pallas kernels (compiled for TPU).
    # "interpret": force pallas kernels in interpret mode (CPU validation).
    # "eager": force the pure-jnp Tier-3 path.
    mode: str = "auto"
    # Forced kernel tier ("tpu" | "interpret" | "eager"); overrides ``mode``
    # when set. The REPRO_FORCE_TIER env var overrides both, so any tier is
    # exercisable on any host without touching config plumbing.
    force_tier: str | None = None
    # Crossover below which launch latency dominates (paper §4: d_out >= 2048
    # and rows * d_out >= 2048 * 6144).
    min_fused_dout: int = 2048
    min_fused_elems: int = 2048 * 6144

    # --- norm (paper §2) ---
    # "factored" (ours) | "dense_ba" | "peft_eye" (baselines, §5.3 / §1).
    norm_impl: str = "factored"
    norm_chunk_mb: int | None = 256
    # Beyond-paper: precompute ||W||^2_row once (paper §2.3 "future work").
    cache_base_norm: bool = False

    # --- compose (paper §3) ---
    save_inner: bool = True
    magnitude_trainable: bool = True
    dropout: float = 0.0
    # Matmul-fused compose (beyond-paper, one fusion deeper): compute the
    # LoRA up-projection h@Bᵀ inside the compose kernel so y_lora is never
    # written to HBM. Only taken on the fused backends when the (128-padded)
    # rank stays below the crossover — above it the per-row-tile re-reads
    # of B exceed the y_lora write+read the fusion saves (B traffic ≈
    # (M/block_m)·d_out·r vs 2·M·d_out, i.e. profitable while
    # r ≲ 2·block_m). ``mm_fused_max_rank=None`` derives exactly that
    # 2·block_m bound from the bytes model at the CONFIGURED matmul-fused
    # block rows (``mm_block_rows``, falling back to ``block_rows``), so
    # tuning either knob re-calibrates the guard; set an int to pin it.
    compose_matmul_fused: bool = True
    mm_fused_max_rank: int | None = None

    # --- kernel block shapes (perf-tunable; see EXPERIMENTS.md §Perf) ---
    block_rows: int = 256
    block_cols: int = 1024
    # block_m of the matmul-fused compose grid; None → ``block_rows``.
    # Decode-shaped call sites (rows « block_rows) additionally shrink the
    # grid to the sublane-rounded row count via ``resolve_mm_block_rows``
    # so a 2-row decode batch is padded to 8 kernel rows, not 256.
    mm_block_rows: int | None = None
    norm_block_rows: int = 256
    norm_block_k: int = 512

    def __post_init__(self):
        if self.rank <= 0:
            raise ValueError(f"rank must be positive, got {self.rank}")
        if self.mode not in ("auto", "fused", "interpret", "eager"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if (self.force_tier is not None
                and _normalize_tier(self.force_tier) is None):
            raise ValueError(
                f"unknown force_tier {self.force_tier!r} (expected one of "
                f"'tpu'/'fused', 'interpret', 'eager')")
        if self.norm_impl not in ("factored", "dense_ba", "peft_eye"):
            raise ValueError(f"unknown norm_impl {self.norm_impl!r}")
        if self.mm_block_rows is not None and self.mm_block_rows <= 0:
            raise ValueError(
                f"mm_block_rows must be positive, got {self.mm_block_rows}")
        if self.dropout != 0.0:
            raise NotImplementedError(
                "dropout routes to the chunked eager path (paper App. B); "
                "only p=0 is wired in this repro")

    @property
    def scaling(self) -> float:
        """LoRA scaling s: alpha/rank, or alpha/sqrt(rank) under rsLoRA."""
        if self.rslora:
            return self.alpha / math.sqrt(self.rank)
        return self.alpha / self.rank

    def resolve_mode(self) -> str:
        """Apply the env-var overrides (paper App. B + forced tier).

        Precedence: REPRO_DORA_FUSED=0 kill switch > REPRO_FORCE_TIER >
        REPRO_DORA_MODE > ``force_tier`` config field > ``mode``.
        """
        if _env_flag("REPRO_DORA_FUSED") == "0":
            return "eager"
        tier = _env_flag("REPRO_FORCE_TIER")
        if tier is not None:
            mode = _normalize_tier(tier)
            if mode is None:
                raise ValueError(
                    f"REPRO_FORCE_TIER={tier!r} is not a known tier "
                    f"(expected 'tpu'/'fused', 'interpret', or 'eager')")
            return mode
        forced = _env_flag("REPRO_DORA_MODE")
        if forced is not None:
            mode = forced.strip().lower()
            if mode != "auto":
                mode = _normalize_tier(mode)
            if mode is None:
                raise ValueError(
                    f"REPRO_DORA_MODE={forced!r} is not a known mode "
                    f"(expected 'auto', 'fused'/'tpu', 'interpret', or "
                    f"'eager')")
            return mode
        if self.force_tier is not None:
            return _normalize_tier(self.force_tier)
        return self.mode

    def resolve_mm_block_rows(self, rows: int | None = None) -> int:
        """block_m of the matmul-fused compose grid.

        ``rows`` (the call site's flattened row count, when known) shrinks
        the grid for decode-shaped shapes: the block never exceeds the
        sublane-rounded row count, so small-M calls pad to the next
        multiple of 8 rows instead of a full ``block_rows`` tile.
        """
        bm = self.mm_block_rows if self.mm_block_rows is not None \
            else self.block_rows
        return shrink_block_rows(bm, rows)

    def resolve_mm_fused_max_rank(self, rows: int | None = None) -> int:
        """Rank crossover for the matmul-fused compose: explicit override
        or the bytes-model bound 2·block_m at the configured matmul-fused
        block rows (see the ``compose_matmul_fused`` field comment).
        ``rows`` prices the bound at the block the call site actually
        executes: decode-shaped calls shrink the grid, which shrinks the
        profitable rank range with it (the B re-reads stop amortizing) —
        the committed BENCH_compose.json decode row records exactly that
        regression."""
        if self.mm_fused_max_rank is not None:
            return self.mm_fused_max_rank
        return 2 * self.resolve_mm_block_rows(rows)

    def resolve_chunk_mb(self) -> int | None:
        env = _env_flag("REPRO_DORA_NORM_CHUNK_MB")
        if env is not None:
            v = int(env)
            return None if v <= 0 else v
        return self.norm_chunk_mb
