"""DoRA adapter: parameter init + the adapted linear application.

Forward contract (paper App. A):

    ΔY = g ⊙ (s · X·Aᵀ·Bᵀ) + (g − 1) ⊙ Y_base,   Y = Y_base + ΔY
    g  = m / max(w_norm, ε)            (fp32, outside the no-grad context)
    w_norm = ||W + s·B·A||_row         (fp32, detached, recomputed per step)

Bias is subtracted before the compose and re-added after (i.e. the compose
operates on the bias-free Y_base); the norm is recomputed every forward and
never cached across steps. Weights follow the paper's [d_out, d_in]
convention with per-output-row norms.

``dora_linear`` is the single integration point the models use; it routes
through the three-tier dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import compose as _compose
from repro.core import dispatch as _dispatch
from repro.core import factored_norm as _norm
from repro.core.config import DoRAConfig

_F32 = jnp.float32


def init_dora_params(key, W, cfg: DoRAConfig, *, m_dtype=jnp.float32):
    """Init A ~ U(-1/√d_in, 1/√d_in) (PEFT's LoRA-A default), B = 0,
    m = ||W||_row (DoRA init). Supports stacked weights [..., d_out, d_in]
    (layer stacks / experts) by vmapping over leading dims."""
    if W.ndim > 2:
        keys = jax.random.split(key, W.shape[0])
        return jax.vmap(
            lambda k, w: init_dora_params(k, w, cfg, m_dtype=m_dtype)
        )(keys, W)
    d_out, d_in = W.shape
    bound = 1.0 / (d_in ** 0.5)
    A = jax.random.uniform(key, (cfg.rank, d_in), W.dtype, -bound, bound)
    B = jnp.zeros((d_out, cfg.rank), W.dtype)
    # At init B = 0 so ||W + sBA|| = ||W||: reuse the factored base term.
    base_sq, _, _ = _norm.factored_norm_terms(W, A, B, compute_cross=False)
    m = jnp.sqrt(jnp.maximum(base_sq, 0.0)).astype(m_dtype)
    out = {"A": A, "B": B, "m": m}
    if cfg.cache_base_norm:
        # Paper §2.3 future work, implemented (H3.2): W is frozen, so
        # ||W||²_row is precomputed once into a [d_out] fp32 buffer and
        # carried in the adapter tree — the per-step norm never re-reads
        # W for the base term.
        out["base_sq"] = base_sq
    return out


def compute_weight_norm(W, A, B, cfg: DoRAConfig, *, axis_name=None,
                        base_sq_cache=None, interpret: bool | None = None):
    """Detached fp32 [d_out] row norm of the composed weight, routed through
    the configured implementation."""
    impl = cfg.norm_impl
    if axis_name is not None:
        # Sharded accumulation (beyond-paper, DESIGN.md §5): only the
        # factored algebra distributes; the baselines would all-gather.
        return _norm.factored_norm_sharded(
            W, A, B, cfg.scaling, axis_name=axis_name,
            chunk_mb=cfg.resolve_chunk_mb(),
            base_sq_cache=base_sq_cache)
    if impl == "peft_eye":
        return _norm.norm_peft_eye(W, A, B, cfg.scaling)
    if impl == "dense_ba":
        return _norm.norm_dense_ba(W, A, B, cfg.scaling)
    plan = _dispatch.plan_norm(cfg, d_out=W.shape[0])
    if plan.fused:
        from repro.kernels import ops as _kops
        return _kops.fused_norm(
            W, A, B, cfg.scaling,
            block_rows=cfg.norm_block_rows, block_k=cfg.norm_block_k,
            interpret=(plan.interpret if interpret is None else interpret),
            base_sq_cache=base_sq_cache)
    return _norm.factored_norm(W, A, B, cfg.scaling,
                               chunk_mb=cfg.resolve_chunk_mb(),
                               base_sq_cache=base_sq_cache)


def compose_delta(y_base, y_lora, g, cfg: DoRAConfig, *, training: bool):
    """Route the compose through the three-tier dispatch."""
    _compose.check_broadcast(g, y_base)
    rows = 1
    for d in y_base.shape[:-1]:
        rows *= d
    plan = _dispatch.plan_compose(cfg, training=training, rows=rows,
                                  d_out=y_base.shape[-1])
    if plan.tier is _dispatch.Tier.EAGER:
        return _compose.compose_stable(y_base, y_lora, g, cfg.scaling)
    from repro.kernels import ops as _kops
    if plan.tier is _dispatch.Tier.FUSED_FWD:
        g = jax.lax.stop_gradient(g)
        return _kops.fused_compose(
            y_base, y_lora, g, cfg.scaling, save_inner=False,
            mag_grad=False, block_m=cfg.block_rows, block_n=cfg.block_cols,
            interpret=plan.interpret)
    return _kops.fused_compose(
        y_base, y_lora, g, cfg.scaling,
        save_inner=cfg.save_inner and cfg.magnitude_trainable,
        mag_grad=cfg.magnitude_trainable,
        block_m=cfg.block_rows, block_n=cfg.block_cols,
        interpret=plan.interpret)


def dora_linear(x, W, adapter: dict[str, Any], cfg: DoRAConfig, *,
                bias=None, training: bool = True, axis_name=None,
                base_sq_cache=None, constrain=None):
    """Adapted linear: x [..., d_in] → y [..., d_out].

    W: frozen [d_out, d_in]; adapter: {"A": [r, d_in], "B": [d_out, r],
    "m": [d_out]}. ``axis_name``: if W/A are d_in-sharded inside shard_map,
    the norm partials psum over this axis. ``constrain``: optional
    sharding-constraint fn applied to y_base / y_lora — row-parallel call
    sites pin the sequence-parallel sharding here so the partial sums
    lower to reduce-scatter and the compose runs seq-sharded
    (EXPERIMENTS.md §Perf H1.4).
    """
    A, B, m = adapter["A"], adapter["B"], adapter["m"]
    if base_sq_cache is None and "base_sq" in adapter:
        base_sq_cache = adapter["base_sq"]
    if base_sq_cache is not None:
        base_sq_cache = jax.lax.stop_gradient(base_sq_cache)
    if not cfg.magnitude_trainable:
        m = jax.lax.stop_gradient(m)
    w_norm = compute_weight_norm(W, A, B, cfg, axis_name=axis_name,
                                 base_sq_cache=base_sq_cache)
    eps = _norm.dtype_eps(x.dtype)
    g = _compose.magnitude_scale(m, w_norm, eps)

    W = jax.lax.stop_gradient(W)
    y_base = x @ W.T
    y_lora = (x @ A.T) @ B.T
    if constrain is not None:
        y_base = constrain(y_base)
        y_lora = constrain(y_lora)
    delta = compose_delta(y_base, y_lora, g, cfg, training=training)
    y = y_base + delta
    if bias is not None:
        y = y + bias  # bias re-added after the compose (paper App. A)
    return y


def dora_linear_stacked(x, W, adapter, cfg: DoRAConfig, *, training=True):
    """vmap over a leading stack dim (e.g. experts): x [E, ..., d_in],
    W [E, d_out, d_in], adapter leaves stacked on dim 0."""
    return jax.vmap(
        lambda xe, we, ad: dora_linear(xe, we, ad, cfg, training=training)
    )(x, W, adapter)


@dataclasses.dataclass(frozen=True)
class DoRAParamSpec:
    """Bookkeeping for one adapted weight: used by optimizer masking and
    sharding-rule generation."""
    path: str
    d_out: int
    d_in: int
    rank: int
