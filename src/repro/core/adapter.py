"""DoRA adapter: parameter init + the adapted linear application.

Forward contract (paper App. A):

    ΔY = g ⊙ (s · X·Aᵀ·Bᵀ) + (g − 1) ⊙ Y_base,   Y = Y_base + ΔY
    g  = m / max(w_norm, ε)            (fp32, outside the no-grad context)
    w_norm = ||W + s·B·A||_row         (fp32, detached, recomputed per step)

Bias is subtracted before the compose and re-added after (i.e. the compose
operates on the bias-free Y_base); under training the norm is recomputed
every forward. Weights follow the paper's [d_out, d_in] convention with
per-output-row norms.

``dora_linear`` is the single integration point the models use; it routes
through the three-tier dispatch. Two hot-path overhauls live here:

  - **Matmul-fused compose** (plan flag ``matmul_fused``): when the rank
    passes the crossover guard, the LoRA up-projection ``h @ Bᵀ`` runs
    inside the compose kernel and the ``[M, d_out]`` y_lora tensor is never
    materialized in HBM — including under SPMD: sharded call sites pin the
    rank-space ``h`` (rows like the output, rank replicated) instead of a
    materialized y_lora, and an expressible :class:`~repro.core.sharding.
    ComposeSharding` plan runs the kernel shard-local under shard_map.
  - **Frozen-adapter serving state** (:func:`precompute_adapter_state`):
    during generation A/B/m are frozen, so ``w_norm`` — and hence ``g`` —
    is computed ONCE per adapter set and carried in the adapter tree as a
    ``"g"`` leaf; the decode loop then does zero factored-norm work per
    token. **Invalidation contract:** the cached state is only valid while
    A/B/m are untouched — ``dora_linear(training=True)`` refuses a tree
    carrying ``"g"`` so a stale cache can never silently leak into
    training; rebuild the state after every adapter update.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import ad_checkpoint as _adc

from repro.core import compose as _compose
from repro.core import dispatch as _dispatch
from repro.core import factored_norm as _norm
from repro.core.config import DoRAConfig
from repro.core.sharding import ComposeSharding, as_compose_sharding

_F32 = jnp.float32


def init_dora_params(key, W, cfg: DoRAConfig, *, m_dtype=jnp.float32):
    """Init A ~ U(-1/√d_in, 1/√d_in) (PEFT's LoRA-A default), B = 0,
    m = ||W||_row (DoRA init). Supports stacked weights [..., d_out, d_in]
    (layer stacks / experts) by vmapping over leading dims."""
    if W.ndim > 2:
        keys = jax.random.split(key, W.shape[0])
        return jax.vmap(
            lambda k, w: init_dora_params(k, w, cfg, m_dtype=m_dtype)
        )(keys, W)
    d_out, d_in = W.shape
    bound = 1.0 / (d_in ** 0.5)
    A = jax.random.uniform(key, (cfg.rank, d_in), W.dtype, -bound, bound)
    B = jnp.zeros((d_out, cfg.rank), W.dtype)
    # At init B = 0 so ||W + sBA|| = ||W||: reuse the factored base term.
    base_sq, _, _ = _norm.factored_norm_terms(W, A, B, compute_cross=False)
    m = jnp.sqrt(jnp.maximum(base_sq, 0.0)).astype(m_dtype)
    out = {"A": A, "B": B, "m": m}
    if cfg.cache_base_norm:
        # Paper §2.3 future work, implemented (H3.2): W is frozen, so
        # ||W||²_row is precomputed once into a [d_out] fp32 buffer and
        # carried in the adapter tree — the per-step norm never re-reads
        # W for the base term.
        out["base_sq"] = base_sq
    return out


def compute_weight_norm(W, A, B, cfg: DoRAConfig, *, axis_name=None,
                        base_sq_cache=None, interpret: bool | None = None):
    """Detached fp32 [d_out] row norm of the composed weight, routed through
    the configured implementation. Every route tags its result with the
    ``"dora_wnorm"`` checkpoint name: the layer-remat policy saves it
    (instead of recomputing O(d_out·d_in) in the backward) and tests
    assert from the jaxpr that cached-state serving steps contain no norm
    work at all."""
    return _adc.checkpoint_name(
        _compute_weight_norm(W, A, B, cfg, axis_name=axis_name,
                             base_sq_cache=base_sq_cache,
                             interpret=interpret), "dora_wnorm")


def _compute_weight_norm(W, A, B, cfg: DoRAConfig, *, axis_name,
                         base_sq_cache, interpret):
    impl = cfg.norm_impl
    if axis_name is not None:
        # Sharded accumulation (beyond-paper, DESIGN.md §5): only the
        # factored algebra distributes; the baselines would all-gather.
        return _norm.factored_norm_sharded(
            W, A, B, cfg.scaling, axis_name=axis_name,
            chunk_mb=cfg.resolve_chunk_mb(),
            base_sq_cache=base_sq_cache)
    if impl == "peft_eye":
        return _norm.norm_peft_eye(W, A, B, cfg.scaling)
    if impl == "dense_ba":
        return _norm.norm_dense_ba(W, A, B, cfg.scaling)
    plan = _dispatch.plan_norm(cfg, d_out=W.shape[0])
    if plan.fused:
        from repro.kernels import ops as _kops
        return _kops.fused_norm(
            W, A, B, cfg.scaling,
            block_rows=cfg.norm_block_rows, block_k=cfg.norm_block_k,
            interpret=(plan.interpret if interpret is None else interpret),
            base_sq_cache=base_sq_cache)
    return _norm.factored_norm(W, A, B, cfg.scaling,
                               chunk_mb=cfg.resolve_chunk_mb(),
                               base_sq_cache=base_sq_cache)


def _row_count(shape) -> int:
    rows = 1
    for d in shape[:-1]:
        rows *= d
    return rows


def compose_delta(y_base, y_lora, g, cfg: DoRAConfig, *, training: bool):
    """Route the compose through the three-tier dispatch (materialized
    y_lora form — rank unknown here, so never matmul-fused)."""
    _compose.check_broadcast(g, y_base)
    plan = _dispatch.plan_compose(cfg, training=training,
                                  rows=_row_count(y_base.shape),
                                  d_out=y_base.shape[-1])
    if plan.tier is _dispatch.Tier.EAGER:
        return _compose.compose_stable(y_base, y_lora, g, cfg.scaling)
    from repro.kernels import ops as _kops
    if plan.tier is _dispatch.Tier.FUSED_FWD:
        g = jax.lax.stop_gradient(g)
        return _kops.fused_compose(
            y_base, y_lora, g, cfg.scaling, save_inner=False,
            mag_grad=False, block_m=cfg.block_rows, block_n=cfg.block_cols,
            interpret=plan.interpret)
    return _kops.fused_compose(
        y_base, y_lora, g, cfg.scaling,
        save_inner=cfg.save_inner and cfg.magnitude_trainable,
        mag_grad=cfg.magnitude_trainable,
        block_m=cfg.block_rows, block_n=cfg.block_cols,
        interpret=plan.interpret)


def compose_delta_factored(y_base, h, B, g, cfg: DoRAConfig, *,
                           training: bool,
                           sharding: ComposeSharding | None = None,
                           constrain=None):
    """Compose from the factored LoRA activation ``h = x@Aᵀ``.

    When the plan resolves matmul-fused, the up-projection h@Bᵀ runs inside
    the compose kernel and y_lora never touches HBM; otherwise y_lora is
    materialized once and the classic element-wise path runs (identical
    math — tier-equivalence is tested).

    ``sharding``: the call site's :class:`ComposeSharding` plan. An
    expressible plan rides the KernelPlan into the shard_map'd kernel
    (shard-local tiles, no y_lora anywhere); an inexpressible one falls
    back to the materialized-lora route, where ``constrain`` (the output
    constraint — usually ``sharding`` itself or a legacy callable) pins
    y_lora so the TP partial sums still lower to reduce-scatter (H1.4).
    """
    _compose.check_broadcast(g, y_base)
    rows = _row_count(y_base.shape)
    plan = _dispatch.plan_compose(cfg, training=training, rows=rows,
                                  d_out=y_base.shape[-1],
                                  rank=B.shape[-1], sharding=sharding)
    if plan.matmul_fused:
        from repro.kernels import ops as _kops
        mag_grad = cfg.magnitude_trainable
        if plan.tier is _dispatch.Tier.FUSED_FWD:
            g = jax.lax.stop_gradient(g)
            mag_grad = False
        rows_local = rows // (plan.sharding.row_shards
                              if plan.sharding is not None else 1)
        return _kops.fused_compose_mm(
            y_base, h, B, g, cfg.scaling, mag_grad=mag_grad,
            block_m=cfg.resolve_mm_block_rows(rows_local),
            block_n=cfg.block_cols,
            interpret=plan.interpret, sharding=plan.sharding)
    y_lora = h @ B.T
    if constrain is not None:
        y_lora = constrain(y_lora)
    return compose_delta(y_base, y_lora, g, cfg, training=training)


def dora_linear(x, W, adapter: dict[str, Any], cfg: DoRAConfig, *,
                bias=None, training: bool = True, axis_name=None,
                base_sq_cache=None, constrain=None, tenant_groups=None):
    """Adapted linear: x [..., d_in] → y [..., d_out].

    W: frozen [d_out, d_in]; adapter: {"A": [r, d_in], "B": [d_out, r],
    "m": [d_out]} plus optional cached leaves — "base_sq" (precomputed
    ||W||²_row, H3.2) and the frozen-adapter serving state written by
    :func:`precompute_adapter_state` ("g", optionally "gsB"). A cached "g"
    skips the factored norm entirely (zero norm FLOPs per decode token) and
    is refused under ``training=True`` (invalidation contract).

    ``axis_name``: if W/A are d_in-sharded inside shard_map, the norm
    partials psum over this axis. ``constrain``: the call site's sharding —
    either a :class:`ComposeSharding` plan (or a callable carrying one as
    ``.plan``, like ``launch.sharding.make_boundary_constraint``'s), or a
    bare row-constraint callable. Sharded call sites pin y_base AND the
    rank-space intermediate ``h`` (rows sharded like the output, rank
    replicated) — never a materialized y_lora — so the matmul-fused route
    stays available under SPMD and the TP partial sums still lower to
    reduce-scatter (H1.4). With a full plan the fused kernels run
    shard-local under shard_map; a bare callable must be a row-only
    constraint (its feature entry replicated), which every
    sequence-parallel boundary constraint is.

    ``tenant_groups``: multi-tenant serving. EITHER a static tuple of
    ``(start, size)`` row blocks partitioning x's leading (batch) dim —
    one per tenant, compile-time signature — OR a TRACED int32 ``[B]``
    array of per-row positions into the stacked tenant dim (dynamic fleet
    serving: one executable for every tenant mix). Adapter leaves carry a
    leading tenant dim K — see :func:`dora_linear_grouped`.
    """
    if tenant_groups is not None:
        return dora_linear_grouped(x, W, adapter, cfg, tenant_groups,
                                   bias=bias, training=training,
                                   constrain=constrain)
    A, B, m = adapter["A"], adapter["B"], adapter["m"]
    plan_sh = as_compose_sharding(constrain)
    cfn = plan_sh if plan_sh is not None else constrain
    if "g" in adapter:
        if training:
            raise ValueError(
                "adapter tree carries precomputed serving state ('g'), "
                "which is stale the moment A/B/m change: it is invalid "
                "under training=True. Train on the raw adapter tree and "
                "rebuild the state with precompute_adapter_state() after "
                "the update.")
        g = jax.lax.stop_gradient(adapter["g"]).astype(_F32)
    else:
        if base_sq_cache is None and "base_sq" in adapter:
            base_sq_cache = adapter["base_sq"]
        if base_sq_cache is not None:
            base_sq_cache = jax.lax.stop_gradient(base_sq_cache)
        w_norm = compute_weight_norm(W, A, B, cfg, axis_name=axis_name,
                                     base_sq_cache=base_sq_cache)
        eps = _norm.dtype_eps(x.dtype)
        g = _compose.magnitude_scale(m, w_norm, eps)
    if not cfg.magnitude_trainable:
        g = jax.lax.stop_gradient(g)

    W = jax.lax.stop_gradient(W)
    y_base = x @ W.T
    h = x @ A.T
    if cfn is not None:
        y_base = cfn(y_base)
        # Constrain the RANK-SPACE intermediate, not y_lora: rows shard
        # exactly like the output, the rank dim replicates — [M, r] is the
        # cheap tensor to pin, and the fused compose stays factored.
        h = plan_sh.constrain_h(h) if plan_sh is not None else cfn(h)
    if "gsB" in adapter and not training:
        # Serving fast path (opt-in, see precompute_adapter_state): g·s is
        # pre-folded into B, so the per-token work collapses to two
        # matmuls + one fused multiply-add — the g·s broadcast over the
        # [M, d_out] lora term is gone (only the (g-1)·base one remains).
        # Sharded call sites take it too: h is already pinned rank-space
        # above, and the folded up-projection output inherits the output
        # constraint like any row-parallel matmul.
        gsB = jax.lax.stop_gradient(adapter["gsB"])
        if plan_sh is not None and plan_sh.b_dout_axes and gsB.ndim == 2:
            # B's d_out carries FSDP axes beyond the output's (the ROADMAP
            # b_spec gap): declare the true layout so GSPMD reshards the
            # small [d_out, r] folded weight explicitly, not the
            # activations.
            gsB = plan_sh.constrain_b(gsB)
        t = jax.lax.dot_general(
            h.astype(_F32), gsB.astype(_F32),
            (((x.ndim - 1,), (1,)), ((), ())), preferred_element_type=_F32)
        delta = ((g - 1.0) * y_base.astype(_F32) + t).astype(y_base.dtype)
        y = y_base + delta
    else:
        delta = compose_delta_factored(y_base, h, B, g, cfg,
                                       training=training, sharding=plan_sh,
                                       constrain=cfn)
        y = y_base + delta
    if bias is not None:
        y = y + bias  # bias re-added after the compose (paper App. A)
    return y


def dora_linear_stacked(x, W, adapter, cfg: DoRAConfig, *, bias=None,
                        training=True, base_sq_cache=None, constrain=None):
    """vmap over a leading stack dim (e.g. experts): x [E, ..., d_in],
    W [E, d_out, d_in], adapter leaves stacked on dim 0; ``bias`` /
    ``base_sq_cache`` (both [E, d_out] when given), ``training`` and
    ``constrain`` are forwarded so expert/layer stacks hit the same cached
    base-norm fast path — and the same SPMD-aware matmul-fused compose —
    as the unstacked call. ``constrain`` is a per-slice plan/callable (the
    stack dim is the vmap axis; specs describe the unstacked shapes)."""
    def one(xe, we, ad, be, bq):
        return dora_linear(xe, we, ad, cfg, bias=be, training=training,
                           base_sq_cache=bq, constrain=constrain)

    return jax.vmap(
        one,
        in_axes=(0, 0, 0,
                 None if bias is None else 0,
                 None if base_sq_cache is None else 0),
    )(x, W, adapter, bias, base_sq_cache)


def check_tenant_groups(tenant_groups, batch: int) -> tuple:
    """Validate a multi-tenant grouping: a tuple of ``(start, size)`` row
    blocks that tile ``[0, batch)`` contiguously in order (the server sorts
    request rows by adapter before building the step). Static — runs at
    trace time, so a bad grouping fails at step-build, not mid-decode."""
    groups = tuple((int(s), int(n)) for s, n in tenant_groups)
    if not groups:
        raise ValueError("tenant_groups must name at least one group")
    expect = 0
    for k, (start, size) in enumerate(groups):
        if start != expect or size < 1:
            raise ValueError(
                f"tenant_groups must tile the batch contiguously: group "
                f"{k} is (start={start}, size={size}) but rows 0..{expect} "
                f"are covered so far (groups={groups})")
        expect = start + size
    if expect != batch:
        raise ValueError(
            f"tenant_groups {groups} cover {expect} rows, batch has {batch}")
    return groups


def dora_linear_grouped(x, W, adapter: dict[str, Any], cfg: DoRAConfig,
                        tenant_groups, *, bias=None, training: bool = False,
                        constrain=None):
    """Multi-tenant adapted linear: one call serves a batch whose rows use
    per-row adapters out of a K-stacked serving tree (x [B, ..., d_in]).

    ``adapter`` leaves carry a leading tenant dim K (``stack_adapter_
    states``) and MUST be a folded serving tree — ``"g"`` and ``"gsB"``
    from ``precompute_adapter_state(fold_gsb=True)`` — so the per-group
    work is exactly the homogeneous broadcast-free decode compose: zero
    factored-norm work per token, and each row reads its own adapter state
    once (the cache-hit path prices identically to single-tenant cached
    decode — gated in ``scripts/check_bench_drift.py``).

    ``tenant_groups`` selects one of TWO grouping contracts:

    - **Static** (a tuple of ``(start, size)`` row blocks): grouping is a
      compile-time signature; each group's rows are a contiguous static
      slice run through the *same ops as the homogeneous path*, so a
      mixed-adapter batch is bitwise-equal (fp32) to serving each tenant
      sequentially with its own precomputed state — for groups of ≥ 2
      rows (XLA's single-row matmuls take a gemv path whose reduction
      order differs; 1-row groups are allclose, see docs/numerics.md).
      One executable per tenant-mix signature.
    - **Dynamic** (a TRACED int32 ``[B]`` array of per-row stack
      positions): the fleet-serving path. Every tenant's contribution is
      computed by ONE K-batched contraction (reduction order independent
      of the index values) and each row's result is then a pure gather
      (:func:`repro.core.compose.select_tenant`) — so admission and
      retirement change VALUES, never the compile signature: one decode
      executable serves every tenant mix. Per-row results are BITWISE
      per-tenant-sequential serving (the select touches no arithmetic);
      the price is K× adapter-path FLOPs per call, the XLA-expressible
      form of the S-LoRA gathered-BGMV kernel (a Pallas gather-BGMV is
      the TPU-tier residual, ROADMAP).
    """
    if training:
        raise ValueError(
            "dora_linear_grouped is a serving-only path: the grouped "
            "compose consumes precomputed per-tenant state ('g'/'gsB') "
            "that is stale the moment A/B/m change. Train per-tenant on "
            "the raw adapter trees.")
    missing = [k for k in ("g", "gsB") if k not in adapter]
    if missing:
        raise ValueError(
            f"multi-tenant grouped serving needs the FOLDED per-tenant "
            f"state (missing {missing!r} leaves): precompute each "
            f"tenant with precompute_adapter_state(..., fold_gsb=True) "
            f"(AdapterStateCache.for_serving does) and stack with "
            f"stack_adapter_states before building the grouped step.")
    A, g, gsB = adapter["A"], adapter["g"], adapter["gsB"]
    if W.ndim > 2:
        raise NotImplementedError(
            "grouped multi-tenant serving of stacked/expert weights "
            f"(W rank {W.ndim}) is not supported")
    if not isinstance(tenant_groups, (tuple, list)):
        return _dora_linear_dyn(x, W, A, g, gsB, tenant_groups, bias=bias,
                                constrain=constrain)
    groups = check_tenant_groups(tenant_groups, x.shape[0])
    K = A.shape[0]
    if len(groups) != K:
        raise ValueError(
            f"{len(groups)} tenant groups but the stacked adapter tree "
            f"carries K={K} tenants")
    plan_sh = as_compose_sharding(constrain)
    cfn = plan_sh if plan_sh is not None else constrain

    W = jax.lax.stop_gradient(W)
    y_base = x @ W.T
    if cfn is not None:
        y_base = cfn(y_base)
    y32 = y_base.astype(_F32)
    contract = (((x.ndim - 1,), (1,)), ((), ()))
    deltas = []
    for k, (start, size) in enumerate(groups):
        # Static row block, static tenant index: the ops below are the
        # SAME dots/elementwise the homogeneous gsB fast path runs on a
        # batch of `size` rows — bitwise parity by construction.
        xk = jax.lax.slice_in_dim(x, start, start + size, axis=0)
        hk = xk @ jax.lax.stop_gradient(A[k]).T
        gk = jax.lax.stop_gradient(g[k]).astype(_F32)
        gsBk = jax.lax.stop_gradient(gsB[k])
        if plan_sh is not None and plan_sh.b_dout_axes and gsBk.ndim == 2:
            gsBk = plan_sh.constrain_b(gsBk)
        tk = jax.lax.dot_general(hk.astype(_F32), gsBk.astype(_F32),
                                 contract, preferred_element_type=_F32)
        yk = jax.lax.slice_in_dim(y32, start, start + size, axis=0)
        deltas.append(((gk - 1.0) * yk + tk).astype(y_base.dtype))
    y = y_base + jnp.concatenate(deltas, axis=0)
    if bias is not None:
        y = y + bias
    return y


def _dora_linear_dyn(x, W, A, g, gsB, idx, *, bias=None, constrain=None):
    """Traced dynamic grouped compose (fleet serving): per-row adapters
    selected by a traced int32 stack position ``idx`` [B].

    Bitwise contract (locked in tests/test_engine.py + tests/test_
    property.py): the K-batched einsums below reduce over the SAME axes
    in the SAME order as the homogeneous gsB fast path's ``x @ Aᵀ`` /
    fp32 ``h·gsBᵀ`` for every stacked k, and the per-row select is a pure
    gather — so row b's output is bitwise ``dora_linear`` under adapter
    ``idx[b]``. The g term is row-local elementwise, applied per row from
    the gathered ``g[idx]``."""
    from repro.core.compose import select_tenant
    if x.ndim != 3:
        raise NotImplementedError(
            f"dynamic grouped serving expects [B, S, d_in] activations "
            f"(got ndim={x.ndim}); the serving steps always run the "
            f"model's batched token layout")
    idx = jnp.asarray(idx, jnp.int32)
    plan_sh = as_compose_sharding(constrain)
    cfn = plan_sh if plan_sh is not None else constrain
    W = jax.lax.stop_gradient(W)
    y_base = x @ W.T
    if cfn is not None:
        y_base = cfn(y_base)
    y32 = y_base.astype(_F32)
    A = jax.lax.stop_gradient(A)
    gsB = jax.lax.stop_gradient(gsB)
    g = jax.lax.stop_gradient(g)
    # All-K down-projection, THEN the gather: [B, S, K, r]. One gemm over
    # the shared d_in reduction — the selected slice is bitwise x @ A[k]ᵀ.
    h_all = jnp.einsum("bsd,krd->bskr", x, A)
    h = select_tenant(h_all, idx)                       # [B, S, r]
    # All-K folded up-projection in fp32 (preferred_element_type pins the
    # accumulator exactly like the homogeneous path's dot_general).
    t_all = jnp.einsum("bsr,kor->bsko", h.astype(_F32), gsB.astype(_F32),
                       preferred_element_type=_F32)     # [B, S, K, d_out]
    t = select_tenant(t_all, idx)                       # [B, S, d_out]
    g_row = jnp.take(g.astype(_F32), idx, axis=0)       # [B, d_out]
    delta = ((g_row[:, None, :] - 1.0) * y32 + t).astype(y_base.dtype)
    y = y_base + delta
    if bias is not None:
        y = y + bias
    return y


def stack_adapter_states(states, *, axis: int = 0):
    """Stack K congruent per-tenant serving trees leaf-wise along a new
    tenant dim at ``axis`` (0 for bare adapter leaves; the model-level
    trees from ``make_precompute_step`` use axis=1 so the scan dim stays
    leading: leaves go [n_scan, ...] → [n_scan, K, ...])."""
    states = list(states)
    if not states:
        raise ValueError("need at least one per-tenant state to stack")
    return jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves, axis=axis), *states)


# ---------------------------------------------------------------------------
# Frozen-adapter serving state (decode does zero norm work per token).
# ---------------------------------------------------------------------------

def _is_adapter_leaf(node) -> bool:
    return isinstance(node, dict) and {"A", "B", "m"} <= set(node.keys())


def precompute_adapter_state(params, adapters, cfg: DoRAConfig, *,
                             act_dtype=None, fold_gsb: bool = False):
    """Compute the per-adapter serving state once for a frozen adapter set.

    Walks the adapter tree alongside the congruent ``params`` tree and
    returns a NEW adapter tree whose leaves additionally carry

      - ``"g"``  — fp32 [d_out] magnitude scale m / max(||W+sBA||_row, ε),
        computed with the exact runtime eps (``act_dtype`` must match the
        activation dtype the model runs in, else g is not bitwise-equal to
        the recomputed one);
      - ``"gsB"`` (``fold_gsb=True`` only) — fp32 [d_out, r] with g·s folded
        into B, enabling the broadcast-free decode compose. Off by default
        because the folded evaluation order differs from the canonical
        ``s·lora``-first form by last-ulp rounding.

    Stacked leaves ([n_scan, ...] / experts) are handled by vmapping over
    the leading dims. The returned tree is for **serving only**: prefill
    and decode skip the factored norm entirely, and ``dora_linear``
    raises if the tree reaches a ``training=True`` call (the invalidation
    contract — any update to A/B/m invalidates the cache, so rebuild the
    state after each training step before serving again).
    """
    eps = _norm.dtype_eps(act_dtype if act_dtype is not None else _F32)

    def leaf_state(W, ad):
        if W.ndim > 2:
            return jax.vmap(leaf_state)(W, ad)
        w_norm = compute_weight_norm(W, ad["A"], ad["B"], cfg,
                                     base_sq_cache=ad.get("base_sq"))
        g = _compose.magnitude_scale(ad["m"], w_norm, eps)
        # Strip any prior serving state first: re-precomputing a folded
        # tree with fold_gsb=False must not leave a stale "gsB" behind
        # (dora_linear would silently prefer it over the bitwise path).
        out = {k: v for k, v in ad.items() if k not in ("g", "gsB")}
        out["g"] = jax.lax.stop_gradient(g)
        if fold_gsb:
            gsB = (g * cfg.scaling)[:, None] * ad["B"].astype(_F32)
            out["gsB"] = jax.lax.stop_gradient(gsB)
        return out

    def walk(p_node, a_node):
        if _is_adapter_leaf(a_node):
            return leaf_state(p_node, a_node)
        return {k: walk(p_node[k], v) for k, v in a_node.items()}

    return walk(params, adapters)


def invalidate_adapter_state(adapters):
    """Strip the serving-state leaves ("g"/"gsB") from an adapter tree,
    returning the raw trainable tree — the inverse of
    :func:`precompute_adapter_state`."""
    if _is_adapter_leaf(adapters):
        return {k: v for k, v in adapters.items() if k not in ("g", "gsB")}
    return {k: invalidate_adapter_state(v) for k, v in adapters.items()}


@dataclasses.dataclass(frozen=True)
class DoRAParamSpec:
    """Bookkeeping for one adapted weight: used by optimizer masking and
    sharding-rule generation."""
    path: str
    d_out: int
    d_in: int
    rank: int
