"""DoRA composition in the numerically stable form (paper §3.1).

    delta = (g - 1) ⊙ base + g ⊙ s ⊙ lora,      g = m / max(w_norm, eps)

The algebraically equivalent ``g ⊙ (s*lora + base) - base`` suffers
catastrophic cancellation when g ≈ 1 — and g concentrates tightly around
unity in practice (DoRA initializes m = ||W||_row; the paper measures 100 %
of g values inside the bf16 collapse zone). The stable form keeps the small
correction (g - 1) explicit and computes it in fp32.

Canonical evaluation order (paper §3.1): ``s * lora`` first, then ``g·(·)``,
so every eager path produces bitwise-identical outputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_F32 = jnp.float32


def magnitude_scale(m, w_norm, eps: float):
    """g = m / max(w_norm, eps), fp32 (paper Eq. 6).

    Always computed *outside* the kernels so the Pallas and eager tiers share
    one precision context (paper §2.2, §4). w_norm is already detached; m
    carries the gradient.
    """
    return m.astype(_F32) / jnp.maximum(w_norm.astype(_F32), eps)


def check_broadcast(g, base):
    """Magnitude broadcast shape guard (paper App. B): g must broadcast
    exclusively along the last dimension of the activation."""
    if g.ndim != 1 or base.shape[-1] != g.shape[0]:
        raise ValueError(
            f"magnitude scale of shape {g.shape} does not broadcast along the "
            f"last dim of activations with shape {base.shape}; this shape "
            f"routes to the eager fallback in the paper and is unsupported "
            f"here")


def compose_stable(base, lora, g, s: float):
    """Eager (Tier-3) stable compose; fp32 intermediates, input-dtype output."""
    check_broadcast(g, base)
    g32 = g.astype(_F32)
    t = jnp.asarray(float(s), _F32) * lora.astype(_F32)   # s*lora first
    delta = (g32 - 1.0) * base.astype(_F32) + g32 * t
    return delta.astype(base.dtype)


def compose_naive(base, lora, g, s: float):
    """The cancellation-prone form, evaluated in the input dtype.

    Only used by the numerical-stability benchmark (paper Fig. 1); never
    dispatched.
    """
    dt = base.dtype
    inner = jnp.asarray(s, dt) * lora + base
    return g.astype(dt) * inner - base


def compose_reference_fp64(base, lora, g, s: float):
    """fp64 oracle for stability tests (paper Fig. 1 reference)."""
    b = base.astype(jnp.float64)
    l = lora.astype(jnp.float64)
    g64 = g.astype(jnp.float64)
    return (g64 - 1.0) * b + g64 * (float(s) * l)


def compose_inner(base, lora, s: float):
    """inner = s*lora + base — the saved tensor for the magnitude gradient
    (paper §4 Tier 1): d_mag = rowsum(dY ⊙ inner) / w_norm."""
    return (base.astype(_F32) + jnp.asarray(float(s), _F32)
            * lora.astype(_F32)).astype(base.dtype)


def select_tenant(all_k, idx):
    """Exact per-row tenant select for the TRACED dynamic grouped compose
    (fleet serving, see :func:`repro.core.adapter.dora_linear_grouped`).

    ``all_k`` is an all-tenant intermediate ``[B, S, K, ...]`` — every
    row's contribution computed for every stacked tenant ``k`` by ONE
    batched contraction whose reduction order is tenant-independent —
    and ``idx`` the traced per-row int32 tenant index ``[B]``. The
    select is a pure gather (``take_along_axis`` on the K axis): no
    arithmetic touches the values, so row ``b``'s result is BITWISE the
    homogeneous single-tenant computation under adapter ``idx[b]``.
    Selecting AFTER the contraction is the whole trick — gathering the
    per-row adapter first and batching the matmuls would put each row
    through a different (M=1 gemv) reduction order and break bitwise
    parity with sequential serving (docs/numerics.md)."""
    b = all_k.shape[0]
    if idx.shape != (b,):
        raise ValueError(
            f"per-row tenant index has shape {idx.shape}; need ({b},) — "
            f"one stacked-tenant position per batch row")
    ix = idx.reshape((b,) + (1,) * (all_k.ndim - 1)).astype(jnp.int32)
    return jnp.squeeze(jnp.take_along_axis(all_k, ix, axis=2), axis=2)
