"""Three-tier runtime dispatch (paper §4, Fig. 2, Table 2).

Tier 1 — fused backward: training + accelerator + above crossover. The
         custom-vjp fused op saves ``inner`` for the magnitude gradient.
Tier 2 — fused forward: inference + accelerator. Forward-only kernel, no
         residuals.
Tier 3 — eager fallback: CPU / forced-off / sub-crossover shapes / unmet
         shape constraints (d_out % 128 != 0, bad magnitude broadcast).

On TPU the "Triton available" predicate becomes "backend is tpu" (Pallas
compiles) — or ``mode='interpret'`` for CPU validation, where the kernels run
through the Pallas interpreter. Shapes are static under jit, so tier
selection happens at trace time, exactly like the paper's Python-level
``_compose_with_dispatch``.
"""
from __future__ import annotations

import enum

import jax

from repro.core.config import DoRAConfig


class Tier(enum.Enum):
    FUSED_BWD = 1
    FUSED_FWD = 2
    EAGER = 3


def _platform() -> str:
    return jax.default_backend()


def above_crossover(rows: int, d_out: int, cfg: DoRAConfig) -> bool:
    """Paper §4: d_out >= 2048 and rows*d_out >= 2048*6144; below this,
    launch latency dominates (KV projections with d_out as low as 512 fall
    through to Tier 3)."""
    return (d_out >= cfg.min_fused_dout
            and rows * d_out >= cfg.min_fused_elems)


def shape_supported(d_out: int) -> bool:
    """Paper App. C: d_out must divide the 128-lane block."""
    return d_out % 128 == 0


def select_tier(cfg: DoRAConfig, *, training: bool, rows: int,
                d_out: int) -> Tier:
    mode = cfg.resolve_mode()
    if mode == "eager":
        return Tier.EAGER
    if not shape_supported(d_out):
        return Tier.EAGER
    if mode in ("fused", "interpret"):
        return Tier.FUSED_BWD if training else Tier.FUSED_FWD
    # mode == "auto"
    if _platform() != "tpu":
        return Tier.EAGER
    if not above_crossover(rows, d_out, cfg):
        return Tier.EAGER
    return Tier.FUSED_BWD if training else Tier.FUSED_FWD


def use_interpret(cfg: DoRAConfig) -> bool:
    return cfg.resolve_mode() == "interpret" or _platform() != "tpu"
