"""Three-tier runtime dispatch (paper §4, Fig. 2, Table 2).

Tier 1 — fused backward: training + accelerator + above crossover. The
         custom-vjp fused op saves ``inner`` for the magnitude gradient.
Tier 2 — fused forward: inference + accelerator. Forward-only kernel, no
         residuals.
Tier 3 — eager fallback: CPU / forced-off / sub-crossover shapes / unmet
         shape constraints (d_out % 128 != 0, bad magnitude broadcast).

Every tier routes through ONE capability-probed dispatch table
(:data:`DISPATCH_TABLE`): a kernel *backend* ("tpu" — compiled Pallas,
"interpret" — the Pallas interpreter for CPU validation, "eager" — pure
jnp) is resolved from the probes in :mod:`repro.compat.probes`, the config
mode, and the forced-tier override (``REPRO_FORCE_TIER`` env var or
``DoRAConfig.force_tier``), and the paper's Tier-1/2/3 split is then layered
on top of that backend. Shapes are static under jit, so selection happens at
trace time, exactly like the paper's Python-level ``_compose_with_dispatch``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable

from repro.compat import probes
from repro.core.config import DoRAConfig
from repro.core.sharding import ComposeSharding


class Tier(enum.Enum):
    FUSED_BWD = 1
    FUSED_FWD = 2
    EAGER = 3


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """One row of the dispatch table: how a tier's kernels execute."""
    name: str                      # "tpu" | "interpret" | "eager"
    fused: bool                    # routes to the Pallas kernels
    interpret: bool                # Pallas interpreter (CPU validation)
    available: Callable[[], bool]  # capability probe


DISPATCH_TABLE: dict[str, KernelBackend] = {
    "tpu": KernelBackend("tpu", fused=True, interpret=False,
                         available=probes.can_compile_pallas_tpu),
    "interpret": KernelBackend("interpret", fused=True, interpret=True,
                               available=probes.has_pallas),
    "eager": KernelBackend("eager", fused=False, interpret=False,
                           available=lambda: True),
}

# Config/env mode → table row. "fused" means "the compiled kernels" and
# degrades to the interpreter off-TPU so one config runs on any host.
_MODE_TO_BACKEND = {"fused": "tpu", "interpret": "interpret",
                    "eager": "eager"}


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Resolved execution plan for one kernel call site."""
    tier: Tier
    backend: str       # DISPATCH_TABLE key actually used
    interpret: bool    # pass to pallas_call
    # Matmul-fused compose: the LoRA up-projection h@Bᵀ runs inside the
    # compose kernel (y_lora never materialized). Only ever True on a fused
    # tier with a crossover-eligible rank (see ``mm_fused_eligible``).
    matmul_fused: bool = False
    # SPMD plan for the call site (None = unsharded / legacy constraint).
    # When set together with ``matmul_fused``, the kernel wrapper runs the
    # compose shard-local under shard_map with block specs derived from the
    # mesh axis sizes; the plan is only ever attached when
    # ``sharding.kernel_expressible(d_out)`` holds.
    sharding: ComposeSharding | None = None

    @property
    def fused(self) -> bool:
        return self.tier is not Tier.EAGER


def available_backends() -> tuple[str, ...]:
    """Table rows whose capability probe passes on this host, best first."""
    return tuple(name for name, b in DISPATCH_TABLE.items()
                 if b.available())


def resolve_backend(cfg: DoRAConfig) -> KernelBackend:
    """Mode/override → the dispatch-table row to execute on.

    A *forced* tier (``REPRO_FORCE_TIER`` / ``cfg.force_tier``, surfaced
    through ``cfg.resolve_mode()``) must be honored or fail loudly; the
    only soft degrade is mode="fused" on a non-TPU host, which falls to the
    interpreter so the same config validates on CPU (paper App. B).
    """
    mode = cfg.resolve_mode()
    if mode == "auto":
        name = "tpu" if DISPATCH_TABLE["tpu"].available() else "eager"
        return DISPATCH_TABLE[name]
    name = _MODE_TO_BACKEND[mode]
    backend = DISPATCH_TABLE[name]
    if backend.available():
        return backend
    if name == "tpu" and DISPATCH_TABLE["interpret"].available():
        return DISPATCH_TABLE["interpret"]
    raise RuntimeError(
        f"kernel tier {name!r} was forced but is unavailable on this host: "
        f"{probes.why_unavailable(name)}")


def above_crossover(rows: int, d_out: int, cfg: DoRAConfig) -> bool:
    """Paper §4: d_out >= 2048 and rows*d_out >= 2048*6144; below this,
    launch latency dominates (KV projections with d_out as low as 512 fall
    through to Tier 3)."""
    return (d_out >= cfg.min_fused_dout
            and rows * d_out >= cfg.min_fused_elems)


def shape_supported(d_out: int) -> bool:
    """Paper App. C: d_out must divide the 128-lane block."""
    return d_out % 128 == 0


def mm_fused_eligible(rank: int | None, cfg: DoRAConfig,
                      rows: int | None = None) -> bool:
    """Crossover guard for the matmul-fused compose: the kernel re-reads the
    B tile once per row-tile, so its extra traffic is ~(rows/block_m)·
    d_out·r bytes vs the 2·rows·d_out the fusion saves — profitable while
    the (lane-padded) rank stays below ``mm_fused_max_rank`` (≈2·block_m
    by the bytes model, derived at the block the call site actually
    executes — see ``DoRAConfig.resolve_mm_fused_max_rank``). ``rows``
    prices decode-shaped calls at their shrunken grid, where the B
    re-read stops amortizing and the materialized path wins (the
    committed decode row of BENCH_compose.json records the 0.67x ratio).
    ``rank=None`` (call sites composing an already materialized y_lora)
    is never eligible."""
    if rank is None or not cfg.compose_matmul_fused:
        return False
    rank_padded = (rank + 127) // 128 * 128
    return rank_padded <= cfg.resolve_mm_fused_max_rank(rows)


def plan_compose(cfg: DoRAConfig, *, training: bool, rows: int,
                 d_out: int, rank: int | None = None,
                 sharding: ComposeSharding | None = None) -> KernelPlan:
    """Resolve the compose call site to (Tier, backend, interpret, mm-fused,
    sharding).

    The shape constraint outranks even a forced tier: d_out % 128 != 0 is
    inexpressible in the 128-lane kernels, and the paper (App. B/C)
    specifies the eager fallback for it — same precedence the seed
    dispatch had. ``rank``: the adapter rank when the caller still holds
    the factored ``h = x@Aᵀ`` (enables the matmul-fused kernel); None when
    only the materialized y_lora is available. ``sharding``: the call
    site's :class:`ComposeSharding` plan; when the plan is expressible for
    the kernels (even d_out shards, 128-lane local blocks) the matmul-fused
    route runs shard-local under it, and the shape constraint is evaluated
    on the LOCAL d_out shard — the unsharded path is just the one-device
    instance. An inexpressible plan drops the matmul fusion (the
    materialized-lora route honours the constraint instead); it never
    errors.
    """
    rows_local = rows
    if sharding is not None:
        row_shards = max(sharding.row_shards, 1)
        if not sharding.kernel_expressible(d_out) \
                or rows % row_shards != 0:
            # The d_out shard breaks the 128-lane block constraint, or
            # the rows do not divide the row axes: inexpressible for the
            # shard-local kernels, eager fallback (the caller still
            # applies the constraints; GSPMD partitions jnp).
            return KernelPlan(Tier.EAGER, "eager", False)
        rows_local = rows // row_shards
    local_dout = sharding.local_dout(d_out) if sharding is not None \
        else d_out
    if not shape_supported(local_dout):
        return KernelPlan(Tier.EAGER, "eager", False)
    mode = cfg.resolve_mode()
    backend = resolve_backend(cfg)
    if not backend.fused:
        return KernelPlan(Tier.EAGER, backend.name, False)
    if mode == "auto" and not above_crossover(rows, d_out, cfg):
        return KernelPlan(Tier.EAGER, "eager", False)
    tier = Tier.FUSED_BWD if training else Tier.FUSED_FWD
    mm = mm_fused_eligible(rank, cfg, rows_local)
    return KernelPlan(tier, backend.name, backend.interpret,
                      matmul_fused=mm, sharding=sharding if mm else None)


def plan_norm(cfg: DoRAConfig, *, d_out: int) -> KernelPlan:
    """Resolve the factored-norm call site. The norm kernel is forward-only
    (the norm is detached), so the fused choice is Tier 2 by construction;
    no crossover guard — the norm reads the whole [d_out, d_in] weight, so
    the fused pass wins at every adapted-layer size (paper §2.3)."""
    if not shape_supported(d_out):
        return KernelPlan(Tier.EAGER, "eager", False)
    backend = resolve_backend(cfg)
    if not backend.fused:
        return KernelPlan(Tier.EAGER, backend.name, False)
    return KernelPlan(Tier.FUSED_FWD, backend.name, backend.interpret)


def plan_gather(cfg: DoRAConfig | None, *, head_elems: int) -> KernelPlan:
    """Resolve the paged K/V gather call site (block pool → logical view;
    ``repro.kernels.paged_gather``). Forward-only by construction (the
    cache carries no gradients), so the fused choice is Tier 2, like the
    norm. ``head_elems`` = Hkv*hd, the flattened trailing dim of one
    cache block — the 128-lane constraint applies to it; unsupported
    shapes (and ``cfg=None``: serving a base model with no adapter
    config) take the eager gather, which is bitwise-identical (both
    tiers are pure copies + zero fill), so the fallback costs layout,
    never parity."""
    if cfg is None or not shape_supported(head_elems):
        return KernelPlan(Tier.EAGER, "eager", False)
    backend = resolve_backend(cfg)
    if not backend.fused:
        return KernelPlan(Tier.EAGER, backend.name, False)
    return KernelPlan(Tier.FUSED_FWD, backend.name, backend.interpret)


def select_tier(cfg: DoRAConfig, *, training: bool, rows: int,
                d_out: int) -> Tier:
    return plan_compose(cfg, training=training, rows=rows,
                        d_out=d_out).tier


def use_interpret(cfg: DoRAConfig) -> bool:
    backend = resolve_backend(cfg)
    if not backend.fused:
        # Eager never reaches a pallas_call; answer for "if it did".
        return not probes.is_tpu()
    return backend.interpret
