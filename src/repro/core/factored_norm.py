"""Factored row-wise norm computation (paper §2, Algorithm 1).

Decomposes the row-wise squared norm of the composed DoRA weight

    ||W + s*B*A||^2_row = ||W||^2_row  +  2s * <W, BA>_row  +  s^2 * ||BA||^2_row
                          `-- base --'    `---- cross ----'     `--- ba_sq ---'

into three terms computable through O(d_out*r + r^2) intermediates:

    cross_j = rowsum(B ⊙ U)_j,   U = W @ A^T          [d_out, r]
    ba_j    = rowsum((B @ G) ⊙ B)_j,  G = A @ A^T     [r, r]

so the dense [d_out, d_in] product B@A is never materialized. All
accumulation is fp32 (paper §2.2); the result is detached (DoRA §4.3 treats
the norm as a constant w.r.t. gradients) and assembled as

    w_norm = sqrt(max(base + 2s*cross + s^2*ba, 0)).

This module is the *eager* (Tier-3) implementation plus the two baselines the
paper benchmarks against (PEFT's identity-matrix pattern, dense B@A) and the
sharded variant (explicit psum of the three per-row partials over the weight's
d_in-sharding axis) that extends the paper beyond its FSDP2 limitation (§6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_F32 = jnp.float32


def dtype_eps(dtype) -> float:
    """Dtype-aware epsilon for the magnitude division (paper App. B)."""
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)):
        return 1e-6
    return 1e-12


def chunk_size(d_out: int, d_in: int, budget_mb: int | None) -> int:
    """cs = min(d_in, floor(budget / (d_out * 4))), aligned to 64 (Alg. 1)."""
    if budget_mb is None:
        return d_in
    cs = max(1, (budget_mb * (1 << 20)) // (d_out * 4))
    cs = min(d_in, cs)
    if cs >= 64:
        cs = (cs // 64) * 64
    return cs


def factored_norm_terms(W, A, B, *, chunk_mb: int | None = None,
                        compute_cross: bool = True):
    """Return (base_sq, cross, ba_sq), all fp32 [d_out].

    ``compute_cross=False`` is the s=0 fast path (paper App. B): cross/ba_sq
    are skipped and U/G never allocated.
    """
    d_out, d_in = W.shape
    if not compute_cross:
        zeros = jnp.zeros((d_out,), _F32)
        if chunk_mb is None:
            w32 = W.astype(_F32)
            return jnp.sum(w32 * w32, axis=1), zeros, zeros
        base_sq = jnp.zeros((d_out,), _F32)
        cs = chunk_size(d_out, d_in, chunk_mb)
        for c in range(0, d_in, cs):
            wc = W[:, c:c + cs].astype(_F32)
            base_sq = base_sq + jnp.sum(wc * wc, axis=1)
        return base_sq, zeros, zeros

    r = A.shape[0]
    B32 = B.astype(_F32)
    cs = chunk_size(d_out, d_in, chunk_mb)
    if cs >= d_in:
        W32 = W.astype(_F32)
        A32 = A.astype(_F32)
        base_sq = jnp.sum(W32 * W32, axis=1)
        G = A32 @ A32.T                        # [r, r]
        U = W32 @ A32.T                        # [d_out, r]
        cross = jnp.sum(B32 * U, axis=1)
    else:
        base_sq = jnp.zeros((d_out,), _F32)
        cross = jnp.zeros((d_out,), _F32)
        G = jnp.zeros((r, r), _F32)
        for c in range(0, d_in, cs):
            wc = W[:, c:c + cs].astype(_F32)   # [d_out, cs]
            ac = A[:, c:c + cs].astype(_F32)   # [r, cs]
            base_sq = base_sq + jnp.sum(wc * wc, axis=1)
            G = G + ac @ ac.T
            uc = wc @ ac.T                     # [d_out, r] — not retained
            cross = cross + jnp.sum(B32 * uc, axis=1)
    ba_sq = jnp.sum((B32 @ G) * B32, axis=1)
    return base_sq, cross, ba_sq


def assemble_norm(base_sq, cross, ba_sq, s: float):
    """w_norm = sqrt(max(base + 2s*cross + s^2*ba, 0))  (paper Eq. 5).

    The clamp uses max(), which — like torch.clamp_min — propagates NaNs
    (paper App. C) rather than collapsing them to zero.
    """
    two_s = jnp.asarray(2.0 * float(s), _F32)
    s2 = jnp.asarray(float(s) * float(s), _F32)
    wn2 = base_sq + two_s * cross + s2 * ba_sq
    return jnp.sqrt(jnp.maximum(wn2, 0.0))


def factored_norm(W, A, B, s: float, *, chunk_mb: int | None = None,
                  base_sq_cache=None):
    """Detached fp32 row-wise norm of W + s*B*A via the factored terms.

    ``base_sq_cache``: beyond-paper option (paper §2.3 leaves it as future
    work) — since W is frozen, ||W||^2_row can be precomputed once into a
    [d_out] fp32 buffer, eliminating the rank-independent base transient.
    """
    if s == 0.0 and base_sq_cache is not None:
        return jax.lax.stop_gradient(jnp.sqrt(jnp.maximum(base_sq_cache, 0.0)))
    if base_sq_cache is not None:
        _, cross, ba_sq = factored_norm_terms(
            jax.lax.stop_gradient(W), A, B, chunk_mb=chunk_mb)
        base_sq = base_sq_cache
    else:
        base_sq, cross, ba_sq = factored_norm_terms(
            jax.lax.stop_gradient(W), A, B,
            chunk_mb=chunk_mb, compute_cross=(s != 0.0))
    out = assemble_norm(base_sq, cross, ba_sq, s)
    return jax.lax.stop_gradient(out)


def factored_norm_sharded(W, A, B, s: float, *, axis_name,
                          chunk_mb: int | None = None,
                          base_sq_cache=None):
    """Factored norm with W (and A) sharded along d_in over ``axis_name``.

    This is the distributed accumulation the paper describes as future work
    for FSDP2 (§6): each shard computes local partials of base_sq, cross and
    G; three small psums ([d_out], [d_out], [r, r]) replace an all-gather of
    the weight shard. B and the output are replicated (d_out-sized vectors
    are "small enough to replicate", paper §6). Call inside shard_map.

    ``base_sq_cache``: the ALREADY-REDUCED ||W||²_row (H3.2) — skips both
    the local W² pass and its psum.
    """
    d_out, _ = W.shape
    r = A.shape[0]
    W = jax.lax.stop_gradient(W)
    if s == 0.0:
        if base_sq_cache is not None:
            return jax.lax.stop_gradient(
                jnp.sqrt(jnp.maximum(base_sq_cache, 0.0)))
        base_l, _, _ = factored_norm_terms(W, A, B, chunk_mb=chunk_mb,
                                           compute_cross=False)
        base_sq = jax.lax.psum(base_l, axis_name)
        return jax.lax.stop_gradient(jnp.sqrt(jnp.maximum(base_sq, 0.0)))
    A32 = A.astype(_F32)
    B32 = B.astype(_F32)
    G_l = A32 @ A32.T
    U_l = W.astype(_F32) @ A32.T
    cross_l = jnp.sum(B32 * U_l, axis=1)
    # rowsum(B ⊙ ΣU_s) = Σ rowsum(B ⊙ U_s): cross partials sum linearly.
    if base_sq_cache is not None:
        base_sq = base_sq_cache
    else:
        W32 = W.astype(_F32)
        base_sq = jax.lax.psum(jnp.sum(W32 * W32, axis=1), axis_name)
    cross = jax.lax.psum(cross_l, axis_name)
    G = jax.lax.psum(G_l, axis_name)
    ba_sq = jnp.sum((B32 @ G) * B32, axis=1)
    return jax.lax.stop_gradient(assemble_norm(base_sq, cross, ba_sq, s))


# ---------------------------------------------------------------------------
# Baselines the paper compares against (§1 code listing, §5.3).
# ---------------------------------------------------------------------------

def norm_peft_eye(W, A, B, s: float):
    """HF PEFT's identity-matrix pattern (paper §1): materializes a
    [d_in, d_in] identity *and* the dense B@A product."""
    d_in = W.shape[1]
    x_eye = jnp.eye(d_in, dtype=A.dtype)
    lora_weight = ((x_eye @ A.T) @ B.T).T          # [d_out, d_in]
    composed = W.astype(_F32) + float(s) * lora_weight.astype(_F32)
    return jax.lax.stop_gradient(jnp.linalg.norm(composed, axis=1))


def norm_dense_ba(W, A, B, s: float):
    """Direct dense product (paper §5.3 "Dense (B@A)"): avoids the identity
    matrix but still materializes the full [d_out, d_in] product."""
    ba = B.astype(_F32) @ A.astype(_F32)
    composed = W.astype(_F32) + float(s) * ba
    return jax.lax.stop_gradient(jnp.linalg.norm(composed, axis=1))


def norm_reference_fp64(W, A, B, s: float):
    """fp64 oracle for tests/benchmarks."""
    W64 = W.astype(jnp.float64)
    ba = B.astype(jnp.float64) @ A.astype(jnp.float64)
    return jnp.sqrt(jnp.sum((W64 + float(s) * ba) ** 2, axis=1))
