"""SPMD sharding plans for the DoRA compose hot path.

The PR-2 matmul-fused compose only fired on the unsharded path: call sites
passing a sharding ``constrain`` materialized ``y_lora = h @ Bᵀ`` just so
the constraint had a tensor to pin. This module closes that gap (ROADMAP
open item #1) by making the *rank-space* intermediate the thing that gets
constrained: for an output ``y [..., d_out]`` with PartitionSpec
``out_spec``, the factored activation ``h = x @ Aᵀ [..., r]`` is pinned to
``out_spec`` with the feature entry dropped (rows sharded identically,
rank replicated), and ``B`` / ``g`` are pinned congruent with ``d_out``.
The compose kernel then runs fully shard-local — each device composes its
``[rows_local, d_out_local]`` tile from its replicated-rank ``h`` shard —
and the ``[M, d_out]`` ``y_lora`` tensor never exists, sharded or not.
The unsharded path is simply the one-device-mesh instance of this plan.

:class:`ComposeSharding` is the per-module plan threaded through
``KernelPlan`` (see :mod:`repro.core.dispatch`) down to the shard_map'd
kernel wrappers in :mod:`repro.kernels.ops`. It is a frozen, hashable
value object so kernel makers can key lru-caches on it.

Supported output specs (see README "Sharding semantics"):

  - **row-sharded rows** (sequence/batch parallelism): any leading entry
    may name mesh axes; the rank dim of ``h`` stays replicated and the
    kernel needs no collectives in the forward.
  - **row-sharded d_out** (tensor parallelism): the last entry names mesh
    axes; ``B``/``g`` shard congruently and the backward psums ``d_h``
    over those axes (the one collective the contraction over a sharded
    ``d_out`` cannot avoid).
  - any combination of the two, provided the local ``d_out`` shard keeps
    the 128-lane kernel constraint (:meth:`kernel_expressible`); plans
    that fail it fall back to the materialized-lora route, never error.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _entry_axes(entry) -> tuple[str, ...]:
    """Mesh axes named by one PartitionSpec entry (None → ())."""
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        return tuple(entry)
    return (entry,)


def _axes_size(mesh, axes: tuple[str, ...]) -> int:
    size = 1
    shape = dict(mesh.shape)
    for a in axes:
        size *= shape[a]
    return size


@dataclasses.dataclass(frozen=True)
class ComposeSharding:
    """Sharding plan for one adapted-linear call site.

    ``out_spec`` is the PartitionSpec of the module output ``y [..., d_out]``
    (one entry per output dim). Everything else — the spec of ``h``, ``B``,
    ``g``, the flattened-2D kernel specs, the collective axes of the
    backward — is derived from it.
    """

    mesh: Any                 # jax.sharding.Mesh (duck-typed in logic tests)
    out_spec: P               # spec of the [..., d_out] output
    # Extra mesh axes FSDP-sharding B's (and g/m's) d_out BEYOND the axes
    # the output itself carries (ROADMAP `b_spec` gap): wo/w_down shard
    # their d_out over the FSDP axes, but the module output's feature dim
    # only names the TP axis. Declaring them here makes the plan honest
    # about B's true layout — the folded-gsB serving path constrains the
    # [d_out, r] cached weight to it (so GSPMD reshards the SMALL tensor,
    # explicitly, instead of silently misplacing it at a shard_map
    # boundary), and the shard-local kernel path becomes *inexpressible*
    # (each device holds a smaller B piece than the output shard it must
    # produce) — dispatch falls back to the constrained materialized
    # route, and :func:`repro.kernels.ops.fused_compose_mm` raises a
    # clear error naming the spec if handed such a plan directly.
    b_dout_axes: tuple[str, ...] = ()

    # -- derived specs ------------------------------------------------------

    @property
    def row_axes(self) -> tuple[str, ...]:
        """Mesh axes sharding the (flattened) row dims, in dim order."""
        axes: list[str] = []
        for entry in tuple(self.out_spec)[:-1]:
            axes.extend(_entry_axes(entry))
        return tuple(axes)

    @property
    def dout_axes(self) -> tuple[str, ...]:
        """Mesh axes sharding the d_out (feature) dim."""
        spec = tuple(self.out_spec)
        return _entry_axes(spec[-1]) if spec else ()

    @property
    def dout_shards(self) -> int:
        return _axes_size(self.mesh, self.dout_axes)

    @property
    def row_shards(self) -> int:
        return _axes_size(self.mesh, self.row_axes)

    @property
    def h_spec(self) -> P:
        """Spec of the rank-space intermediate ``h [..., r]``: rows shard
        exactly like the output, the rank dim is always replicated."""
        return P(*(tuple(self.out_spec)[:-1] + (None,)))

    def _b_dout_entry(self):
        """The d_out PartitionSpec entry for B/g/m: the output's d_out axes
        widened by the declared FSDP axes (``b_dout_axes``)."""
        axes = self.dout_axes + tuple(
            a for a in self.b_dout_axes if a not in self.dout_axes)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    @property
    def b_spec(self) -> P:
        """Spec of ``B [d_out, r]``: congruent with the output d_out, plus
        any declared FSDP axes on d_out (``b_dout_axes``)."""
        return P(self._b_dout_entry(), None)

    @property
    def vec_spec(self) -> P:
        """Spec of per-feature vectors (``g``/``m``/``w_norm`` [d_out]) —
        sharded like B's d_out (they live with the weight, not the
        activation)."""
        return P(self._b_dout_entry())

    def flat2d(self) -> tuple[Any, Any]:
        """(row_entry, dout_entry) for the kernel's flattened [M, d_out]
        view: all leading entries merge into one row entry (valid because
        the flatten collapses dims in row-major order, outer axes first)."""
        row = self.row_axes
        spec = tuple(self.out_spec)
        return (row if len(row) > 1 else (row[0] if row else None),
                spec[-1] if spec else None)

    # -- expressibility -----------------------------------------------------

    def local_dout(self, d_out: int) -> int:
        return d_out // max(self.dout_shards, 1)

    def kernel_expressible(self, d_out: int) -> bool:
        """Can the fused kernels run shard-local under this plan? Needs the
        d_out shard to be even and to keep the 128-lane block constraint
        (paper App. C, applied to the LOCAL shard) — and B congruent with
        the output d_out: declared FSDP axes on B (``b_dout_axes``) leave
        each device with a smaller B piece than the output shard it must
        produce, so the shard-local kernel cannot run without a gather and
        dispatch falls back to the constrained materialized route."""
        if any(a not in self.dout_axes for a in self.b_dout_axes):
            return False
        shards = self.dout_shards
        return d_out % max(shards, 1) == 0 and \
            self.local_dout(d_out) % 128 == 0

    # -- constraint application --------------------------------------------

    def _constrain(self, x, spec: P):
        if len(spec) > x.ndim:
            raise ValueError(
                f"ComposeSharding built for a rank-{len(self.out_spec)} "
                f"output cannot constrain a rank-{x.ndim} tensor "
                f"(spec {spec})")
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def __call__(self, y):
        """Pin a [..., d_out] tensor (y_base / delta / y) to ``out_spec`` —
        makes the plan a drop-in for the legacy ``constrain`` callables."""
        return self._constrain(y, self.out_spec)

    def constrain_h(self, h):
        """Pin the rank-space intermediate (rows like y, rank replicated)."""
        return self._constrain(h, self.h_spec)

    def constrain_vec(self, v):
        """Pin a per-feature [d_out] vector (g, w_norm)."""
        return self._constrain(v, self.vec_spec)

    def constrain_b(self, b):
        """Pin a ``B``-shaped [d_out, r] weight (the raw B or the folded
        serving ``gsB``) to its TRUE layout — the output-congruent d_out
        axes widened by the declared FSDP axes. The folded-gsB decode path
        applies this before its up-projection so a B whose d_out is
        FSDP-sharded is resharded EXPLICITLY on the small [d_out, r]
        tensor (GSPMD's visible choice) rather than silently misplaced."""
        if b.ndim != 2:
            raise ValueError(
                f"constrain_b expects an unstacked [d_out, r] weight, got "
                f"rank-{b.ndim}")
        return self._constrain(b, self.b_spec)


def plan_for_output(mesh, out_spec,
                    b_dout_axes: tuple[str, ...] = ()) -> ComposeSharding:
    """Build the compose plan for a module whose output carries
    ``out_spec`` on ``mesh``. ``b_dout_axes``: mesh axes FSDP-sharding the
    adapted weight's d_out beyond the output spec's own feature axes (the
    ROADMAP ``b_spec`` gap — see :class:`ComposeSharding.b_dout_axes`)."""
    return ComposeSharding(mesh, P(*tuple(out_spec)),
                           b_dout_axes=tuple(b_dout_axes))


def as_compose_sharding(constrain) -> ComposeSharding | None:
    """Extract the plan from a ``constrain`` argument: either a
    :class:`ComposeSharding` itself or a legacy callable carrying one as
    its ``.plan`` attribute (``launch.sharding.make_boundary_constraint``
    attaches it). Bare callables without a plan return None — they are
    applied as opaque row constraints by the caller."""
    if constrain is None:
        return None
    if isinstance(constrain, ComposeSharding):
        return constrain
    plan = getattr(constrain, "plan", None)
    if plan is not None and isinstance(plan, ComposeSharding):
        return plan
    return None
