"""Checkpoint + fault-tolerance substrate: atomic npz shards, manifest with
content hashes, keep-k GC, latest-resume, preemption handling, heartbeats."""
from repro.checkpoint.store import (
    CheckpointConfig, save_checkpoint, restore_checkpoint, latest_step,
    garbage_collect,
)
from repro.checkpoint.fault import (
    PreemptionHandler, Heartbeat, StragglerMonitor,
)

__all__ = [
    "CheckpointConfig", "save_checkpoint", "restore_checkpoint",
    "latest_step", "garbage_collect", "PreemptionHandler", "Heartbeat",
    "StragglerMonitor",
]
