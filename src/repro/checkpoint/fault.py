"""Fault-tolerance primitives: preemption capture, heartbeats, straggler
detection.

These are the host-side pieces of the 1000+-node posture:

  - **PreemptionHandler**: converts SIGTERM (the cloud preemption signal)
    into a checked flag; the train loop polls it each step and triggers an
    immediate checkpoint + clean exit instead of dying mid-step.
  - **Heartbeat**: each host touches ``<dir>/host_<i>`` with its step and
    wall time every step. Cheap (one small atomic file write).
  - **StragglerMonitor**: the launcher-side reader of those heartbeat
    files; a host whose step lags the median by more than ``step_slack``
    or whose file is older than ``dead_after_s`` is flagged. The launcher
    responds with a controlled restart from the last checkpoint (the
    launch script wires this; the monitor only detects).

The coordination medium is the shared filesystem on purpose: it has no
extra dependencies, works under any scheduler, and a restart reads the
same state the failed run wrote. A production deployment can swap the
medium (etcd, GCS) behind the same interface.
"""
from __future__ import annotations

import json
import os
import signal
import tempfile
import time


class PreemptionHandler:
    """Installs a SIGTERM/SIGINT handler that only sets a flag.

    Both signals are handled by default: SIGTERM is the cloud preemption
    notice, and an operator's Ctrl-C (SIGINT) must take the same
    checkpoint-then-exit path rather than raising KeyboardInterrupt
    mid-step. Pass ``signals=(signal.SIGTERM,)`` to leave SIGINT alone.
    The serving-side counterpart of this posture is
    ``repro.launch.faults.FaultPlan`` (deterministic fault injection for
    the decode engine)."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested


def _atomic_write_json(path: str, obj) -> None:
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d)
    with os.fdopen(fd, "w") as f:
        json.dump(obj, f)
    os.rename(tmp, path)


class Heartbeat:
    """Per-host liveness/progress file.

    Deliberately stamps EPOCH time (``time.time()``), not the monotonic
    clock the rest of the repo uses (``repro.obs.monotonic``): the
    heartbeat is read by OTHER processes (StragglerMonitor on the
    launcher), and monotonic clocks are not comparable across process
    boundaries. This is the one sanctioned wall-epoch timestamp.
    """

    def __init__(self, directory: str, process_index: int):
        self.path = os.path.join(directory, f"host_{process_index:05d}.json")
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int) -> None:
        _atomic_write_json(self.path, {"step": step, "time": time.time()})


class StragglerMonitor:
    """Launcher-side detector over the heartbeat directory."""

    def __init__(self, directory: str, *, step_slack: int = 5,
                 dead_after_s: float = 300.0):
        self.directory = directory
        self.step_slack = step_slack
        self.dead_after_s = dead_after_s

    def read(self) -> dict[str, dict]:
        out = {}
        if not os.path.isdir(self.directory):
            return out
        for name in sorted(os.listdir(self.directory)):
            if not name.startswith("host_"):
                continue
            try:
                with open(os.path.join(self.directory, name)) as f:
                    out[name] = json.load(f)
            except (json.JSONDecodeError, OSError):
                # Torn read of an in-flight beat: treat as stale, not fatal.
                out[name] = {"step": -1, "time": 0.0}
        return out

    def stragglers(self, now: float | None = None) -> list[str]:
        beats = self.read()
        if not beats:
            return []
        now = time.time() if now is None else now
        steps = sorted(b["step"] for b in beats.values())
        median = steps[len(steps) // 2]
        flagged = []
        for name, b in beats.items():
            if now - b["time"] > self.dead_after_s:
                flagged.append(name)
            elif median - b["step"] > self.step_slack:
                flagged.append(name)
        return flagged

    def healthy(self, expected_hosts: int, now: float | None = None) -> bool:
        beats = self.read()
        return len(beats) == expected_hosts and not self.stragglers(now)
