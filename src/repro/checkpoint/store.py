"""Atomic, shard-per-host checkpointing for adapter + optimizer state.

Layout (per checkpoint step N):

    <dir>/step_<N>/shard_<host>.npz     flattened pytree leaves
    <dir>/step_<N>/MANIFEST.json       step, tree paths, shapes, dtypes,
                                       per-shard sha256, mesh metadata
    <dir>/LATEST                       text file: last *committed* step

Write protocol (crash-safe): write shards into ``step_<N>.tmp/``, fsync,
write MANIFEST last, atomic-rename the directory, then update LATEST (also
via tmp+rename). A reader never observes a partial checkpoint: if the
rename didn't happen the step directory doesn't exist; if LATEST wasn't
updated the previous step is used.

Elastic resize: adapter + optimizer state is DP-replicated (adapters are
small), so a checkpoint taken on any (pod x data) mesh restores onto any
other mesh whose model axis splits the same way — the manifest records the
model-axis size and ``restore_checkpoint`` verifies only that. This is the
"elastic DP" posture from DESIGN.md §5.

Only *adapter* and *optimizer* state is checkpointed — the frozen base
weights are content-addressed by config and never written (at 30B+ params
that is the difference between a 100 MB and a 60 GB checkpoint).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile

import numpy as np

from repro.compat import tree as ctree


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    every_steps: int = 100
    keep: int = 3


def _flatten_with_names(tree):
    flat = ctree.flatten_with_path(tree)[0]
    return {ctree.path_str(path): np.asarray(leaf) for path, leaf in flat}


def _unflatten_like(tree_like, named):
    paths, treedef = ctree.flatten_with_path(tree_like)
    leaves = []
    for path, like in paths:
        name = ctree.path_str(path)
        if name not in named:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = named[name]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"checkpoint leaf {name!r} shape {arr.shape} != expected "
                f"{like.shape} (elastic resize only re-partitions the data "
                f"axis; model-axis/param shapes must match)")
        leaves.append(arr.astype(like.dtype))
    return ctree.unflatten(treedef, leaves)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(cfg: CheckpointConfig, step: int, state: dict, *,
                    process_index: int = 0, process_count: int = 1,
                    mesh_meta: dict | None = None) -> str:
    """Atomically persist ``state`` (a pytree dict) for ``step``.

    Multi-host: every host writes its own shard_<i>.npz (here the state is
    DP-replicated so shards are identical — the shard structure is what a
    sharded-state variant plugs into); host 0 writes the manifest and
    commits LATEST.
    """
    os.makedirs(cfg.directory, exist_ok=True)
    final_dir = os.path.join(cfg.directory, f"step_{step:08d}")
    tmp_dir = final_dir + ".tmp"
    if process_index == 0:
        shutil.rmtree(tmp_dir, ignore_errors=True)
        os.makedirs(tmp_dir, exist_ok=True)

    named = _flatten_with_names(state)
    shard_path = os.path.join(tmp_dir, f"shard_{process_index:05d}.npz")
    with open(shard_path, "wb") as f:
        np.savez(f, **named)
        f.flush()
        os.fsync(f.fileno())

    if process_index == 0:
        manifest = {
            "step": step,
            "process_count": process_count,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in named.items()},
            "shards": {os.path.basename(shard_path): _sha256(shard_path)},
            "mesh": mesh_meta or {},
        }
        man_path = os.path.join(tmp_dir, "MANIFEST.json")
        with open(man_path, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        # Commit: atomic rename, then LATEST via tmp+rename.
        shutil.rmtree(final_dir, ignore_errors=True)
        os.rename(tmp_dir, final_dir)
        fd, tmp_latest = tempfile.mkstemp(dir=cfg.directory)
        with os.fdopen(fd, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp_latest, os.path.join(cfg.directory, "LATEST"))
        garbage_collect(cfg)
    return final_dir


def latest_step(cfg: CheckpointConfig) -> int | None:
    path = os.path.join(cfg.directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        step = int(f.read().strip())
    if not os.path.isdir(os.path.join(cfg.directory, f"step_{step:08d}")):
        return None  # LATEST committed but dir vanished: treat as none
    return step


def restore_checkpoint(cfg: CheckpointConfig, state_like: dict,
                       step: int | None = None, *,
                       process_index: int = 0,
                       expect_model_axis: int | None = None):
    """Restore into the structure of ``state_like``. Returns (state, step)
    or (None, None) when no checkpoint exists (cold start)."""
    if step is None:
        step = latest_step(cfg)
        if step is None:
            return None, None
    d = os.path.join(cfg.directory, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    if expect_model_axis is not None:
        saved = manifest.get("mesh", {}).get("model")
        if saved is not None and saved != expect_model_axis:
            raise ValueError(
                f"checkpoint was taken with model axis {saved}, cannot "
                f"restore onto model axis {expect_model_axis} (elastic "
                f"resize covers the data/pod axes only)")
    # DP-replicated state: any shard restores any host. Prefer our own.
    shard = os.path.join(d, f"shard_{process_index:05d}.npz")
    if not os.path.exists(shard):
        shards = sorted(p for p in os.listdir(d) if p.startswith("shard_"))
        shard = os.path.join(d, shards[0])
    base = os.path.basename(shard)
    want = manifest.get("shards", {}).get(base)
    if want is not None:
        got = _sha256(shard)
        if got != want:
            raise IOError(f"checkpoint shard {base} hash mismatch "
                          f"({got[:12]} != {want[:12]}): corrupt shard")
    with np.load(shard) as z:
        named = {k: z[k] for k in z.files}
    return _unflatten_like(state_like, named), step


def garbage_collect(cfg: CheckpointConfig) -> list[str]:
    """Keep the newest ``cfg.keep`` committed checkpoints; delete older."""
    if not os.path.isdir(cfg.directory):
        return []
    steps = sorted(
        p for p in os.listdir(cfg.directory)
        if p.startswith("step_") and not p.endswith(".tmp"))
    doomed = steps[:-cfg.keep] if cfg.keep > 0 else []
    removed = []
    for p in doomed:
        shutil.rmtree(os.path.join(cfg.directory, p), ignore_errors=True)
        removed.append(p)
    return removed
