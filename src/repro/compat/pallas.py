"""Pallas construction shims (compiler params, scratch memory spaces).

Two API drifts are absorbed here:

  - the TPU compiler-params class was renamed ``TPUCompilerParams`` →
    ``CompilerParams`` across Pallas releases; :func:`tpu_compiler_params`
    constructs whichever the installed JAX exposes (dropping kwargs the
    old signature does not know, which are tuning hints, never semantics);
  - scratch memory-space constructors (``pltpu.VMEM`` / ``pltpu.SMEM``)
    live behind the same import gate so a host without the pallas.tpu
    extension degrades to a clear error only when a kernel actually runs.

``compiler_params=None`` is valid for ``pl.pallas_call`` on every supported
version (and ignored entirely in interpret mode), so a missing params class
is non-fatal for CPU validation.
"""
from __future__ import annotations

from typing import Any

from repro.compat import probes

try:  # pragma: no branch
    from jax.experimental import pallas as pl  # noqa: F401
except Exception:  # pragma: no cover - pallas-free host
    pl = None

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover - pallas-free host
    pltpu = None


def _params_cls():
    """The installed TPU compiler-params class (new name preferred)."""
    if pltpu is None:
        return None
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    return cls


def tpu_compiler_params(*, dimension_semantics=None,
                        **kwargs: Any):
    """Build TPU compiler params portably; ``None`` when unavailable.

    Unknown kwargs (version-specific tuning knobs like
    ``vmem_limit_bytes``) are retried without — they affect scheduling,
    not results, so dropping them on an older JAX is safe.
    """
    cls = _params_cls()
    if cls is None:
        return None
    kw = dict(kwargs)
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    while True:
        try:
            return cls(**kw)
        except TypeError as e:
            # Drop one unknown kwarg and retry; bail out when none are left
            # to drop (a genuine signature error should surface).
            dropped = _drop_unknown_kwarg(kw, e)
            if not dropped:
                raise


def _drop_unknown_kwarg(kw: dict, err: TypeError) -> bool:
    msg = str(err)
    for name in list(kw):
        if name != "dimension_semantics" and repr(name) in msg:
            del kw[name]
            return True
    return False


def resolve_interpret(interpret: bool | None) -> bool:
    """None → probe: interpret mode everywhere except a real TPU backend
    (where Mosaic compiles the kernel)."""
    if interpret is None:
        return not probes.can_compile_pallas_tpu()
    return bool(interpret)


def vmem(shape, dtype):
    """VMEM scratch allocation spec (``scratch_shapes=[vmem(...)]``)."""
    if pltpu is None:
        raise RuntimeError("VMEM scratch requested but "
                           + probes.why_unavailable("interpret"))
    return pltpu.VMEM(tuple(shape), dtype)


def smem(shape, dtype):
    """SMEM scratch allocation spec."""
    if pltpu is None:
        raise RuntimeError("SMEM scratch requested but "
                           + probes.why_unavailable("interpret"))
    return pltpu.SMEM(tuple(shape), dtype)
