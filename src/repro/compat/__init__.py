"""Version-portability shim layer.

The repo targets a Pallas/JAX API surface that drifts across releases
(``jax.tree.flatten_with_path``, ``pltpu.CompilerParams`` vs
``TPUCompilerParams``, ``jax.make_mesh(axis_types=...)``, ``jax.shard_map``).
Every module under ``src/repro`` goes through this package instead of calling
those APIs directly, so a JAX upgrade (or downgrade) is absorbed in exactly
one place:

  - :mod:`repro.compat.tree`   — pytree utilities with path support
  - :mod:`repro.compat.pallas` — Pallas TPU/GPU compiler-params + scratch
  - :mod:`repro.compat.mesh`   — mesh construction / shard_map entry points
  - :mod:`repro.compat.probes` — dtype/device/backend capability probes
  - :mod:`repro.compat.xla`    — compiled-artifact introspection (memory /
    cost analysis)

Policy: shims prefer the NEW API name when present and fall back to the old
one; they never silently change numerics — anything that cannot be expressed
on the installed version raises with the probe's reason string.
"""
from repro.compat import mesh, pallas, probes, tree, xla

__all__ = ["tree", "pallas", "mesh", "probes", "xla"]
