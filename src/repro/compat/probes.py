"""Dtype / device / backend capability probes.

Cheap, cached predicates the dispatch table keys on. Probes never raise:
a missing module or an un-initializable backend reads as "capability
absent", and :func:`why_unavailable` carries the reason string for error
messages ("tier 'tpu' forced but unavailable: ...").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.lru_cache(maxsize=None)
def backend_platform() -> str:
    """The default JAX backend platform ("cpu" | "tpu" | "gpu")."""
    try:
        return jax.default_backend()
    except Exception:  # pragma: no cover - no backend at all
        return "cpu"


def is_tpu() -> bool:
    return backend_platform() == "tpu"


def is_cpu_only() -> bool:
    return backend_platform() == "cpu"


@functools.lru_cache(maxsize=None)
def device_kind() -> str:
    """Marketing name of device 0 ("TPU v5e", "cpu", ...)."""
    try:
        return jax.devices()[0].device_kind
    except Exception:  # pragma: no cover
        return "unknown"


@functools.lru_cache(maxsize=None)
def has_pallas() -> bool:
    """Pallas importable at all (interpret mode runs anywhere it is)."""
    try:
        from jax.experimental import pallas  # noqa: F401
        return True
    except Exception:
        return False


@functools.lru_cache(maxsize=None)
def has_pallas_tpu() -> bool:
    """The pallas.tpu extension importable (compiler params, VMEM, ...)."""
    try:
        from jax.experimental.pallas import tpu  # noqa: F401
        return True
    except Exception:
        return False


def can_compile_pallas_tpu() -> bool:
    """True when Pallas kernels can be *compiled* (Mosaic), i.e. the host
    actually has a TPU backend — interpret mode does not need this."""
    return has_pallas_tpu() and is_tpu()


@functools.lru_cache(maxsize=None)
def supports_x64() -> bool:
    """fp64 arrays representable under the current jax_enable_x64 setting."""
    try:
        return jnp.zeros((), jnp.float64).dtype == jnp.float64
    except Exception:
        return False


@functools.lru_cache(maxsize=None)
def supports_dtype(dtype) -> bool:
    """Can the default backend materialize arrays of ``dtype``?"""
    try:
        jnp.zeros((1,), dtype).block_until_ready()
        return True
    except Exception:
        return False


def why_unavailable(tier_name: str) -> str:
    """Human-readable reason a kernel tier cannot run on this host."""
    if tier_name == "tpu":
        if not has_pallas_tpu():
            return "jax.experimental.pallas.tpu is not importable"
        return (f"backend is {backend_platform()!r}, not 'tpu' "
                f"(Mosaic compilation needs a TPU)")
    if tier_name == "interpret":
        return "jax.experimental.pallas is not importable"
    return "eager tier is always available"


def clear_probe_caches() -> None:
    """Reset every cached probe (tests monkeypatch backends)."""
    for fn in (backend_platform, device_kind, has_pallas, has_pallas_tpu,
               supports_x64, supports_dtype):
        fn.cache_clear()
