"""Pytree utilities with path support, portable across JAX versions.

``jax.tree.flatten_with_path`` / ``jax.tree.map_with_path`` only exist on
newer JAX; older releases spell them ``jax.tree_util.tree_flatten_with_path``
/ ``tree_map_with_path``. The non-path helpers (``map``, ``flatten``, ...)
are re-exported too so callers depend on ONE tree API regardless of where
the installed JAX puts it.

Path entries are the standard ``DictKey``/``SequenceKey``/``GetAttrKey``
objects on every supported version; :func:`path_key` and :func:`path_str`
normalize them to plain strings (checkpoint manifests, optimizer masks).
"""
from __future__ import annotations

from typing import Any, Callable

import jax

_tree = getattr(jax, "tree", None)
_tu = jax.tree_util


def _resolve(new_name: str, old_name: str) -> Callable:
    fn = getattr(_tree, new_name, None) if _tree is not None else None
    if fn is not None:
        return fn
    return getattr(_tu, old_name)


flatten = _resolve("flatten", "tree_flatten")
unflatten = _resolve("unflatten", "tree_unflatten")
leaves = _resolve("leaves", "tree_leaves")
structure = _resolve("structure", "tree_structure")
map = _resolve("map", "tree_map")  # noqa: A001 - mirrors jax.tree.map
flatten_with_path = _resolve("flatten_with_path", "tree_flatten_with_path")
map_with_path = _resolve("map_with_path", "tree_map_with_path")
leaves_with_path = _resolve("leaves_with_path", "tree_leaves_with_path")


def path_key(entry: Any) -> str:
    """One path entry → its plain-string key.

    Handles DictKey (.key), GetAttrKey (.name), SequenceKey (.idx) and
    falls back to str() for anything exotic a custom pytree registers.
    """
    for attr in ("key", "name", "idx"):
        v = getattr(entry, attr, None)
        if v is not None:
            return str(v)
    return str(entry)


def path_str(path, sep: str = "/") -> str:
    """Full key path → a stable flat name (checkpoint leaf names)."""
    return sep.join(path_key(k) for k in path)
