"""Mesh construction and shard_map entry points, portable across JAX.

Newer JAX grew ``jax.make_mesh(..., axis_types=AxisType.Auto)`` and promoted
``shard_map`` to ``jax.shard_map``; older releases have neither ``AxisType``
nor the promoted name (``jax.experimental.shard_map.shard_map``). The repo's
meshes are always fully "auto" (GSPMD derives the collectives), which is
exactly the old default — so on old JAX the axis-type argument is simply
omitted, with identical partitioning semantics.
"""
from __future__ import annotations

import jax

AxisType = getattr(jax.sharding, "AxisType", None)


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn  # noqa: F811
    return fn


shard_map = _resolve_shard_map()


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with auto axis types wherever expressible."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if AxisType is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(AxisType.Auto,) * len(tuple(axis_names)),
                **kwargs)
        except TypeError:
            pass  # AxisType exists but make_mesh predates axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map_unchecked(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across the kwarg rename.

    The kernel wrappers run Pallas calls inside the mapped body; the
    replication checker has no rule for them, so checking must be
    disabled. The kwarg that disables it was renamed ``check_rep`` →
    ``check_vma`` across JAX releases — try both, and fall back to the
    bare call (newest JAX drops the kwarg once sharding-in-types lands).
    """
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
        except TypeError as e:
            if kw and next(iter(kw)) in str(e):
                continue
            raise
    raise AssertionError("unreachable")  # pragma: no cover
