"""Compiled-artifact introspection shims.

``compiled.memory_analysis().peak_memory_in_bytes`` only exists on newer
jaxlib; older CompiledMemoryStats exposes the component sizes instead.
The fallback reconstructs the device-memory peak the way the allocator
accounts it: temp (activations/workspace) + arguments + outputs, minus
donated/aliased buffers counted twice.
"""
from __future__ import annotations


def peak_memory_bytes(compiled) -> int:
    """Best-available peak device memory for a compiled executable.

    ``peak_memory_in_bytes`` covers execution-time allocations (temps and
    outputs), NOT the resident argument buffers — call sites that want a
    total footprint add ``argument_size_in_bytes - alias_size_in_bytes``
    themselves, so the fallback must not fold arguments in or they would
    be double-counted.
    """
    mem = compiled.memory_analysis()
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is not None:
        return int(peak)
    return int(mem.temp_size_in_bytes + mem.output_size_in_bytes)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every version.

    Old jaxlib returns ``[{...}]`` (one entry per computation); new
    returns the dict directly. Multi-computation entries are summed for
    the scalar keys the repo reads ("flops", "bytes accessed").
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, dict):
        return cost
    if not cost:
        return {}
    if len(cost) == 1:
        return dict(cost[0])
    out: dict = {}
    for entry in cost:
        for k, v in entry.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + v
            else:
                out.setdefault(k, v)
    return out
