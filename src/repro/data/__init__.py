"""Data pipeline: deterministic synthetic token streams, per-host sharding,
double-buffered prefetch."""
from repro.data.pipeline import (
    DataConfig, SyntheticLMDataset, make_train_iterator, prefetch,
    host_shard_slice,
)

__all__ = ["DataConfig", "SyntheticLMDataset", "make_train_iterator",
           "prefetch", "host_shard_slice"]
