"""Deterministic synthetic LM data pipeline.

Design constraints for the 1000+-node posture:

  - **Deterministic & restart-safe**: batch ``i`` is a pure function of
    ``(seed, i)`` — resuming from step N regenerates exactly the batches a
    non-failed run would have seen; no data-loader state needs
    checkpointing beyond the step counter.
  - **Per-host sharding**: each host materializes only its slice of the
    global batch (``host_shard_slice``), so host memory is independent of
    the global batch size. The slice is by *global example index*, so any
    (pod × data) re-partition after an elastic resize reads the same global
    stream.
  - **Prefetch**: a double-buffered background thread overlaps host-side
    batch synthesis with device compute (the synthetic generator is cheap,
    but the structure is what a real tokenized-shard reader plugs into).

The synthetic stream is a Zipf-ish unigram mix with a deterministic
"grammar" (bigram shift) so the loss actually decreases during example
training runs — pure-uniform tokens have irreducible loss == log V and
make convergence checks (paper §5.9 analogue) meaningless.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    # Zipf exponent for the unigram backbone; 0 = uniform.
    zipf_a: float = 1.1
    # Fraction of positions forced to the deterministic bigram successor —
    # the learnable structure of the stream.
    structure_p: float = 0.75


def host_shard_slice(global_batch: int, process_index: int,
                     process_count: int) -> slice:
    """Contiguous per-host slice of the global batch (by example index)."""
    if global_batch % process_count != 0:
        raise ValueError(
            f"global_batch {global_batch} not divisible by "
            f"process_count {process_count}")
    per = global_batch // process_count
    return slice(process_index * per, (process_index + 1) * per)


class SyntheticLMDataset:
    """Batch ``i`` is a pure function of (seed, i): restart-safe by design."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        # Deterministic unigram distribution (Zipf over a seed-shuffled rank
        # order) and a fixed bigram successor table tok -> (a*tok+b) % V.
        rng = np.random.default_rng(cfg.seed)
        ranks = rng.permutation(v)
        with np.errstate(divide="ignore"):
            p = 1.0 / np.power(np.arange(1, v + 1, dtype=np.float64),
                               cfg.zipf_a)
        self._probs = (p / p.sum())[ranks]
        self._bigram_a = int(rng.integers(1, v)) | 1   # odd → full cycle
        self._bigram_b = int(rng.integers(0, v))

    def global_batch_np(self, step: int) -> dict[str, np.ndarray]:
        """The full [global_batch, seq_len] batch for ``step`` (all hosts)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        # Structured positions follow the bigram successor of the *final*
        # previous token: next = (a * prev + b) mod V — generated
        # sequentially so chains survive substitution.
        noise = rng.choice(V, size=(B, S + 1), p=self._probs) \
            .astype(np.int64)
        struct = rng.random((B, S)) < cfg.structure_p
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = noise[:, 0]
        for t in range(1, S + 1):
            succ = (self._bigram_a * toks[:, t - 1] + self._bigram_b) % V
            toks[:, t] = np.where(struct[:, t - 1], succ, noise[:, t])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch_np(self, step: int, process_index: int = 0,
                      process_count: int = 1) -> dict[str, np.ndarray]:
        sl = host_shard_slice(self.cfg.global_batch, process_index,
                              process_count)
        g = self.global_batch_np(step)
        return {k: v[sl] for k, v in g.items()}


def make_train_iterator(cfg: DataConfig, *, start_step: int = 0,
                        process_index: int = 0, process_count: int = 1):
    """Infinite iterator of host-local numpy batches starting at
    ``start_step`` (resume point)."""
    ds = SyntheticLMDataset(cfg)
    step = start_step
    while True:
        yield ds.host_batch_np(step, process_index, process_count)
        step += 1


def prefetch(iterator, depth: int = 2):
    """Double-buffered background prefetch: overlaps batch synthesis /
    host-to-device transfer with device compute."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    sentinel = object()

    def producer():
        try:
            for item in iterator:
                q.put(item)
        finally:
            q.put(sentinel)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is sentinel:
            return
        yield item
