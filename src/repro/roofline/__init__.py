"""Roofline analysis from compiled dry-run artifacts (no real hardware)."""
from repro.roofline.analysis import (
    HW, HloAnalysis, analyze_hlo_text, roofline_terms, model_flops,
)

__all__ = ["HW", "HloAnalysis", "analyze_hlo_text", "roofline_terms",
           "model_flops"]
