"""Three-term roofline model over post-SPMD compiled HLO text.

Why a custom analyzer: ``compiled.cost_analysis()`` does NOT multiply ops
inside ``while`` bodies by their trip count (verified empirically — a
4-step scan reports ~1 body's flops), and our models are scanned over
layers, so XLA's own numbers undercount by ~num_layers. The compiled HLO
text, however, carries ``backend_config={"known_trip_count":{"n":...}}``
on every scan-derived while op, so an exact correction is parseable.

The analyzer walks the partitioned (= per-device) HLO:

  - **FLOPs**: every ``dot`` op contributes 2 x prod(result dims) x
    prod(contracting dims) x trip-multiplier. Element-wise flops are
    ignored (sub-1% for transformer workloads).
  - **HBM traffic**: every *top-level* op in ENTRY / while bodies counts
    operand + result bytes once (a fusion reads its inputs once and
    writes its outputs once — the fusion-level caching abstraction that
    rooflines assume). Ops inside fusion computations are NOT counted.
  - **Collective bytes**: all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute operand bytes x trip-multiplier,
    converted to per-device link traffic with ring-algorithm factors:
    AG: (n-1)x shard, AR: 2(n-1)/n, RS: (n-1)/n, A2A: (n-1)/n, CP: 1x.

Terms (seconds, per device — the HLO is already per-device):

    compute    = flops / peak_flops
    memory     = hbm_bytes / hbm_bw
    collective = link_bytes / link_bw

Hardware constants are TPU v5e-class: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-given).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass(frozen=True)
class HW:
    """Per-chip hardware constants (TPU v5e-class, assignment-given)."""
    peak_flops: float = 197e12        # bf16
    hbm_bw: float = 819e9             # bytes/s
    link_bw: float = 50e9             # bytes/s per ICI link
    hbm_bytes: float = 16 * 2**30     # capacity, for the fits-check


def _shape_bytes_and_dims(type_str: str):
    """Total bytes and the dims of the FIRST array in a type string
    (tuples: bytes summed, dims of first element)."""
    total = 0
    first_dims = None
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d] if dims_s else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        if first_dims is None:
            first_dims = dims
    return total, (first_dims if first_dims is not None else [])


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)\(([^\n]*)$")


def _parse_computations(hlo: str):
    """Split HLO text into computations: name -> list of op dicts."""
    comps: dict[str, list[dict]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            cur = "ENTRY"
            comps.setdefault(cur, [])
            continue
        m = re.match(r"^%([\w\.\-]+)\s*\(", s)
        if m and s.endswith("{") and ") -> " in s:
            cur = m.group(1)
            comps.setdefault(cur, [])
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if om:
            name, type_str, opcode, rest = om.groups()
            comps.setdefault(cur, []).append({
                "name": name, "type": type_str, "op": opcode,
                "rest": rest, "line": s,
            })
    return comps


def _operand_names(rest: str) -> list[str]:
    """Operand names from the call-paren contents (up to the closing paren
    at depth 0)."""
    out = []
    depth = 0
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        token += ch
    for part in token.split(","):
        part = part.strip()
        m = re.search(r"%([\w\.\-]+)\s*$", part)
        if m:
            out.append(m.group(1))
    return out


def _attr_dims(rest: str, key: str) -> list[int]:
    m = re.search(key + r"=\{([0-9,]*)\}", rest)
    if not m:
        return []
    return [int(x) for x in m.group(1).split(",") if x]


def _group_size(rest: str) -> int:
    # replica_groups=[8,2]<=[16] → groups of 2; or {{0,1},{2,3}} form.
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class HloAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    link_bytes: float = 0.0
    collective_bytes_raw: float = 0.0
    by_collective: dict = dataclasses.field(default_factory=dict)
    dot_flops_top: list = dataclasses.field(default_factory=list)
    hbm_top: list = dataclasses.field(default_factory=list)
    coll_top: list = dataclasses.field(default_factory=list)
    notes: list = dataclasses.field(default_factory=list)

    def to_json(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["dot_flops_top"] = d["dot_flops_top"][:10]
        d["hbm_top"] = d["hbm_top"][:10]
        d["coll_top"] = d["coll_top"][:10]
        return d

    def report(self, k: int = 12) -> str:
        """Human-readable per-op breakdown — the hillclimb 'profile'."""
        lines = [f"flops/chip {self.flops:.3e}  hbm {self.hbm_bytes:.3e}B"
                 f"  link {self.link_bytes:.3e}B"]
        lines.append("-- top HBM traffic ops (bytes x trips) --")
        for b, l in self.hbm_top[:k]:
            lines.append(f"  {b:10.3e}  {l}")
        lines.append("-- top collectives (link bytes x trips) --")
        for b, l in self.coll_top[:k]:
            lines.append(f"  {b:10.3e}  {l}")
        lines.append("-- top dots (flops) --")
        for f, l in self.dot_flops_top[:k]:
            lines.append(f"  {f:10.3e}  {l}")
        return "\n".join(lines)


def analyze_hlo_text(hlo: str) -> HloAnalysis:
    comps = _parse_computations(hlo)

    # --- symbol tables: op name -> (bytes, dims) per computation ---------
    sym: dict[str, dict[str, tuple[float, list[int]]]] = {}
    for cname, ops in comps.items():
        table = {}
        for op in ops:
            table[op["name"]] = _shape_bytes_and_dims(op["type"])
        sym[cname] = table

    # --- effective read size of fusion parameters -------------------------
    # A fusion that only dynamic-slices a parameter reads the SLICE from
    # HBM, not the whole buffer (scan bodies slice their stacked inputs).
    # fusion computation -> [effective bytes per parameter index].
    fusion_param_bytes: dict[str, list[float]] = {}
    for cname, ops in comps.items():
        params: dict[str, int] = {}
        full: list[float] = []
        for op in ops:
            if op["op"] == "parameter":
                idx = len(full)
                params[op["name"]] = idx
                full.append(_shape_bytes_and_dims(op["type"])[0])
        if not params:
            continue
        sliced: dict[int, float] = {}
        direct: set[int] = set()
        for op in ops:
            if op["op"] == "parameter":
                continue
            operands = _operand_names(op["rest"])
            if op["op"] in ("dynamic-slice", "slice") and operands \
                    and operands[0] in params:
                res, _ = _shape_bytes_and_dims(op["type"])
                i = params[operands[0]]
                sliced[i] = sliced.get(i, 0.0) + res
                operands = operands[1:]  # index operands: scalars
            for o in operands:
                if o in params:
                    direct.add(params[o])
        eff = []
        for i, fb in enumerate(full):
            if i in direct or i not in sliced:
                eff.append(fb)
            else:
                eff.append(min(fb, sliced[i]))
        fusion_param_bytes[cname] = eff

    # --- trip-count multipliers ------------------------------------------
    # while ops: body=%comp, known_trip_count n. Multiplier of a body =
    # multiplier of the computation containing the while x n.
    body_of: dict[str, tuple[str, int]] = {}  # body comp -> (parent, n)
    for cname, ops in comps.items():
        for op in ops:
            if op["op"] == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", op["rest"])
                tm = re.search(r'known_trip_count[^0-9]*(\d+)', op["rest"])
                n = int(tm.group(1)) if tm else 1
                if bm:
                    body_of[bm.group(1)] = (cname, n)

    mult: dict[str, float] = {}

    def get_mult(cname: str) -> float:
        if cname in mult:
            return mult[cname]
        if cname == "ENTRY":
            mult[cname] = 1.0
        elif cname in body_of:
            parent, n = body_of[cname]
            mult[cname] = n * get_mult(parent)
        else:
            # fusion / reduce / conditional-branch computations: counted at
            # their call sites, not walked -> multiplier irrelevant (0).
            mult[cname] = 0.0
        return mult[cname]

    # computations we walk top-level: ENTRY + while bodies (+ conditional
    # branches would go here; none in these models).
    walk = ["ENTRY"] + list(body_of.keys())

    out = HloAnalysis()
    for cname in walk:
        if cname not in comps:
            continue
        m = get_mult(cname) or 1.0
        table = sym.get(cname, {})
        for op in comps[cname]:
            opc = op["op"]
            if opc in ("parameter", "constant", "while", "tuple",
                       "get-tuple-element", "bitcast", "after-all",
                       # dtype converts fuse into producers/consumers on
                       # the TPU pipeline; XLA:CPU leaves them top-level —
                       # charging them would bill phantom traffic.
                       "convert"):
                continue
            res_bytes, res_dims = _shape_bytes_and_dims(op["type"])
            operands = _operand_names(op["rest"])
            opd_bytes = sum(table.get(o, (0.0, []))[0] for o in operands)

            if opc == "dot":
                # flops = 2 x prod(result) x prod(contracting dims of lhs)
                lhs = operands[0] if operands else None
                lhs_dims = table.get(lhs, (0.0, []))[1] if lhs else []
                cdims = _attr_dims(op["rest"], "lhs_contracting_dims")
                k = 1
                for c in cdims:
                    if c < len(lhs_dims):
                        k *= lhs_dims[c]
                nres = 1
                for d in res_dims:
                    nres *= d
                f = 2.0 * nres * k * m
                out.flops += f
                out.dot_flops_top.append((f, op["line"][:120]))

            # ---- HBM traffic special cases -------------------------------
            # Slicing ops inside while bodies take the FULL carried tensor
            # as an operand; actual traffic is the slice, not the buffer.
            hbm = None
            if opc == "dynamic-slice" or opc == "gather":
                hbm = 2.0 * res_bytes
            elif opc == "dynamic-update-slice":
                upd = (table.get(operands[1], (0.0, []))[0]
                       if len(operands) > 1 else res_bytes)
                hbm = 2.0 * upd
            elif opc == "fusion":
                comp_m = re.search(r"calls=%?([\w\.\-]+)", op["rest"])
                fname = comp_m.group(1) if comp_m else None
                # Trivial fusions (convert/bitcast/reshape only) also fuse
                # away on TPU.
                if fname in comps and all(
                        f["op"] in ("parameter", "convert", "bitcast",
                                    "reshape", "broadcast")
                        for f in comps[fname]):
                    continue
                # Per-parameter effective reads: parameters consumed only
                # through (dynamic-)slice inside the fusion are charged at
                # slice size — scan bodies slice their stacked inputs.
                eff = fusion_param_bytes.get(fname)
                sizes = [table.get(o, (0.0, []))[0] for o in operands]
                if eff is not None and len(eff) == len(sizes):
                    charges = [min(s, e) for s, e in zip(sizes, eff)]
                else:
                    charges = sizes
                reads = sum(charges)
                root_dus = False
                if fname in comps:
                    for fop in comps[fname]:
                        if fop["op"] == "dynamic-update-slice" and \
                                fop["line"].startswith("ROOT"):
                            root_dus = True
                if root_dus and sizes:
                    # In-place update fusion: the aliased buffer (largest
                    # operand) is neither fully read nor fully written —
                    # charge the other reads + an equal write.
                    ibuf = max(range(len(sizes)), key=lambda i: sizes[i])
                    other = reads - charges[ibuf]
                    hbm = 2.0 * other
                else:
                    hbm = reads + res_bytes

            if any(opc.startswith(c) for c in _COLLECTIVES):
                n = _group_size(op["rest"])
                base = opd_bytes
                if opc.startswith("all-gather"):
                    traffic = base * (n - 1)
                elif opc.startswith("all-reduce"):
                    traffic = base * 2.0 * (n - 1) / n
                elif opc.startswith("reduce-scatter"):
                    traffic = base * (n - 1) / n
                elif opc.startswith("all-to-all"):
                    traffic = base * (n - 1) / n
                else:  # collective-permute
                    traffic = base
                out.collective_bytes_raw += base * m
                out.link_bytes += traffic * m
                key = opc.split(".")[0]
                out.by_collective[key] = out.by_collective.get(key, 0.0) \
                    + traffic * m
                out.coll_top.append((traffic * m,
                                     f"x{m:g} {op['line'][:140]}"))

            # HBM traffic: operands + result, once per top-level op.
            if hbm is None:
                hbm = opd_bytes + res_bytes
            out.hbm_bytes += hbm * m
            out.hbm_top.append((hbm * m, f"x{m:g} {op['line'][:140]}"))

    for attr in ("dot_flops_top", "hbm_top", "coll_top"):
        vals = getattr(out, attr)
        vals.sort(key=lambda t: -t[0])
        setattr(out, attr, vals[:30])
    return out


def roofline_terms(analysis: HloAnalysis, hw: HW = HW()) -> dict[str, float]:
    compute = analysis.flops / hw.peak_flops
    memory = analysis.hbm_bytes / hw.hbm_bw
    collective = analysis.link_bytes / hw.link_bw
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])
    bound = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant[0],
        "bound_s": bound,
        # fraction of roofline the *useful* compute achieves if the step ran
        # exactly at the bound: compute / bound.
        "roofline_fraction": (compute / bound) if bound > 0 else 0.0,
    }


def model_flops(mcfg, *, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = mcfg.count_active_params()
    per_tok = 6 * n if kind == "train" else 2 * n
    return float(per_tok) * tokens


def dump_json(path: str, payload: dict) -> None:
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
