"""Optimizer substrate: AdamW over adapter params only, cosine schedule,
global-norm clipping, optional gradient compression for cross-pod reduce."""
from repro.optim.adamw import (
    OptimizerConfig, adamw_init, adamw_update, cosine_warmup_schedule,
    clip_by_global_norm, global_norm,
)
from repro.optim.compression import (
    compress_bf16, decompress_bf16, int8_ef_compress, int8_ef_decompress,
    init_error_feedback,
)

__all__ = [
    "OptimizerConfig", "adamw_init", "adamw_update",
    "cosine_warmup_schedule", "clip_by_global_norm", "global_norm",
    "compress_bf16", "decompress_bf16", "int8_ef_compress",
    "int8_ef_decompress", "init_error_feedback",
]
