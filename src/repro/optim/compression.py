"""Gradient compression for the cross-pod (DCN) all-reduce.

At 512+ chips the adapter gradients psum over (pod, data); the pod axis
crosses DCN where bandwidth is ~10x scarcer than ICI. Two schemes:

  - **bf16 cast** (lossless enough in practice): halves DCN bytes. Safe
    default; stateless.
  - **int8 + error feedback**: per-tensor symmetric quantization with a
    residual carried across steps (Seide et al. error feedback), so the
    quantization error is re-injected instead of lost — unbiased in the
    long run. 4x fewer DCN bytes than fp32.

Both compress *before* the cross-pod reduce and decompress after; the
within-pod (ICI) reduce stays full precision. Usage in the train step:

    g_local = psum(g, 'data')                    # ICI, fp32
    g_q, scale = int8_ef_compress(g_local, ef)   # quantize
    g_q = psum(g_q.astype(f32), 'pod')           # DCN, 8-bit payload
    g, ef = int8_ef_decompress(g_q, scale, ...)  # dequantize + new residual
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.compat import tree as ctree

_F32 = jnp.float32


def compress_bf16(grads):
    return ctree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads):
    return ctree.map(lambda g: g.astype(_F32), grads)


def init_error_feedback(grads_like):
    return ctree.map(lambda g: jnp.zeros(g.shape, _F32), grads_like)


def _quantize_one(g, ef):
    """Symmetric per-tensor int8 with error feedback residual."""
    corrected = g.astype(_F32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    return q, scale, corrected


def int8_ef_compress(grads, ef):
    """Returns (q_tree int8, scale_tree fp32 scalar, corrected_tree fp32).

    ``corrected`` is needed by the decompress step to compute the new
    residual locally (corrected - dequantized)."""
    flat = ctree.map(_quantize_one, grads, ef)
    q = ctree.map(lambda t: t[0], flat,
                     is_leaf=lambda x: isinstance(x, tuple))
    scale = ctree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    corrected = ctree.map(lambda t: t[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
    return q, scale, corrected


def int8_ef_decompress(q, scale, corrected):
    """Dequantize and compute the new error-feedback residual."""
    deq = ctree.map(lambda qi, s: qi.astype(_F32) * s, q, scale)
    new_ef = ctree.map(lambda c, d: c - d, corrected, deq)
    return deq, new_ef
