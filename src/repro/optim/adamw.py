"""AdamW for DoRA fine-tuning (adapter params only; base weights frozen).

Written against raw pytrees (no optax dependency in this container). Mirrors
the paper's training setup (§5.9: AdamW, cosine-ish schedule, grad clip).
Optimizer state lives only for adapter leaves — the frozen base model carries
zero optimizer memory, which is the whole point of PEFT at scale.

fp32 master moments regardless of param dtype; update applied in fp32 and
cast back. Weight decay is decoupled (AdamW) and skipped for the magnitude
vector ``m`` (a norm-like parameter) by the default mask.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import tree as ctree

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 1e-4
    betas: tuple[float, float] = (0.9, 0.999)
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float | None = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_warmup_schedule(cfg: OptimizerConfig, step):
    """Linear warmup → cosine decay to min_lr_ratio * lr."""
    step = jnp.asarray(step, _F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jax.Array:
    leaves = ctree.leaves(tree)
    sq = sum(jnp.sum(jnp.square(l.astype(_F32))) for l in leaves)
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return ctree.map(lambda g: (g.astype(_F32) * scale).astype(g.dtype),
                     grads), norm


def _default_wd_mask(path, leaf) -> bool:
    """Decay A/B matrices; skip the magnitude vector m (norm-like) and
    the frozen base_sq cache (H3.2 — constant, zero grad)."""
    return ctree.path_key(path[-1]) not in ("m", "base_sq")


def adamw_init(params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, _F32)
    return {
        "mu": ctree.map(zeros, params),
        "nu": ctree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: OptimizerConfig, *,
                 wd_mask=None):
    """One AdamW step. Returns (new_params, new_state, stats).

    ``wd_mask(path, leaf) -> bool``: True = apply weight decay (default:
    everything except magnitude vectors).
    """
    wd_mask = wd_mask or _default_wd_mask
    count = state["count"] + 1
    lr = cosine_warmup_schedule(cfg, count)

    pre_norm = global_norm(grads)
    if cfg.clip_norm is not None:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)

    b1, b2 = cfg.betas
    c1 = 1.0 - b1 ** count.astype(_F32)
    c2 = 1.0 - b2 ** count.astype(_F32)

    flat_g = ctree.flatten_with_path(grads)[0]
    masks = {tuple(str(k) for k in path): wd_mask(path, leaf)
             for path, leaf in flat_g}

    def upd(path, p, g, mu, nu):
        g32 = g.astype(_F32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        mhat = mu / c1
        nhat = nu / c2
        step = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if masks[tuple(str(k) for k in path)] and cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(_F32)
        new_p = (p.astype(_F32) - lr * step).astype(p.dtype)
        return new_p, mu, nu

    flat_p, treedef = ctree.flatten_with_path(params)
    flat_mu = ctree.leaves(state["mu"])
    flat_nu = ctree.leaves(state["nu"])
    flat_gl = [leaf for _, leaf in flat_g]

    new_p, new_mu, new_nu = [], [], []
    for (path, p), g, mu, nu in zip(flat_p, flat_gl, flat_mu, flat_nu):
        a, b, c = upd(path, p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)

    new_state = {
        "mu": ctree.unflatten(treedef, new_mu),
        "nu": ctree.unflatten(treedef, new_nu),
        "count": count,
    }
    stats = {"lr": lr, "grad_norm": pre_norm}
    return ctree.unflatten(treedef, new_p), new_state, stats
