#!/usr/bin/env bash
# Tier-1 gate: run the test suite with the Pallas interpret tier forced
# (every compose/norm call exercises the fused kernels through the Pallas
# interpreter on CPU) and fail on any regression below the recorded pass
# count.
#
# Usage:  scripts/run_tier1.sh [extra pytest args...]
# Env:    REPRO_TIER1_MIN_PASS     recorded floor (default below)
#         REPRO_TIER1_MAX_FAIL     allowed failures (default 0)
#         REPRO_TIER1_INSTALL_DEV  "1": pip-install requirements-dev.txt
#                                  first (CI does this; containers without
#                                  network keep the gated skips instead)
#         REPRO_FORCE_TIER         tier to force (default: interpret;
#                                  "default" = leave the dispatch
#                                  unforced, the CI matrix's other leg)
#
# The "N skipped" column is the two hypothesis-gated modules
# (tests/test_property.py, tests/test_ssm_scan.py): with
# requirements-dev.txt installed (CI always does) they RUN and the
# expected skip count is 0; without it they self-skip. The pass floor
# below is the hypothesis-absent count — CI's dev-installed runs pass
# MORE, never fewer.
#
# Baselines (keep in sync with ROADMAP.md; "2 skipped" rows were
# measured on hypothesis-absent containers, see above):
#   seed     127 passed / 81 failed / 2 collection errors
#   post-PR1 250 passed / 0 failed / 2 skipped — every seed failure was
#            JAX API drift, absorbed by src/repro/compat/
#   post-PR2 292 passed / 0 failed / 2 skipped
#   post-PR3 317 passed / 0 failed / 2 skipped (SPMD compose + CI gates)
#   post-PR4 358 passed / 0 failed / 2 skipped (multi-tenant serving + docs)
#   post-PR5 385 passed / 0 failed / 2 skipped (continuous-batching engine)
#   post-PR6 393 passed / 0 failed / 2 skipped (speculative decoding +
#            submit-time adapter pinning)
#   post-PR7 422 passed / 0 failed / 2 skipped (fault-tolerant serving:
#            deadlines, preemption, quarantine, FaultPlan injection)
#   post-PR8 428 passed / 0 failed / 2 skipped (paged KV cache + chunked
#            prefill: block pool, paged==rect bitwise, check_paged gate)
#   post-PR9 443 passed / 0 failed / 2 skipped (fleet serving: traced
#            dynamic grouping, tiered adapter cache, churn fuzzer)
#   post-PR10 474 passed / 0 failed / 2 skipped (observability: lifecycle
#            tracing, latency histograms, metrics export; tracing
#            on == off bitwise)
set -euo pipefail
cd "$(dirname "$0")/.."

MIN_PASS="${REPRO_TIER1_MIN_PASS:-474}"
MAX_FAIL="${REPRO_TIER1_MAX_FAIL:-0}"
if [ "${REPRO_TIER1_INSTALL_DEV:-0}" = "1" ]; then
    pip install -q -r requirements-dev.txt
fi
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
TIER="${REPRO_FORCE_TIER:-interpret}"
if [ "${TIER}" = "default" ]; then
    # CI matrix leg: run with the dispatch left alone (mode=auto resolves
    # to the eager tier on CPU hosts).
    unset REPRO_FORCE_TIER
    TIER="(unforced)"
else
    export REPRO_FORCE_TIER="${TIER}"
fi

out="$(mktemp)"
trap 'rm -f "$out"' EXIT

# || true: pytest exits nonzero on any failure; the gate below decides.
python -m pytest -q "$@" 2>&1 | tee "$out" || true

summary="$(grep -E '[0-9]+ (passed|failed|error)' "$out" | tail -1)"
passed="$(grep -oE '[0-9]+ passed' "$out" | tail -1 | grep -oE '[0-9]+' || echo 0)"
failed="$(grep -oE '[0-9]+ failed' "$out" | tail -1 | grep -oE '[0-9]+' || echo 0)"
errors="$(grep -oE '[0-9]+ errors?' "$out" | tail -1 | grep -oE '[0-9]+' || echo 0)"
skipped="$(grep -oE '[0-9]+ skipped' "$out" | tail -1 | grep -oE '[0-9]+' || echo 0)"
# The only sanctioned skips are the two hypothesis-gated modules, and
# only when hypothesis is absent: with it installed, 0 skips expected —
# a new unexplained skip is a silently-disabled test, which is a FAIL.
if python -c "import hypothesis" >/dev/null 2>&1; then
    EXPECT_SKIP=0
else
    EXPECT_SKIP=2
fi

echo
echo "tier-1 summary: ${summary:-<no pytest summary found>}"
if [ "${errors}" -gt 0 ]; then
    echo "tier-1 FAIL: ${errors} collection error(s) (seed had 2; must stay 0)"
    exit 1
fi
if [ "${failed}" -gt "${MAX_FAIL}" ]; then
    echo "tier-1 FAIL: ${failed} failed > allowed ${MAX_FAIL}"
    exit 1
fi
if [ "${passed}" -lt "${MIN_PASS}" ]; then
    echo "tier-1 FAIL: ${passed} passed < recorded floor ${MIN_PASS}"
    exit 1
fi
if [ $# -eq 0 ] && [ "${skipped}" -ne "${EXPECT_SKIP}" ]; then
    echo "tier-1 FAIL: ${skipped} skipped != expected ${EXPECT_SKIP}" \
         "(hypothesis $(python -c 'import hypothesis' >/dev/null 2>&1 \
          && echo present || echo absent))"
    exit 1
fi
echo "tier-1 OK: ${passed} passed, ${failed} failed, ${skipped} skipped (floor ${MIN_PASS}, tier ${TIER})"

# End-to-end smokes (still under the forced tier, so the fused kernels and
# the frozen-adapter cache path are exercised through the Pallas
# interpreter on every gate). set -e aborts the gate on any failure.
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
echo
echo "serve smoke (tier ${TIER}): adapter cache + padded prefill"
python -m repro.launch.serve --arch qwen2-7b --smoke --batch 2 \
    --prompt-len 16 --gen-len 4
echo
echo "multi-tenant serve smoke (tier ${TIER}): LRU cache + grouped decode"
python -m repro.launch.serve --arch qwen2-7b --smoke --batch 2 \
    --prompt-len 16 --gen-len 4 --tenants 3
echo
echo "continuous serve smoke (tier ${TIER}): slot-scheduled engine"
python -m repro.launch.serve --arch qwen2-7b --smoke --batch 2 \
    --prompt-len 16 --gen-len 4 --continuous
echo
echo "speculative serve smoke (tier ${TIER}): draft/verify/rewind + oracle"
python -m repro.launch.serve --arch qwen2-7b --smoke --batch 2 \
    --prompt-len 16 --gen-len 4 --continuous --speculative 3
echo
echo "fault-injection serve smoke (tier ${TIER}): quarantine + deadlines"
echo "  + obs: --trace-out/--metrics-out on the faulty run, then assert"
obs_trace="$(mktemp --suffix=.jsonl)"
obs_prom="$(mktemp --suffix=.prom)"
python -m repro.launch.serve --arch qwen2-7b --smoke --batch 2 \
    --prompt-len 16 --gen-len 4 --continuous --inject nan@3 --deadline 8 \
    --trace-out "$obs_trace" --metrics-out "$obs_prom"
# The poisoned request's lifecycle must end quarantined ->
# terminal(error_numeric), and the metrics snapshot must parse as
# Prometheus text with the quarantine counter visible.
python - "$obs_trace" "$obs_prom" <<'PY'
import json, sys
from repro.obs import parse_prometheus
by_rid = {}
with open(sys.argv[1]) as f:
    for line in f:
        e = json.loads(line)
        if e.get("request_id") is not None:
            by_rid.setdefault(e["request_id"], []).append(e)
poisoned = [rid for rid, evs in by_rid.items()
            if any(e["name"] == "quarantined" for e in evs)]
assert poisoned, "nan@3 left no quarantined request in the trace"
for rid in poisoned:
    names = [e["name"] for e in by_rid[rid]]
    assert names[-2:] == ["quarantined", "terminal"], \
        f"rid {rid}: lifecycle tail {names[-2:]} != quarantined->terminal"
    term = by_rid[rid][-1]
    assert term["data"]["reason"] == "error_numeric", term
parsed = parse_prometheus(open(sys.argv[2]).read())
assert parsed["repro_engine_quarantined_total"] >= 1, \
    "quarantine counter missing from the Prometheus snapshot"
assert any(k.startswith('repro_requests_finished_total{reason="error_numeric"')
           for k in parsed), sorted(parsed)[:5]
print(f"obs smoke OK: {len(poisoned)} poisoned request(s) traced "
      f"quarantined -> terminal(error_numeric); metrics parse as "
      f"Prometheus ({len(parsed)} series, quarantine visible)")
PY
rm -f "$obs_trace" "$obs_prom"
echo
echo "paged serve smoke (tier ${TIER}): block pool + chunked prefill + oracle"
python -m repro.launch.serve --arch qwen2-7b --smoke --batch 2 \
    --prompt-len 16 --gen-len 4 --continuous --paged
echo
echo "fleet serve smoke (tier ${TIER}): dynamic grouping, ONE decode executable"
python -m repro.launch.serve --arch qwen2-7b --smoke --batch 2 \
    --prompt-len 8 --gen-len 4 --rank 4 --fleet 5
echo
echo "bench smoke: compose kernels (incl. matmul-fused) + serving cache"
python -m benchmarks.compose_bench --smoke
python -m benchmarks.serve_bench --smoke
echo
echo "bench-drift gate: analytic bytes models vs committed BENCH_*.json"
python scripts/check_bench_drift.py
echo
echo "docs gate: executable guides + module references (docs/*.md)"
python scripts/check_docs.py
echo "tier-1 smokes OK"
