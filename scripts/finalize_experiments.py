"""Inject the final roofline table into EXPERIMENTS.md (replaces the
<!-- ROOFLINE_TABLE --> marker with the rendered table from
results/dryrun/*.json)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline_run import load, render_markdown  # noqa: E402

MARK = "<!-- ROOFLINE_TABLE -->"


def main() -> None:
    rows = load("16x16")
    mp = load("2x16x16")
    table = render_markdown(rows)
    block = (f"{len(rows)} single-pod cells (+ {len(mp)} multi-pod "
             f"compiles):\n\n" + table + "\n")
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    if MARK in text:
        text = text.replace(MARK, block)
    else:
        # replace the previously injected table: regenerate whole file is
        # overkill; append an updated section instead
        text += "\n### Updated roofline table\n\n" + block
    with open(path, "w") as f:
        f.write(text)
    print(f"injected {len(rows)} single-pod rows, {len(mp)} multi-pod")


if __name__ == "__main__":
    main()
