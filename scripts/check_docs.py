#!/usr/bin/env python
"""Docs gate: the guides in docs/ cannot rot.

Two checks over every ``docs/*.md``:

1. **Executable blocks** — every fenced ```python block is extracted and
   executed (all blocks of one file concatenated, in order, in one fresh
   subprocess) on a CPU host with the interpret tier forced
   (``JAX_PLATFORMS=cpu``, ``REPRO_FORCE_TIER=interpret``) — the same
   environment the CI tier matrix runs. A block that stops matching the
   code fails CI with the doc file and block number named.

2. **Module references** — every dotted ``repro.*`` reference and every
   literal ``src/repro/**`` path mentioned anywhere in the docs must
   resolve: paths must exist on disk; dotted references are resolved by
   importing their longest importable module prefix and walking the
   remaining segments with getattr — so renaming a module, class,
   function, or config field breaks the doc check, not a reader.

Wired into ``scripts/run_tier1.sh`` and the CI workflow. Exit status: 0
clean, 1 on any failure.
"""
from __future__ import annotations

import importlib
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(ROOT, "docs")
SRC = os.path.join(ROOT, "src")

_FENCE_RE = re.compile(r"^```(\S*)\s*$")
_DOTTED_RE = re.compile(r"\brepro(?:\.[A-Za-z_]\w*)+")
_PATH_RE = re.compile(r"\bsrc/repro/[\w/.\-]+")


def extract_blocks(text: str) -> list[tuple[int, str]]:
    """(starting line number, code) for every fenced ``python`` block."""
    blocks, in_block, lang, buf, start = [], False, "", [], 0
    for i, line in enumerate(text.splitlines(), 1):
        m = _FENCE_RE.match(line)
        if m and not in_block:
            in_block, lang, buf, start = True, m.group(1), [], i + 1
        elif m and in_block:
            if lang == "python":
                blocks.append((start, "\n".join(buf)))
            in_block = False
        elif in_block:
            buf.append(line)
    return blocks


def run_doc_blocks(path: str) -> list[str]:
    """Execute a doc's python blocks (concatenated, one subprocess)."""
    with open(path) as f:
        text = f.read()
    blocks = extract_blocks(text)
    if not blocks:
        return []
    code = "\n\n".join(
        f"# --- {os.path.basename(path)} block {i + 1} (line {ln}) ---\n"
        f"{src}" for i, (ln, src) in enumerate(blocks))
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_FORCE_TIER"] = "interpret"
    env["PYTHONPATH"] = SRC + os.pathsep + ROOT + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run([sys.executable, "-c", code], cwd=ROOT,
                         capture_output=True, text=True, env=env,
                         timeout=900)
    if out.returncode != 0:
        return [f"{os.path.relpath(path, ROOT)}: python blocks failed "
                f"(exit {out.returncode}):\n--- stdout ---\n{out.stdout}"
                f"\n--- stderr ---\n{out.stderr.strip()[-3000:]}"]
    print(f"  {os.path.relpath(path, ROOT)}: {len(blocks)} python "
          f"block(s) executed ok")
    return []


def check_reference(ref: str) -> str | None:
    """Resolve ``repro.a.b.C`` — import the longest importable module
    prefix, getattr-walk the rest. Returns an error string or None."""
    parts = ref.split(".")
    mod, k = None, 0
    for k in range(len(parts), 0, -1):
        name = ".".join(parts[:k])
        try:
            mod = importlib.import_module(name)
            break
        except ImportError:
            continue
        except Exception as e:                      # pragma: no cover
            return f"{ref}: importing {name} raised {type(e).__name__}: {e}"
    if mod is None or k < 2:
        return f"{ref}: no importable module prefix under 'repro'"
    obj = mod
    for attr in parts[k:]:
        if not hasattr(obj, attr):
            return (f"{ref}: {'.'.join(parts[:k])} has no attribute "
                    f"{attr!r}")
        obj = getattr(obj, attr)
    return None


def check_doc_references(path: str) -> list[str]:
    with open(path) as f:
        text = f.read()
    errors = []
    rel = os.path.relpath(path, ROOT)
    refs = sorted(set(_DOTTED_RE.findall(text)))
    for ref in refs:
        err = check_reference(ref)
        if err:
            errors.append(f"{rel}: {err}")
    paths = sorted(set(p.rstrip(".,)") for p in _PATH_RE.findall(text)))
    for p in paths:
        full = os.path.join(ROOT, p)
        # bare directories may be referenced with or without a trailing /
        if not (os.path.exists(full) or os.path.isdir(full.rstrip("/"))):
            errors.append(f"{rel}: referenced path {p} does not exist")
    if not errors:
        print(f"  {rel}: {len(refs)} module refs + {len(paths)} paths ok")
    return errors


def main() -> int:
    sys.path[:0] = [SRC, ROOT]
    docs = sorted(
        os.path.join(DOCS, f) for f in os.listdir(DOCS)
        if f.endswith(".md")) if os.path.isdir(DOCS) else []
    if not docs:
        print(f"ERROR: no docs/*.md found under {DOCS}")
        return 1
    errors = []
    print(f"docs gate: {len(docs)} guide(s)")
    print("— module/path references —")
    for d in docs:
        errors += check_doc_references(d)
    print("— executable python blocks (CPU, interpret tier) —")
    for d in docs:
        errors += run_doc_blocks(d)
    if errors:
        print("\ndocs gate FAIL:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("\ndocs gate OK: every fenced python block executes and every "
          "referenced module resolves.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
