"""Hillclimb profiler: compile one (arch x shape) cell and print the
per-op roofline breakdown (top HBM ops, top collectives, top dots).

    PYTHONPATH=src python scripts/profile_cell.py falcon-mamba-7b \
        prefill_32k [--multi-pod] [--norm-impl factored] [--rank 384]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.compat import xla as cxla
from repro.core import DoRAConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import StepConfig, cell_specs
from repro.roofline import analyze_hlo_text, roofline_terms


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--norm-impl", default="factored")
    ap.add_argument("--cache-base-norm", action="store_true")
    ap.add_argument("--rank", type=int, default=384)
    ap.add_argument("--loss-tokens", type=int, default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--top", type=int, default=14)
    ap.add_argument("--dump-hlo", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    scfg = StepConfig(
        dora=DoRAConfig(rank=args.rank, alpha=args.rank / 2.0,
                        norm_impl=args.norm_impl,
                        cache_base_norm=args.cache_base_norm),
        loss_tokens=args.loss_tokens, grad_accum=args.grad_accum)
    cell = cell_specs(args.arch, args.shape, mesh, scfg=scfg)
    with mesh:
        j = jax.jit(cell["step"], in_shardings=cell["in_shardings"],
                    out_shardings=cell["out_shardings"],
                    donate_argnums=cell["donate"])
        compiled = j.lower(*cell["args"]).compile()
    hlo = compiled.as_text()
    if args.dump_hlo:
        with open(args.dump_hlo, "w") as f:
            f.write(hlo)
    ana = analyze_hlo_text(hlo)
    terms = roofline_terms(ana)
    mem = compiled.memory_analysis()
    print(f"== {args.arch} x {args.shape} "
          f"({'2x16x16' if args.multi_pod else '16x16'}) "
          f"norm={args.norm_impl} ==")
    print(f"compute {terms['compute_s']*1e3:.1f} ms | memory "
          f"{terms['memory_s']*1e3:.1f} ms | collective "
          f"{terms['collective_s']*1e3:.1f} ms -> {terms['dominant']}")
    print(f"peak {(cxla.peak_memory_bytes(compiled) + mem.argument_size_in_bytes - mem.alias_size_in_bytes)/2**30:.2f} GiB")
    print(ana.report(args.top))


if __name__ == "__main__":
    main()
