#!/usr/bin/env python
"""Bench-drift gate: the analytic bytes models must not regress.

Re-runs the *deterministic* bytes-model sections of
``benchmarks/compose_bench.py`` and ``benchmarks/serve_bench.py`` — the
analytic HBM-traffic numbers that transfer to TPU — at the current code's
defaults, and fails when the prediction REGRESSES versus the committed
artifacts:

  - ``BENCH_compose.json``: ``bytes_fused_model`` (matmul-fused kernel
    traffic) grew, or ``model_ratio`` (unfused/fused traffic, the
    headline win) shrank;
  - ``BENCH_serve.json`` (``multi_tenant.model`` section): any per-token
    adapter-path bytes grew, or the multi-tenant cache-hit path stopped
    pricing IDENTICALLY to single-tenant cached decode (``mt_hit_bytes ==
    cached_gsb_bytes`` — the grouped path must not cost extra per token);
  - ``BENCH_serve.json`` (``continuous`` section): the deterministic
    schedule model re-simulated from the committed arrival trace — the
    continuous-batching engine must need NO MORE decode steps than
    committed and must keep beating the static baseline (fewer decode
    steps, higher mean slot occupancy) for the same trace: the static
    batch pays idle-row decode, and a scheduler change that loses that
    win is a serving regression;
  - ``BENCH_serve.json`` (``speculative`` section): the speculative
    accept-rate schedule model re-simulated from the committed trace at
    the committed draft window ``k`` — speculative decode must keep
    needing fewer full-DoRA steps (verify + fallback decode) than plain
    decode emits tokens, at the full AND the degraded accept rate;
  - ``BENCH_serve.json`` (``paged`` section): the block-paged engine's
    schedule/block model re-simulated from the committed long-context
    trace, and its memory model re-priced from the current cache
    shapes — paged residency (peak blocks actually touched, and the
    pool allocation itself) must stay strictly under the rectangular
    ``slots * max_len`` reservation, and the chunked admission must not
    cost more ticks or decode steps than committed;
  - ``BENCH_serve.json`` (``obs`` section): the per-request lifecycle
    model re-simulated from the committed congested arrival trace —
    queue-wait p50 (admission latency under load) must not grow,
    occupancy must not shrink, and TTFT must keep coinciding with
    queue wait (the first token comes from the admission prefill);
  - ``BENCH_serve.json`` (``fleet`` section): the dynamic-grouping
    signature model re-simulated from the committed churny multi-tenant
    trace — the dynamic engine must keep compiling exactly ONE decode
    executable while the static engine needs one per distinct slot
    layout, and the tiered-cache admission model must keep a spilled
    tenant strictly cheaper to re-admit than a cold one.

Measured sections (HLO bytes-accessed, wall clocks, tok/s) are
machine-dependent and stay informational — they are never gated here.

An *improvement* (prediction strictly better than committed) passes but
prints a reminder to regenerate the artifact
(``python -m benchmarks.compose_bench --artifact BENCH_compose.json`` /
``python -m benchmarks.serve_bench --smoke --artifact BENCH_serve.json``)
so the committed trajectory keeps up with the code.

Exit status: 0 clean, 1 on regression (CI fails the PR).
"""
from __future__ import annotations

import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [ROOT, os.path.join(ROOT, "src")]

# Relative slack for float round-trips through JSON; the models are pure
# integer arithmetic, so any real drift is far larger.
EPS = 1e-9

_SHAPE_RE = re.compile(r"^(\d+)x(\d+)r(\d+)$")


def check(artifact_path: str) -> int:
    from benchmarks.compose_bench import DTYPE_SIZE, mm_kernel_bytes_model

    with open(artifact_path) as f:
        committed = json.load(f)

    failures = []
    improvements = []
    rows = committed.get("matmul_fused", [])
    if not rows:
        print(f"ERROR: no matmul_fused rows in {artifact_path}")
        return 1
    print(f"bench-drift gate: {len(rows)} bytes-model rows "
          f"from {artifact_path}")
    for row in rows:
        shape = row["shape"]
        m_ = _SHAPE_RE.match(shape)
        if not m_:
            failures.append(f"{shape}: unparseable shape string")
            continue
        m, n, r = (int(g) for g in m_.groups())
        model = mm_kernel_bytes_model(m, n, r, DTYPE_SIZE)
        got_fused = model["bytes_fused_model"]
        got_ratio = model["model_ratio"]
        want_fused = row["bytes_fused_model"]
        want_ratio = row["model_ratio"]
        status = "ok"
        if got_fused > want_fused * (1 + EPS):
            status = "REGRESSION"
            failures.append(
                f"{shape}: predicted fused traffic regressed "
                f"{want_fused:.0f} -> {got_fused:.0f} bytes")
        elif got_ratio < want_ratio * (1 - EPS):
            status = "REGRESSION"
            failures.append(
                f"{shape}: predicted traffic ratio regressed "
                f"{want_ratio:.4f}x -> {got_ratio:.4f}x")
        elif got_fused < want_fused * (1 - EPS) \
                or got_ratio > want_ratio * (1 + EPS):
            status = "improved"
            improvements.append(shape)
        print(f"  {shape:>16}: fused {want_fused:>12.0f} -> "
              f"{got_fused:>12.0f} B, ratio {want_ratio:.4f}x -> "
              f"{got_ratio:.4f}x  [{status}]")

    if failures:
        print("\nbench-drift FAIL: predicted HBM traffic regressed vs the "
              "committed artifact:")
        for f_ in failures:
            print(f"  - {f_}")
        print("If the regression is intentional (a deliberate model "
              "change), regenerate the artifact and justify it in the PR:\n"
              "  python -m benchmarks.compose_bench --artifact "
              "BENCH_compose.json")
        return 1
    if improvements:
        print(f"\nbench-drift OK (improved: {', '.join(improvements)}) — "
              f"regenerate BENCH_compose.json to record the better model.")
    else:
        print("\nbench-drift OK: analytic bytes models match the committed "
              "artifact.")
    return 0


def check_serve(artifact_path: str) -> int:
    """Gate the serve bench's analytic adapter-path model: re-price from
    the committed shape, fail on growth, and enforce the multi-tenant
    invariant mt_hit == cached_gsb (a cache hit adds no per-token cost)."""
    from benchmarks.serve_bench import adapter_decode_bytes_model

    with open(artifact_path) as f:
        committed = json.load(f)
    model = committed.get("multi_tenant", {}).get("model")
    if not model:
        print(f"ERROR: no multi_tenant.model section in {artifact_path} — "
              f"regenerate: python -m benchmarks.serve_bench --smoke "
              f"--artifact BENCH_serve.json")
        return 1
    got = adapter_decode_bytes_model(model["d_out"], model["d_in"],
                                     model["rank"], model["dtype_size"])
    failures = []
    improvements = []
    for field in ("uncached_bytes", "cached_bytes", "cached_gsb_bytes",
                  "mt_hit_bytes"):
        want, now = model[field], got[field]
        status = "ok"
        if now > want * (1 + EPS):
            status = "REGRESSION"
            failures.append(f"{field}: predicted per-token adapter bytes "
                            f"grew {want:.0f} -> {now:.0f}")
        elif now < want * (1 - EPS):
            status = "improved"
            improvements.append(field)
        print(f"  {field:>18}: {want:>10.0f} -> {now:>10.0f} B  [{status}]")
    if got["mt_hit_bytes"] != got["cached_gsb_bytes"]:
        failures.append(
            f"multi-tenant cache-hit path no longer prices identically to "
            f"single-tenant cached decode: mt_hit={got['mt_hit_bytes']} != "
            f"cached_gsb={got['cached_gsb_bytes']} — the grouped decode "
            f"must read each row's A/gsB/g exactly once")
    if failures:
        print("\nserve-drift FAIL:")
        for f_ in failures:
            print(f"  - {f_}")
        print("If intentional, regenerate and justify in the PR:\n"
              "  python -m benchmarks.serve_bench --smoke --artifact "
              "BENCH_serve.json")
        return 1
    if improvements:
        print(f"\nserve-drift OK (improved: {', '.join(improvements)}) — "
              f"regenerate BENCH_serve.json to record the better model.")
    else:
        print("\nserve-drift OK: analytic adapter-path model matches the "
              "committed artifact (mt_hit == cached_gsb).")
    return 0


def check_continuous(artifact_path: str) -> int:
    """Gate the continuous-batching schedule model: re-simulate the
    committed arrival trace (pure host arithmetic — the scheduling is
    model-independent) and fail when the engine needs more decode steps /
    less occupancy than committed, or stops beating the static baseline."""
    from benchmarks.serve_bench import (make_arrival_trace,
                                        simulate_continuous,
                                        simulate_static)

    with open(artifact_path) as f:
        committed = json.load(f)
    section = committed.get("continuous")
    if not section:
        print(f"ERROR: no continuous section in {artifact_path} — "
              f"regenerate: python -m benchmarks.serve_bench --smoke "
              f"--artifact BENCH_serve.json")
        return 1
    tp = dict(section["trace"])
    slots = tp.pop("slots")
    tp.pop("max_len", None)
    tp["gen_lens"] = tuple(tp["gen_lens"])
    trace = make_arrival_trace(**tp)
    sim_e = simulate_continuous(trace, slots=slots)
    sim_s = simulate_static(trace, slots=slots)

    failures = []
    improvements = []
    rows = [("engine decode_steps", sim_e["decode_steps"],
             section["engine_model"]["decode_steps"], False),
            ("engine mean_occupancy", sim_e["mean_occupancy"],
             section["engine_model"]["mean_occupancy"], True),
            ("static decode_steps", sim_s["decode_steps"],
             section["static_model"]["decode_steps"], None)]
    for name, now, want, higher_is_better in rows:
        status = "ok"
        if higher_is_better is None:
            pass  # informational context row, never gated
        elif higher_is_better and now < want * (1 - EPS):
            status = "REGRESSION"
            failures.append(f"{name}: {want:.4f} -> {now:.4f}")
        elif higher_is_better is False and now > want * (1 + EPS):
            status = "REGRESSION"
            failures.append(f"{name}: {want:.4f} -> {now:.4f}")
        elif (higher_is_better and now > want * (1 + EPS)) or \
                (higher_is_better is False and now < want * (1 - EPS)):
            status = "improved"
            improvements.append(name)
        print(f"  {name:>24}: {want:>10.4f} -> {now:>10.4f}  [{status}]")
    if sim_e["decode_steps"] > sim_s["decode_steps"]:
        failures.append(
            f"the engine no longer beats static batching on the trace: "
            f"{sim_e['decode_steps']} engine decode steps > "
            f"{sim_s['decode_steps']} static — continuous batching must "
            f"not pay MORE decode row-work than the idle-row baseline")
    if sim_e["mean_occupancy"] < sim_s["mean_occupancy"] - EPS:
        failures.append(
            f"engine occupancy {sim_e['mean_occupancy']:.4f} fell below "
            f"the static baseline's {sim_s['mean_occupancy']:.4f}")
    if failures:
        print("\ncontinuous-drift FAIL:")
        for f_ in failures:
            print(f"  - {f_}")
        print("If intentional, regenerate and justify in the PR:\n"
              "  python -m benchmarks.serve_bench --smoke --artifact "
              "BENCH_serve.json")
        return 1
    if improvements:
        print(f"\ncontinuous-drift OK (improved: "
              f"{', '.join(improvements)}) — regenerate BENCH_serve.json "
              f"to record the better schedule.")
    else:
        print("\ncontinuous-drift OK: the re-simulated schedule matches "
              "the committed artifact and the engine still beats the "
              "static baseline.")
    return 0


def check_speculative(artifact_path: str) -> int:
    """Gate the speculative-decode schedule model: re-simulate the
    committed arrival trace at the committed k / accept rates (pure host
    arithmetic) and fail when speculative needs more verify steps than
    committed, or stops beating plain decode — every plain decode step is
    one full-DoRA forward per emitted token, so speculative must clear
    ``verify_steps + fallback decode_steps < plain generated_tokens`` at
    the FULL and the DEGRADED accept rate alike (a win that only exists
    for perfect drafts is no win)."""
    from benchmarks.serve_bench import (make_arrival_trace,
                                        simulate_continuous,
                                        simulate_speculative)

    with open(artifact_path) as f:
        committed = json.load(f)
    section = committed.get("speculative")
    if not section:
        print(f"ERROR: no speculative section in {artifact_path} — "
              f"regenerate: python -m benchmarks.serve_bench --smoke "
              f"--artifact BENCH_serve.json")
        return 1
    tp = dict(section["trace"])
    slots = tp.pop("slots")
    max_len = tp.pop("max_len")
    k = tp.pop("k")
    degraded = tp.pop("degraded_accept_rate")
    tp["gen_lens"] = tuple(tp["gen_lens"])
    trace = make_arrival_trace(**tp)
    sim_full = simulate_speculative(trace, slots=slots, max_len=max_len,
                                    k=k, accept_rate=1.0)
    sim_deg = simulate_speculative(trace, slots=slots, max_len=max_len,
                                   k=k, accept_rate=degraded)
    sim_plain = simulate_continuous(trace, slots=slots)
    plain_tokens = sim_plain["generated_tokens"]

    failures = []
    improvements = []
    rows = [("spec verify_steps", sim_full["verify_steps"],
             section["speculative_model"]["verify_steps"], False),
            ("spec fallback decode", sim_full["decode_steps"],
             section["speculative_model"]["decode_steps"], False),
            ("degraded verify_steps", sim_deg["verify_steps"],
             section["degraded_model"]["verify_steps"], False),
            ("plain generated_tokens", plain_tokens,
             section["plain_model"]["generated_tokens"], None)]
    for name, now, want, higher_is_better in rows:
        status = "ok"
        if higher_is_better is None:
            pass  # informational context row, never gated
        elif higher_is_better is False and now > want * (1 + EPS):
            status = "REGRESSION"
            failures.append(f"{name}: {want:.4f} -> {now:.4f}")
        elif higher_is_better is False and now < want * (1 - EPS):
            status = "improved"
            improvements.append(name)
        print(f"  {name:>24}: {want:>10.4f} -> {now:>10.4f}  [{status}]")
    for label, sim in (("full-accept", sim_full),
                       (f"degraded({degraded})", sim_deg)):
        full_dora_steps = sim["verify_steps"] + sim["decode_steps"]
        if full_dora_steps >= plain_tokens:
            failures.append(
                f"speculative decode ({label}) stopped beating plain "
                f"decode: {sim['verify_steps']} verify + "
                f"{sim['decode_steps']} fallback decode steps >= "
                f"{plain_tokens} tokens plain decode emits — each plain "
                f"token is a full-DoRA forward, so speculative must need "
                f"strictly fewer full-DoRA steps")
    if failures:
        print("\nspeculative-drift FAIL:")
        for f_ in failures:
            print(f"  - {f_}")
        print("If intentional, regenerate and justify in the PR:\n"
              "  python -m benchmarks.serve_bench --smoke --artifact "
              "BENCH_serve.json")
        return 1
    if improvements:
        print(f"\nspeculative-drift OK (improved: "
              f"{', '.join(improvements)}) — regenerate BENCH_serve.json "
              f"to record the better schedule.")
    else:
        print("\nspeculative-drift OK: the re-simulated schedule matches "
              "the committed artifact and speculative still beats plain "
              "decode at full AND degraded accept rates.")
    return 0


def check_paged(artifact_path: str) -> int:
    """Gate the paged-cache schedule AND memory models: re-simulate the
    committed long-context trace (pure host arithmetic — schedule plus
    the engine's block reserve/grow/free accounting) and re-price the
    residency from the CURRENT cache shapes. Fails when the paged
    engine needs more ticks / decode steps / peak blocks than
    committed, when a block grew, or when paged residency stops beating
    the rectangular ``slots * max_len`` reservation — the tentpole's
    whole point."""
    from benchmarks.serve_bench import (make_longcontext_trace,
                                        paged_cache_bytes_model,
                                        simulate_paged)
    from repro.configs import get_config

    with open(artifact_path) as f:
        committed = json.load(f)
    section = committed.get("paged")
    if not section:
        print(f"ERROR: no paged section in {artifact_path} — "
              f"regenerate: python -m benchmarks.serve_bench --smoke "
              f"--artifact BENCH_serve.json")
        return 1
    tp = dict(section["trace"])
    slots = tp.pop("slots")
    max_len = tp.pop("max_len")
    block_size = tp.pop("block_size")
    n_blocks = tp.pop("n_blocks")
    chunk = tp.pop("prefill_chunk")
    long_kw = {k: tp.pop(k) for k in
               ("long_arrival", "long_prompt_len", "long_gen_len")}
    tp["gen_lens"] = tuple(tp["gen_lens"])
    trace = make_longcontext_trace(tp, **long_kw)
    sim = simulate_paged(trace, slots=slots, max_len=max_len,
                         block_size=block_size, n_blocks=n_blocks,
                         chunk=chunk)
    mcfg = get_config("qwen2-7b", smoke=True)
    model = paged_cache_bytes_model(
        mcfg, slots=slots, max_len=max_len, block_size=block_size,
        n_blocks=n_blocks, peak_used_blocks=sim["peak_used_blocks"],
        mean_resident_blocks=sim["mean_resident_blocks"])

    failures = []
    improvements = []
    sched = section["schedule_model"]
    mem = section["memory_model"]
    rows = [("paged steps", sim["steps"], sched["steps"], False),
            ("paged decode_steps", sim["decode_steps"],
             sched["decode_steps"], False),
            ("paged mean_occupancy", sim["mean_occupancy"],
             sched["mean_occupancy"], True),
            ("peak_used_blocks", sim["peak_used_blocks"],
             sched["peak_used_blocks"], False),
            ("bytes_per_block", model["bytes_per_block"],
             mem["bytes_per_block"], False),
            ("peak_resident_bytes", model["peak_resident_bytes"],
             mem["peak_resident_bytes"], False),
            ("rect_kv_bytes", model["rect_kv_bytes"],
             mem["rect_kv_bytes"], None)]
    for name, now, want, higher_is_better in rows:
        status = "ok"
        if higher_is_better is None:
            pass  # informational context row, never gated
        elif higher_is_better and now < want * (1 - EPS):
            status = "REGRESSION"
            failures.append(f"{name}: {want:.4f} -> {now:.4f}")
        elif higher_is_better is False and now > want * (1 + EPS):
            status = "REGRESSION"
            failures.append(f"{name}: {want:.4f} -> {now:.4f}")
        elif (higher_is_better and now > want * (1 + EPS)) or \
                (higher_is_better is False and now < want * (1 - EPS)):
            status = "improved"
            improvements.append(name)
        print(f"  {name:>24}: {want:>10.4f} -> {now:>10.4f}  [{status}]")
    if model["peak_resident_bytes"] >= model["rect_kv_bytes"]:
        failures.append(
            f"paged residency stopped beating the rectangular "
            f"reservation: peak {model['peak_resident_bytes']} >= rect "
            f"{model['rect_kv_bytes']} bytes — the block pool must not "
            f"touch more HBM than the cache it replaces")
    if model["pool_kv_bytes"] >= model["rect_kv_bytes"]:
        failures.append(
            f"the paged pool ALLOCATION stopped beating rectangular: "
            f"{model['pool_kv_bytes']} >= {model['rect_kv_bytes']} bytes "
            f"— n_blocks must stay under slots * max_blocks for the "
            f"committed trace")
    if sim["peak_used_blocks"] >= model["rect_blocks"]:
        failures.append(
            f"peak block demand {sim['peak_used_blocks']} >= the "
            f"rectangular {model['rect_blocks']} blocks on the "
            f"long-context trace")
    if failures:
        print("\npaged-drift FAIL:")
        for f_ in failures:
            print(f"  - {f_}")
        print("If intentional, regenerate and justify in the PR:\n"
              "  python -m benchmarks.serve_bench --smoke --artifact "
              "BENCH_serve.json")
        return 1
    if improvements:
        print(f"\npaged-drift OK (improved: {', '.join(improvements)}) — "
              f"regenerate BENCH_serve.json to record the better model.")
    else:
        print("\npaged-drift OK: the re-simulated paged schedule and "
              "re-priced residency match the committed artifact and stay "
              "under the rectangular reservation.")
    return 0


def check_fleet(artifact_path: str) -> int:
    """Gate the fleet-serving models (PR 9): re-simulate the committed
    churny multi-tenant trace (pure host arithmetic mirroring
    ``DecodeEngine._slot_grouping``) and re-price the admission bytes
    model. Fails when

      1. the dynamic engine stops compiling exactly ONE decode
         executable over the trace (churn-invariance is the tentpole);
      2. the re-simulated signature counts diverge from the committed
         ones (the simulator and ``_slot_grouping`` are asserted equal
         against the REAL engines at artifact-regeneration time, so a
         drift here means one of them changed without the other);
      3. the committed trace stops exercising churn (static needs ≤ 1
         signature — the dynamic win would be vacuous);
      4. a spilled tenant stops being strictly cheaper to re-admit than
         a cold one (the tiered cache's whole point), or its modelled
         bytes grow."""
    from benchmarks.serve_bench import (fleet_admission_bytes_model,
                                        make_fleet_trace, simulate_fleet)

    with open(artifact_path) as f:
        committed = json.load(f)
    section = committed.get("fleet")
    if not section:
        print(f"ERROR: no fleet section in {artifact_path} — "
              f"regenerate: python -m benchmarks.serve_bench --smoke "
              f"--artifact BENCH_serve.json")
        return 1
    tp = dict(section["trace"])
    slots = tp.pop("slots")
    tp.pop("max_len", None)
    tp["gen_lens"] = tuple(tp["gen_lens"])
    trace = make_fleet_trace(**tp)
    sim = simulate_fleet(trace, slots=slots)
    sched = section["schedule_model"]
    am = section["admission_model"]
    model = fleet_admission_bytes_model(am["d_out"], am["d_in"],
                                        am["rank"], am["dtype_size"])

    failures = []
    improvements = []
    rows = [("dynamic signatures", sim["dynamic_signatures"],
             sched["dynamic_signatures"], False),
            ("static signatures", sim["static_signatures"],
             sched["static_signatures"], None),
            ("fleet decode_steps", sim["decode_steps"],
             sched["decode_steps"], False),
            ("spilled admission B", model["spilled_admission_bytes"],
             am["spilled_admission_bytes"], False),
            ("cold admission B", model["cold_admission_bytes"],
             am["cold_admission_bytes"], None)]
    for name, now, want, higher_is_better in rows:
        status = "ok"
        if higher_is_better is None:
            pass  # informational context row, gated separately below
        elif higher_is_better is False and now > want * (1 + EPS):
            status = "REGRESSION"
            failures.append(f"{name}: {want:.4f} -> {now:.4f}")
        elif higher_is_better is False and now < want * (1 - EPS):
            status = "improved"
            improvements.append(name)
        print(f"  {name:>24}: {want:>10.4f} -> {now:>10.4f}  [{status}]")
    if sim["dynamic_signatures"] != 1:
        failures.append(
            f"the dynamic engine's decode-executable count is "
            f"{sim['dynamic_signatures']}, not 1 — tenant churn leaked "
            f"into the compile signature")
    if sim["static_signatures"] != sched["static_signatures"]:
        failures.append(
            f"re-simulated static signature count "
            f"{sim['static_signatures']} != committed "
            f"{sched['static_signatures']} — simulate_fleet or the trace "
            f"generator changed without regenerating the artifact (the "
            f"simulator is asserted against the real engine there)")
    if sim["static_signatures"] <= sim["dynamic_signatures"]:
        failures.append(
            f"the committed trace no longer exercises tenant churn: the "
            f"static engine needs only {sim['static_signatures']} "
            f"signature(s) — the dynamic win would be vacuous")
    if model["spilled_admission_bytes"] >= model["cold_admission_bytes"]:
        failures.append(
            f"a spilled tenant stopped being strictly cheaper to admit "
            f"than a cold one: spilled "
            f"{model['spilled_admission_bytes']} B >= cold "
            f"{model['cold_admission_bytes']} B — the host tier must "
            f"save the W-reading precompute, not just move it")
    if failures:
        print("\nfleet-drift FAIL:")
        for f_ in failures:
            print(f"  - {f_}")
        print("If intentional, regenerate and justify in the PR:\n"
              "  python -m benchmarks.serve_bench --smoke --artifact "
              "BENCH_serve.json")
        return 1
    if improvements:
        print(f"\nfleet-drift OK (improved: {', '.join(improvements)}) — "
              f"regenerate BENCH_serve.json to record the better model.")
    else:
        print("\nfleet-drift OK: ONE dynamic decode executable vs "
              f"{sim['static_signatures']} static signatures on the "
              "committed churny trace; spilled admission stays cheaper "
              "than cold.")
    return 0


def check_degraded(artifact_path: str) -> int:
    """Gate the fault-containment schedule model (PR 7): re-simulate the
    committed continuous trace with ONE preemption and ONE quarantine
    (pure host arithmetic, :func:`benchmarks.serve_bench
    .simulate_degraded`) and fail when the degraded engine loses more
    than the displaced rows' own work:

      1. tokens lost == the quarantined row's undelivered budget exactly
         (a fault must not eat co-resident rows' tokens);
      2. prefills grow by exactly the one continuation re-prefill
         (preempt/resume costs one prefill, nothing else);
      3. decode steps grow by at most the preempted row's remaining
         budget (displacement delays work, it must not multiply it)."""
    from benchmarks.serve_bench import (make_arrival_trace,
                                        simulate_continuous,
                                        simulate_degraded)

    with open(artifact_path) as f:
        committed = json.load(f)
    section = committed.get("continuous")
    if not section:
        print(f"ERROR: no continuous section in {artifact_path} — "
              f"regenerate: python -m benchmarks.serve_bench --smoke "
              f"--artifact BENCH_serve.json")
        return 1
    tp = dict(section["trace"])
    slots = tp.pop("slots")
    tp.pop("max_len", None)
    tp["gen_lens"] = tuple(tp["gen_lens"])
    trace = make_arrival_trace(**tp)
    clean = simulate_continuous(trace, slots=slots)
    deg = simulate_degraded(trace, slots=slots, preempt_step=4,
                            quarantine_step=8)

    failures = []
    lost = deg["lost_tokens"]
    want_tokens = clean["generated_tokens"] - lost
    print(f"  degraded schedule (preempt@4, quarantine@8): "
          f"lost_tokens={lost} displaced_steps={deg['displaced_steps']} "
          f"extra_prefills={deg['extra_prefills']}")
    print(f"  {'generated_tokens':>24}: {want_tokens:>10d} == "
          f"{deg['generated_tokens']:>10d} (clean - lost)")
    if deg["generated_tokens"] != want_tokens:
        failures.append(
            f"fault containment broken: degraded run generated "
            f"{deg['generated_tokens']} tokens, expected clean "
            f"{clean['generated_tokens']} minus the quarantined row's "
            f"{lost} — a fault leaked into co-resident rows' output")
    print(f"  {'prefills':>24}: "
          f"{clean['prefills'] + deg['extra_prefills']:>10d} == "
          f"{deg['prefills']:>10d} (clean + resume re-prefill)")
    if deg["prefills"] != clean["prefills"] + deg["extra_prefills"]:
        failures.append(
            f"preempt/resume no longer costs exactly one re-prefill: "
            f"{deg['prefills']} prefills vs clean {clean['prefills']} + "
            f"{deg['extra_prefills']} continuation")
    bound = clean["decode_steps"] + deg["displaced_steps"]
    print(f"  {'decode_steps':>24}: {deg['decode_steps']:>10d} <= "
          f"{bound:>10d} (clean + displaced budget)")
    if deg["decode_steps"] > bound:
        failures.append(
            f"degraded engine pays {deg['decode_steps']} decode steps > "
            f"clean {clean['decode_steps']} + displaced "
            f"{deg['displaced_steps']} — preemption must delay the "
            f"victim's work, not multiply the fleet's")
    if failures:
        print("\ndegraded-drift FAIL:")
        for f_ in failures:
            print(f"  - {f_}")
        print("The degraded schedule is derived from the SAME committed "
              "trace as check_continuous; fix the scheduler, do not "
              "regenerate the artifact around this gate.")
        return 1
    print("\ndegraded-drift OK: one preemption + one quarantine lose "
          "only the displaced rows' own work.")
    return 0


def check_obs(artifact_path: str) -> int:
    """Gate the observability lifecycle model (PR 10): re-simulate the
    committed congested arrival trace's per-request lifecycle ticks
    (pure host arithmetic mirroring what a ``TraceRecorder`` journals —
    ``benchmarks.serve_bench.simulate_obs``, asserted equal to a traced
    REAL engine at artifact-regeneration time) and fail when

      1. queue-wait p50 grows — admission latency on the canonical
         congested trace is the headline scheduler-quality number;
      2. queue-wait p90 / TTFT p50 grow or occupancy p50 shrinks;
      3. TTFT stops coinciding with queue wait tick-for-tick — the
         first token must keep coming from the admission prefill, not a
         later decode step;
      4. the committed trace stops exercising queueing (queue-wait max
         of zero would make gate 1 vacuous)."""
    from benchmarks.serve_bench import make_arrival_trace, simulate_obs

    with open(artifact_path) as f:
        committed = json.load(f)
    section = committed.get("obs")
    if not section:
        print(f"ERROR: no obs section in {artifact_path} — "
              f"regenerate: python -m benchmarks.serve_bench --smoke "
              f"--artifact BENCH_serve.json")
        return 1
    tp = dict(section["trace"])
    slots = tp.pop("slots")
    tp.pop("max_len", None)
    tp["gen_lens"] = tuple(tp["gen_lens"])
    trace = make_arrival_trace(**tp)
    sim = simulate_obs(trace, slots=slots)
    want = section["lifecycle_model"]

    failures = []
    improvements = []
    rows = [("queue_wait p50", sim["queue_wait_ticks"]["p50"],
             want["queue_wait_ticks"]["p50"], False),
            ("queue_wait p90", sim["queue_wait_ticks"]["p90"],
             want["queue_wait_ticks"]["p90"], False),
            ("ttft p50", sim["ttft_ticks"]["p50"],
             want["ttft_ticks"]["p50"], False),
            ("occupancy p50", sim["occupancy"]["p50"],
             want["occupancy"]["p50"], True),
            ("admit_to_retire p50", sim["admit_to_retire_ticks"]["p50"],
             want["admit_to_retire_ticks"]["p50"], None)]
    for name, now, want_v, higher_is_better in rows:
        status = "ok"
        if higher_is_better is None:
            pass  # gen-length distribution, informational context row
        elif higher_is_better and now < want_v * (1 - EPS):
            status = "REGRESSION"
            failures.append(f"{name}: {want_v:.4f} -> {now:.4f}")
        elif higher_is_better is False and now > want_v * (1 + EPS):
            status = "REGRESSION"
            failures.append(f"{name}: {want_v:.4f} -> {now:.4f}")
        elif (higher_is_better and now > want_v * (1 + EPS)) or \
                (higher_is_better is False and now < want_v * (1 - EPS)):
            status = "improved"
            improvements.append(name)
        print(f"  {name:>24}: {want_v:>10.4f} -> {now:>10.4f}  [{status}]")
    if sim["ttft_ticks"] != sim["queue_wait_ticks"]:
        failures.append(
            f"TTFT {sim['ttft_ticks']} no longer coincides with queue "
            f"wait {sim['queue_wait_ticks']} — the first token must come "
            f"from the admission prefill itself, not a later decode tick")
    if sim["queue_wait_ticks"]["max"] <= 0:
        failures.append(
            "the committed trace no longer exercises queueing (queue-wait "
            "max is 0) — the queue-wait gate would be vacuous; tighten "
            "mean_interarrival in run_obs")
    if failures:
        print("\nobs-drift FAIL:")
        for f_ in failures:
            print(f"  - {f_}")
        print("If intentional, regenerate and justify in the PR:\n"
              "  python -m benchmarks.serve_bench --smoke --artifact "
              "BENCH_serve.json")
        return 1
    if improvements:
        print(f"\nobs-drift OK (improved: {', '.join(improvements)}) — "
              f"regenerate BENCH_serve.json to record the better "
              f"lifecycle numbers.")
    else:
        print("\nobs-drift OK: the re-simulated lifecycle percentiles "
              "match the committed artifact; queue-wait p50 "
              f"{want['queue_wait_ticks']['p50']:.0f} ticks holds on the "
              "congested trace.")
    return 0


if __name__ == "__main__":
    if len(sys.argv) > 1:
        compose_path, serve_path = sys.argv[1], (
            sys.argv[2] if len(sys.argv) > 2 else
            os.path.join(ROOT, "BENCH_serve.json"))
    else:
        compose_path = os.path.join(ROOT, "BENCH_compose.json")
        serve_path = os.path.join(ROOT, "BENCH_serve.json")
    rc = check(compose_path)
    print()
    rc = check_serve(serve_path) or rc
    print()
    rc = check_continuous(serve_path) or rc
    print()
    rc = check_speculative(serve_path) or rc
    print()
    rc = check_paged(serve_path) or rc
    print()
    rc = check_degraded(serve_path) or rc
    print()
    rc = check_fleet(serve_path) or rc
    print()
    rc = check_obs(serve_path) or rc
    sys.exit(rc)
