#!/usr/bin/env python
"""Bench-drift gate: the analytic bytes models must not regress.

Re-runs the *deterministic* bytes-model sections of
``benchmarks/compose_bench.py`` — the analytic HBM-traffic numbers that
transfer to TPU — at the current code's defaults, and fails when the
prediction REGRESSES versus the committed ``BENCH_compose.json``:

  - ``bytes_fused_model`` (matmul-fused kernel traffic) grew, or
  - ``model_ratio`` (unfused/fused traffic, the headline win) shrank.

Measured sections (HLO bytes-accessed, wall clocks) are machine-dependent
and stay informational — they are never gated here.

An *improvement* (prediction strictly better than committed) passes but
prints a reminder to regenerate the artifact
(``python -m benchmarks.compose_bench --artifact BENCH_compose.json``)
so the committed trajectory keeps up with the code.

Exit status: 0 clean, 1 on regression (CI fails the PR).
"""
from __future__ import annotations

import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path[:0] = [ROOT, os.path.join(ROOT, "src")]

# Relative slack for float round-trips through JSON; the models are pure
# integer arithmetic, so any real drift is far larger.
EPS = 1e-9

_SHAPE_RE = re.compile(r"^(\d+)x(\d+)r(\d+)$")


def check(artifact_path: str) -> int:
    from benchmarks.compose_bench import DTYPE_SIZE, mm_kernel_bytes_model

    with open(artifact_path) as f:
        committed = json.load(f)

    failures = []
    improvements = []
    rows = committed.get("matmul_fused", [])
    if not rows:
        print(f"ERROR: no matmul_fused rows in {artifact_path}")
        return 1
    print(f"bench-drift gate: {len(rows)} bytes-model rows "
          f"from {artifact_path}")
    for row in rows:
        shape = row["shape"]
        m_ = _SHAPE_RE.match(shape)
        if not m_:
            failures.append(f"{shape}: unparseable shape string")
            continue
        m, n, r = (int(g) for g in m_.groups())
        model = mm_kernel_bytes_model(m, n, r, DTYPE_SIZE)
        got_fused = model["bytes_fused_model"]
        got_ratio = model["model_ratio"]
        want_fused = row["bytes_fused_model"]
        want_ratio = row["model_ratio"]
        status = "ok"
        if got_fused > want_fused * (1 + EPS):
            status = "REGRESSION"
            failures.append(
                f"{shape}: predicted fused traffic regressed "
                f"{want_fused:.0f} -> {got_fused:.0f} bytes")
        elif got_ratio < want_ratio * (1 - EPS):
            status = "REGRESSION"
            failures.append(
                f"{shape}: predicted traffic ratio regressed "
                f"{want_ratio:.4f}x -> {got_ratio:.4f}x")
        elif got_fused < want_fused * (1 - EPS) \
                or got_ratio > want_ratio * (1 + EPS):
            status = "improved"
            improvements.append(shape)
        print(f"  {shape:>16}: fused {want_fused:>12.0f} -> "
              f"{got_fused:>12.0f} B, ratio {want_ratio:.4f}x -> "
              f"{got_ratio:.4f}x  [{status}]")

    if failures:
        print("\nbench-drift FAIL: predicted HBM traffic regressed vs the "
              "committed artifact:")
        for f_ in failures:
            print(f"  - {f_}")
        print("If the regression is intentional (a deliberate model "
              "change), regenerate the artifact and justify it in the PR:\n"
              "  python -m benchmarks.compose_bench --artifact "
              "BENCH_compose.json")
        return 1
    if improvements:
        print(f"\nbench-drift OK (improved: {', '.join(improvements)}) — "
              f"regenerate BENCH_compose.json to record the better model.")
    else:
        print("\nbench-drift OK: analytic bytes models match the committed "
              "artifact.")
    return 0


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.join(ROOT, "BENCH_compose.json")
    sys.exit(check(path))
