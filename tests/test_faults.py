"""Fault-tolerant serving: the deterministic FaultPlan harness and the
engine's containment contracts — per-row quarantine, deadlines,
priority preemption with bitwise resume, stale/evict/slow injection,
the degradation ladder (speculative auto-disable, EngineBusy
backpressure), picklable results, and the fault-invariant compiled
surface.

The one invariant everything here locks: a fault is contained to the
row (or request) it hits. Co-resident rows' token streams stay bitwise
identical to a fault-free run, every submitted request finishes exactly
once with a reason from ``FINISH_REASONS``, and no fault path compiles
a new executable.
"""
from __future__ import annotations

import os
import pickle
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AdapterStateCache, DoRAConfig
from repro.launch.engine import (FINISH_REASONS, DecodeEngine, EngineBusy)
from repro.launch.faults import (FAULT_KINDS, MAX_SLOW_S, FaultEvent,
                                 FaultPlan)
from repro.launch.serve import EngineServer, Request, generate
from repro.launch.steps import StepConfig
from repro.launch.train import build_state

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
ARCH = "qwen2-7b"


def _setup(tenants=1):
    mcfg = get_config(ARCH, smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg)
    for t in range(tenants):
        _, ad, _ = build_state(mcfg, DCFG, 10 + t)
        cache.register(f"t{t}", ad)
    return mcfg, scfg, params, cache


def _perturb(adapters, seed, scale=0.1):
    """Non-identity adapters (random B leaves): seed-built trees have
    B == 0, so the draft path would equal the full path and every
    speculative draft would be accepted — useless for exercising the
    accept-rate ladder."""
    key = jax.random.PRNGKey(seed)
    cnt = [0]

    def f(path, leaf):
        cnt[0] += 1
        if "'B'" in "/".join(str(p) for p in path):
            return jax.random.normal(jax.random.fold_in(key, cnt[0]),
                                     leaf.shape, leaf.dtype) * scale
        return leaf
    return jax.tree_util.tree_map_with_path(f, adapters)


def _alone(mcfg, scfg, params, cache, prompt, gen_len, max_len, adapter):
    toks = np.asarray(generate(
        mcfg, params, cache.current_handle(adapter), scfg,
        np.asarray(prompt)[None], gen_len=gen_len, max_len=max_len,
        adapter_cache=cache))
    return toks[0, len(prompt):]


class TestFaultPlan:
    """The harness itself: parsing, validation, determinism."""

    def test_parse_round_trip(self):
        plan = FaultPlan.parse(" nan@3:1, evict@5, stale@2 ,slow@4 ")
        assert len(plan) == 4
        assert plan.nan_slots(3) == (1,)
        assert plan.nan_slots(4) == ()
        assert plan.evict_at(5) and not plan.evict_at(4)
        assert plan.stale_at(2) and not plan.stale_at(3)
        assert plan.slow_at(4) > 0.0 and plan.slow_at(5) == 0.0
        assert plan.last_step == 5
        assert FaultPlan.parse("") == FaultPlan()
        # nan with no slot poisons ALL active rows at that tick
        assert FaultPlan.parse("nan@7").nan_slots(7) == (None,)

    def test_parse_rejects_bad_specs(self):
        with pytest.raises(ValueError, match="kind"):
            FaultPlan.parse("explode@3")
        with pytest.raises(ValueError):
            FaultPlan.parse("nan@notanumber")
        with pytest.raises(ValueError):
            FaultPlan.parse("nan")

    def test_event_validation(self):
        with pytest.raises(ValueError, match="kind"):
            FaultEvent(kind="explode", step=1)
        with pytest.raises(ValueError, match="step"):
            FaultEvent(kind="nan", step=-1)
        assert set(FAULT_KINDS) == {"nan", "evict", "stale", "slow"}

    def test_slow_duration_capped(self):
        plan = FaultPlan(events=(FaultEvent("slow", 2, duration_s=10.0),
                                 FaultEvent("slow", 2, duration_s=10.0)))
        assert plan.slow_at(2) == MAX_SLOW_S

    def test_random_is_seed_deterministic(self):
        kw = dict(steps=20, slots=4, n_nan=2, n_evict=1, n_stale=1,
                  n_slow=1)
        a = FaultPlan.random(7, **kw)
        b = FaultPlan.random(7, **kw)
        c = FaultPlan.random(8, **kw)
        assert a == b and len(a) == 5
        assert a != c
        for e in a.events:
            assert e.kind in FAULT_KINDS and 0 <= e.step < 20


class TestQuarantine:
    ML = 14

    def test_nan_poisons_only_its_row(self):
        """ACCEPTANCE: a NaN injected into one slot's logits retires that
        request ``error_numeric`` with its tokens-so-far; the co-resident
        row's stream is BITWISE the fault-free oracle."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(0)
        p0 = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        p1 = rng.integers(0, mcfg.vocab_size, 7, dtype=np.int32)
        ref0 = _alone(mcfg, scfg, params, cache, p0, 6, self.ML, "t0")
        ref1 = _alone(mcfg, scfg, params, cache, p1, 6, self.ML, "t0")
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=self.ML,
                           adapter_cache=cache,
                           fault_plan=FaultPlan.parse("nan@2:0"))
        eng.submit(p0, adapter="t0", max_new_tokens=6)
        eng.submit(p1, adapter="t0", max_new_tokens=6)
        r0, r1 = eng.run()
        assert r0.finish_reason == "error_numeric"
        # tick 0 admits+decodes (2 tokens), tick 1 decodes (3); tick 2's
        # poisoned logits emit nothing — the stream so far is kept and is
        # a PREFIX of the clean oracle (the fault cost no wrong token)
        np.testing.assert_array_equal(r0.tokens, ref0[:3])
        assert r1.finish_reason == "length"
        np.testing.assert_array_equal(r1.tokens, ref1)
        st = eng.stats()
        assert st.quarantined == 1 and st.injected_nans == 1
        assert not eng.has_work()

    def test_nan_all_rows_quarantines_every_active(self):
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(1)
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=12,
                           adapter_cache=cache,
                           fault_plan=FaultPlan.parse("nan@1"))
        for P in (5, 6):
            eng.submit(rng.integers(0, mcfg.vocab_size, P, dtype=np.int32),
                       adapter="t0", max_new_tokens=5)
        results = eng.run()
        assert [r.finish_reason for r in results] == ["error_numeric"] * 2
        assert all(r.tokens.shape == (2,) for r in results)
        assert eng.stats().quarantined == 2

    def test_freed_row_readmits_cleanly_after_quarantine(self):
        """The quarantined slot is a normal free slot afterwards: a
        queued request admits into it and matches its oracle."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(2)
        p0 = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        p1 = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=12,
                           adapter_cache=cache,
                           fault_plan=FaultPlan.parse("nan@1:0"))
        eng.submit(p0, adapter="t0", max_new_tokens=6)
        eng.submit(p1, adapter="t0", max_new_tokens=3)
        r0, r1 = eng.run()
        assert r0.finish_reason == "error_numeric"
        assert r1.finish_reason == "length"
        np.testing.assert_array_equal(
            r1.tokens, _alone(mcfg, scfg, params, cache, p1, 3, 12, "t0"))


class TestDeadlines:
    def test_active_row_times_out_with_partial_tokens(self):
        """A deadline expiring mid-decode retires ``timeout`` with the
        tokens generated so far — a PREFIX of the uninterrupted oracle."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(3)
        p = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        ref = _alone(mcfg, scfg, params, cache, p, 8, 14, "t0")
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=14,
                           adapter_cache=cache)
        eng.submit(p, adapter="t0", max_new_tokens=8, deadline_ticks=3)
        (r,) = eng.run()
        assert r.finish_reason == "timeout"
        # tick 0 = admit + decode (2 tokens), ticks 1-2 one each; the
        # deadline check at the top of tick 3 fires before any decode
        np.testing.assert_array_equal(r.tokens, ref[:4])
        assert eng.stats().timeouts == 1 and not eng.has_work()

    def test_queued_request_times_out_without_admission(self):
        """A request whose deadline expires while QUEUED finishes
        ``timeout`` with zero tokens; the running request is unaffected
        bitwise."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(4)
        p0 = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        p1 = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=15,
                           adapter_cache=cache)
        eng.submit(p0, adapter="t0", max_new_tokens=10)
        eng.submit(p1, adapter="t0", max_new_tokens=4, deadline_ticks=2)
        r0, r1 = eng.run()
        assert r1.finish_reason == "timeout" and r1.tokens.shape == (0,)
        assert r0.finish_reason == "length"
        np.testing.assert_array_equal(
            r0.tokens, _alone(mcfg, scfg, params, cache, p0, 10, 15, "t0"))

    def test_generous_deadline_changes_nothing(self):
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(5)
        p = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=12,
                           adapter_cache=cache)
        eng.submit(p, adapter="t0", max_new_tokens=4, deadline_ticks=100)
        (r,) = eng.run()
        assert r.finish_reason == "length"
        np.testing.assert_array_equal(
            r.tokens, _alone(mcfg, scfg, params, cache, p, 4, 12, "t0"))
        assert eng.stats().timeouts == 0

    def test_submit_rejects_nonpositive_deadline(self):
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=10,
                           adapter_cache=cache)
        with pytest.raises(ValueError, match="deadline_ticks"):
            eng.submit(np.zeros(3, np.int32), adapter="t0",
                       max_new_tokens=2, deadline_ticks=0)


class TestPreemption:
    ML = 14

    def test_preempt_resume_is_bitwise(self):
        """ACCEPTANCE: a higher-priority arrival displaces the running
        request; the victim's generated-so-far tokens are kept, it
        re-queues as a continuation re-prefilled through the SAME traced
        prefill-into-slot step, and its full greedy stream is BITWISE the
        uninterrupted oracle — preemption delays, it never corrupts."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(6)
        p0 = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        ph = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        ref0 = _alone(mcfg, scfg, params, cache, p0, 8, self.ML, "t0")
        refh = _alone(mcfg, scfg, params, cache, ph, 2, self.ML, "t0")
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=self.ML,
                           adapter_cache=cache)
        eng.submit(p0, adapter="t0", max_new_tokens=8)
        for _ in range(2):
            eng.step()
        eng.submit(ph, adapter="t0", max_new_tokens=2, priority=5)
        results = {r.request_id: r for r in eng.run()}
        r0, rh = results[0], results[1]
        assert rh.finish_reason == "length"
        np.testing.assert_array_equal(rh.tokens, refh)
        assert r0.finish_reason == "length" and r0.preempted == 1
        np.testing.assert_array_equal(r0.tokens, ref0)
        # the result reports the ORIGINAL prompt, not the continuation's
        np.testing.assert_array_equal(r0.prompt, p0)
        st = eng.stats()
        assert st.preemptions == 1
        assert not eng.has_work()

    def test_preemption_keeps_temperature_stream(self):
        """Sampling keys fold (key_id, token-count) and the continuation
        resumes the count at its prior-token offset: a preempted
        temperature stream equals the unpreempted one token-for-token."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(7)
        p0 = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        ph = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        ref = DecodeEngine(mcfg, scfg, params, slots=1, max_len=self.ML,
                           adapter_cache=cache, temperature=0.7, seed=5)
        ref.submit(p0, adapter="t0", max_new_tokens=6, key_id=0)
        (ra,) = ref.run()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=self.ML,
                           adapter_cache=cache, temperature=0.7, seed=5)
        eng.submit(p0, adapter="t0", max_new_tokens=6, key_id=0)
        for _ in range(2):
            eng.step()
        eng.submit(ph, adapter="t0", max_new_tokens=2, priority=3,
                   key_id=1)
        results = {r.request_id: r for r in eng.run()}
        assert results[0].preempted == 1
        np.testing.assert_array_equal(results[0].tokens, ra.tokens)

    def test_equal_priority_never_preempts_and_keeps_fifo(self):
        """All-default priorities are EXACTLY the old FIFO engine: same
        admission order, zero preemptions — backward compatible bitwise."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(8)
        reqs = [(rng.integers(0, mcfg.vocab_size, 4 + i % 3,
                              dtype=np.int32), 2 + i % 2)
                for i in range(5)]

        def drive(**submit_kw):
            eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=12,
                               adapter_cache=cache)
            for p, g in reqs:
                eng.submit(p, adapter="t0", max_new_tokens=g, **submit_kw)
            return eng.run(), eng.stats()

        plain, _ = drive()
        prio, st = drive(priority=0)
        assert st.preemptions == 0
        for a, b in zip(plain, prio):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            assert a.admitted_step == b.admitted_step

    def test_priority_orders_queue_admission(self):
        """A high-priority QUEUED request jumps the FIFO at the next free
        slot (no preemption needed when it can simply go first)."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(9)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=12,
                           adapter_cache=cache)
        for prio in (0, 0, 2):
            eng.submit(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                       adapter="t0", max_new_tokens=2, priority=prio)
        results = {r.request_id: r for r in eng.run()}
        # rid 0 admits first (slot was free at submit-tick); rid 2 beats
        # rid 1 to the next free slot despite arriving after it
        assert results[2].admitted_step < results[1].admitted_step
        assert eng.stats().preemptions == 0


class TestInjectionAndCounters:
    def test_stale_injection_drives_the_real_miss_path(self):
        """``stale@t`` hands the next admission a version-bumped handle:
        the REAL AdapterCacheMiss stale path fires, the request finishes
        ``error`` with a picklable cause, and the engine keeps serving."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(10)
        p0 = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        p1 = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=12,
                           adapter_cache=cache,
                           fault_plan=FaultPlan.parse("stale@0"))
        eng.submit(p0, adapter="t0", max_new_tokens=3)
        eng.submit(p1, adapter="t0", max_new_tokens=3)
        r0, r1 = eng.run()
        assert r0.finish_reason == "error"
        assert r0.error_type == "AdapterCacheMiss"
        assert "stale" in r0.error_message
        assert r1.finish_reason == "length"
        np.testing.assert_array_equal(
            r1.tokens, _alone(mcfg, scfg, params, cache, p1, 3, 12, "t0"))
        assert eng.stats().stale_injected == 1

    def test_evict_and_slow_change_no_tokens(self):
        """Forced eviction and slow ticks are pure stress: the states are
        pinned at submit, so every stream stays bitwise — only the
        counters move."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(11)
        reqs = [(rng.integers(0, mcfg.vocab_size, P, dtype=np.int32), 4)
                for P in (5, 6)]

        def drive(plan):
            eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=12,
                               adapter_cache=cache, fault_plan=plan)
            for p, g in reqs:
                eng.submit(p, adapter="t0", max_new_tokens=g)
            return eng.run(), eng.stats()

        clean, _ = drive(None)
        faulty, st = drive(FaultPlan.parse("evict@1,slow@2:0"))
        assert st.forced_evictions == 1 and st.slow_ticks == 1
        for a, b in zip(clean, faulty):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            assert a.finish_reason == b.finish_reason

    def test_compiled_surface_is_fault_invariant(self):
        """ACCEPTANCE: every fault/recovery path — quarantine, deadline,
        preemption+resume, eviction, slow — reuses the SAME single
        (prefill-into-slot, decode) pair; faults never compile."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(12)
        eng = DecodeEngine(
            mcfg, scfg, params, slots=2, max_len=14, adapter_cache=cache,
            fault_plan=FaultPlan.parse("nan@2:0,evict@3,slow@1"))
        for i in range(3):
            eng.submit(rng.integers(0, mcfg.vocab_size, 4 + i,
                                    dtype=np.int32),
                       adapter="t0", max_new_tokens=5,
                       deadline_ticks=4 if i == 2 else None)
        for _ in range(2):
            eng.step()
        eng.submit(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                   adapter="t0", max_new_tokens=2, priority=5)
        results = eng.run()
        assert len(results) == 4
        assert all(r.finish_reason in FINISH_REASONS for r in results)
        counts = eng.compile_counts()
        assert counts["prefill_into_slot"] == 1, counts
        assert counts["decode"] == {None: 1}, counts
        assert counts["draft"] == 0 and counts["verify"] == {}, counts


# The committed join/leave arrival trace (see tests/test_engine.py).
_TRACE = [(1, 8, 8), (1, 8, 6), (1, 8, 4), (4, 8, 10), (6, 8, 10),
          (11, 8, 8), (23, 8, 6), (23, 8, 10), (28, 8, 8), (30, 8, 4),
          (32, 8, 4), (32, 8, 10)]


def _drive_trace(eng, prompts, adapters):
    streams: dict[int, list[int]] = {}

    def on_token(rid, tok):
        streams.setdefault(rid, []).append(tok)

    i, step = 0, 0
    while i < len(_TRACE) or eng.has_work():
        while i < len(_TRACE) and _TRACE[i][0] <= step:
            eng.submit(prompts[i], adapter=adapters[i],
                       max_new_tokens=_TRACE[i][2], key_id=i)
            i += 1
        eng.step(on_token)
        step += 1
    return streams


class TestDegradationLadder:
    ML = 18
    K = 3

    def test_speculative_auto_disable_and_reenable(self):
        """ACCEPTANCE: with non-identity adapters and a floor the accept
        rate cannot clear, the engine disables speculation (plain decode,
        counters record the transition), re-enables after the cooldown —
        and the streams stay BITWISE plain-greedy throughout."""
        mcfg, scfg, params, cache = _setup()
        _, ad, _ = build_state(mcfg, DCFG, 10)
        cache.update("t0", _perturb(ad, 7))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
                   for _, P, _ in _TRACE]
        ads = ["t0"] * len(_TRACE)
        spec = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                            adapter_cache=cache, speculative_k=self.K,
                            spec_accept_floor=0.99, spec_window=2,
                            spec_reenable_after=2)
        plain = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                             adapter_cache=cache)
        got = _drive_trace(spec, prompts, ads)
        want = _drive_trace(plain, prompts, ads)
        assert got == want
        st = spec.stats()
        assert st.spec_disables >= 1, st
        assert st.spec_reenables >= 1, st
        assert st.verify_steps > 0        # it did speculate between trips
        assert st.decode_steps > 0        # and fell back while disabled

    def test_floor_zero_never_trips(self):
        """The default floor (0.0) is OFF: imperfect drafts alone never
        disable speculation."""
        mcfg, scfg, params, cache = _setup()
        _, ad, _ = build_state(mcfg, DCFG, 10)
        cache.update("t0", _perturb(ad, 7))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
                   for _, P, _ in _TRACE]
        eng = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                           adapter_cache=cache, speculative_k=self.K)
        _drive_trace(eng, prompts, ["t0"] * len(_TRACE))
        st = eng.stats()
        assert st.spec_disables == 0 and st.spec_reenables == 0
        assert 0 < st.accepted_drafts < st.draft_steps, st

    def test_busy_backpressure_on_thrashing_cache(self):
        """ACCEPTANCE: when the LRU is thrashing (a full window of
        evicting misses), submitting a COLD handle raises EngineBusy with
        the retry-after hint instead of queueing work that evicts a hot
        tenant; a RESIDENT handle keeps admitting."""
        mcfg, scfg, params, cache = _setup(tenants=2)
        h0 = cache.current_handle("t0")
        h1 = cache.current_handle("t1")
        cache.get_state(params, h0)
        cache.max_bytes = cache.stats().current_bytes  # exactly one state
        # alternate the two tenants: every lookup evicts the other
        for _ in range(3):
            cache.get_state(params, h1)
            cache.get_state(params, h0)
        assert cache.thrashing() and cache.is_resident(h0)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=10,
                           adapter_cache=cache)
        rng = np.random.default_rng(13)
        p = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        with pytest.raises(EngineBusy) as ei:
            eng.submit(p, adapter="t1", max_new_tokens=2)
        assert ei.value.retry_after == cache.thrash_window
        assert not eng.has_work()
        assert eng.stats().busy_rejections == 1
        # the resident tenant is NOT rejected
        eng.submit(p, adapter="t0", max_new_tokens=2)
        (r,) = eng.run()
        assert r.finish_reason == "length" and r.tokens.shape == (2,)

    def test_spilled_handle_exempt_from_backpressure(self):
        """ACCEPTANCE (PR 9 tiered cache): a handle whose state sits in
        the HOST spill tier is never refused with EngineBusy — it costs
        one host→device reload (queue latency), not the precompute the
        backpressure guards against — and serving it raises no
        AdapterCacheMiss even under warm-only routing."""
        mcfg, scfg, params, cache = _setup(tenants=3)
        h0 = cache.current_handle("t0")
        h1 = cache.current_handle("t1")
        h2 = cache.current_handle("t2")
        cache.get_state(params, h0)
        cache.max_bytes = cache.stats().current_bytes   # one state fits
        cache.host_max_bytes = 10 * cache.max_bytes     # spill tier on
        cache.get_state(params, h1)                     # evicts t0 → spills
        assert cache.is_spilled(h0)
        # freeze the host tier (no further spills) and thrash the device
        # LRU with the OTHER two tenants: every lookup an evicting miss,
        # while t0 stays parked in the spill tier
        cache.host_max_bytes = None
        for _ in range(3):
            cache.get_state(params, h2)
            cache.get_state(params, h1)
        assert cache.thrashing()
        assert cache.is_spilled(h0) and not cache.is_resident(h0)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=10,
                           adapter_cache=cache, allow_miss=False)
        rng = np.random.default_rng(14)
        p = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        # the SPILLED tenant submits and serves despite the thrash —
        # and despite allow_miss=False (a reload is not a miss)
        eng.submit(p, adapter="t0", max_new_tokens=2)
        (r,) = eng.run()
        assert r.finish_reason == "length" and r.tokens.shape == (2,)
        assert eng.stats().busy_rejections == 0
        assert cache.stats().reloads >= 1

    def test_stale_handle_still_raises_through_backpressure(self):
        """Backpressure only guards COLD-but-current handles; a stale
        handle keeps its hard AdapterCacheMiss (it can never resolve)."""
        from repro.core import AdapterCacheMiss
        mcfg, scfg, params, cache = _setup(tenants=2)
        stale = cache.current_handle("t0")
        _, ad_new, _ = build_state(mcfg, DCFG, 99)
        cache.update("t0", ad_new)
        h0 = cache.current_handle("t0")
        h1 = cache.current_handle("t1")
        cache.get_state(params, h0)
        cache.max_bytes = cache.stats().current_bytes
        for _ in range(3):
            cache.get_state(params, h1)
            cache.get_state(params, h0)
        assert cache.thrashing()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=10,
                           adapter_cache=cache)
        with pytest.raises(AdapterCacheMiss, match="stale"):
            eng.submit(np.zeros(3, np.int32), adapter=stale,
                       max_new_tokens=2)


class TestResultPickling:
    def test_results_round_trip_including_errors(self):
        """SATELLITE: RequestResult is picklable — the error rides as
        ``error_type``/``error_message`` strings; the live exception is
        a debug accessor that does not survive (and must not break) the
        round trip."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(14)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=12,
                           adapter_cache=cache,
                           fault_plan=FaultPlan.parse("stale@0"))
        eng.submit(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                   adapter="t0", max_new_tokens=3)
        eng.submit(rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32),
                   adapter="t0", max_new_tokens=3)
        results = eng.run()
        err = next(r for r in results if r.finish_reason == "error")
        ok = next(r for r in results if r.finish_reason == "length")
        assert err.error is not None          # live, in-process
        back_err, back_ok = pickle.loads(pickle.dumps([err, ok]))
        assert back_err.error is None         # the live object stays home
        assert back_err.error_type == "AdapterCacheMiss"
        assert "stale" in back_err.error_message
        assert back_err.finish_reason == "error"
        np.testing.assert_array_equal(back_ok.tokens, ok.tokens)
        np.testing.assert_array_equal(back_ok.prompt, ok.prompt)


class TestEngineServerPlumbing:
    def test_per_request_deadlines_and_priorities(self):
        """EngineServer.run threads scalar or per-request deadline/
        priority down to submit; a wrong-length list fails fast."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(15)
        reqs = [Request(rng.integers(0, mcfg.vocab_size, P,
                                     dtype=np.int32), "t0")
                for P in (5, 6)]
        server = EngineServer(mcfg, scfg, params, cache=cache, slots=2,
                              max_len=14)
        results = server.run(reqs, gen_len=8, deadline_ticks=2,
                             priority=[0, 1])
        assert [r.finish_reason for r in results] == ["timeout"] * 2
        assert all(len(r.tokens) <= 3 for r in results)
        with pytest.raises(ValueError, match="deadline_ticks"):
            server.run(reqs, gen_len=2, deadline_ticks=[1, 2, 3])
        with pytest.raises(ValueError, match="priority"):
            server.run(reqs, gen_len=2, priority=[1])
        assert not server.engine.has_work()

    def test_server_fault_plan_pass_through(self):
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(16)
        reqs = [Request(rng.integers(0, mcfg.vocab_size, 5,
                                     dtype=np.int32), "t0")
                for _ in range(2)]
        server = EngineServer(mcfg, scfg, params, cache=cache, slots=2,
                              max_len=12,
                              fault_plan=FaultPlan.parse("nan@1:1"))
        results = server.run(reqs, gen_len=5)
        reasons = sorted(r.finish_reason for r in results)
        assert reasons == ["error_numeric", "length"]
        assert server.engine.stats().quarantined == 1


# ---------------------------------------------------------------------------
# Forced 2-device mesh (subprocess): containment under SPMD.
# ---------------------------------------------------------------------------

def _run_subprocess(code: str, devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    env.pop("REPRO_FORCE_TIER", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


_FAULT_SPMD = """
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import AdapterStateCache, DoRAConfig
    from repro.launch.engine import DecodeEngine
    from repro.launch.faults import FaultPlan
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    assert jax.device_count() == 2
    mesh = make_debug_mesh(2, 1)
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
    mcfg = get_config("qwen2-7b", smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg, mesh)
    _, ad, _ = build_state(mcfg, DCFG, 10)
    cache.register("t0", ad)

    ML = 14
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, mcfg.vocab_size, P, dtype=np.int32), g)
            for P, g in [(5, 6), (6, 6), (4, 5), (5, 4)]]

    def drive(plan):
        eng = DecodeEngine(mcfg, scfg, params, slots=4, max_len=ML,
                           adapter_cache=cache, mesh=mesh,
                           fault_plan=plan)
        for p, g in reqs:
            eng.submit(p, adapter="t0", max_new_tokens=g)
        return eng.run(), eng

    clean, _ = drive(None)
    # slot 1's row is poisoned at tick 2; every OTHER row must stay
    # bitwise identical to the fault-free run under the same 2-device
    # mesh — containment is an SPMD property too (the quarantine guard
    # reads the same host logits sampling already fetched)
    faulty, eng = drive(FaultPlan.parse("nan@2:1"))
    assert eng.stats().quarantined == 1
    for c, f in zip(clean, faulty):
        if f.finish_reason == "error_numeric":
            assert f.request_id == 1
            assert np.array_equal(f.tokens, c.tokens[:len(f.tokens)])
        else:
            assert f.finish_reason == c.finish_reason
            assert np.array_equal(f.tokens, c.tokens), f.request_id
    counts = eng.compile_counts()
    assert counts["prefill_into_slot"] == 1, counts
    assert counts["decode"] == {None: 1}, counts
    print("FAULT_SPMD_OK")
"""


@pytest.mark.slow
def test_fault_containment_spmd():
    """Acceptance on a forced 2-device CPU mesh: a quarantined row's
    neighbours stream bitwise the fault-free run's tokens, and the fault
    path compiles nothing."""
    out = _run_subprocess(_FAULT_SPMD, 2)
    assert "FAULT_SPMD_OK" in out, out
