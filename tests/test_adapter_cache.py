"""Multi-tenant adapter-state LRU: eviction order and byte accounting
under forced tiny ``max_bytes``, bitwise hit-vs-recompute parity per
tenant, invalidation-on-version-bump, the warm-only (``allow_miss=False``)
rejection contract, and composition with ``invalidate_adapter_state``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdapterCacheMiss, AdapterHandle, AdapterStateCache,
                        DoRAConfig, init_dora_params,
                        invalidate_adapter_state, precompute_adapter_state)
from repro.core.adapter_cache import mesh_fingerprint, serving_state_nbytes

DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
D_OUT, D_IN = 32, 24


def _precompute(params, adapters):
    return precompute_adapter_state(params, adapters, DCFG,
                                    act_dtype=jnp.float32, fold_gsb=True)


def _tenant(seed: int):
    # dtypes pinned to fp32: other test modules flip jax_enable_x64 at
    # import (collection) time, and the byte-accounting assertions below
    # must not depend on suite composition.
    key = jax.random.PRNGKey(seed)
    W = jax.random.normal(key, (D_OUT, D_IN), jnp.float32)
    adp = init_dora_params(jax.random.fold_in(key, 1), W, DCFG)
    adp["B"] = 0.2 * jax.random.normal(jax.random.fold_in(key, 2),
                                       adp["B"].shape, jnp.float32)
    return adp


@pytest.fixture()
def setup():
    W = jax.random.normal(jax.random.PRNGKey(99), (D_OUT, D_IN),
                          jnp.float32)
    cache = AdapterStateCache(_precompute, act_dtype=jnp.float32,
                              fold_gsb=True)
    return W, cache


# One tenant's resident cached bytes — the FULL state tree, fp32: a
# jitted precompute materializes fresh A/B/m buffers alongside g/gsB, so
# the whole tree is what max_bytes must bound.
R = DCFG.rank
STATE_BYTES = 4 * (R * D_IN          # A
                   + D_OUT * R       # B
                   + D_OUT           # m
                   + D_OUT           # g
                   + D_OUT * R)      # gsB


class TestAccounting:
    def test_state_bytes_counts_the_full_tree(self, setup):
        W, cache = setup
        h = cache.register("a", _tenant(0))
        state = cache.get_state(W, h)
        assert serving_state_nbytes(state) == STATE_BYTES
        assert cache.stats().current_bytes == STATE_BYTES
        # stripping the serving leaves leaves the raw-weight bytes
        raw_only = invalidate_adapter_state(state)
        assert serving_state_nbytes(raw_only) == \
            STATE_BYTES - 4 * (D_OUT + D_OUT * R)

    def test_lru_eviction_order_under_tiny_budget(self, setup):
        W, _ = setup
        cache = AdapterStateCache(_precompute, act_dtype=jnp.float32,
                                  fold_gsb=True,
                                  max_bytes=2 * STATE_BYTES)
        hs = [cache.register(f"t{i}", _tenant(i)) for i in range(3)]
        cache.get_state(W, hs[0])
        cache.get_state(W, hs[1])
        # touch t0 so t1 becomes the LRU victim
        cache.get_state(W, hs[0])
        cache.get_state(W, hs[2])            # evicts t1, not t0
        keys = [k.adapter_id for k in cache.cached_keys()]
        assert keys == ["t0", "t2"]
        st = cache.stats()
        assert st.evictions == 1 and st.entries == 2
        assert st.current_bytes == 2 * STATE_BYTES

    def test_single_oversized_entry_is_kept(self, setup):
        W, _ = setup
        cache = AdapterStateCache(_precompute, act_dtype=jnp.float32,
                                  fold_gsb=True, max_bytes=STATE_BYTES // 2)
        h = cache.register("big", _tenant(0))
        cache.get_state(W, h)
        st = cache.stats()
        assert st.entries == 1 and st.current_bytes == STATE_BYTES
        h2 = cache.register("big2", _tenant(1))
        cache.get_state(W, h2)               # evicts 'big', keeps 'big2'
        assert [k.adapter_id for k in cache.cached_keys()] == ["big2"]


class TestHitParity:
    def test_hit_is_bitwise_the_recomputed_state(self, setup):
        W, cache = setup
        adp = _tenant(3)
        h = cache.register("t", adp)
        miss = cache.get_state(W, h)
        hit = cache.get_state(W, h)
        assert cache.stats().hits == 1 and cache.stats().misses == 1
        fresh = _precompute(W, adp)
        for k in ("g", "gsB"):
            np.testing.assert_array_equal(np.asarray(hit[k]),
                                          np.asarray(fresh[k]))
        assert hit is miss                   # the same resident tree

    def test_per_tenant_states_are_independent(self, setup):
        W, cache = setup
        h0 = cache.register("t0", _tenant(0))
        h1 = cache.register("t1", _tenant(1))
        g0 = np.asarray(cache.get_state(W, h0)["g"])
        g1 = np.asarray(cache.get_state(W, h1)["g"])
        assert not np.array_equal(g0, g1)


class TestInvalidation:
    def test_register_strips_serving_state(self, setup):
        """Registering a tree that already carries g/gsB composes with the
        invalidate_adapter_state training contract: the registry holds the
        RAW tree, and the state is re-derived through the cache."""
        W, cache = setup
        adp = _tenant(0)
        served = _precompute(W, adp)
        cache.register("t", served)
        raw = cache.adapters("t")
        assert "g" not in raw and "gsB" not in raw
        assert set(raw.keys()) == set(adp.keys())

    def test_version_bump_drops_old_states_and_rejects_old_handles(
            self, setup):
        W, cache = setup
        adp = _tenant(0)
        h0 = cache.register("t", adp)
        g_v0 = np.asarray(cache.get_state(W, h0)["g"])
        adp2 = dict(adp)
        adp2["B"] = adp["B"] + 0.1
        h1 = cache.update("t", adp2)
        assert h1.version == 1
        assert cache.stats().entries == 0     # v0 state dropped
        assert cache.stats().invalidations == 1
        with pytest.raises(AdapterCacheMiss, match="stale adapter handle"):
            cache.get_state(W, h0)
        g_v1 = np.asarray(cache.get_state(W, h1)["g"])
        assert not np.array_equal(g_v0, g_v1)
        # the fresh v1 state matches a from-scratch precompute bitwise
        np.testing.assert_array_equal(
            g_v1, np.asarray(_precompute(W, adp2)["g"]))

    def test_update_leaves_previously_fetched_states_intact(self, setup):
        """A state tree fetched BEFORE an update() stays usable after it:
        the bump drops the cache's reference, but the engine pins such
        trees on in-flight requests (see DecodeEngine.submit), so the
        cache must neither mutate nor strip the copies it handed out."""
        W, cache = setup
        adp = _tenant(0)
        h0 = cache.register("t", adp)
        pinned = cache.get_state(W, h0)
        before = {k: np.asarray(v) for k, v in pinned.items()}
        adp2 = dict(adp)
        adp2["B"] = adp["B"] + 0.1
        cache.update("t", adp2)               # invalidates v0 in the cache
        for k in before:
            np.testing.assert_array_equal(np.asarray(pinned[k]), before[k])
        # and the pinned tree is still the exact v0 precompute
        np.testing.assert_array_equal(
            np.asarray(pinned["g"]), np.asarray(_precompute(W, adp)["g"]))

    def test_explicit_invalidate_keeps_registry(self, setup):
        W, cache = setup
        h = cache.register("t", _tenant(0))
        cache.get_state(W, h)
        assert cache.invalidate("t") == 1
        assert cache.stats().entries == 0
        cache.get_state(W, h)                 # re-derivable: still registered
        assert cache.stats().misses == 2


class TestWarmOnlyRouting:
    def test_allow_miss_false_names_every_key_field(self, setup):
        W, cache = setup
        h = cache.register("prod-adapter", _tenant(0))
        with pytest.raises(AdapterCacheMiss) as ei:
            cache.get_state(W, h, allow_miss=False)
        msg = str(ei.value)
        for field in ("prod-adapter", "version=0", "act_dtype=float32",
                      "fold_gsb=True", "sharding=None", "allow_miss"):
            assert field in msg, (field, msg)
        assert ei.value.key.adapter_id == "prod-adapter"
        # warming the cache makes the same call succeed
        cache.get_state(W, h)
        cache.get_state(W, h, allow_miss=False)

    def test_unregistered_id_rejected(self, setup):
        W, cache = setup
        with pytest.raises(AdapterCacheMiss, match="not registered"):
            cache.get_state(W, AdapterHandle("ghost", 0))

    def test_duplicate_register_rejected(self, setup):
        _, cache = setup
        cache.register("t", _tenant(0))
        with pytest.raises(ValueError, match="already registered"):
            cache.register("t", _tenant(1))


class TestKeying:
    def test_key_carries_dtype_fold_and_sharding(self):
        cache = AdapterStateCache(_precompute, act_dtype=jnp.bfloat16,
                                  fold_gsb=False, sharding=(("model", 4),))
        cache.register("t", _tenant(0))
        key = cache.make_key(cache.current_handle("t"))
        assert key.act_dtype == "bfloat16"
        assert key.fold_gsb is False
        assert key.sharding == (("model", 4),)
        assert hash(key) == hash(key)

    def test_mesh_fingerprint(self):
        from repro.compat.mesh import make_mesh
        assert mesh_fingerprint(None) is None
        mesh = make_mesh((1, 1), ("data", "model"))
        assert mesh_fingerprint(mesh) == (("data", 1), ("model", 1))


def _tiered(max_dev: int, max_host: int | None):
    return AdapterStateCache(_precompute, act_dtype=jnp.float32,
                             fold_gsb=True, max_bytes=max_dev,
                             host_max_bytes=max_host)


class TestHostTier:
    """PR 9 tiered cache: device-LRU eviction SPILLS to a host-RAM tier
    instead of discarding; a later lookup RELOADS (host→device copy, not
    a precompute, not a miss). Conservation: every byte lives in exactly
    one tier, and the two tiers' byte counters never double-count or
    leak across spill/reload/invalidate cycles."""

    def test_spill_moves_bytes_exactly_once(self, setup):
        W, _ = setup
        cache = _tiered(2 * STATE_BYTES, 10 * STATE_BYTES)
        hs = [cache.register(f"t{i}", _tenant(i)) for i in range(3)]
        for h in hs:
            cache.get_state(W, h)
        st = cache.stats()
        # t0 spilled; t1/t2 device-resident — no byte counted twice,
        # none dropped.
        assert st.entries == 2 and st.host_entries == 1
        assert st.current_bytes == 2 * STATE_BYTES
        assert st.host_bytes == STATE_BYTES
        assert st.spills == 1 and st.reloads == 0
        assert cache.is_spilled(hs[0]) and not cache.is_resident(hs[0])
        # exactly-one-tier residency for every tenant
        for h in hs:
            assert cache.is_resident(h) != cache.is_spilled(h)

    def test_reload_is_bitwise_and_not_a_miss(self, setup):
        W, _ = setup
        cache = _tiered(2 * STATE_BYTES, 10 * STATE_BYTES)
        hs = [cache.register(f"t{i}", _tenant(i)) for i in range(3)]
        fresh = {h: {k: np.asarray(v) for k, v in
                     cache.get_state(W, h).items()} for h in hs}
        misses_before = cache.stats().misses
        state = cache.get_state(W, hs[0])          # reload from host
        st = cache.stats()
        assert st.reloads == 1
        assert st.misses == misses_before, \
            "a host-tier reload must not count as a miss"
        for k in ("A", "g", "gsB"):
            np.testing.assert_array_equal(np.asarray(state[k]),
                                          fresh[hs[0]][k])
        # the reload moved it back: device-resident, host slot freed
        assert cache.is_resident(hs[0]) and not cache.is_spilled(hs[0])
        assert st.host_bytes == STATE_BYTES        # the NEW spill victim
        assert st.current_bytes == 2 * STATE_BYTES

    def test_reload_does_not_feed_the_thrash_signal(self, setup):
        W, _ = setup
        cache = AdapterStateCache(_precompute, act_dtype=jnp.float32,
                                  fold_gsb=True, max_bytes=STATE_BYTES,
                                  host_max_bytes=10 * STATE_BYTES,
                                  thrash_window=2)
        hs = [cache.register(f"t{i}", _tenant(i)) for i in range(2)]
        cache.get_state(W, hs[0])
        cache.get_state(W, hs[1])       # evicting miss → spills t0
        assert not cache.thrashing()
        # ping-pong between the two: every lookup is now a RELOAD (the
        # other tenant spills), and reloads must read as warm traffic —
        # the thrash window never fills with evicting misses.
        for _ in range(4):
            cache.get_state(W, hs[0])
            cache.get_state(W, hs[1])
        st = cache.stats()
        assert st.reloads == 8 and not cache.thrashing()

    def test_warm_only_routing_serves_spilled_states(self, setup):
        """allow_miss=False means 'no precompute on the serve path'; a
        spilled state costs a host→device copy, not a precompute, so it
        must serve — the EngineBusy/backpressure exemption."""
        W, _ = setup
        cache = _tiered(STATE_BYTES, 10 * STATE_BYTES)
        hs = [cache.register(f"t{i}", _tenant(i)) for i in range(2)]
        cache.get_state(W, hs[0])
        cache.get_state(W, hs[1])                  # spills t0
        assert cache.is_spilled(hs[0])
        state = cache.get_state(W, hs[0], allow_miss=False)   # no raise
        assert cache.stats().reloads == 1
        np.testing.assert_array_equal(
            np.asarray(state["g"]),
            np.asarray(_precompute(W, cache.adapters("t0"))["g"]))
        # a COLD tenant still raises under warm-only routing
        h2 = cache.register("cold", _tenant(5))
        with pytest.raises(AdapterCacheMiss):
            cache.get_state(W, h2, allow_miss=False)

    def test_version_bump_invalidates_both_tiers(self, setup):
        W, _ = setup
        cache = _tiered(STATE_BYTES, 10 * STATE_BYTES)
        hs = [cache.register(f"t{i}", _tenant(i)) for i in range(2)]
        cache.get_state(W, hs[0])
        cache.get_state(W, hs[1])                  # t0 spilled
        assert cache.is_spilled(hs[0])
        adp2 = dict(_tenant(0))
        adp2["B"] = adp2["B"] + 0.1
        cache.update("t0", adp2)
        st = cache.stats()
        # the spilled v0 state is gone — a reload must NEVER resurrect a
        # stale version from the host tier
        assert not cache.is_spilled(hs[0]) and not cache.is_resident(hs[0])
        assert st.host_bytes == 0 and st.host_entries == 0
        with pytest.raises(AdapterCacheMiss, match="stale adapter handle"):
            cache.get_state(W, hs[0])
        # explicit invalidate() also clears the host tier
        cache.get_state(W, cache.current_handle("t0"))
        cache.get_state(W, hs[1])                  # spills t0@v1
        assert cache.invalidate("t0") == 1
        assert cache.stats().host_entries == 0

    def test_host_budget_drops_oldest_spill(self, setup):
        W, _ = setup
        cache = _tiered(STATE_BYTES, 2 * STATE_BYTES)
        hs = [cache.register(f"t{i}", _tenant(i)) for i in range(4)]
        for h in hs:
            cache.get_state(W, h)
        st = cache.stats()
        # t0..t2 spilled in order; the 2-state host budget dropped t0
        assert st.spills == 3 and st.host_drops == 1
        assert st.host_entries == 2
        assert st.host_bytes == 2 * STATE_BYTES
        assert [k.adapter_id for k in cache.spilled_keys()] == ["t1", "t2"]
        assert not cache.is_spilled(hs[0])
        # a dropped spill is simply cold again: next lookup is a miss
        misses = cache.stats().misses
        cache.get_state(W, hs[0])
        assert cache.stats().misses == misses + 1

    def test_no_host_tier_is_the_legacy_cache(self, setup):
        """host_max_bytes=None (the default) keeps PR-4 semantics
        bitwise: evictions discard, is_spilled is always False, and the
        tier counters stay zero."""
        W, _ = setup
        cache = AdapterStateCache(_precompute, act_dtype=jnp.float32,
                                  fold_gsb=True, max_bytes=STATE_BYTES)
        hs = [cache.register(f"t{i}", _tenant(i)) for i in range(2)]
        cache.get_state(W, hs[0])
        cache.get_state(W, hs[1])
        st = cache.stats()
        assert st.evictions == 1 and st.spills == 0
        assert st.host_entries == 0 and st.host_bytes == 0
        assert not cache.is_spilled(hs[0])
        misses = st.misses
        cache.get_state(W, hs[0])                  # full precompute again
        assert cache.stats().misses == misses + 1
