"""Continuous-batching decode engine: the slot lifecycle (admission /
EOS / budget / max-len retirement), the join/leave-vs-alone oracle
equivalence, the fixed-shape compile-count acceptance, the zero-norm-work
decode jaxpr, per-row cache semantics, the arch rejection contracts, and
the 2-device subprocess mesh run.

Oracle contract: a request served MID-STREAM (joining a running batch,
sharing its decode step with strangers at other depths) must produce the
same greedy tokens as the same request served alone through
``generate()`` with the same adapter state — fp32-bitwise where the
grouped ≥2-row guarantee applies (single-handle slot tables run the
homogeneous gsB path; per-slot 1-row groups are allclose, see
docs/numerics.md).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AdapterStateCache, DoRAConfig
from repro.launch.engine import DecodeEngine
from repro.launch.serve import EngineServer, MultiTenantServer, Request, \
    generate
from repro.launch.steps import (StepConfig, make_decode_step,
                                make_prefill_into_slot_step)
from repro.launch.train import build_state
from repro.models import init_cache

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
ARCH = "qwen2-7b"


def _setup(tenants=1):
    mcfg = get_config(ARCH, smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg)
    for t in range(tenants):
        _, ad, _ = build_state(mcfg, DCFG, 10 + t)
        cache.register(f"t{t}", ad)
    return mcfg, scfg, params, cache


def _alone(mcfg, scfg, params, cache, prompt, gen_len, max_len, adapter):
    """The oracle: the same request served alone through generate()."""
    toks = np.asarray(generate(
        mcfg, params, cache.current_handle(adapter), scfg,
        np.asarray(prompt)[None], gen_len=gen_len, max_len=max_len,
        adapter_cache=cache))
    return toks[0, len(prompt):]


class TestSlotLifecycle:
    ML = 14

    def test_join_leave_oracle_equivalence(self):
        """ACCEPTANCE: 3 mixed-length requests through 2 slots — r1
        retires early, r2 joins the RUNNING batch — and every request's
        greedy tokens equal serving it alone through generate()."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=self.ML,
                          adapter_cache=cache)
        rng = np.random.default_rng(0)
        reqs = [(rng.integers(0, mcfg.vocab_size, P, dtype=np.int32), g)
                for P, g in [(5, 6), (6, 3), (4, 5)]]
        for p, g in reqs:
            eng.submit(p, adapter="t0", max_new_tokens=g)
        results = eng.run()
        assert [r.request_id for r in results] == [0, 1, 2]
        # r2 could only start after a retirement freed a slot
        assert results[2].admitted_step > results[1].finished_step \
            or results[2].admitted_step > results[0].finished_step
        for r, (p, g) in zip(results, reqs):
            assert r.finish_reason == "length"
            np.testing.assert_array_equal(
                r.tokens, _alone(mcfg, scfg, params, cache, p, g, self.ML,
                                 "t0"),
                err_msg=f"request {r.request_id} served mid-stream "
                        f"diverged from serving it alone")

    def test_streaming_and_prompt_roundtrip(self):
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=10,
                          adapter_cache=cache)
        rng = np.random.default_rng(1)
        p = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        rid = eng.submit(p, adapter="t0", max_new_tokens=3)
        seen = []
        results = eng.run(on_token=lambda r, t: seen.append((r, t)))
        np.testing.assert_array_equal(results[0].prompt, p)
        assert seen == [(rid, int(t)) for t in results[0].tokens]

    def test_admission_under_full_slot_table(self):
        """5 requests, 2 slots: the table never overflows, admission is
        FIFO, every request completes, and the queue drains through
        retirements (prefills == admissions == 5)."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=10,
                          adapter_cache=cache)
        rng = np.random.default_rng(2)
        reqs = [(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                 2 + (i % 3)) for i in range(5)]
        for p, g in reqs:
            eng.submit(p, adapter="t0", max_new_tokens=g)
        results = eng.run()
        st = eng.stats()
        assert st.prefills == st.admitted == st.retired == 5
        assert not eng.has_work()
        # FIFO admission: request i is never admitted before request i-1
        admits = [r.admitted_step for r in results]
        assert admits == sorted(admits)
        # never more than `slots` rows active in one decode step
        assert st.slot_steps <= 2 * st.decode_steps
        for r, (p, g) in zip(results, reqs):
            np.testing.assert_array_equal(
                r.tokens, _alone(mcfg, scfg, params, cache, p, g, 10, "t0"))

    def test_eos_retirement_frees_slot_for_waiting_request(self):
        """A request retiring on EOS stops early AND hands its row to the
        queue; the late joiner still matches its oracle."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(3)
        p0 = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        ref = _alone(mcfg, scfg, params, cache, p0, 6, 14, "t0")
        eos = int(ref[2])            # a mid-stream greedy token as EOS
        stop = int(np.where(ref == eos)[0][0])   # earliest occurrence
        assert stop < len(ref) - 1, "eos must cut generation short"
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=14,
                          adapter_cache=cache)
        eng.submit(p0, adapter="t0", max_new_tokens=6, eos_id=eos)
        p1 = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        eng.submit(p1, adapter="t0", max_new_tokens=3)
        r0, r1 = eng.run()
        assert r0.finish_reason == "eos"
        np.testing.assert_array_equal(r0.tokens, ref[:stop + 1])
        assert r1.admitted_step > r0.finished_step
        np.testing.assert_array_equal(
            r1.tokens, _alone(mcfg, scfg, params, cache, p1, 3, 14, "t0"))

    def test_max_len_retirement_caps_generation(self):
        """A budget larger than the cache bound retires at max_len with
        exactly max_len - P tokens (the row never writes out of bounds)."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(4)
        p = rng.integers(0, mcfg.vocab_size, 6, dtype=np.int32)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=10,
                          adapter_cache=cache)
        eng.submit(p, adapter="t0", max_new_tokens=50)
        (r,) = eng.run()
        assert r.finish_reason == "max_len"
        assert r.tokens.shape == (4,)       # max_len - P
        np.testing.assert_array_equal(
            r.tokens, _alone(mcfg, scfg, params, cache, p, 4, 10, "t0"))

    def test_single_token_budget_never_occupies_a_decode_row(self):
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(5)
        p = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=8,
                          adapter_cache=cache)
        eng.submit(p, adapter="t0", max_new_tokens=1)
        (r,) = eng.run()
        assert r.tokens.shape == (1,) and r.finish_reason == "length"
        assert eng.stats().decode_steps == 0
        np.testing.assert_array_equal(
            r.tokens, _alone(mcfg, scfg, params, cache, p, 1, 8, "t0"))

    def test_submit_contracts(self):
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=6,
                          adapter_cache=cache)
        with pytest.raises(ValueError, match="P \\+ 1 <= max_len"):
            eng.submit(np.zeros(6, np.int32), adapter="t0",
                       max_new_tokens=2)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(np.zeros(3, np.int32), adapter="t0",
                       max_new_tokens=0)
        with pytest.raises(ValueError, match="adapter id or handle"):
            eng.submit(np.zeros(3, np.int32), max_new_tokens=2)


class TestCompiledSurface:
    def test_compile_count_fixed_shape(self):
        """ACCEPTANCE: a join/leave trace over mixed prompt lengths and
        budgets compiles EXACTLY one (prefill-into-slot, decode) pair —
        slot index, prompt length and per-row depths are all traced."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=12,
                          adapter_cache=cache)
        rng = np.random.default_rng(6)
        for i in range(5):
            eng.submit(rng.integers(0, mcfg.vocab_size, 3 + i,
                                    dtype=np.int32),
                       adapter="t0", max_new_tokens=1 + (i % 3))
        eng.run()
        counts = eng.compile_counts()
        assert counts["prefill_into_slot"] == 1, counts
        assert counts["decode"] == {None: 1}, counts

    def test_multi_adapter_group_signatures_compile_once_each(self):
        """Mixed-handle slot tables compile one decode per grouping
        signature; re-serving the same mix reuses them all."""
        mcfg, scfg, params, cache = _setup(tenants=2)
        eng = DecodeEngine(mcfg, scfg, params, slots=4, max_len=12,
                          adapter_cache=cache)
        rng = np.random.default_rng(7)

        def serve_mix():
            for t in (0, 0, 1, 1):
                eng.submit(rng.integers(0, mcfg.vocab_size, 5,
                                        dtype=np.int32),
                           adapter=f"t{t}", max_new_tokens=4)
            return eng.run()

        serve_mix()
        counts1 = eng.compile_counts()
        serve_mix()
        assert eng.compile_counts() == counts1
        assert all(n == 1 for n in counts1["decode"].values()), counts1
        assert ((0, 2), (2, 2)) in counts1["decode"]

    def test_engine_decode_jaxpr_has_zero_norm_work(self):
        """ACCEPTANCE: the engine's decode step — per-row-length cache,
        folded serving state — contains zero ``dora_wnorm`` ops."""
        mcfg, scfg, params, cache = _setup()
        state = cache.get_state(params, cache.current_handle("t0"))
        dec_cache = init_cache(mcfg, 2, 8, row_lens=True)
        decode = make_decode_step(mcfg, scfg, None, batch=2)
        jaxpr = str(jax.make_jaxpr(decode)(
            params, state, dec_cache,
            {"tokens": jnp.zeros((2, 1), jnp.int32)}))
        assert "dora_wnorm" not in jaxpr

    def test_per_row_cache_lengths(self):
        """The cache's "len" is a [slots] vector with each row at its own
        depth: after a prefill at P and d decode writes, row j stands at
        P_j + d_j — fetched ONCE here for the assertion; the scheduler
        itself never reads it back (host mirrors only)."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=12,
                          adapter_cache=cache)
        rng = np.random.default_rng(8)
        # g=4: 3 decode writes; g=2: 1 decode write. Both admitted at
        # step 0, so slot 1 idles (len += 1 per decode step, garbage
        # rows) after its request retires — until the cache is reused.
        eng.submit(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                   adapter="t0", max_new_tokens=4)
        eng.submit(rng.integers(0, mcfg.vocab_size, 6, dtype=np.int32),
                   adapter="t0", max_new_tokens=2)
        eng.run()
        lens = np.asarray(eng.cache["len"])
        assert lens.shape == (2,)
        # slot 0: P=4, three decode writes -> 7
        assert lens[0] == 7, lens
        # slot 1: P=6 + one live write + one idle decode tick -> >= 7
        # (idle rows keep counting; re-admission rewinds via prefill)
        assert lens[1] >= 7, lens


class TestArchContracts:
    def test_ssm_arch_rejected_naming_the_reason(self):
        """SATELLITE: Mamba/SSM admission fails LOUDLY — the state
        integrates every token and cannot rewind to a slot's true prompt
        length."""
        mcfg = get_config("falcon-mamba-7b", smoke=True)
        scfg = StepConfig(dora=DCFG)
        params, adapters, _ = build_state(mcfg, DCFG, 0)
        with pytest.raises(NotImplementedError,
                           match="integrate every processed token"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8,
                         adapters=adapters)
        with pytest.raises(NotImplementedError, match="cannot rewind"):
            make_prefill_into_slot_step(mcfg, scfg, None, seq=8)

    def test_moe_arch_rejected(self):
        mcfg = get_config("qwen2-moe-a2.7b", smoke=True)
        scfg = StepConfig(dora=DCFG)
        params, adapters, _ = build_state(mcfg, DCFG, 0)
        with pytest.raises(NotImplementedError, match="couples batch rows"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8,
                         adapters=adapters)

    def test_engine_requires_exactly_one_adapter_source(self):
        """Neither source is an error; BOTH is too — a handle-less active
        slot would be indistinguishable from a free one in the grouping
        and silently decode under a neighbour's tenant state."""
        mcfg, scfg, params, cache = _setup()
        with pytest.raises(ValueError, match="not both, not neither"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8)
        state = cache.get_state(params, cache.current_handle("t0"))
        with pytest.raises(ValueError, match="not both, not neither"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8,
                         adapters=state, adapter_cache=cache)

    def test_failed_resolution_errors_request_without_wedging(self):
        """A stale handle hit at ADMISSION (tenant updated while the
        request waited) can NEVER re-resolve — versions only move
        forward — so the request is dropped WITH an errored result:
        never silently lost, never wedging the FIFO behind it."""
        from repro.core import AdapterCacheMiss
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=10,
                          adapter_cache=cache)
        rng = np.random.default_rng(12)
        stale = cache.current_handle("t0")
        _, ad_new, _ = build_state(mcfg, DCFG, 99)
        cache.update("t0", ad_new)          # stale's version is now behind
        p0 = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        p1 = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        eng.submit(p0, adapter=stale, max_new_tokens=2)
        eng.submit(p1, adapter="t0", max_new_tokens=2)   # current version
        r0, r1 = eng.run()
        assert r0.finish_reason == "error"
        assert isinstance(r0.error, AdapterCacheMiss)
        assert "stale adapter handle" in str(r0.error)
        assert r0.tokens.shape == (0,)
        # the request QUEUED BEHIND the stale one still served normally
        assert r1.finish_reason == "length" and r1.tokens.shape == (2,)
        assert not eng.has_work() and eng.stats().admitted == 1

    def test_run_delivers_results_exactly_once(self):
        """The engine persists across run() calls (EngineServer /
        MultiTenantServer reuse it): results are handed over once, not
        retained forever."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=8,
                          adapter_cache=cache)
        rng = np.random.default_rng(13)
        eng.submit(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                   adapter="t0", max_new_tokens=2)
        first = eng.run()
        assert len(first) == 1
        assert eng.results() == [] and eng.run() == []
        eng.submit(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                   adapter="t0", max_new_tokens=2)
        second = eng.run()
        assert [r.request_id for r in second] == [1]

    def test_cache_mesh_fingerprint_mismatch_rejected(self):
        from repro.launch.mesh import make_debug_mesh
        mcfg, scfg, params, cache = _setup()     # cache keyed mesh=None
        mesh = make_debug_mesh(1, 1)
        with pytest.raises(ValueError, match="keyed for sharding"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8,
                         adapter_cache=cache, mesh=mesh)


class TestEngineServer:
    def test_mixed_lengths_and_adapters_match_oracle(self):
        """EngineServer.run: mixed prompt lengths AND mixed adapters in
        one slot table; every request matches its generate() oracle."""
        mcfg, scfg, params, cache = _setup(tenants=2)
        server = EngineServer(mcfg, scfg, params, cache=cache, slots=3,
                              max_len=14)
        rng = np.random.default_rng(9)
        reqs, meta = [], []
        for i, (P, t) in enumerate([(5, 0), (7, 1), (4, 0), (6, 1)]):
            p = rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
            reqs.append(Request(p, f"t{t}"))
            meta.append((p, f"t{t}"))
        results = server.run(reqs, gen_len=4)
        for r, (p, t) in zip(results, meta):
            np.testing.assert_array_equal(
                r.tokens, _alone(mcfg, scfg, params, cache, p, 4, 14, t),
                err_msg=f"request {r.request_id} ({t})")
        assert server.engine.stats().mean_occupancy > 0.5

    def test_multitenant_server_routes_mixed_lengths_through_engine(self):
        """SATELLITE: MultiTenantServer.serve admits mixed-length batches
        via the engine (list of ragged rows, each matching its oracle);
        static=True keeps the legacy length-bucket error."""
        mcfg, scfg, params, cache = _setup(tenants=2)
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        rng = np.random.default_rng(10)
        reqs = [Request(rng.integers(0, mcfg.vocab_size, P,
                                     dtype=np.int32), f"t{t}")
                for P, t in [(5, 0), (7, 1), (6, 0)]]
        out = server.serve(reqs, gen_len=3, max_len=12)
        assert isinstance(out, list)
        for row, r in zip(out, reqs):
            p = np.asarray(r.prompt)
            np.testing.assert_array_equal(row[:len(p)], p)
            np.testing.assert_array_equal(
                row[len(p):],
                _alone(mcfg, scfg, params, cache, p, 3, 12, r.adapter))
        with pytest.raises(ValueError, match="length bucket"):
            server.serve(reqs, gen_len=3, max_len=12, static=True)
        with pytest.raises(ValueError, match="return_logits"):
            server.serve(reqs, gen_len=3, max_len=12, return_logits=True)

    def test_failed_serve_does_not_poison_the_cached_engine(self):
        """A serve() that raises on a stale handle must leave the CACHED
        engine servable: the next call with only valid adapters works
        (regression: the stale request used to stay queued forever)."""
        from repro.core import AdapterCacheMiss
        mcfg, scfg, params, cache = _setup(tenants=2)
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        rng = np.random.default_rng(14)
        stale = cache.current_handle("t0")
        _, ad_new, _ = build_state(mcfg, DCFG, 98)
        cache.update("t0", ad_new)
        bad = [Request(rng.integers(0, mcfg.vocab_size, 5,
                                    dtype=np.int32), stale),
               Request(rng.integers(0, mcfg.vocab_size, 6,
                                    dtype=np.int32), "t1")]
        with pytest.raises(AdapterCacheMiss, match="stale"):
            server.serve(bad, gen_len=2, max_len=10)
        good = [Request(rng.integers(0, mcfg.vocab_size, 5,
                                     dtype=np.int32), "t0"),
                Request(rng.integers(0, mcfg.vocab_size, 6,
                                     dtype=np.int32), "t1")]
        out = server.serve(good, gen_len=2, max_len=10)
        assert [len(o) for o in out] == [7, 8]

    def test_bad_request_mid_batch_queues_nothing(self):
        """All-or-nothing submission: a request that fails validation in
        the MIDDLE of a batch (unregistered adapter id / oversized
        prompt) fails the whole call before anything is queued — no
        orphans stealing slots from (or streaming into) the next call."""
        mcfg, scfg, params, cache = _setup()
        server = EngineServer(mcfg, scfg, params, cache=cache, slots=2,
                              max_len=10)
        rng = np.random.default_rng(16)
        ok = Request(rng.integers(0, mcfg.vocab_size, 5,
                                  dtype=np.int32), "t0")
        with pytest.raises(KeyError, match="not registered"):
            server.run([ok, Request(ok.prompt, "typo-id")], gen_len=2)
        with pytest.raises(ValueError, match="P \\+ 1 <= max_len"):
            server.run([ok, Request(np.zeros(10, np.int32), "t0")],
                       gen_len=2)
        assert not server.engine.has_work()
        seen = []
        results = server.run([ok], gen_len=2,
                             on_token=lambda r, t: seen.append(r))
        assert len(results) == 1 and results[0].tokens.shape == (2,)
        # only the surviving call's request ever streamed
        assert set(seen) == {results[0].request_id}

    def test_mixed_length_temperature_reproducible_across_calls(self):
        """Sampling keys fold in the request's index within the CALL, so
        repeated serves through the persistent cached engine reproduce
        their tokens (the engine's global request ids keep growing)."""
        mcfg, scfg, params, cache = _setup()
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        rng = np.random.default_rng(15)
        reqs = [Request(rng.integers(0, mcfg.vocab_size, P,
                                     dtype=np.int32), "t0")
                for P in (5, 7)]
        out1 = server.serve(reqs, gen_len=3, max_len=12, temperature=0.9,
                            seed=5)
        out2 = server.serve(reqs, gen_len=3, max_len=12, temperature=0.9,
                            seed=5)
        for a, b in zip(out1, out2):
            np.testing.assert_array_equal(a, b)

    def test_uniform_lengths_forced_through_engine_match_static(self):
        """static=False on a uniform-length batch: engine tokens equal
        the static path's tokens (same greedy math, different scheduler)."""
        mcfg, scfg, params, cache = _setup()
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        rng = np.random.default_rng(11)
        reqs = [Request(rng.integers(0, mcfg.vocab_size, 6,
                                     dtype=np.int32), "t0")
                for _ in range(3)]
        static = np.asarray(server.serve(reqs, gen_len=3, max_len=10))
        cont = server.serve(reqs, gen_len=3, max_len=10, static=False)
        for row, srow in zip(cont, static):
            np.testing.assert_array_equal(row, srow)


# ---------------------------------------------------------------------------
# Forced 2-device mesh (subprocess): join/leave trace under SPMD.
# ---------------------------------------------------------------------------

def _run_subprocess(code: str, devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    env.pop("REPRO_FORCE_TIER", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


_ENGINE_SPMD = """
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import AdapterStateCache, DoRAConfig
    from repro.launch.engine import DecodeEngine
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.serve import generate
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    assert jax.device_count() == 2
    mesh = make_debug_mesh(2, 1)     # slots shard over the data axis
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
    mcfg = get_config("qwen2-7b", smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg, mesh)
    _, ad, _ = build_state(mcfg, DCFG, 10)
    cache.register("t0", ad)

    ML = 12
    eng = DecodeEngine(mcfg, scfg, params, slots=4, max_len=ML,
                       adapter_cache=cache, mesh=mesh)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, mcfg.vocab_size, P, dtype=np.int32), g)
            for P, g in [(5, 5), (6, 2), (4, 4), (5, 3), (6, 4)]]
    for p, g in reqs:
        eng.submit(p, adapter="t0", max_new_tokens=g)
    results = eng.run()
    counts = eng.compile_counts()
    assert counts["prefill_into_slot"] == 1, counts
    assert counts["decode"] == {None: 1}, counts
    for r, (p, g) in zip(results, reqs):
        ref = np.asarray(generate(mcfg, params, cache.current_handle("t0"),
                                  scfg, p[None], gen_len=g, max_len=ML,
                                  adapter_cache=cache, mesh=mesh))
        assert np.array_equal(r.tokens, ref[0, len(p):]), r.request_id
    print("ENGINE_SPMD_OK")
"""


@pytest.mark.slow
def test_engine_spmd_join_leave():
    """Acceptance on a forced 2-device CPU mesh: a join/leave trace
    through slots sharded over the data axis serves every request the
    same greedy tokens as generate() alone under the same mesh, with one
    compiled (prefill, decode) pair."""
    out = _run_subprocess(_ENGINE_SPMD, 2)
    assert "ENGINE_SPMD_OK" in out, out
