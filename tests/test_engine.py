"""Continuous-batching decode engine: the slot lifecycle (admission /
EOS / budget / max-len retirement), the join/leave-vs-alone oracle
equivalence, the fixed-shape compile-count acceptance, the zero-norm-work
decode jaxpr, per-row cache semantics, the arch rejection contracts, and
the 2-device subprocess mesh run.

Oracle contract: a request served MID-STREAM (joining a running batch,
sharing its decode step with strangers at other depths) must produce the
same greedy tokens as the same request served alone through
``generate()`` with the same adapter state — fp32-bitwise where the
grouped ≥2-row guarantee applies (single-handle slot tables run the
homogeneous gsB path; per-slot 1-row groups are allclose, see
docs/numerics.md).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AdapterStateCache, DoRAConfig
from repro.launch.engine import DecodeEngine
from repro.launch.serve import EngineServer, MultiTenantServer, Request, \
    generate
from repro.launch.steps import (StepConfig, make_decode_step,
                                make_draft_step,
                                make_prefill_into_slot_step,
                                make_verify_step)
from repro.launch.train import build_state
from repro.models import init_cache

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
ARCH = "qwen2-7b"


def _setup(tenants=1):
    mcfg = get_config(ARCH, smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg)
    for t in range(tenants):
        _, ad, _ = build_state(mcfg, DCFG, 10 + t)
        cache.register(f"t{t}", ad)
    return mcfg, scfg, params, cache


def _perturb(adapters, seed, scale=0.1):
    """Non-identity variant of an adapter tree: inject random B leaves
    (A/m keep their seed values). Seed-built trees have B == 0, so every
    version would otherwise stream identical tokens — useless for
    distinguishing pinned-version from current-version. The mild default
    scale keeps the adapted model CLOSE to base: speculative drafts are
    then right sometimes and wrong sometimes, which is exactly what the
    oracle tests need (bitwise equality through real rejections)."""
    key = jax.random.PRNGKey(seed)
    cnt = [0]

    def f(path, leaf):
        cnt[0] += 1
        if "'B'" in "/".join(str(p) for p in path):
            return jax.random.normal(jax.random.fold_in(key, cnt[0]),
                                     leaf.shape, leaf.dtype) * scale
        return leaf
    return jax.tree_util.tree_map_with_path(f, adapters)


def _alone(mcfg, scfg, params, cache, prompt, gen_len, max_len, adapter):
    """The oracle: the same request served alone through generate()."""
    toks = np.asarray(generate(
        mcfg, params, cache.current_handle(adapter), scfg,
        np.asarray(prompt)[None], gen_len=gen_len, max_len=max_len,
        adapter_cache=cache))
    return toks[0, len(prompt):]


class TestSlotLifecycle:
    ML = 14

    def test_join_leave_oracle_equivalence(self):
        """ACCEPTANCE: 3 mixed-length requests through 2 slots — r1
        retires early, r2 joins the RUNNING batch — and every request's
        greedy tokens equal serving it alone through generate()."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=self.ML,
                          adapter_cache=cache)
        rng = np.random.default_rng(0)
        reqs = [(rng.integers(0, mcfg.vocab_size, P, dtype=np.int32), g)
                for P, g in [(5, 6), (6, 3), (4, 5)]]
        for p, g in reqs:
            eng.submit(p, adapter="t0", max_new_tokens=g)
        results = eng.run()
        assert [r.request_id for r in results] == [0, 1, 2]
        # r2 could only start after a retirement freed a slot
        assert results[2].admitted_step > results[1].finished_step \
            or results[2].admitted_step > results[0].finished_step
        for r, (p, g) in zip(results, reqs):
            assert r.finish_reason == "length"
            np.testing.assert_array_equal(
                r.tokens, _alone(mcfg, scfg, params, cache, p, g, self.ML,
                                 "t0"),
                err_msg=f"request {r.request_id} served mid-stream "
                        f"diverged from serving it alone")

    def test_streaming_and_prompt_roundtrip(self):
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=10,
                          adapter_cache=cache)
        rng = np.random.default_rng(1)
        p = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        rid = eng.submit(p, adapter="t0", max_new_tokens=3)
        seen = []
        results = eng.run(on_token=lambda r, t: seen.append((r, t)))
        np.testing.assert_array_equal(results[0].prompt, p)
        assert seen == [(rid, int(t)) for t in results[0].tokens]

    def test_admission_under_full_slot_table(self):
        """5 requests, 2 slots: the table never overflows, admission is
        FIFO, every request completes, and the queue drains through
        retirements (prefills == admissions == 5)."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=10,
                          adapter_cache=cache)
        rng = np.random.default_rng(2)
        reqs = [(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                 2 + (i % 3)) for i in range(5)]
        for p, g in reqs:
            eng.submit(p, adapter="t0", max_new_tokens=g)
        results = eng.run()
        st = eng.stats()
        assert st.prefills == st.admitted == st.retired == 5
        assert not eng.has_work()
        # FIFO admission: request i is never admitted before request i-1
        admits = [r.admitted_step for r in results]
        assert admits == sorted(admits)
        # never more than `slots` rows active in one decode step
        assert st.slot_steps <= 2 * st.decode_steps
        for r, (p, g) in zip(results, reqs):
            np.testing.assert_array_equal(
                r.tokens, _alone(mcfg, scfg, params, cache, p, g, 10, "t0"))

    def test_eos_retirement_frees_slot_for_waiting_request(self):
        """A request retiring on EOS stops early AND hands its row to the
        queue; the late joiner still matches its oracle."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(3)
        p0 = rng.integers(0, mcfg.vocab_size, 5, dtype=np.int32)
        ref = _alone(mcfg, scfg, params, cache, p0, 6, 14, "t0")
        eos = int(ref[2])            # a mid-stream greedy token as EOS
        stop = int(np.where(ref == eos)[0][0])   # earliest occurrence
        assert stop < len(ref) - 1, "eos must cut generation short"
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=14,
                          adapter_cache=cache)
        eng.submit(p0, adapter="t0", max_new_tokens=6, eos_id=eos)
        p1 = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        eng.submit(p1, adapter="t0", max_new_tokens=3)
        r0, r1 = eng.run()
        assert r0.finish_reason == "eos"
        np.testing.assert_array_equal(r0.tokens, ref[:stop + 1])
        assert r1.admitted_step > r0.finished_step
        np.testing.assert_array_equal(
            r1.tokens, _alone(mcfg, scfg, params, cache, p1, 3, 14, "t0"))

    def test_max_len_retirement_caps_generation(self):
        """A budget larger than the cache bound retires at max_len with
        exactly max_len - P tokens (the row never writes out of bounds)."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(4)
        p = rng.integers(0, mcfg.vocab_size, 6, dtype=np.int32)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=10,
                          adapter_cache=cache)
        eng.submit(p, adapter="t0", max_new_tokens=50)
        (r,) = eng.run()
        assert r.finish_reason == "max_len"
        assert r.tokens.shape == (4,)       # max_len - P
        np.testing.assert_array_equal(
            r.tokens, _alone(mcfg, scfg, params, cache, p, 4, 10, "t0"))

    def test_single_token_budget_never_occupies_a_decode_row(self):
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(5)
        p = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=8,
                          adapter_cache=cache)
        eng.submit(p, adapter="t0", max_new_tokens=1)
        (r,) = eng.run()
        assert r.tokens.shape == (1,) and r.finish_reason == "length"
        assert eng.stats().decode_steps == 0
        np.testing.assert_array_equal(
            r.tokens, _alone(mcfg, scfg, params, cache, p, 1, 8, "t0"))

    def test_submit_contracts(self):
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=6,
                          adapter_cache=cache)
        with pytest.raises(ValueError, match="P \\+ 1 <= max_len"):
            eng.submit(np.zeros(6, np.int32), adapter="t0",
                       max_new_tokens=2)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(np.zeros(3, np.int32), adapter="t0",
                       max_new_tokens=0)
        with pytest.raises(ValueError, match="adapter id or handle"):
            eng.submit(np.zeros(3, np.int32), max_new_tokens=2)


class TestCompiledSurface:
    def test_compile_count_fixed_shape(self):
        """ACCEPTANCE: a join/leave trace over mixed prompt lengths and
        budgets compiles EXACTLY one (prefill-into-slot, decode) pair —
        slot index, prompt length and per-row depths are all traced."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=12,
                          adapter_cache=cache)
        rng = np.random.default_rng(6)
        for i in range(5):
            eng.submit(rng.integers(0, mcfg.vocab_size, 3 + i,
                                    dtype=np.int32),
                       adapter="t0", max_new_tokens=1 + (i % 3))
        eng.run()
        counts = eng.compile_counts()
        assert counts["prefill_into_slot"] == 1, counts
        assert counts["decode"] == {None: 1}, counts

    def test_multi_adapter_group_signatures_compile_once_each(self):
        """Mixed-handle slot tables compile one decode per grouping
        signature; re-serving the same mix reuses them all."""
        mcfg, scfg, params, cache = _setup(tenants=2)
        eng = DecodeEngine(mcfg, scfg, params, slots=4, max_len=12,
                          adapter_cache=cache)
        rng = np.random.default_rng(7)

        def serve_mix():
            for t in (0, 0, 1, 1):
                eng.submit(rng.integers(0, mcfg.vocab_size, 5,
                                        dtype=np.int32),
                           adapter=f"t{t}", max_new_tokens=4)
            return eng.run()

        serve_mix()
        counts1 = eng.compile_counts()
        serve_mix()
        assert eng.compile_counts() == counts1
        assert all(n == 1 for n in counts1["decode"].values()), counts1
        assert ((0, 2), (2, 2)) in counts1["decode"]

    def test_engine_decode_jaxpr_has_zero_norm_work(self):
        """ACCEPTANCE: the engine's decode step — per-row-length cache,
        folded serving state — contains zero ``dora_wnorm`` ops."""
        mcfg, scfg, params, cache = _setup()
        state = cache.get_state(params, cache.current_handle("t0"))
        dec_cache = init_cache(mcfg, 2, 8, row_lens=True)
        decode = make_decode_step(mcfg, scfg, None, batch=2)
        jaxpr = str(jax.make_jaxpr(decode)(
            params, state, dec_cache,
            {"tokens": jnp.zeros((2, 1), jnp.int32)}))
        assert "dora_wnorm" not in jaxpr

    def test_per_row_cache_lengths(self):
        """The cache's "len" is a [slots] vector with each row at its own
        depth: after a prefill at P and d decode writes, row j stands at
        P_j + d_j — fetched ONCE here for the assertion; the scheduler
        itself never reads it back (host mirrors only)."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=12,
                          adapter_cache=cache)
        rng = np.random.default_rng(8)
        # g=4: 3 decode writes; g=2: 1 decode write. Both admitted at
        # step 0, so slot 1 idles (len += 1 per decode step, garbage
        # rows) after its request retires — until the cache is reused.
        eng.submit(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                   adapter="t0", max_new_tokens=4)
        eng.submit(rng.integers(0, mcfg.vocab_size, 6, dtype=np.int32),
                   adapter="t0", max_new_tokens=2)
        eng.run()
        lens = np.asarray(eng.cache["len"])
        assert lens.shape == (2,)
        # slot 0: P=4, three decode writes -> 7
        assert lens[0] == 7, lens
        # slot 1: P=6 + one live write + one idle decode tick -> >= 7
        # (idle rows keep counting; re-admission rewinds via prefill)
        assert lens[1] >= 7, lens


class TestArchContracts:
    def test_ssm_arch_rejected_naming_the_reason(self):
        """SATELLITE: Mamba/SSM admission fails LOUDLY — the state
        integrates every token and cannot rewind to a slot's true prompt
        length."""
        mcfg = get_config("falcon-mamba-7b", smoke=True)
        scfg = StepConfig(dora=DCFG)
        params, adapters, _ = build_state(mcfg, DCFG, 0)
        with pytest.raises(NotImplementedError,
                           match="integrate every processed token"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8,
                         adapters=adapters)
        with pytest.raises(NotImplementedError, match="cannot rewind"):
            make_prefill_into_slot_step(mcfg, scfg, None, seq=8)

    def test_moe_arch_rejected(self):
        mcfg = get_config("qwen2-moe-a2.7b", smoke=True)
        scfg = StepConfig(dora=DCFG)
        params, adapters, _ = build_state(mcfg, DCFG, 0)
        with pytest.raises(NotImplementedError, match="couples batch rows"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8,
                         adapters=adapters)

    def test_engine_requires_exactly_one_adapter_source(self):
        """Neither source is an error; BOTH is too — a handle-less active
        slot would be indistinguishable from a free one in the grouping
        and silently decode under a neighbour's tenant state."""
        mcfg, scfg, params, cache = _setup()
        with pytest.raises(ValueError, match="not both, not neither"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8)
        state = cache.get_state(params, cache.current_handle("t0"))
        with pytest.raises(ValueError, match="not both, not neither"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8,
                         adapters=state, adapter_cache=cache)

    def test_stale_handle_fails_at_submit_without_wedging(self):
        """A handle that is ALREADY stale at submission can NEVER
        resolve — versions only move forward — and submit is where the
        serving tree gets pinned, so it raises right there: nothing is
        queued, nothing wedges, and the engine keeps serving."""
        from repro.core import AdapterCacheMiss
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=10,
                          adapter_cache=cache)
        rng = np.random.default_rng(12)
        stale = cache.current_handle("t0")
        _, ad_new, _ = build_state(mcfg, DCFG, 99)
        cache.update("t0", ad_new)          # stale's version is now behind
        p0 = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        p1 = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        with pytest.raises(AdapterCacheMiss, match="stale adapter handle"):
            eng.submit(p0, adapter=stale, max_new_tokens=2)
        assert not eng.has_work()            # the failed submit queued nothing
        eng.submit(p1, adapter="t0", max_new_tokens=2)   # current version
        (r1,) = eng.run()
        assert r1.finish_reason == "length" and r1.tokens.shape == (2,)
        assert not eng.has_work() and eng.stats().admitted == 1

    def test_update_mid_request_keeps_the_submitted_version_pinned(self):
        """ACCEPTANCE: the serving tree is pinned at SUBMIT. An
        AdapterStateCache.update() landing while requests are in flight
        — one decoding in its slot, one still QUEUED behind it — must
        neither error them nor re-route them: both stream the tokens of
        the version they were submitted against, and only the NEXT
        submission picks up the bumped version."""
        mcfg, scfg, params, cache = _setup()
        _, ad, _ = build_state(mcfg, DCFG, 50)
        # Seed-registered adapters have B == 0 (identity); install two
        # genuinely different non-identity versions so re-routing a
        # pinned request would actually change its stream.
        old_h = cache.update("t0", _perturb(ad, 1))
        rng = np.random.default_rng(21)
        prompts = [rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
                   for _ in range(3)]
        # v-old oracles, computed while that version is still current
        want_old = [_alone(mcfg, scfg, params, cache, p, 3, 12, "t0")
                    for p in prompts[:2]]
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=12,
                          adapter_cache=cache)
        eng.submit(prompts[0], adapter="t0", max_new_tokens=3)
        eng.submit(prompts[1], adapter="t0", max_new_tokens=3)
        eng.step()                  # admits r0 only; r1 waits in the FIFO
        new_h = cache.update("t0", _perturb(ad, 2))     # mid-request bump
        assert new_h.version == old_h.version + 1
        eng.submit(prompts[2], adapter="t0", max_new_tokens=3)
        want_new = _alone(mcfg, scfg, params, cache, prompts[2], 3, 12,
                          "t0")
        r0, r1, r2 = eng.run()
        assert [r.finish_reason for r in (r0, r1, r2)] == ["length"] * 3
        # the running AND the queued pre-update requests kept v-old ...
        np.testing.assert_array_equal(r0.tokens, want_old[0])
        np.testing.assert_array_equal(r1.tokens, want_old[1])
        # ... and the post-update submission serves v-new
        np.testing.assert_array_equal(r2.tokens, want_new)
        assert (want_old[1].tolist() != want_new.tolist()
                or want_old[0].tolist() != want_new.tolist()), \
            "perturbed versions produced identical streams; the pinning " \
            "assertion above is vacuous — pick different perturbations"

    def test_run_delivers_results_exactly_once(self):
        """The engine persists across run() calls (EngineServer /
        MultiTenantServer reuse it): results are handed over once, not
        retained forever."""
        mcfg, scfg, params, cache = _setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=8,
                          adapter_cache=cache)
        rng = np.random.default_rng(13)
        eng.submit(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                   adapter="t0", max_new_tokens=2)
        first = eng.run()
        assert len(first) == 1
        assert eng.results() == [] and eng.run() == []
        eng.submit(rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32),
                   adapter="t0", max_new_tokens=2)
        second = eng.run()
        assert [r.request_id for r in second] == [1]

    def test_cache_mesh_fingerprint_mismatch_rejected(self):
        from repro.launch.mesh import make_debug_mesh
        mcfg, scfg, params, cache = _setup()     # cache keyed mesh=None
        mesh = make_debug_mesh(1, 1)
        with pytest.raises(ValueError, match="keyed for sharding"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=8,
                         adapter_cache=cache, mesh=mesh)


class TestEngineServer:
    def test_mixed_lengths_and_adapters_match_oracle(self):
        """EngineServer.run: mixed prompt lengths AND mixed adapters in
        one slot table; every request matches its generate() oracle."""
        mcfg, scfg, params, cache = _setup(tenants=2)
        server = EngineServer(mcfg, scfg, params, cache=cache, slots=3,
                              max_len=14)
        rng = np.random.default_rng(9)
        reqs, meta = [], []
        for i, (P, t) in enumerate([(5, 0), (7, 1), (4, 0), (6, 1)]):
            p = rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
            reqs.append(Request(p, f"t{t}"))
            meta.append((p, f"t{t}"))
        results = server.run(reqs, gen_len=4)
        for r, (p, t) in zip(results, meta):
            np.testing.assert_array_equal(
                r.tokens, _alone(mcfg, scfg, params, cache, p, 4, 14, t),
                err_msg=f"request {r.request_id} ({t})")
        assert server.engine.stats().mean_occupancy > 0.5

    def test_multitenant_server_routes_mixed_lengths_through_engine(self):
        """SATELLITE: MultiTenantServer.serve admits mixed-length batches
        via the engine (list of ragged rows, each matching its oracle);
        static=True keeps the legacy length-bucket error."""
        mcfg, scfg, params, cache = _setup(tenants=2)
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        rng = np.random.default_rng(10)
        reqs = [Request(rng.integers(0, mcfg.vocab_size, P,
                                     dtype=np.int32), f"t{t}")
                for P, t in [(5, 0), (7, 1), (6, 0)]]
        out = server.serve(reqs, gen_len=3, max_len=12)
        assert isinstance(out, list)
        for row, r in zip(out, reqs):
            p = np.asarray(r.prompt)
            np.testing.assert_array_equal(row[:len(p)], p)
            np.testing.assert_array_equal(
                row[len(p):],
                _alone(mcfg, scfg, params, cache, p, 3, 12, r.adapter))
        with pytest.raises(ValueError, match="length bucket"):
            server.serve(reqs, gen_len=3, max_len=12, static=True)
        with pytest.raises(ValueError, match="return_logits"):
            server.serve(reqs, gen_len=3, max_len=12, return_logits=True)

    def test_failed_serve_does_not_poison_the_cached_engine(self):
        """A serve() that raises on a stale handle must leave the CACHED
        engine servable: the next call with only valid adapters works
        (regression: the stale request used to stay queued forever)."""
        from repro.core import AdapterCacheMiss
        mcfg, scfg, params, cache = _setup(tenants=2)
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        rng = np.random.default_rng(14)
        stale = cache.current_handle("t0")
        _, ad_new, _ = build_state(mcfg, DCFG, 98)
        cache.update("t0", ad_new)
        bad = [Request(rng.integers(0, mcfg.vocab_size, 5,
                                    dtype=np.int32), stale),
               Request(rng.integers(0, mcfg.vocab_size, 6,
                                    dtype=np.int32), "t1")]
        with pytest.raises(AdapterCacheMiss, match="stale"):
            server.serve(bad, gen_len=2, max_len=10)
        good = [Request(rng.integers(0, mcfg.vocab_size, 5,
                                     dtype=np.int32), "t0"),
                Request(rng.integers(0, mcfg.vocab_size, 6,
                                     dtype=np.int32), "t1")]
        out = server.serve(good, gen_len=2, max_len=10)
        assert [len(o) for o in out] == [7, 8]

    def test_bad_request_mid_batch_queues_nothing(self):
        """All-or-nothing submission: a request that fails validation in
        the MIDDLE of a batch (unregistered adapter id / oversized
        prompt) fails the whole call before anything is queued — no
        orphans stealing slots from (or streaming into) the next call."""
        mcfg, scfg, params, cache = _setup()
        server = EngineServer(mcfg, scfg, params, cache=cache, slots=2,
                              max_len=10)
        rng = np.random.default_rng(16)
        ok = Request(rng.integers(0, mcfg.vocab_size, 5,
                                  dtype=np.int32), "t0")
        with pytest.raises(KeyError, match="not registered"):
            server.run([ok, Request(ok.prompt, "typo-id")], gen_len=2)
        with pytest.raises(ValueError, match="P \\+ 1 <= max_len"):
            server.run([ok, Request(np.zeros(10, np.int32), "t0")],
                       gen_len=2)
        assert not server.engine.has_work()
        seen = []
        results = server.run([ok], gen_len=2,
                             on_token=lambda r, t: seen.append(r))
        assert len(results) == 1 and results[0].tokens.shape == (2,)
        # only the surviving call's request ever streamed
        assert set(seen) == {results[0].request_id}

    def test_mixed_length_temperature_reproducible_across_calls(self):
        """Sampling keys fold in the request's index within the CALL, so
        repeated serves through the persistent cached engine reproduce
        their tokens (the engine's global request ids keep growing)."""
        mcfg, scfg, params, cache = _setup()
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        rng = np.random.default_rng(15)
        reqs = [Request(rng.integers(0, mcfg.vocab_size, P,
                                     dtype=np.int32), "t0")
                for P in (5, 7)]
        out1 = server.serve(reqs, gen_len=3, max_len=12, temperature=0.9,
                            seed=5)
        out2 = server.serve(reqs, gen_len=3, max_len=12, temperature=0.9,
                            seed=5)
        for a, b in zip(out1, out2):
            np.testing.assert_array_equal(a, b)

    def test_uniform_lengths_forced_through_engine_match_static(self):
        """static=False on a uniform-length batch: engine tokens equal
        the static path's tokens (same greedy math, different scheduler)."""
        mcfg, scfg, params, cache = _setup()
        server = MultiTenantServer(mcfg, scfg, params, cache=cache)
        rng = np.random.default_rng(11)
        reqs = [Request(rng.integers(0, mcfg.vocab_size, 6,
                                     dtype=np.int32), "t0")
                for _ in range(3)]
        static = np.asarray(server.serve(reqs, gen_len=3, max_len=10))
        cont = server.serve(reqs, gen_len=3, max_len=10, static=False)
        for row, srow in zip(cont, static):
            np.testing.assert_array_equal(row, srow)


# The committed join/leave arrival trace — (arrival_step, P, gen_len)
# literals of make_arrival_trace(n_requests=12, mean_interarrival=2.0,
# prompt_len=8, gen_lens=(4, 6, 8, 10), seed=0), i.e. exactly the trace
# the BENCH_serve.json "speculative" section is gated on.
_TRACE = [(1, 8, 8), (1, 8, 6), (1, 8, 4), (4, 8, 10), (6, 8, 10),
          (11, 8, 8), (23, 8, 6), (23, 8, 10), (28, 8, 8), (30, 8, 4),
          (32, 8, 4), (32, 8, 10)]


def _drive_trace(eng, prompts, adapters):
    """Feed _TRACE into a persistent engine tick-by-tick; returns the
    {request_id: [token, ...]} STREAMS exactly as on_token emitted them
    (order within a request matters: speculative verify must release
    accepted tokens in sequence, not just end with the right array)."""
    streams: dict[int, list[int]] = {}

    def on_token(rid, tok):
        streams.setdefault(rid, []).append(tok)

    i, step = 0, 0
    while i < len(_TRACE) or eng.has_work():
        while i < len(_TRACE) and _TRACE[i][0] <= step:
            eng.submit(prompts[i], adapter=adapters[i],
                       max_new_tokens=_TRACE[i][2], key_id=i)
            i += 1
        eng.step(on_token)
        step += 1
    return streams


class TestSpeculative:
    """Speculative decode: adapter-free drafts + one batched full-DoRA
    verify per tick, rewinding each row's cache to the accepted frontier.
    The greedy contract is BITWISE: speculative streams equal plain
    decode streams token-for-token, whatever the accept rate."""
    ML = 18
    K = 3

    def _spec_setup(self, tenants=1):
        mcfg, scfg, params, cache = _setup(tenants=tenants)
        # Seed-built adapters have B == 0: the base-path draft would then
        # BE the full path and every draft would be accepted trivially.
        # Perturbed non-identity adapters make verify actually reject.
        for t in range(tenants):
            _, ad, _ = build_state(mcfg, DCFG, 10 + t)
            cache.update(f"t{t}", _perturb(ad, 7 + t))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
                   for _, P, _ in _TRACE]
        return mcfg, scfg, params, cache, prompts

    def test_speculative_streams_equal_plain_bitwise(self):
        """ACCEPTANCE: over the committed arrival trace, a speculative
        engine (k=3) streams exactly the tokens the plain engine does,
        per request, in order — while actually speculating (verify ticks
        ran, drafts were both accepted and rejected)."""
        mcfg, scfg, params, cache, prompts = self._spec_setup()
        ads = ["t0"] * len(_TRACE)
        spec = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                            adapter_cache=cache, speculative_k=self.K)
        plain = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                             adapter_cache=cache)
        got = _drive_trace(spec, prompts, ads)
        want = _drive_trace(plain, prompts, ads)
        assert got == want
        st = spec.stats()
        ps = plain.stats()
        assert st.generated_tokens == ps.generated_tokens
        # it really speculated: k drafts per verify tick, and the
        # full-DoRA step count (verify + fallback decode) needs at most
        # plain decode's steps and FEWER than the tokens plain emits —
        # the artifact gate's win condition (scripts/check_bench_drift)
        assert st.verify_steps > 0
        assert st.draft_steps == self.K * st.verify_steps
        assert st.verify_steps + st.decode_steps <= ps.decode_steps
        assert st.verify_steps + st.decode_steps < ps.generated_tokens
        # non-identity adapters make some drafts wrong: the oracle above
        # must hold THROUGH rejections, not because everything matched
        assert 0 < st.accepted_drafts < st.draft_steps, st

    def test_speculative_temperature_falls_back_to_plain(self):
        """temperature > 0 silently disables speculation (the drafts
        would bias the sample stream): the engine runs plain decode and
        the speculative counters stay zero."""
        mcfg, scfg, params, cache, prompts = self._spec_setup()
        eng = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                           adapter_cache=cache, speculative_k=self.K,
                           temperature=0.7, seed=5)
        got = _drive_trace(eng, prompts, ["t0"] * len(_TRACE))
        st = eng.stats()
        assert st.verify_steps == 0 and st.draft_steps == 0
        assert st.decode_steps > 0
        assert sum(len(v) for v in got.values()) == st.generated_tokens

    def test_speculative_compile_surface(self):
        """ACCEPTANCE: one compiled (draft, verify) pair per (slots,
        max_len, k, group-signature) — the whole committed trace, twice,
        compiles exactly 1 draft and 1 verify per signature/window, on
        top of the usual single prefill + per-signature decode."""
        mcfg, scfg, params, cache, prompts = self._spec_setup()
        ads = ["t0"] * len(_TRACE)
        eng = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                           adapter_cache=cache, speculative_k=self.K)
        _drive_trace(eng, prompts, ads)
        counts = eng.compile_counts()
        assert counts["prefill_into_slot"] == 1, counts
        assert counts["draft"] == 1, counts
        assert counts["verify"] == {(None, self.K + 1): 1}, counts
        assert all(n == 1 for n in counts["decode"].values()), counts
        # the same trace again must reuse every executable
        _drive_trace(eng, prompts, ads)
        assert eng.compile_counts() == counts

    def test_speculative_compile_surface_multi_tenant(self):
        """Mixed-handle slot tables: the verify LRU keys on (grouping
        signature, window) and compiles each exactly once."""
        mcfg, scfg, params, cache, prompts = self._spec_setup(tenants=2)
        ads = [f"t{i % 2}" for i in range(len(_TRACE))]
        eng = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                           adapter_cache=cache, speculative_k=self.K)
        got = _drive_trace(eng, prompts, ads)
        plain = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                             adapter_cache=cache)
        assert got == _drive_trace(plain, prompts, ads)
        counts = eng.compile_counts()
        assert counts["draft"] == 1, counts
        assert counts["verify"], counts
        assert all(n == 1 for n in counts["verify"].values()), counts
        assert all(window == self.K + 1
                   for _, window in counts["verify"]), counts

    def test_draft_jaxpr_has_zero_adapter_work(self):
        """ACCEPTANCE: the draft step is the BASE model — zero
        ``dora_wnorm`` ops and zero adapter matmuls (it does not even
        take an adapter argument); the verify step keeps the folded
        zero-norm property of the decode step."""
        mcfg, scfg, params, cache = _setup()
        state = cache.get_state(params, cache.current_handle("t0"))
        dec_cache = init_cache(mcfg, 2, 8, row_lens=True)
        draft = make_draft_step(mcfg, scfg, None, batch=2)
        jd = str(jax.make_jaxpr(draft)(
            params, dec_cache, {"tokens": jnp.zeros((2, 1), jnp.int32)}))
        verify = make_verify_step(mcfg, scfg, None, batch=2, window=4)
        jv = str(jax.make_jaxpr(verify)(
            params, state, dec_cache,
            {"tokens": jnp.zeros((2, 4), jnp.int32)}))
        decode = make_decode_step(mcfg, scfg, None, batch=2)
        jdec = str(jax.make_jaxpr(decode)(
            params, state, dec_cache,
            {"tokens": jnp.zeros((2, 1), jnp.int32)}))
        assert "dora_wnorm" not in jd
        assert "dora_wnorm" not in jv
        # the decode/verify steps carry the adapter (A / folded-gsB)
        # matmuls on top of the base projections; the draft must not
        assert jd.count("dot_general") < jdec.count("dot_general")
        assert jv.count("dot_general") == jdec.count("dot_general")


class TestPaged:
    """Block-paged KV cache + chunked prefill: paging is a LAYOUT
    change, not a semantics change. Greedy streams are BITWISE the
    rectangular engine's whatever the chunking, and with a chunk
    covering the whole prompt the tick-level schedule is identical too
    — while the cache lives in a block pool that drains to empty."""
    ML = 18
    BS = 6              # divides ML; default prefill_chunk = BS < P = 8

    def test_paged_streams_equal_rectangular_bitwise(self):
        """ACCEPTANCE: over the committed arrival trace, the paged
        engine (chunk = 6 < P = 8, so every admission actually streams
        in two chunks) emits exactly the rectangular engine's greedy
        streams, drains its pool, and compiles one chunk-prefill + one
        decode — never the monolithic prefill-into-slot."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
                   for _, P, _ in _TRACE]
        ads = ["t0"] * len(_TRACE)
        rect = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                            adapter_cache=cache)
        paged = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                             adapter_cache=cache, paged=True,
                             block_size=self.BS)
        want = _drive_trace(rect, prompts, ads)
        got = _drive_trace(paged, prompts, ads)
        assert got == want
        assert paged.stats().generated_tokens == rect.stats().generated_tokens
        ps = paged.pool_stats()
        assert ps["used_blocks"] == 0, f"leaked blocks: {ps}"
        assert ps["per_slot_blocks"] == [0] * 4, ps
        assert ps["peak_used_blocks"] > 0, ps
        counts = paged.compile_counts()
        assert counts["prefill_into_slot"] == 0, counts
        assert counts["prefill_chunk"] == 1, counts
        assert counts["decode"] == {None: 1}, counts

    def test_chunk_covering_prompt_reproduces_rect_schedule(self):
        """With prefill_chunk >= P every admission completes in ONE
        tick, so the paged engine's tick-level counters — not just its
        streams — equal the rectangular engine's exactly."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
                   for _, P, _ in _TRACE]
        ads = ["t0"] * len(_TRACE)
        rect = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                            adapter_cache=cache)
        paged = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                             adapter_cache=cache, paged=True,
                             block_size=self.BS, prefill_chunk=9)
        want = _drive_trace(rect, prompts, ads)
        got = _drive_trace(paged, prompts, ads)
        assert got == want
        st_r, st_p = rect.stats(), paged.stats()
        for field in ("steps", "decode_steps", "prefills",
                      "generated_tokens", "slot_steps"):
            assert getattr(st_p, field) == getattr(st_r, field), field

    def test_paged_speculative_streams_bitwise(self):
        """Speculation composes with paging: a speculative paged engine
        (non-identity adapters, so drafts are genuinely rejected AND
        accepted) streams exactly the plain RECTANGULAR engine's greedy
        tokens, and the rewind's block release leaves the pool drained."""
        mcfg, scfg, params, cache = _setup()
        _, ad, _ = build_state(mcfg, DCFG, 10)
        cache.update("t0", _perturb(ad, 7))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
                   for _, P, _ in _TRACE]
        ads = ["t0"] * len(_TRACE)
        spec = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                            adapter_cache=cache, paged=True,
                            block_size=self.BS, speculative_k=3)
        plain = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                             adapter_cache=cache)
        got = _drive_trace(spec, prompts, ads)
        want = _drive_trace(plain, prompts, ads)
        assert got == want
        st = spec.stats()
        assert st.verify_steps > 0
        assert 0 < st.accepted_drafts < st.draft_steps, st
        assert spec.pool_stats()["used_blocks"] == 0

    def test_small_pool_reclaims_and_stays_bitwise(self):
        """A pool SMALLER than slots * max_blocks forces head-of-line
        deferral and reclaim preemption mid-trace — the streams must
        still be bitwise the rectangular engine's, and the pool must
        never exceed its capacity nor leak."""
        mcfg, scfg, params, cache = _setup()
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
                   for _, P, _ in _TRACE]
        ads = ["t0"] * len(_TRACE)
        rect = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                            adapter_cache=cache)
        small = DecodeEngine(mcfg, scfg, params, slots=4, max_len=self.ML,
                             adapter_cache=cache, paged=True,
                             block_size=self.BS, n_blocks=8)  # < 4*3
        want = _drive_trace(rect, prompts, ads)
        got = _drive_trace(small, prompts, ads)
        assert got == want
        ps = small.pool_stats()
        assert ps["used_blocks"] == 0, ps
        assert 0 < ps["peak_used_blocks"] <= 8, ps

    def test_paged_constructor_contracts(self):
        """Paged kwargs on a rectangular engine, a non-dividing block
        size, and an undersized pool are rejected loudly."""
        mcfg, scfg, params, cache = _setup()
        with pytest.raises(ValueError, match="paged"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=self.ML,
                         adapter_cache=cache, block_size=self.BS)
        with pytest.raises(ValueError, match="multiple"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=self.ML,
                         adapter_cache=cache, paged=True, block_size=5)
        with pytest.raises(ValueError, match="n_blocks"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=self.ML,
                         adapter_cache=cache, paged=True,
                         block_size=self.BS, n_blocks=2)


class TestFleetServing:
    """PR 9 traced dynamic grouping + the per-adapter rate limit.

    Contract under test: a DYNAMIC engine (``dynamic_grouping=True``)
    serves arbitrary tenant mixes through exactly ONE decode executable
    (tenant churn changes values — stack rows, the per-row index — never
    the compile signature) while streaming tokens bitwise-identical to
    the STATIC-signature engine and to each request served alone."""
    ML = 14

    def _fleet(self, tenants=3):
        mcfg, scfg, params, cache = _setup(tenants=tenants)
        # distinct non-zero B per tenant: seed-built trees have B == 0,
        # so every tenant would otherwise stream identical tokens and a
        # mis-indexed fleet stack could never be caught.
        for t in range(tenants):
            cache.update(f"t{t}", _perturb(cache.adapters(f"t{t}"), 40 + t))
        return mcfg, scfg, params, cache

    def _trace(self, mcfg, n=7, tenants=3, seed=0):
        rng = np.random.default_rng(seed)
        return [(rng.integers(0, mcfg.vocab_size, 4 + (i % 3),
                              dtype=np.int32),
                 3 + (i % 3), f"t{i % tenants}") for i in range(n)]

    def _run(self, mcfg, scfg, params, cache, reqs, **kw):
        eng = DecodeEngine(mcfg, scfg, params, slots=3, max_len=self.ML,
                           adapter_cache=cache, **kw)
        for p, g, a in reqs:
            eng.submit(p, adapter=a, max_new_tokens=g)
        res = eng.run()
        return eng, {r.request_id: r.tokens.tolist() for r in res}

    def test_dynamic_streams_match_static_and_oracle_bitwise(self):
        """ACCEPTANCE: a mixed-tenant trace through the dynamic engine is
        bitwise the static-signature engine AND each request served alone
        (per-tenant sequential serving)."""
        mcfg, scfg, params, cache = self._fleet()
        reqs = self._trace(mcfg)
        e_dyn, dyn = self._run(mcfg, scfg, params, cache, reqs,
                               dynamic_grouping=True)
        _, sta = self._run(mcfg, scfg, params, cache, reqs)
        assert dyn == sta, "dynamic streams diverged from static grouping"
        for (p, g, a), (rid, toks) in zip(reqs, sorted(dyn.items())):
            np.testing.assert_array_equal(
                toks, _alone(mcfg, scfg, params, cache, p, g, self.ML, a),
                err_msg=f"request {rid} under dynamic grouping diverged "
                        f"from serving it alone")
        counts = e_dyn.compile_counts()
        assert counts["decode"] == {"dynamic": 1}, counts
        assert counts["adapter_insert"] == 1, counts

    def test_compile_counts_are_churn_invariant(self):
        """ACCEPTANCE (seeded mirror of the hypothesis churn fuzzer): N
        adapters ≫ slots, random submit/update interleavings across
        waves — the dynamic engine ends every wave with exactly ONE
        decode executable and ONE adapter_insert executable, and every
        request finishes exactly once."""
        tenants = 4
        mcfg, scfg, params, cache = self._fleet(tenants=tenants)
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=self.ML,
                           adapter_cache=cache, dynamic_grouping=True)
        rng = np.random.default_rng(7)
        submitted, finished = [], []
        for wave in range(3):
            for _ in range(4):
                t = int(rng.integers(tenants))
                p = rng.integers(0, mcfg.vocab_size,
                                 int(rng.integers(3, 7)), dtype=np.int32)
                submitted.append(eng.submit(
                    p, adapter=f"t{t}", max_new_tokens=int(
                        rng.integers(2, 5))))
            for _ in range(int(rng.integers(1, 6))):
                if eng.has_work():
                    eng.step()
            finished += [r.request_id for r in eng.pop_results()]
            # churn BETWEEN waves: version-bump a random tenant (new
            # handle → new stack position; pinned in-flight states keep
            # serving v_old) and drop another tenant's cached state.
            bump = int(rng.integers(tenants))
            cache.update(f"t{bump}",
                         _perturb(cache.adapters(f"t{bump}"), 90 + wave))
            cache.invalidate(f"t{int(rng.integers(tenants))}")
            counts = eng.compile_counts()
            assert counts["decode"] == {"dynamic": 1}, (wave, counts)
        finished += [r.request_id for r in eng.run()]
        assert sorted(finished) == sorted(submitted), \
            "requests lost or double-finished under churn"
        assert len(set(finished)) == len(finished)
        counts = eng.compile_counts()
        assert counts["decode"] == {"dynamic": 1}, counts
        assert counts["adapter_insert"] == 1, counts
        assert counts["prefill_into_slot"] == 1, counts
        assert eng.stats().stack_inserts > 0
        # fleet positions drained with the slot table
        assert len(eng._dyn_free) == eng.slots and not eng._dyn_pos

    def test_dynamic_speculative_and_paged_stay_bitwise(self):
        """The dynamic stack composes with the PR-8 tick modes: greedy
        speculative and paged dynamic streams equal the plain static
        streams bitwise, with one ("dynamic", window) verify signature."""
        mcfg, scfg, params, cache = self._fleet()
        reqs = self._trace(mcfg)
        _, plain = self._run(mcfg, scfg, params, cache, reqs)
        e_spec, spec = self._run(mcfg, scfg, params, cache, reqs,
                                 dynamic_grouping=True, speculative_k=2)
        assert spec == plain
        assert list(e_spec.compile_counts()["verify"]) == [("dynamic", 3)]
        e_paged, paged = self._run(mcfg, scfg, params, cache, reqs,
                                   dynamic_grouping=True, paged=True)
        assert paged == plain
        assert e_paged.pool_stats()["used_blocks"] == 0

    def test_max_active_per_adapter_prevents_starvation(self):
        """SATELLITE: a hot tenant's burst is rate-limited to its slot
        share — the fleet's other tenants admit and finish while the
        burst drains, instead of queueing behind it."""
        mcfg, scfg, params, cache = self._fleet(tenants=2)
        eng = DecodeEngine(mcfg, scfg, params, slots=3, max_len=self.ML,
                           adapter_cache=cache, max_active_per_adapter=1)
        rng = np.random.default_rng(3)
        p = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        hot = [eng.submit(p, adapter="t0", max_new_tokens=4)
               for _ in range(5)]
        other = eng.submit(p, adapter="t1", max_new_tokens=4)
        max_hot = 0
        while eng.has_work():
            eng.step()
            max_hot = max(max_hot, sum(
                1 for s in eng._slots
                if s.occupied and s.handle.adapter_id == "t0"))
        results = {r.request_id: r for r in eng.pop_results()}
        assert max_hot == 1, \
            f"rate limit violated: {max_hot} concurrent t0 slots"
        assert len(results) == 6
        assert all(r.finish_reason == "length" for r in results.values())
        # no starvation: t1 finished before the hot burst drained
        assert results[other].finished_step < max(
            results[rid].finished_step for rid in hot)
        # the limit never displaced anyone — it holds requests in the
        # queue, it does not preempt
        assert eng.stats().preemptions == 0

    def test_rate_limited_requests_keep_queue_order(self):
        """Held-back requests keep their queue positions: once the hot
        tenant's slot frees, its NEXT request admits in FIFO order."""
        mcfg, scfg, params, cache = self._fleet(tenants=2)
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=self.ML,
                           adapter_cache=cache, max_active_per_adapter=1)
        rng = np.random.default_rng(4)
        p = rng.integers(0, mcfg.vocab_size, 4, dtype=np.int32)
        rids = [eng.submit(p, adapter="t0", max_new_tokens=3)
                for _ in range(3)]
        results = {r.request_id: r for r in eng.run()}
        admits = [results[r].admitted_step for r in rids]
        assert admits == sorted(admits), "rate-limited FIFO order broken"

    def test_dynamic_requires_adapter_cache(self):
        mcfg, scfg, params, cache = _setup()
        h = cache.current_handle("t0")
        fixed = cache.get_state(params, h)
        with pytest.raises(ValueError, match="dynamic_grouping"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=10,
                         adapters=fixed, dynamic_grouping=True)
        with pytest.raises(ValueError, match="max_active_per_adapter"):
            DecodeEngine(mcfg, scfg, params, slots=2, max_len=10,
                         adapter_cache=cache, max_active_per_adapter=0)

    def test_dynamic_decode_jaxpr_has_zero_norm_work(self):
        """The dynamic grouped step keeps the serving contract: zero
        ``dora_wnorm`` ops per token (all norm work was precomputed)."""
        mcfg, scfg, params, cache = self._fleet()
        eng, _ = self._run(mcfg, scfg, params, cache, self._trace(mcfg),
                           dynamic_grouping=True)
        step = make_decode_step(mcfg, scfg, batch=3, dynamic_groups=True)
        groups, adapters = eng._slot_grouping()
        assert groups == "dynamic"
        cache_tree = init_cache(mcfg, 3, self.ML, row_lens=True)
        jaxpr = jax.make_jaxpr(step)(
            params, adapters, cache_tree,
            {"tokens": jnp.zeros((3, 1), jnp.int32),
             "adapter_idx": jnp.zeros((3,), jnp.int32)})
        assert "dora_wnorm" not in str(jaxpr), \
            "dynamic decode recomputes norm work per token"


# ---------------------------------------------------------------------------
# Forced 2-device mesh (subprocess): join/leave trace under SPMD.
# ---------------------------------------------------------------------------

def _run_subprocess(code: str, devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    env.pop("REPRO_FORCE_TIER", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


_ENGINE_SPMD = """
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import AdapterStateCache, DoRAConfig
    from repro.launch.engine import DecodeEngine
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.serve import generate
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    assert jax.device_count() == 2
    mesh = make_debug_mesh(2, 1)     # slots shard over the data axis
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
    mcfg = get_config("qwen2-7b", smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg, mesh)
    _, ad, _ = build_state(mcfg, DCFG, 10)
    cache.register("t0", ad)

    ML = 12
    eng = DecodeEngine(mcfg, scfg, params, slots=4, max_len=ML,
                       adapter_cache=cache, mesh=mesh)
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, mcfg.vocab_size, P, dtype=np.int32), g)
            for P, g in [(5, 5), (6, 2), (4, 4), (5, 3), (6, 4)]]
    for p, g in reqs:
        eng.submit(p, adapter="t0", max_new_tokens=g)
    results = eng.run()
    counts = eng.compile_counts()
    assert counts["prefill_into_slot"] == 1, counts
    assert counts["decode"] == {None: 1}, counts
    for r, (p, g) in zip(results, reqs):
        ref = np.asarray(generate(mcfg, params, cache.current_handle("t0"),
                                  scfg, p[None], gen_len=g, max_len=ML,
                                  adapter_cache=cache, mesh=mesh))
        assert np.array_equal(r.tokens, ref[0, len(p):]), r.request_id
    print("ENGINE_SPMD_OK")
"""


@pytest.mark.slow
def test_engine_spmd_join_leave():
    """Acceptance on a forced 2-device CPU mesh: a join/leave trace
    through slots sharded over the data axis serves every request the
    same greedy tokens as generate() alone under the same mesh, with one
    compiled (prefill, decode) pair."""
    out = _run_subprocess(_ENGINE_SPMD, 2)
    assert "ENGINE_SPMD_OK" in out, out


_SPEC_SPMD = """
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import AdapterStateCache, DoRAConfig
    from repro.launch.engine import DecodeEngine
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    assert jax.device_count() == 2
    mesh = make_debug_mesh(2, 1)     # slots shard over the data axis
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
    mcfg = get_config("qwen2-7b", smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg, mesh)
    _, ad, _ = build_state(mcfg, DCFG, 10)
    cache.register("t0", ad)
    # non-identity adapters (random B, seed A/m): verify must actually
    # reject some drafts AND accept some — see _perturb in the test file
    key = jax.random.PRNGKey(7)
    cnt = [0]

    def perturb(path, leaf):
        cnt[0] += 1
        if "'B'" in "/".join(str(p) for p in path):
            return jax.random.normal(jax.random.fold_in(key, cnt[0]),
                                     leaf.shape, leaf.dtype) * 0.1
        return leaf
    cache.update("t0", jax.tree_util.tree_map_with_path(perturb, ad))

    # the committed arrival trace (see _TRACE in tests/test_engine.py)
    TRACE = [(1, 8, 8), (1, 8, 6), (1, 8, 4), (4, 8, 10), (6, 8, 10),
             (11, 8, 8), (23, 8, 6), (23, 8, 10), (28, 8, 8), (30, 8, 4),
             (32, 8, 4), (32, 8, 10)]
    ML, K = 18, 3
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
               for _, P, _ in TRACE]

    def drive(eng):
        streams = {}
        i, step = 0, 0
        while i < len(TRACE) or eng.has_work():
            while i < len(TRACE) and TRACE[i][0] <= step:
                eng.submit(prompts[i], adapter="t0",
                           max_new_tokens=TRACE[i][2], key_id=i)
                i += 1
            eng.step(lambda rid, tok: streams.setdefault(rid,
                                                         []).append(tok))
            step += 1
        return streams

    spec = DecodeEngine(mcfg, scfg, params, slots=4, max_len=ML,
                        adapter_cache=cache, mesh=mesh, speculative_k=K)
    plain = DecodeEngine(mcfg, scfg, params, slots=4, max_len=ML,
                         adapter_cache=cache, mesh=mesh)
    got, want = drive(spec), drive(plain)
    assert got == want, "speculative streams diverged from plain decode"
    st = spec.stats()
    assert st.verify_steps > 0 and st.draft_steps == K * st.verify_steps
    assert 0 < st.accepted_drafts < st.draft_steps, st
    counts = spec.compile_counts()
    assert counts["draft"] == 1, counts
    assert counts["verify"] == {(None, K + 1): 1}, counts
    print("SPEC_SPMD_OK")
"""


@pytest.mark.slow
def test_engine_spmd_speculative_oracle():
    """Acceptance on a forced 2-device CPU mesh: speculative decode over
    the committed arrival trace streams exactly the plain engine's greedy
    tokens, with one compiled (draft, verify) pair, while genuinely
    accepting AND rejecting drafts."""
    out = _run_subprocess(_SPEC_SPMD, 2)
    assert "SPEC_SPMD_OK" in out, out


_PAGED_SPMD = """
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import AdapterStateCache, DoRAConfig
    from repro.launch.engine import DecodeEngine
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    assert jax.device_count() == 2
    mesh = make_debug_mesh(2, 1)     # slots shard over the data axis
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
    mcfg = get_config("qwen2-7b", smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg, mesh)
    _, ad, _ = build_state(mcfg, DCFG, 10)
    cache.register("t0", ad)

    ML = 12
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, mcfg.vocab_size, P, dtype=np.int32), g)
            for P, g in [(5, 5), (6, 2), (4, 4), (5, 3), (6, 4)]]

    # prefill_chunk=3 < every P: admission genuinely streams in chunks
    # under SPMD (the block pool is replicated host state; the pool
    # arrays shard like the rectangular cache did)
    paged = DecodeEngine(mcfg, scfg, params, slots=4, max_len=ML,
                         adapter_cache=cache, mesh=mesh, paged=True,
                         block_size=6, prefill_chunk=3)
    rect = DecodeEngine(mcfg, scfg, params, slots=4, max_len=ML,
                        adapter_cache=cache, mesh=mesh)
    for p, g in reqs:
        paged.submit(p, adapter="t0", max_new_tokens=g)
        rect.submit(p, adapter="t0", max_new_tokens=g)
    got = paged.run()
    want = rect.run()
    for rp, rr in zip(got, want):
        assert np.array_equal(rp.tokens, rr.tokens), rp.request_id
    counts = paged.compile_counts()
    assert counts["prefill_into_slot"] == 0, counts
    assert counts["prefill_chunk"] == 1, counts
    assert counts["decode"] == {None: 1}, counts
    ps = paged.pool_stats()
    assert ps["used_blocks"] == 0 and ps["peak_used_blocks"] > 0, ps
    print("PAGED_SPMD_OK")
"""


@pytest.mark.slow
def test_engine_spmd_paged_oracle():
    """Acceptance on a forced 2-device CPU mesh: the block-paged engine
    with multi-chunk admission streams exactly the rectangular engine's
    greedy tokens under SPMD, with one compiled chunk-prefill + decode
    pair and a fully drained pool."""
    out = _run_subprocess(_PAGED_SPMD, 2)
    assert "PAGED_SPMD_OK" in out, out
