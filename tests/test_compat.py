"""The compat layer itself: tree-path round-trips, compiler-params
construction under both Pallas API names (monkeypatched), and forced-tier
dispatch selection. These tests guard the guarantee every other module
relies on: one JAX upgrade == one shim change, zero call-site changes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import mesh as cmesh
from repro.compat import pallas as cpal
from repro.compat import probes
from repro.compat import tree as ctree
from repro.core import DoRAConfig, dispatch


# ---------------------------------------------------------------------------
# tree
# ---------------------------------------------------------------------------

TREE = {"stack": {"l0": {"A": 1, "B": [2, 3]}}, "m": 4}


def test_flatten_with_path_round_trip():
    flat, treedef = ctree.flatten_with_path(TREE)
    rebuilt = ctree.unflatten(treedef, [leaf for _, leaf in flat])
    assert rebuilt == TREE


def test_paths_match_plain_flatten_order():
    flat, treedef = ctree.flatten_with_path(TREE)
    plain, plain_def = ctree.flatten(TREE)
    assert [leaf for _, leaf in flat] == plain
    assert treedef == plain_def


def test_path_str_names():
    flat, _ = ctree.flatten_with_path(TREE)
    names = [ctree.path_str(p) for p, _ in flat]
    assert "stack/l0/A" in names
    assert "stack/l0/B/0" in names
    assert "m" in names


def test_map_matches_jax_tree_map():
    got = ctree.map(lambda x: x * 10, TREE)
    want = jax.tree_util.tree_map(lambda x: x * 10, TREE)
    assert got == want


def test_flatten_with_path_honors_is_leaf():
    spec = {"a": ("linear", (4, 2)), "b": {"c": ("zeros", (3,))}}
    is_leaf = lambda x: isinstance(x, tuple) and isinstance(x[0], str)
    flat, _ = ctree.flatten_with_path(spec, is_leaf=is_leaf)
    assert sorted(ctree.path_str(p) for p, _ in flat) == ["a", "b/c"]
    assert all(isinstance(leaf, tuple) for _, leaf in flat)


# ---------------------------------------------------------------------------
# pallas compiler params under both API names
# ---------------------------------------------------------------------------

class _NewStyleParams:
    def __init__(self, dimension_semantics=None):
        self.dimension_semantics = dimension_semantics


class _OldStyleParams(_NewStyleParams):
    pass


def test_compiler_params_prefers_new_name(monkeypatch):
    monkeypatch.setattr(cpal.pltpu, "CompilerParams", _NewStyleParams,
                        raising=False)
    out = cpal.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert isinstance(out, _NewStyleParams)
    assert out.dimension_semantics == ("parallel", "arbitrary")


def test_compiler_params_falls_back_to_old_name(monkeypatch):
    # Simulate an old JAX: no CompilerParams, only TPUCompilerParams.
    monkeypatch.delattr(cpal.pltpu, "CompilerParams", raising=False)
    monkeypatch.setattr(cpal.pltpu, "TPUCompilerParams", _OldStyleParams,
                        raising=False)
    out = cpal.tpu_compiler_params(dimension_semantics=("parallel",))
    assert isinstance(out, _OldStyleParams)
    assert out.dimension_semantics == ("parallel",)


def test_compiler_params_drops_unknown_tuning_kwargs(monkeypatch):
    monkeypatch.delattr(cpal.pltpu, "CompilerParams", raising=False)
    monkeypatch.setattr(cpal.pltpu, "TPUCompilerParams", _OldStyleParams,
                        raising=False)
    out = cpal.tpu_compiler_params(dimension_semantics=("arbitrary",),
                                   vmem_limit_bytes=1 << 20)
    assert isinstance(out, _OldStyleParams)
    assert out.dimension_semantics == ("arbitrary",)


def test_compiler_params_constructs_on_installed_jax():
    """Whatever the installed JAX calls the class, construction works and
    pallas_call accepts the result (interpret mode, CPU)."""
    params = cpal.tpu_compiler_params(
        dimension_semantics=("parallel",))

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    x = jnp.ones((8, 128), jnp.float32)
    out = cpal.pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[cpal.pl.BlockSpec((8, 128), lambda i: (0, 0))],
        out_specs=cpal.pl.BlockSpec((8, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        compiler_params=params,
        interpret=True,
    )(x)
    np.testing.assert_allclose(np.asarray(out), 2.0)


def test_resolve_interpret_follows_backend():
    assert cpal.resolve_interpret(True) is True
    assert cpal.resolve_interpret(False) is False
    assert cpal.resolve_interpret(None) == (
        not probes.can_compile_pallas_tpu())


# ---------------------------------------------------------------------------
# mesh
# ---------------------------------------------------------------------------

def test_make_mesh_single_device():
    mesh = cmesh.make_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["data"] == 1


def test_shard_map_resolves():
    assert callable(cmesh.shard_map)


# ---------------------------------------------------------------------------
# xla introspection
# ---------------------------------------------------------------------------

def test_peak_memory_and_cost_dict_on_installed_jax():
    from repro.compat import xla as cxla
    compiled = jax.jit(lambda x: x @ x).lower(
        jnp.ones((64, 64), jnp.float32)).compile()
    assert cxla.peak_memory_bytes(compiled) >= 0
    cost = cxla.cost_analysis_dict(compiled)
    assert isinstance(cost, dict)
    assert cost.get("flops", 0.0) > 0


# ---------------------------------------------------------------------------
# probes
# ---------------------------------------------------------------------------

def test_probes_consistent():
    assert probes.backend_platform() in ("cpu", "gpu", "tpu")
    assert probes.has_pallas()       # this repo requires pallas
    assert probes.has_pallas_tpu()
    if probes.backend_platform() != "tpu":
        assert not probes.can_compile_pallas_tpu()
        assert "tpu" not in dispatch.available_backends()
    assert "eager" in dispatch.available_backends()
    assert "interpret" in dispatch.available_backends()


# ---------------------------------------------------------------------------
# forced-tier dispatch
# ---------------------------------------------------------------------------

def _plan(cfg, d_out=256, rows=1 << 20, training=True):
    return dispatch.plan_compose(cfg, training=training, rows=rows,
                                 d_out=d_out)


def test_force_tier_env_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_TIER", "interpret")
    plan = _plan(DoRAConfig(mode="auto"))
    assert plan.tier is dispatch.Tier.FUSED_BWD
    assert plan.backend == "interpret"
    assert plan.interpret is True


def test_force_tier_env_eager(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_TIER", "eager")
    plan = _plan(DoRAConfig(mode="fused"))
    assert plan.tier is dispatch.Tier.EAGER
    assert plan.interpret is False


def test_force_tier_env_beats_config_field(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_TIER", "eager")
    plan = _plan(DoRAConfig(force_tier="interpret"))
    assert plan.tier is dispatch.Tier.EAGER


def test_force_tier_config_field(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_TIER", raising=False)
    plan = _plan(DoRAConfig(force_tier="interpret"))
    assert plan.backend == "interpret"
    assert plan.interpret is True


def test_force_tier_tpu_degrades_to_interpret_off_tpu(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_TIER", raising=False)
    if probes.is_tpu():
        pytest.skip("degrade path only exists off-TPU")
    plan = _plan(DoRAConfig(force_tier="tpu"))
    assert plan.tier is dispatch.Tier.FUSED_BWD
    assert plan.backend == "interpret"


def test_force_tier_rejects_unknown_env(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_TIER", "warpdrive")
    with pytest.raises(ValueError, match="REPRO_FORCE_TIER"):
        _plan(DoRAConfig())


def test_dora_mode_env_validated_and_aliased(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_TIER", raising=False)
    monkeypatch.setenv("REPRO_DORA_MODE", "tpu")   # tier alias accepted
    assert DoRAConfig().resolve_mode() == "fused"
    monkeypatch.setenv("REPRO_DORA_MODE", "auto")
    assert DoRAConfig(mode="eager").resolve_mode() == "auto"
    monkeypatch.setenv("REPRO_DORA_MODE", "warpdrive")
    with pytest.raises(ValueError, match="REPRO_DORA_MODE"):
        DoRAConfig().resolve_mode()


def test_force_tier_rejects_unknown_config():
    with pytest.raises(ValueError, match="force_tier"):
        DoRAConfig(force_tier="warpdrive")


def test_shape_guard_beats_forced_fused(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_TIER", "interpret")
    plan = _plan(DoRAConfig(), d_out=100)  # not a multiple of 128
    assert plan.tier is dispatch.Tier.EAGER


def test_inference_gets_forward_tier(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_TIER", "interpret")
    plan = _plan(DoRAConfig(), training=False)
    assert plan.tier is dispatch.Tier.FUSED_FWD


def test_auto_mode_on_cpu_is_eager(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_TIER", raising=False)
    if probes.is_tpu():
        pytest.skip("auto on TPU picks the fused tier")
    plan = _plan(DoRAConfig(mode="auto"))
    assert plan.tier is dispatch.Tier.EAGER


def test_norm_plan_matches_compose_backend(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_TIER", "interpret")
    plan = dispatch.plan_norm(DoRAConfig(), d_out=256)
    assert plan.tier is dispatch.Tier.FUSED_FWD
    assert plan.interpret is True
    assert dispatch.plan_norm(DoRAConfig(), d_out=100).tier \
        is dispatch.Tier.EAGER


# ---------------------------------------------------------------------------
# end-to-end: forced interpret tier ≡ eager tier on CPU (acceptance)
# ---------------------------------------------------------------------------

def test_forced_interpret_matches_eager_end_to_end(monkeypatch, rng_key):
    from repro.core import dora_linear, init_dora_params
    cfg = DoRAConfig(rank=8, alpha=16.0)
    W = jax.random.normal(rng_key, (256, 128), jnp.float32)
    adapter = init_dora_params(jax.random.fold_in(rng_key, 1), W, cfg)
    adapter["B"] = 0.02 * jax.random.normal(
        jax.random.fold_in(rng_key, 2), adapter["B"].shape, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng_key, 3), (4, 128),
                          jnp.float32)

    monkeypatch.setenv("REPRO_FORCE_TIER", "interpret")
    y_interp = dora_linear(x, W, adapter, cfg, training=True)
    monkeypatch.setenv("REPRO_FORCE_TIER", "eager")
    y_eager = dora_linear(x, W, adapter, cfg, training=True)
    np.testing.assert_allclose(np.asarray(y_interp), np.asarray(y_eager),
                               rtol=1e-5, atol=1e-5)
