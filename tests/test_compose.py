"""Compose correctness: stable form, dispatch tiers, adapter equivalence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DoRAConfig, compose_stable, compose_naive
import repro.core.adapter as ad
import repro.core.dispatch as dp
import repro.core.factored_norm as fn
from repro.core.compose import magnitude_scale, compose_reference_fp64

jax.config.update("jax_enable_x64", True)


def _setup(key, m, n, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.normal(k1, (m, n), jnp.float32).astype(dtype)
    lora = (0.1 * jax.random.normal(k2, (m, n), jnp.float32)).astype(dtype)
    g = 1.0 + 0.0015 * jax.random.normal(k3, (n,), jnp.float32)
    return base, lora, g


def test_stable_form_beats_naive_near_unity():
    """Paper Fig. 1: near g≈1 in bf16, the naive form g(s·lora+base)-base
    collapses; the stable form stays near the quantization floor."""
    base, lora, g = _setup(jax.random.PRNGKey(0), 2048, 512, jnp.bfloat16)
    s = 0.5
    want = compose_reference_fp64(base, lora, g, s)
    stable = compose_stable(base, lora, g, s).astype(jnp.float64)
    naive = compose_naive(base, lora, g, s).astype(jnp.float64)
    err_stable = float(jnp.max(jnp.abs(stable - want)))
    err_naive = float(jnp.max(jnp.abs(naive - want)))
    # The paper reports ~3.0x lower peak error; require a clear win.
    assert err_stable * 2.0 < err_naive, (err_stable, err_naive)


def test_naive_form_collapse_zone():
    """100% of near-unity g fall in the bf16 collapse zone: with
    |g-1| < eps_bf16/2 the naive form loses the base correction entirely."""
    n = 256
    g = jnp.full((n,), 1.0 + 1e-4, jnp.float32)  # inside bf16 collapse zone
    base = jnp.full((4, n), 100.0, jnp.bfloat16)
    lora = jnp.zeros((4, n), jnp.bfloat16)
    naive = compose_naive(base, lora, g, 1.0)
    stable = compose_stable(base, lora, g, 1.0)
    # naive: g*base - base rounds to 0 in bf16; stable keeps (g-1)*base.
    assert float(jnp.max(jnp.abs(naive.astype(jnp.float32)))) == 0.0
    assert float(jnp.max(jnp.abs(stable.astype(jnp.float32)))) > 0.0


def test_magnitude_scale_precision_context():
    m = jnp.asarray([1.0, 2.0, 0.0], jnp.float32)
    wn = jnp.asarray([2.0, 0.0, 0.0], jnp.float32)
    g = magnitude_scale(m, wn, 1e-6)
    assert g.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(g), [0.5, 2e6, 0.0])


def test_broadcast_guard():
    base = jnp.zeros((4, 8, 16))
    with pytest.raises(ValueError, match="broadcast"):
        compose_stable(base, base, jnp.ones((8,)), 1.0)


class TestDispatch:
    CFG = DoRAConfig(mode="auto")

    @pytest.fixture(autouse=True)
    def _own_env(self, monkeypatch):
        # These tests assert tier selection from cfg.mode alone; a
        # forced-tier harness (scripts/run_tier1.sh) must not leak in.
        monkeypatch.delenv("REPRO_FORCE_TIER", raising=False)
        monkeypatch.delenv("REPRO_DORA_MODE", raising=False)

    def test_sub_crossover_routes_eager(self):
        t = dp.select_tier(self.CFG, training=True, rows=64, d_out=512)
        assert t is dp.Tier.EAGER  # KV-projection-sized: below crossover

    def test_cpu_routes_eager(self):
        t = dp.select_tier(self.CFG, training=True, rows=10**6, d_out=8192)
        assert t is dp.Tier.EAGER  # backend is cpu in this container

    def test_interpret_forces_fused(self):
        cfg = DoRAConfig(mode="interpret")
        assert dp.select_tier(cfg, training=True, rows=8, d_out=128) \
            is dp.Tier.FUSED_BWD
        assert dp.select_tier(cfg, training=False, rows=8, d_out=128) \
            is dp.Tier.FUSED_FWD

    def test_bad_shape_routes_eager(self):
        cfg = DoRAConfig(mode="fused")
        assert dp.select_tier(cfg, training=True, rows=10**6, d_out=100) \
            is dp.Tier.EAGER

    def test_env_force_off(self):
        os.environ["REPRO_DORA_FUSED"] = "0"
        try:
            cfg = DoRAConfig(mode="fused")
            assert dp.select_tier(cfg, training=True, rows=10**6,
                                  d_out=8192) is dp.Tier.EAGER
        finally:
            del os.environ["REPRO_DORA_FUSED"]

    def test_crossover_matches_paper(self):
        # paper §4: d_out >= 2048 AND rows*d_out >= 2048*6144
        assert not dp.above_crossover(6143, 2048, self.CFG)
        assert dp.above_crossover(6144, 2048, self.CFG)
        assert not dp.above_crossover(10**9, 2047, self.CFG)


class TestDoraLinear:
    """The adapted linear must equal the mathematical definition
    m ⊙ x(W+sBA)ᵀ / ||W+sBA||_row for every tier and norm impl."""

    def _check(self, cfg, dtype=jnp.float32, tol=1e-5):
        k = jax.random.PRNGKey(42)
        k1, k2, k3 = jax.random.split(k, 3)
        d_in, d_out, rank = 96, 128, cfg.rank
        x = jax.random.normal(k1, (4, 7, d_in), jnp.float32).astype(dtype)
        W = jax.random.normal(k2, (d_out, d_in), jnp.float32).astype(dtype)
        adapter = ad.init_dora_params(k3, W, cfg)
        # make B nonzero so the test is not trivial
        adapter["B"] = 0.3 * jax.random.normal(k3, adapter["B"].shape,
                                               jnp.float32).astype(dtype)
        adapter["m"] = adapter["m"] * 1.01
        y = ad.dora_linear(x, W, adapter, cfg, training=True)

        s = cfg.scaling
        comp = (W.astype(jnp.float64)
                + s * adapter["B"].astype(jnp.float64)
                @ adapter["A"].astype(jnp.float64))
        wn = jnp.linalg.norm(comp, axis=1)
        want = (adapter["m"].astype(jnp.float64) / wn
                * (x.astype(jnp.float64) @ comp.T))
        np.testing.assert_allclose(np.asarray(y, np.float64),
                                   np.asarray(want), rtol=tol, atol=tol)
        return y

    def test_eager_tier(self):
        self._check(DoRAConfig(rank=8, alpha=16, mode="eager"))

    def test_fused_interpret_tier(self):
        self._check(DoRAConfig(rank=8, alpha=16, mode="interpret"))

    def test_norm_impl_equivalence(self):
        ys = [self._check(DoRAConfig(rank=8, alpha=16, mode="eager",
                                     norm_impl=impl))
              for impl in ("factored", "dense_ba", "peft_eye")]
        for y in ys[1:]:
            np.testing.assert_allclose(np.asarray(ys[0]), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)

    def test_eager_vs_fused_grads(self):
        """Paper §5.9 convergence-equivalence at operator level: grads of
        the two tiers agree."""
        cfg_e = DoRAConfig(rank=8, alpha=16, mode="eager")
        cfg_f = DoRAConfig(rank=8, alpha=16, mode="interpret")
        k = jax.random.PRNGKey(7)
        k1, k2, k3 = jax.random.split(k, 3)
        x = jax.random.normal(k1, (16, 128), jnp.float32)
        W = jax.random.normal(k2, (128, 128), jnp.float32)
        adapter = ad.init_dora_params(k3, W, cfg_e)
        adapter["B"] = 0.1 * jax.random.normal(k3, adapter["B"].shape)

        def loss(adp, cfg):
            y = ad.dora_linear(x, W, adp, cfg, training=True)
            return jnp.sum(y ** 2)

        ge = jax.grad(loss)(adapter, cfg_e)
        gf = jax.grad(loss)(adapter, cfg_f)
        for name in ("A", "B", "m"):
            np.testing.assert_allclose(
                np.asarray(ge[name]), np.asarray(gf[name]),
                rtol=1e-4, atol=1e-4, err_msg=name)

    def test_frozen_magnitude(self):
        cfg = DoRAConfig(rank=4, alpha=8, mode="eager",
                         magnitude_trainable=False)
        k = jax.random.PRNGKey(9)
        x = jax.random.normal(k, (8, 64))
        W = jax.random.normal(k, (128, 64))
        adapter = ad.init_dora_params(k, W, cfg)

        def loss(adp):
            return jnp.sum(ad.dora_linear(x, W, adp, cfg) ** 2)

        g = jax.grad(loss)(adapter)
        assert float(jnp.abs(g["m"]).max()) == 0.0
        # At init B = 0, so the first nonzero adapter gradient lands on B
        # (standard LoRA property); A's gradient is zero through B = 0.
        assert float(jnp.abs(g["B"]).max()) > 0.0

    def test_base_weight_frozen(self):
        cfg = DoRAConfig(rank=4, alpha=8, mode="eager")
        k = jax.random.PRNGKey(10)
        x = jax.random.normal(k, (8, 64))
        W = jax.random.normal(k, (128, 64))
        adapter = ad.init_dora_params(k, W, cfg)

        def loss(w):
            return jnp.sum(ad.dora_linear(x, w, adapter, cfg) ** 2)

        # dora_linear stop-gradients W internally (PEFT semantics).
        g = jax.grad(loss)(W)
        assert float(jnp.abs(g).max()) == 0.0

    def test_bias_handling(self):
        """Bias is subtracted before compose, re-added after (App. A):
        equivalent to composing on the bias-free y_base."""
        cfg = DoRAConfig(rank=4, alpha=8, mode="eager")
        k = jax.random.PRNGKey(11)
        x = jax.random.normal(k, (8, 64))
        W = jax.random.normal(k, (128, 64))
        bias = jax.random.normal(k, (128,))
        adapter = ad.init_dora_params(k, W, cfg)
        adapter["B"] = 0.2 * jax.random.normal(k, adapter["B"].shape)
        y = ad.dora_linear(x, W, adapter, cfg, bias=bias)
        y_nb = ad.dora_linear(x, W, adapter, cfg, bias=None)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_nb + bias),
                                   rtol=1e-6, atol=1e-6)

    def test_init_matches_dora(self):
        """At init (B=0), DoRA is an exact no-op: y == x @ Wᵀ."""
        cfg = DoRAConfig(rank=8, alpha=16, mode="eager")
        k = jax.random.PRNGKey(12)
        x = jax.random.normal(k, (8, 64))
        W = jax.random.normal(k, (128, 64))
        adapter = ad.init_dora_params(k, W, cfg)
        y = ad.dora_linear(x, W, adapter, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ W.T),
                                   rtol=1e-5, atol=1e-5)

    def test_stacked_experts(self):
        cfg = DoRAConfig(rank=4, alpha=8, mode="eager")
        k = jax.random.PRNGKey(13)
        E, d_in, d_out = 3, 32, 128
        x = jax.random.normal(k, (E, 5, d_in))
        W = jax.random.normal(k, (E, d_out, d_in))
        adapter = ad.init_dora_params(k, W, cfg)
        y = ad.dora_linear_stacked(x, W, adapter, cfg)
        assert y.shape == (E, 5, d_out)
        for e in range(E):
            ye = ad.dora_linear(x[e], W[e],
                                jax.tree.map(lambda v: v[e], adapter), cfg)
            np.testing.assert_allclose(np.asarray(y[e]), np.asarray(ye),
                                       rtol=1e-5, atol=1e-5)


def test_scaling_rslora():
    assert DoRAConfig(rank=64, alpha=16, rslora=False).scaling == 16 / 64
    assert DoRAConfig(rank=64, alpha=16, rslora=True).scaling == 16 / 8.0
