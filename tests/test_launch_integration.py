"""Launch-layer integration: multi-device SPMD compile of smoke cells
(subprocess — the 8-device XLA flag must not leak into this process), the
training driver end-to-end with resume, and the serving loop.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_spmd_train_cell_compiles_on_8_devices():
    """A reduced (2 pod x 2 data x 2 model) mesh exercise of the full
    train-step sharding: TP + FSDP + SP + adapter congruence + psums."""
    out = _run_subprocess("""
        import jax
        from repro.compat.mesh import make_mesh
        from repro.launch.steps import cell_specs, StepConfig
        from repro.core import DoRAConfig
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        scfg = StepConfig(dora=DoRAConfig(rank=4, alpha=8.0, mode="eager"))
        cell = cell_specs("qwen2-7b", "train_4k", mesh, smoke=True,
                          scfg=scfg)
        with mesh:
            j = jax.jit(cell["step"], in_shardings=cell["in_shardings"],
                        out_shardings=cell["out_shardings"],
                        donate_argnums=cell["donate"])
            compiled = j.lower(*cell["args"]).compile()
        txt = compiled.as_text()
        assert "all-reduce" in txt  # grad sync must exist
        from repro.compat.xla import peak_memory_bytes
        print("COMPILED", peak_memory_bytes(compiled))
    """)
    assert "COMPILED" in out


@pytest.mark.slow
def test_spmd_decode_cell_compiles_on_8_devices():
    out = _run_subprocess("""
        import jax
        from repro.compat.mesh import make_mesh
        from repro.launch.steps import cell_specs, StepConfig
        from repro.core import DoRAConfig
        mesh = make_mesh((2, 4), ("data", "model"))
        scfg = StepConfig(dora=DoRAConfig(rank=4, alpha=8.0, mode="eager"))
        for arch in ("qwen3-32b", "jamba-v0.1-52b"):
            cell = cell_specs(arch, "decode_32k", mesh, smoke=True,
                              scfg=scfg)
            with mesh:
                j = jax.jit(cell["step"],
                            in_shardings=cell["in_shardings"],
                            out_shardings=cell["out_shardings"],
                            donate_argnums=cell["donate"])
                j.lower(*cell["args"]).compile()
            print("OK", arch)
    """)
    assert out.count("OK") == 2


@pytest.mark.slow
def test_train_driver_runs_and_resumes(tmp_path):
    """Train 6 steps, kill, resume to 10 — the resumed run must continue
    from the checkpoint (step numbering) and the data stream must align."""
    from repro.launch.train import train
    import argparse

    def ns(steps, resume):
        return argparse.Namespace(
            arch="phi4-mini-3.8b", smoke=True, steps=steps, batch=2,
            seq=32, rank=4, alpha=8.0, dora_mode="eager",
            norm_impl="factored", lr=1e-3, warmup=2, clip_norm=1.0,
            loss_tokens=None, grad_accum=1, seed=0, data_seed=7,
            ckpt_dir=str(tmp_path), ckpt_every=3, ckpt_keep=2,
            resume=resume, heartbeat_dir=str(tmp_path / "hb"),
            log_every=100)

    out1 = train(ns(6, False))
    assert out1["steps"] == 6
    out2 = train(ns(10, True))
    assert out2["steps"] == 4  # resumed from step 6
    # heartbeats were written
    assert any(f.startswith("host_") for f in os.listdir(tmp_path / "hb"))


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    """ga=4 microbatching must reproduce the full-batch gradient step."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core import DoRAConfig
    from repro.launch.steps import StepConfig, make_train_step
    from repro.models import init_adapters, init_params
    from repro.optim import OptimizerConfig, adamw_init

    mcfg = get_config("qwen2-7b", smoke=True)
    dcfg = DoRAConfig(rank=4, alpha=8.0, mode="eager")
    key = jax.random.PRNGKey(0)
    params = init_params(key, mcfg)
    adapters = init_adapters(jax.random.fold_in(key, 1), mcfg, params,
                             dcfg)
    opt = adamw_init(adapters)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0,
                                mcfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(6), (4, 32), 0,
                                mcfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels}

    outs = {}
    for ga in (1, 4):
        scfg = StepConfig(dora=dcfg, optim=OptimizerConfig(clip_norm=None),
                          grad_accum=ga)
        step = jax.jit(make_train_step(mcfg, scfg, None, batch=4, seq=32))
        ad, _, m = step(params, adapters, opt, batch)
        outs[ga] = (ad, float(m["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-5)
    a1 = jax.tree.leaves(outs[1][0])
    a4 = jax.tree.leaves(outs[4][0])
    for x, y in zip(a1, a4):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=2e-4, atol=2e-6)


@pytest.mark.slow
def test_serve_generate_greedy_deterministic():
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core import DoRAConfig
    from repro.launch.serve import generate
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    mcfg = get_config("musicgen-medium", smoke=True)
    dcfg = DoRAConfig(rank=4, alpha=8.0, mode="eager")
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, 0)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, mcfg.vocab_size, (2, 8), dtype=np.int32)
    t1 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                             gen_len=4, max_len=12))
    t2 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                             gen_len=4, max_len=12))
    assert t1.shape == (2, 12)
    np.testing.assert_array_equal(t1, t2)


@pytest.mark.slow
def test_grad_compression_dp_example():
    """Runs the shard_map int8+EF gradient-sync demo on 8 fake devices."""
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "grad_compression_dp.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, path], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
