"""Matmul-fused compose: tier equivalence + VJP vs the fp64 eager oracle.

The fused kernel computes the LoRA up-projection h@Bᵀ on-chip and composes
delta = (g-1)⊙base + g⊙s⊙(hBᵀ) in the same pass — y_lora is never
materialized. These tests lock (a) the forward against the fp64 oracle at
the golden tolerances of the elementwise-fused kernel, (b) all three
cotangent families (d_base/d_h, d_B, d_g) against autodiff through the
eager form, on both the interpret and eager backends, including
non-multiple-of-block ranks and padded (ragged) row counts, and (c) the
dispatch crossover guard for the new plan flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.adapter as ad
import repro.core.dispatch as dp
from repro.core import DoRAConfig
from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", True)


def _tol(dtype):
    if dtype == jnp.float32:
        return dict(rtol=1e-5, atol=1e-5)
    return dict(rtol=2e-2, atol=2e-2)


def _mk(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _inputs(key, m, n, r, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    base = _mk(k1, (m, n), dtype)
    h = _mk(k2, (m, r), dtype, 0.3)
    B = _mk(k3, (n, r), dtype, 0.3)
    g = 1.0 + 0.0015 * jax.random.normal(k4, (n,), jnp.float32)
    return base, h, B, g


# (rows, d_out, r) — ragged rows and ranks off the 128-lane / 8-sublane
# grid on purpose; the wrapper pads both.
MM_SHAPES = [(8, 128, 4), (64, 256, 16), (100, 384, 11), (17, 2048, 384),
             (256, 1024, 128), (33, 512, 129)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", MM_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_mm_fwd_matches_fp64_oracle(shape, dtype):
    m, n, r = shape
    base, h, B, g = _inputs(jax.random.PRNGKey(0), m, n, r, dtype)
    s = 1.25
    got = ops.fused_compose_mm(base, h, B, g, s, interpret=True,
                               block_m=32, block_n=128)
    want = ref.ref_compose_mm_fp64(base, h, B, g, s)
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want), **_tol(dtype))
    # headline equivalence metric (paper §5.9): cosine vs the fp64 oracle.
    gf = np.asarray(got, np.float64).ravel()
    wf = np.asarray(want).ravel()
    cos = gf @ wf / (np.linalg.norm(gf) * np.linalg.norm(wf))
    assert cos > 0.9999, cos


@pytest.mark.parametrize("dtype", DTYPES)
def test_mm_fwd_3d_input(dtype):
    base, h, B, g = _inputs(jax.random.PRNGKey(1), 4 * 33, 256, 7, dtype)
    base3 = base.reshape(4, 33, 256)
    h3 = h.reshape(4, 33, 7)
    got = ops.fused_compose_mm(base3, h3, B, g, 2.0, interpret=True,
                               block_m=32, block_n=128)
    want = ref.ref_compose_mm(base3, h3, B, g, 2.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("mag_grad", [True, False])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(64, 512, 16), (37, 256, 11)])
def test_mm_grads_match_eager_autodiff(shape, dtype, mag_grad):
    """All three gradient families of the custom VJP == jax.grad through
    the eager (materialized-lora) form, incl. ragged rows/rank."""
    m, n, r = shape
    base, h, B, g = _inputs(jax.random.PRNGKey(2), m, n, r, dtype)
    s = 1.5

    def fused_loss(b, hh, bb, gg):
        out = ops.fused_compose_mm(b, hh, bb, gg, s, mag_grad=mag_grad,
                                   interpret=True, block_m=32, block_n=128)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    def eager_loss(b, hh, bb, gg):
        out = ref.ref_compose_mm(b, hh, bb, gg, s)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    gf = jax.grad(fused_loss, argnums=(0, 1, 2, 3))(base, h, B, g)
    ge = jax.grad(eager_loss, argnums=(0, 1, 2, 3))(base, h, B, g)
    names = ("d_base", "d_h", "d_B", "d_g")
    for got, want, name in zip(gf, ge, names):
        if name == "d_g" and not mag_grad:
            assert np.all(np.asarray(got) == 0.0)
            continue
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            err_msg=name, **_tol(dtype))


def test_mm_grads_vs_fp64_oracle():
    """Gradients against analytic fp64 cotangents (loss = Σ delta²):
    tighter than the eager cross-check — catches a wrong-but-consistent
    pair of implementations."""
    m, n, r = 48, 384, 24
    base, h, B, g = _inputs(jax.random.PRNGKey(3), m, n, r, jnp.float32)
    s = 0.75

    def loss64(b, hh, bb, gg):
        out = ref.ref_compose_mm_fp64(b, hh, bb, gg, s)
        return jnp.sum(out ** 2)

    def loss_k(b, hh, bb, gg):
        out = ops.fused_compose_mm(b, hh, bb, gg, s, interpret=True,
                                   block_m=16, block_n=128)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    g64 = jax.grad(loss64, argnums=(0, 1, 2, 3))(
        base.astype(jnp.float64), h.astype(jnp.float64),
        B.astype(jnp.float64), g.astype(jnp.float64))
    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(base, h, B, g)
    for got, want, name in zip(gk, g64, ("d_base", "d_h", "d_B", "d_g")):
        scale = np.maximum(np.abs(np.asarray(want)), 1.0)
        err = np.abs(np.asarray(got, np.float64) - np.asarray(want)) / scale
        assert np.max(err) < 5e-5, (name, np.max(err))


@pytest.mark.parametrize("mode", ["interpret", "eager"])
def test_dora_linear_tier_equivalence(mode):
    """dora_linear through the matmul-fused plan == the mathematical
    definition — the same closed form TestDoraLinear checks for the other
    tiers (d_out=128 with rank 8 resolves matmul-fused under interpret;
    max rank pinned: at these tiny test rows the rows-aware bytes-model
    guard would otherwise route the small-M call to the materialized
    path)."""
    cfg = DoRAConfig(rank=8, alpha=16, mode=mode, mm_fused_max_rank=128)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(42), 3)
    d_in, d_out = 96, 128
    x = jax.random.normal(k1, (4, 7, d_in), jnp.float32)
    W = jax.random.normal(k2, (d_out, d_in), jnp.float32)
    adapter = ad.init_dora_params(k3, W, cfg)
    adapter["B"] = 0.3 * jax.random.normal(k3, adapter["B"].shape)
    adapter["m"] = adapter["m"] * 1.01
    if mode == "interpret":
        plan = dp.plan_compose(cfg, training=True, rows=28, d_out=d_out,
                               rank=cfg.rank)
        assert plan.matmul_fused
    y = ad.dora_linear(x, W, adapter, cfg, training=True)
    comp = (W.astype(jnp.float64)
            + cfg.scaling * adapter["B"].astype(jnp.float64)
            @ adapter["A"].astype(jnp.float64))
    wn = jnp.linalg.norm(comp, axis=1)
    want = (adapter["m"].astype(jnp.float64) / wn
            * (x.astype(jnp.float64) @ comp.T))
    np.testing.assert_allclose(np.asarray(y, np.float64), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_dora_linear_mm_grads_match_eager_tier():
    """Adapter gradients through the matmul-fused plan == eager tier
    (extends test_compose.test_eager_vs_fused_grads one fusion deeper)."""
    cfg_e = DoRAConfig(rank=8, alpha=16, mode="eager")
    cfg_f = DoRAConfig(rank=8, alpha=16, mode="interpret",
                       mm_fused_max_rank=128)  # small-M: keep mm route on
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    x = jax.random.normal(k1, (16, 128), jnp.float32)
    W = jax.random.normal(k2, (128, 128), jnp.float32)
    adapter = ad.init_dora_params(k3, W, cfg_e)
    adapter["B"] = 0.1 * jax.random.normal(k3, adapter["B"].shape)

    def loss(adp, cfg):
        return jnp.sum(ad.dora_linear(x, W, adp, cfg, training=True) ** 2)

    ge = jax.grad(loss)(adapter, cfg_e)
    gf = jax.grad(loss)(adapter, cfg_f)
    for name in ("A", "B", "m"):
        np.testing.assert_allclose(
            np.asarray(ge[name]), np.asarray(gf[name]),
            rtol=1e-4, atol=1e-4, err_msg=name)


class TestDispatchFlag:
    @pytest.fixture(autouse=True)
    def _own_env(self, monkeypatch):
        monkeypatch.delenv("REPRO_FORCE_TIER", raising=False)
        monkeypatch.delenv("REPRO_DORA_MODE", raising=False)

    def test_flag_set_on_fused_tier(self):
        cfg = DoRAConfig(mode="interpret", rank=384)
        plan = dp.plan_compose(cfg, training=True, rows=4096, d_out=2048,
                               rank=384)
        assert plan.matmul_fused and plan.tier is dp.Tier.FUSED_BWD

    def test_rank_crossover_guard(self):
        cfg = DoRAConfig(mode="interpret")
        # 640 pads to 768 > mm_fused_max_rank=512: B-tile re-reads would
        # exceed the saved y_lora write+read.
        plan = dp.plan_compose(cfg, training=True, rows=4096, d_out=2048,
                               rank=640)
        assert plan.fused and not plan.matmul_fused
        # 384 pads to 384 ≤ 512: eligible.
        assert dp.mm_fused_eligible(384, cfg)
        assert not dp.mm_fused_eligible(None, cfg)

    def test_rows_aware_guard_decode_shaped(self):
        """Decode-shaped rows shrink the grid AND the profitable rank
        range (the B re-read stops amortizing — the committed 0.67x
        decode row of BENCH_compose.json): the bytes-model bound is
        priced at the block the call actually executes."""
        cfg = DoRAConfig(mode="interpret")
        # steady-state rows: bound 2*256 = 512, rank 64 (pads 128) fires
        assert dp.mm_fused_eligible(64, cfg, rows=4096)
        # decode rows=8: block shrinks to 8, bound 16 < 128 -> off
        assert not dp.mm_fused_eligible(64, cfg, rows=8)
        plan = dp.plan_compose(cfg, training=False, rows=8, d_out=4096,
                               rank=64)
        assert plan.fused and not plan.matmul_fused
        # an explicit pin overrides the bytes model (operator's call)
        cfg_pin = DoRAConfig(mode="interpret", mm_fused_max_rank=512)
        assert dp.mm_fused_eligible(64, cfg_pin, rows=8)

    def test_config_kill_switch(self):
        cfg = DoRAConfig(mode="interpret", compose_matmul_fused=False)
        plan = dp.plan_compose(cfg, training=True, rows=4096, d_out=2048,
                               rank=8)
        assert plan.fused and not plan.matmul_fused

    def test_never_on_eager_tier(self):
        cfg = DoRAConfig(mode="eager")
        plan = dp.plan_compose(cfg, training=True, rows=4096, d_out=2048,
                               rank=8)
        assert plan.tier is dp.Tier.EAGER and not plan.matmul_fused

    def test_bad_dout_raises_in_ops(self):
        base = jnp.zeros((8, 100), jnp.float32)
        h = jnp.zeros((8, 4), jnp.float32)
        B = jnp.zeros((100, 4), jnp.float32)
        with pytest.raises(ValueError, match="divisible by 128"):
            ops.fused_compose_mm(base, h, B, jnp.ones((100,)), 1.0,
                                 interpret=True)
