"""Data pipeline: determinism, restart-safety, per-host sharding,
prefetch semantics, and learnability of the synthetic stream."""
from __future__ import annotations

import numpy as np
import pytest

from repro.data import (DataConfig, SyntheticLMDataset, host_shard_slice,
                        make_train_iterator, prefetch)

CFG = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=7)


def test_batches_deterministic():
    a = SyntheticLMDataset(CFG).global_batch_np(5)
    b = SyntheticLMDataset(CFG).global_batch_np(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_batches_differ_across_steps():
    ds = SyntheticLMDataset(CFG)
    assert not np.array_equal(ds.global_batch_np(0)["tokens"],
                              ds.global_batch_np(1)["tokens"])


def test_labels_are_next_tokens():
    g = SyntheticLMDataset(CFG).global_batch_np(0)
    np.testing.assert_array_equal(g["tokens"][:, 1:], g["labels"][:, :-1])


def test_restart_resumes_same_stream():
    """Resume from step N sees exactly the batches an unbroken run sees."""
    it_full = make_train_iterator(CFG)
    batches = [next(it_full) for _ in range(6)]
    it_resumed = make_train_iterator(CFG, start_step=3)
    for i in range(3):
        got = next(it_resumed)
        np.testing.assert_array_equal(got["tokens"],
                                      batches[3 + i]["tokens"])


def test_host_sharding_partitions_global_batch():
    ds = SyntheticLMDataset(CFG)
    g = ds.global_batch_np(2)
    parts = [ds.host_batch_np(2, i, 4) for i in range(4)]
    stacked = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(stacked, g["tokens"])


def test_host_shard_slice_validates():
    with pytest.raises(ValueError):
        host_shard_slice(10, 0, 3)


def test_prefetch_preserves_order():
    it = make_train_iterator(CFG)
    want = [next(it)["tokens"] for _ in range(4)]
    got = []
    pf = prefetch(make_train_iterator(CFG), depth=2)
    for _ in range(4):
        got.append(next(pf)["tokens"])
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_stream_has_learnable_structure():
    """The bigram successor rule must make next-token prediction beatable:
    the fraction of positions following the deterministic rule should be
    close to structure_p."""
    cfg = DataConfig(vocab_size=256, seq_len=128, global_batch=4,
                     structure_p=0.75, seed=3)
    ds = SyntheticLMDataset(cfg)
    g = ds.global_batch_np(0)
    toks = g["tokens"].astype(np.int64)
    succ = (ds._bigram_a * toks[:, :-1] + ds._bigram_b) % cfg.vocab_size
    frac = (toks[:, 1:] == succ).mean()
    assert 0.6 < frac < 0.9, frac
