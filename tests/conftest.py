"""Shared test harness: deterministic seeding + CPU-pinned backend.

Every test draws randomness through the session-fixed seed below (override
with REPRO_TEST_SEED to reproduce a failing sweep under a different draw),
so a tier-1 run is bit-deterministic on a given host. The JAX platform is
pinned to CPU *before* jax initializes so a stray accelerator (or the TPU
plugin's cloud-metadata probing) can never shift numerics between runs.
"""
from __future__ import annotations

import os

# Must happen before the first jax import anywhere in the test session.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

SESSION_SEED = int(os.environ.get("REPRO_TEST_SEED", "20260731"))


@pytest.fixture(scope="session")
def session_seed() -> int:
    """The session-fixed PRNG seed (REPRO_TEST_SEED to override)."""
    return SESSION_SEED


@pytest.fixture
def rng_key(session_seed):
    """A jax PRNG key derived from the session seed."""
    import jax
    return jax.random.PRNGKey(session_seed)


@pytest.fixture
def np_rng(session_seed):
    """A numpy Generator derived from the session seed."""
    return np.random.default_rng(session_seed)


@pytest.fixture(autouse=True)
def _seed_global_numpy(session_seed):
    """Legacy np.random.* callers see the same stream every run."""
    np.random.seed(session_seed % (2**32))
    yield
