"""Frozen-adapter serving state: bitwise cached-vs-recomputed g over a
multi-token decode, the zero-norm-work jaxpr assertion, the training
invalidation contract, the padded-prefill rewind, and the stacked-linear
kwarg forwarding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.adapter as ad
from repro.configs import get_config
from repro.core import (DoRAConfig, dora_linear, dora_linear_stacked,
                        init_dora_params, invalidate_adapter_state,
                        precompute_adapter_state)
from repro.core.compose import magnitude_scale
from repro.core.factored_norm import dtype_eps
from repro.launch.steps import (StepConfig, make_decode_step,
                                make_precompute_step, make_prefill_step)
from repro.launch.train import build_state

ARCH = "phi4-mini-3.8b"


def _state(dcfg, seed=0):
    mcfg = get_config(ARCH, smoke=True)
    scfg = StepConfig(dora=dcfg)
    params, adapters, _ = build_state(mcfg, dcfg, seed)
    return mcfg, scfg, params, adapters


class TestCachedG:
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")

    def test_cached_g_bitwise_equals_recomputed(self):
        """The precomputed g leaf must be BITWISE the g the uncached
        forward computes (same norm route, same eps)."""
        mcfg, scfg, params, adapters = _state(self.DCFG)
        served = make_precompute_step(mcfg, scfg)(params, adapters)
        leaf = served["stack"]["l0"]["mixer"]["wq"]
        raw = adapters["stack"]["l0"]["mixer"]["wq"]
        W = params["stack"]["l0"]["mixer"]["wq"]
        for i in range(W.shape[0]):
            wn = ad.compute_weight_norm(W[i], raw["A"][i], raw["B"][i],
                                        scfg.dora)
            want = magnitude_scale(raw["m"][i], wn, dtype_eps(mcfg.dtype))
            np.testing.assert_array_equal(np.asarray(leaf["g"][i]),
                                          np.asarray(want))

    def test_decode_bitwise_cached_vs_recomputed(self):
        """Multi-token decode: logits with the cached-g tree must be
        bitwise identical to the per-token-norm path, token by token."""
        mcfg, scfg, params, adapters = _state(self.DCFG)
        served = jax.jit(make_precompute_step(mcfg, scfg))(params, adapters)
        B, P, L, G = 2, 6, 12, 4
        rng = np.random.default_rng(3)
        toks = jnp.asarray(rng.integers(0, mcfg.vocab_size, (B, P)),
                           jnp.int32)
        prefill = jax.jit(make_prefill_step(mcfg, scfg, None, batch=B,
                                            seq=L, padded=True))
        decode = jax.jit(make_decode_step(mcfg, scfg, None, batch=B))
        batch_in = {"tokens": jnp.pad(toks, ((0, 0), (0, L - P))),
                    "prompt_len": jnp.asarray(P, jnp.int32)}
        l_raw, c_raw = prefill(params, adapters, batch_in)
        l_srv, c_srv = prefill(params, served, batch_in)
        np.testing.assert_array_equal(np.asarray(l_raw), np.asarray(l_srv))
        for t in range(G):
            nxt = jnp.argmax(l_raw, axis=-1).astype(jnp.int32)[:, None]
            l_raw, c_raw = decode(params, adapters, c_raw, {"tokens": nxt})
            l_srv, c_srv = decode(params, served, c_srv, {"tokens": nxt})
            assert int(c_raw["len"]) == P + t + 1
            np.testing.assert_array_equal(np.asarray(l_raw),
                                          np.asarray(l_srv),
                                          err_msg=f"token {t}")

    def test_decode_jaxpr_has_zero_norm_work(self):
        """The acceptance-criteria trace assertion: the w_norm computation
        (tagged 'dora_wnorm') appears in the precompute and the uncached
        steps, and NOWHERE in prefill/decode once the state is cached."""
        mcfg, scfg, params, adapters = _state(self.DCFG)
        served = make_precompute_step(mcfg, scfg)(params, adapters)
        B, L = 2, 8
        from repro.models import init_cache
        cache = init_cache(mcfg, B, L)
        tok1 = jnp.zeros((B, 1), jnp.int32)
        tokP = jnp.zeros((B, L), jnp.int32)
        decode = make_decode_step(mcfg, scfg, None, batch=B)
        prefill = make_prefill_step(mcfg, scfg, None, batch=B, seq=L)
        pre_jaxpr = str(jax.make_jaxpr(make_precompute_step(mcfg, scfg))(
            params, adapters))
        assert "dora_wnorm" in pre_jaxpr
        assert "dora_wnorm" in str(jax.make_jaxpr(decode)(
            params, adapters, cache, {"tokens": tok1}))
        assert "dora_wnorm" not in str(jax.make_jaxpr(decode)(
            params, served, cache, {"tokens": tok1}))
        assert "dora_wnorm" not in str(jax.make_jaxpr(prefill)(
            params, served, {"tokens": tokP}))

    def test_training_refuses_cached_state(self):
        """Invalidation contract: a tree carrying serving state must be
        rejected by training call sites; stripping it restores training."""
        dcfg = self.DCFG
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (32, 64))
        x = jax.random.normal(jax.random.fold_in(key, 2), (4, 64))
        adp = init_dora_params(jax.random.fold_in(key, 1), W, dcfg)
        served = precompute_adapter_state(W, adp, dcfg)
        with pytest.raises(ValueError, match="invalid under training"):
            dora_linear(x, W, served, dcfg, training=True)
        y_srv = dora_linear(x, W, served, dcfg, training=False)
        stripped = invalidate_adapter_state(served)
        assert set(stripped.keys()) == set(adp.keys())
        y_raw = dora_linear(x, W, stripped, dcfg, training=True)
        np.testing.assert_allclose(np.asarray(y_srv), np.asarray(y_raw),
                                   rtol=1e-6, atol=1e-6)

    def test_precompute_step_with_mesh_pins_serving_shardings(self):
        """make_precompute_step(mesh=...) constrains the cached leaves to
        the serving shardings (gsB row-sharded like B); on the trivial
        1-device mesh the values are bitwise the unconstrained ones."""
        from repro.launch.mesh import make_debug_mesh
        mcfg, scfg, params, adapters = _state(self.DCFG)
        mesh = make_debug_mesh(1, 1)
        srv_m = jax.jit(make_precompute_step(mcfg, scfg, mesh,
                                             fold_gsb=True))(params,
                                                             adapters)
        srv_n = jax.jit(make_precompute_step(mcfg, scfg, None,
                                             fold_gsb=True))(params,
                                                             adapters)
        assert "gsB" in srv_m["stack"]["l0"]["mixer"]["wq"]
        assert jax.tree.structure(srv_m) == jax.tree.structure(srv_n)
        for a, b in zip(jax.tree.leaves(srv_m), jax.tree.leaves(srv_n)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fold_gsb_matches_unfolded(self):
        key = jax.random.PRNGKey(5)
        W = jax.random.normal(key, (128, 64))
        x = jax.random.normal(jax.random.fold_in(key, 2), (4, 64))
        adp = init_dora_params(jax.random.fold_in(key, 1), W, self.DCFG)
        adp["B"] = 0.2 * jax.random.normal(jax.random.fold_in(key, 3),
                                           adp["B"].shape)
        folded = precompute_adapter_state(W, adp, self.DCFG, fold_gsb=True)
        assert "gsB" in folded
        y_f = dora_linear(x, W, folded, self.DCFG, training=False)
        y_u = dora_linear(x, W, adp, self.DCFG, training=False)
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_u),
                                   rtol=1e-5, atol=1e-5)
        # re-precomputing a folded tree without folding must strip the
        # stale gsB (else the allclose-only path silently persists).
        refolded = precompute_adapter_state(W, folded, self.DCFG,
                                            fold_gsb=False)
        assert "gsB" not in refolded and "g" in refolded

    def test_gsb_fast_path_runs_under_sharding_constraint(self):
        """Sharded call sites used to fall off the broadcast-free decode
        compose (the constraint needed a y_lora to pin); with the
        rank-space constraint they take it too — on the trivial 1-device
        mesh the output is bitwise the unconstrained folded one."""
        from jax.sharding import PartitionSpec as P
        from repro.compat.mesh import make_mesh
        from repro.core.sharding import plan_for_output
        key = jax.random.PRNGKey(9)
        W = jax.random.normal(key, (128, 64))
        x = jax.random.normal(jax.random.fold_in(key, 2), (4, 64))
        adp = init_dora_params(jax.random.fold_in(key, 1), W, self.DCFG)
        adp["B"] = 0.2 * jax.random.normal(jax.random.fold_in(key, 3),
                                           adp["B"].shape)
        folded = precompute_adapter_state(W, adp, self.DCFG, fold_gsb=True)
        plan = plan_for_output(make_mesh((1,), ("model",)), P(None, "model"))
        y_c = jax.jit(lambda x: dora_linear(x, W, folded, self.DCFG,
                                            training=False,
                                            constrain=plan))(x)
        y_n = jax.jit(lambda x: dora_linear(x, W, folded, self.DCFG,
                                            training=False))(x)
        np.testing.assert_array_equal(np.asarray(y_c), np.asarray(y_n))


class TestPaddedPrefill:
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")

    def test_padded_prefill_matches_unpadded(self):
        """The serve.py:46 bug, fixed: padded prefill must return the
        logits of the TRUE last prompt token and rewind the cache to P —
        bitwise against an unpadded prefill. prompt_len is TRACED, so one
        jitted prefill is reused across different P (shape-bucketing)."""
        mcfg, scfg, params, adapters = _state(self.DCFG)
        B, L = 2, 11
        rng = np.random.default_rng(7)
        pre_pad = jax.jit(make_prefill_step(mcfg, scfg, None, batch=B,
                                            seq=L, padded=True))
        decode = jax.jit(make_decode_step(mcfg, scfg, None, batch=B))
        for P in (5, 8):  # same compiled prefill serves both lengths
            toks = jnp.asarray(rng.integers(0, mcfg.vocab_size, (B, P)),
                               jnp.int32)
            pre_raw = jax.jit(make_prefill_step(mcfg, scfg, None, batch=B,
                                                seq=L))
            lp, cp = pre_pad(params, adapters,
                             {"tokens": jnp.pad(toks,
                                                ((0, 0), (0, L - P))),
                              "prompt_len": jnp.asarray(P, jnp.int32)})
            lr, cr = pre_raw(params, adapters, {"tokens": toks})
            assert int(cp["len"]) == P, "cache length not rewound to P"
            assert int(cr["len"]) == P
            np.testing.assert_array_equal(np.asarray(lp), np.asarray(lr))
            # decode writes at position P: the first generated K/V row
            # lands there.
            nxt = jnp.argmax(lp, axis=-1).astype(jnp.int32)[:, None]
            _, cp2 = decode(params, adapters, cp, {"tokens": nxt})
            _, cr2 = decode(params, adapters, cr, {"tokens": nxt})
            assert int(cp2["len"]) == P + 1
            np.testing.assert_array_equal(
                np.asarray(cp2["stack"]["l0"]["k"][:, :, P]),
                np.asarray(cr2["stack"]["l0"]["k"][:, :, P]))
        assert pre_pad._cache_size() == 1, "padded prefill retraced per P"

    def test_padded_prefill_rejects_ssm_archs(self):
        mcfg = get_config("falcon-mamba-7b", smoke=True)
        scfg = StepConfig(dora=self.DCFG)
        with pytest.raises(ValueError, match="attention-only"):
            make_prefill_step(mcfg, scfg, None, batch=2, seq=8,
                              padded=True)

    def test_generate_end_to_end_padded_equals_exact(self):
        from repro.launch.serve import generate
        mcfg, scfg, params, adapters = _state(self.DCFG)
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, mcfg.vocab_size, (2, 6), dtype=np.int32)
        t1 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                                 gen_len=4, max_len=10))
        t2 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                                 gen_len=4, max_len=10,
                                 cache_adapters=False))
        np.testing.assert_array_equal(t1, t2)


class TestDecodeLoopContract:
    """The prefill/decode cache-length contract: hard errors (the
    satellite keeps them), but behind a debug switch — the serving path
    no longer pays an int(cache['len']) device sync per prefill."""
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")

    def _loop_parts(self):
        from repro.launch.steps import make_decode_step, make_prefill_step
        mcfg, scfg, params, adapters = _state(self.DCFG)
        B, L = 2, 10
        prefill = jax.jit(make_prefill_step(mcfg, scfg, None, batch=B,
                                            seq=L, padded=True))
        decode = jax.jit(make_decode_step(mcfg, scfg, None, batch=B))
        rng = np.random.default_rng(11)
        toks = jnp.asarray(rng.integers(0, mcfg.vocab_size, (B, 6)),
                           jnp.int32)
        return params, adapters, prefill, decode, toks, L - 6

    def test_contract_violation_raises_when_checked(self):
        from repro.launch.serve import _decode_loop
        params, adapters, prefill, decode, toks, pad = self._loop_parts()

        def bad_prefill(p, a, b):
            logits, cache = prefill(p, a, b)
            return logits, {**cache, "len": cache["len"] + 1}

        with pytest.raises(RuntimeError, match="prefill left cache"):
            _decode_loop(bad_prefill, decode, params, adapters, toks,
                         prompt_len=6, gen_len=2, pad=pad, temperature=0.0,
                         seed=0, check_contract=True)

        def bad_decode(p, a, c, b):
            logits, cache = decode(p, a, c, b)
            return logits, {**cache, "len": cache["len"] - 1}

        with pytest.raises(RuntimeError, match="decode wrote at"):
            _decode_loop(prefill, bad_decode, params, adapters, toks,
                         prompt_len=6, gen_len=2, pad=pad, temperature=0.0,
                         seed=0, check_contract=True)

    def test_checks_off_by_default_no_host_sync(self, monkeypatch):
        """Default serving: the SAME violations pass through unchecked —
        proof the blocking int() sync is no longer on the hot path — and
        REPRO_SERVE_DEBUG=1 turns the guard back on without a code
        change."""
        from repro.launch.serve import _decode_loop
        monkeypatch.delenv("REPRO_SERVE_DEBUG", raising=False)
        params, adapters, prefill, decode, toks, pad = self._loop_parts()

        def bad_prefill(p, a, b):
            logits, cache = prefill(p, a, b)
            return logits, {**cache, "len": cache["len"] + 1}

        # violation NOT detected (check skipped)...
        out, _ = _decode_loop(bad_prefill, decode, params, adapters, toks,
                              prompt_len=6, gen_len=2, pad=pad,
                              temperature=0.0, seed=0)
        assert out.shape == (2, 8)
        # ...until the env switch re-enables the guard
        monkeypatch.setenv("REPRO_SERVE_DEBUG", "1")
        with pytest.raises(RuntimeError, match="prefill left cache"):
            _decode_loop(bad_prefill, decode, params, adapters, toks,
                         prompt_len=6, gen_len=2, pad=pad,
                         temperature=0.0, seed=0)

    def test_generate_forwards_check_contract(self):
        from repro.launch.serve import generate
        mcfg, scfg, params, adapters = _state(self.DCFG)
        rng = np.random.default_rng(12)
        prompts = rng.integers(0, mcfg.vocab_size, (2, 6), dtype=np.int32)
        t1 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                                 gen_len=2, max_len=10,
                                 check_contract=True))
        t2 = np.asarray(generate(mcfg, params, adapters, scfg, prompts,
                                 gen_len=2, max_len=10,
                                 check_contract=False))
        np.testing.assert_array_equal(t1, t2)


class TestStackedKwargs:
    DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")

    def _stack(self, key, E=3, d_in=32, d_out=128):
        W = jax.random.normal(key, (E, d_out, d_in))
        x = jax.random.normal(jax.random.fold_in(key, 1), (E, 5, d_in))
        adp = init_dora_params(jax.random.fold_in(key, 2), W, self.DCFG)
        bias = jax.random.normal(jax.random.fold_in(key, 3), (E, d_out))
        return W, x, adp, bias

    def test_bias_and_training_forwarded(self):
        W, x, adp, bias = self._stack(jax.random.PRNGKey(13))
        y = dora_linear_stacked(x, W, adp, self.DCFG, bias=bias,
                                training=False)
        for e in range(W.shape[0]):
            ye = dora_linear(x[e], W[e],
                             jax.tree.map(lambda v: v[e], adp), self.DCFG,
                             bias=bias[e], training=False)
            np.testing.assert_allclose(np.asarray(y[e]), np.asarray(ye),
                                       rtol=1e-5, atol=1e-5)

    def test_base_sq_cache_forwarded_and_live(self):
        """A poisoned stacked cache must change the output — proves the
        kwarg actually reaches the per-slice norm fast path."""
        W, x, adp, _ = self._stack(jax.random.PRNGKey(14))
        adp["B"] = 0.2 * jax.random.normal(jax.random.PRNGKey(15),
                                           adp["B"].shape)
        base_sq = jnp.sum(W.astype(jnp.float32) ** 2, axis=2)
        y_ref = dora_linear_stacked(x, W, adp, self.DCFG)
        y_cached = dora_linear_stacked(x, W, adp, self.DCFG,
                                       base_sq_cache=base_sq)
        np.testing.assert_allclose(np.asarray(y_cached), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
        y_bad = dora_linear_stacked(x, W, adp, self.DCFG,
                                    base_sq_cache=base_sq * 4.0)
        assert not np.allclose(np.asarray(y_bad), np.asarray(y_ref))

    def test_stacked_serving_state(self):
        """Stacked leaves (experts) carry the cached g too."""
        W, x, adp, _ = self._stack(jax.random.PRNGKey(16))
        served = precompute_adapter_state(W, adp, self.DCFG)
        assert served["g"].shape == adp["m"].shape
        y_srv = dora_linear_stacked(x, W, served, self.DCFG,
                                    training=False)
        y_raw = dora_linear_stacked(x, W, adp, self.DCFG, training=False)
        np.testing.assert_array_equal(np.asarray(y_srv), np.asarray(y_raw))
