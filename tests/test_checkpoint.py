"""Checkpoint/fault-tolerance: atomic commit, resume, GC, corruption
detection, preemption, straggler monitor."""
from __future__ import annotations

import json
import os
import signal
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointConfig, Heartbeat,
                              PreemptionHandler, StragglerMonitor,
                              garbage_collect, latest_step,
                              restore_checkpoint, save_checkpoint)


def _state(v=1.0):
    return {"adapters": {"A": jnp.full((3, 2), v), "m": jnp.ones((3,))},
            "opt": {"count": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    cfg = CheckpointConfig(str(tmp_path), keep=3)
    save_checkpoint(cfg, 10, _state(2.5))
    restored, step = restore_checkpoint(cfg, _state(0.0))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["adapters"]["A"]),
                                  np.full((3, 2), 2.5))
    assert int(restored["opt"]["count"]) == 7


def test_latest_points_to_newest_commit(tmp_path):
    cfg = CheckpointConfig(str(tmp_path), keep=5)
    for s in (1, 2, 5):
        save_checkpoint(cfg, s, _state(float(s)))
    assert latest_step(cfg) == 5
    restored, step = restore_checkpoint(cfg, _state())
    assert step == 5
    assert float(restored["adapters"]["A"][0, 0]) == 5.0


def test_no_checkpoint_cold_start(tmp_path):
    cfg = CheckpointConfig(str(tmp_path))
    restored, step = restore_checkpoint(cfg, _state())
    assert restored is None and step is None


def test_gc_keeps_k(tmp_path):
    cfg = CheckpointConfig(str(tmp_path), keep=2)
    for s in range(1, 6):
        save_checkpoint(cfg, s, _state())
    dirs = sorted(p for p in os.listdir(tmp_path) if p.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]
    # newest still restorable
    _, step = restore_checkpoint(cfg, _state())
    assert step == 5


def test_corrupt_shard_detected(tmp_path):
    cfg = CheckpointConfig(str(tmp_path))
    d = save_checkpoint(cfg, 1, _state())
    shard = os.path.join(d, "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(30)
        f.write(b"\xde\xad")
    with pytest.raises(IOError, match="hash mismatch"):
        restore_checkpoint(cfg, _state())


def test_shape_mismatch_rejected(tmp_path):
    cfg = CheckpointConfig(str(tmp_path))
    save_checkpoint(cfg, 1, _state())
    bad = {"adapters": {"A": jnp.zeros((4, 2)), "m": jnp.ones((3,))},
           "opt": {"count": jnp.asarray(0, jnp.int32)}}
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(cfg, bad)


def test_model_axis_guard(tmp_path):
    cfg = CheckpointConfig(str(tmp_path))
    save_checkpoint(cfg, 1, _state(), mesh_meta={"model": 16})
    restored, _ = restore_checkpoint(cfg, _state(), expect_model_axis=16)
    assert restored is not None
    with pytest.raises(ValueError, match="model axis"):
        restore_checkpoint(cfg, _state(), expect_model_axis=8)


def test_tmp_dir_never_visible_as_checkpoint(tmp_path):
    """A .tmp directory (simulated crash mid-write) is not restorable."""
    cfg = CheckpointConfig(str(tmp_path))
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(cfg) is None


def test_preemption_handler_catches_sigterm():
    with PreemptionHandler() as h:
        assert not h.preempted
        os.kill(os.getpid(), signal.SIGTERM)
        time.sleep(0.05)
        assert h.preempted


def test_preemption_handler_catches_sigint_by_default():
    """SIGINT is handled as documented: the flag is set and NO
    KeyboardInterrupt escapes — an operator Ctrl-C takes the same
    checkpoint-then-exit path as a cloud SIGTERM."""
    with PreemptionHandler() as h:
        assert not h.preempted
        os.kill(os.getpid(), signal.SIGINT)   # would raise if unhandled
        time.sleep(0.05)
        assert h.preempted
    # handler uninstalled on exit: SIGINT raises again outside the block
    with pytest.raises(KeyboardInterrupt):
        os.kill(os.getpid(), signal.SIGINT)
        time.sleep(0.05)


def test_preemption_handler_explicit_signals_opt_out():
    """signals=(SIGTERM,) leaves SIGINT alone (the pre-fix default)."""
    with PreemptionHandler(signals=(signal.SIGTERM,)) as h:
        with pytest.raises(KeyboardInterrupt):
            os.kill(os.getpid(), signal.SIGINT)
            time.sleep(0.05)
        assert not h.preempted


def test_heartbeat_and_straggler_monitor(tmp_path):
    d = str(tmp_path / "hb")
    for i in range(4):
        Heartbeat(d, i).beat(step=100)
    Heartbeat(d, 4).beat(step=50)  # lagging host
    mon = StragglerMonitor(d, step_slack=5, dead_after_s=1e9)
    assert mon.stragglers() == ["host_00004.json"]
    assert not mon.healthy(expected_hosts=5)
    Heartbeat(d, 4).beat(step=101)
    assert mon.healthy(expected_hosts=5)


def test_straggler_dead_host_detection(tmp_path):
    d = str(tmp_path / "hb")
    Heartbeat(d, 0).beat(step=10)
    # Fake an ancient beat for host 1.
    with open(os.path.join(d, "host_00001.json"), "w") as f:
        json.dump({"step": 10, "time": time.time() - 1e4}, f)
    mon = StragglerMonitor(d, dead_after_s=300)
    assert "host_00001.json" in mon.stragglers()
