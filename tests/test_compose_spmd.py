"""SPMD-aware matmul-fused compose: plan logic, trivial-mesh equivalence,
and sharded-vs-unsharded parity on forced multi-device CPU meshes.

The tentpole contract (ROADMAP open item #1, closed): sharded call sites
constrain the rank-space intermediate ``h`` instead of a materialized
``y_lora``, so the matmul-fused kernel keeps firing under SPMD — the
forward is shard-local (shard_map with block specs derived from the mesh
axis sizes) and the jaxpr contains no ``[M, d_out]`` y_lora dot anywhere.

Multi-device tests run in a subprocess: the
``--xla_force_host_platform_device_count`` XLA flag must be set before jax
initializes, and must not leak into this (CPU-pinned, 1-device) process.
Inside the subprocess:

  - the matmul-fused route is selected for a row-sharded d_out layer and
    the outputs (served logits, cached g) are BITWISE the unsharded
    reference's in fp32 — block shapes are pinned so both programs tile
    identically, and the serving state is precomputed once so both
    consume the same g (recomputing the norm under different GSPMD
    partitionings moves single ulps — that path is asserted allclose);
  - the jaxpr dot_general census: exactly ONE full-width dot (y_base)
    on the fused route, TWO (y_base + materialized y_lora) with the
    fusion disabled;
  - the full VJP (d_base / d_h→d_A / d_B / d_g with cross-shard psums)
    matches the fp64 eager oracle.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.core.adapter as ad
import repro.core.dispatch as dp
from repro.compat.mesh import make_mesh
from repro.core import DoRAConfig, init_dora_params
from repro.core.sharding import (ComposeSharding, as_compose_sharding,
                                 plan_for_output)
from repro.kernels import dora_compose as ck

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class FakeMesh:
    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


MESH = FakeMesh(data=8, model=4)


# ---------------------------------------------------------------------------
# Plan derivation logic (pure, FakeMesh).
# ---------------------------------------------------------------------------

class TestComposeSharding:
    def test_sp_plan_derivations(self):
        """Sequence-parallel output: rows sharded, d_out replicated."""
        plan = ComposeSharding(MESH, P("data", "model", None))
        assert plan.row_axes == ("data", "model")
        assert plan.dout_axes == ()
        assert plan.dout_shards == 1 and plan.row_shards == 32
        assert plan.h_spec == P("data", "model", None)
        assert plan.b_spec == P(None, None)
        assert plan.vec_spec == P(None)
        assert plan.flat2d() == (("data", "model"), None)

    def test_tp_plan_derivations(self):
        """Row-sharded d_out: B/g congruent, h rank-replicated."""
        plan = ComposeSharding(MESH, P("data", None, "model"))
        assert plan.row_axes == ("data",)
        assert plan.dout_axes == ("model",)
        assert plan.dout_shards == 4
        assert plan.h_spec == P("data", None, None)
        assert plan.b_spec == P("model", None)
        assert plan.vec_spec == P("model")
        assert plan.flat2d() == ("data", "model")
        assert plan.local_dout(512) == 128

    def test_kernel_expressible(self):
        plan = ComposeSharding(MESH, P(None, None, "model"))
        assert plan.kernel_expressible(512)       # 512/4 = 128 ✓
        assert not plan.kernel_expressible(256)   # 256/4 = 64 < 128 lanes
        assert not plan.kernel_expressible(300)   # does not divide 4
        sp = ComposeSharding(MESH, P("data", "model", None))
        assert sp.kernel_expressible(128)         # unsharded d_out: global

    def test_as_compose_sharding(self):
        plan = ComposeSharding(MESH, P(None, "model"))
        assert as_compose_sharding(plan) is plan
        fn = lambda x: x  # noqa: E731
        assert as_compose_sharding(fn) is None
        fn.plan = plan
        assert as_compose_sharding(fn) is plan
        assert as_compose_sharding(None) is None

    def test_tuple_entry_axes(self):
        plan = ComposeSharding(MESH, P(("data", "model"), None))
        assert plan.row_shards == 32 and plan.flat2d() == (
            ("data", "model"), None)


class TestBDoutAxes:
    """The ROADMAP ``b_spec`` gap: a B whose d_out is FSDP-sharded beyond
    the output's feature axes. Declared axes widen b_spec/vec_spec, make
    the shard-local kernel inexpressible (clean materialized fallback),
    and fused_compose_mm refuses such a plan loudly."""

    def test_b_spec_widened(self):
        plan = ComposeSharding(MESH, P(None, None, "model"),
                               b_dout_axes=("data",))
        assert plan.b_spec == P(("model", "data"), None)
        assert plan.vec_spec == P(("model", "data"))
        # output-side derivations are untouched
        assert plan.dout_axes == ("model",)
        assert plan.h_spec == P(None, None, None)

    def test_b_spec_unchanged_without_declaration(self):
        plan = ComposeSharding(MESH, P(None, None, "model"))
        assert plan.b_spec == P("model", None)

    def test_congruent_axes_dedup(self):
        """b_dout_axes already carried by the output d_out are harmless
        (no double-naming, still kernel-expressible)."""
        plan = ComposeSharding(MESH, P(None, None, "model"),
                               b_dout_axes=("model",))
        assert plan.b_spec == P("model", None)
        assert plan.kernel_expressible(512)

    def test_extra_axes_break_kernel_expressibility(self):
        plan = ComposeSharding(MESH, P(None, None, "model"),
                               b_dout_axes=("data",))
        assert not plan.kernel_expressible(512)

    def test_dispatch_falls_back_cleanly(self):
        cfg = DoRAConfig(mode="interpret", rank=8)
        plan = ComposeSharding(MESH, P(None, "model"),
                               b_dout_axes=("data",))
        kp = dp.plan_compose(cfg, training=True, rows=4096, d_out=512,
                             rank=8, sharding=plan)
        assert kp.tier is dp.Tier.EAGER and kp.sharding is None

    def test_fused_compose_mm_refuses_plan_naming_spec(self):
        from repro.kernels import ops
        plan = ComposeSharding(MESH, P(None, "model"),
                               b_dout_axes=("data",))
        base = jnp.zeros((8, 512), jnp.float32)
        h = jnp.zeros((8, 8), jnp.float32)
        B = jnp.zeros((512, 8), jnp.float32)
        g = jnp.ones((512,), jnp.float32)
        with pytest.raises(ValueError) as ei:
            ops.fused_compose_mm(base, h, B, g, 2.0, interpret=True,
                                 sharding=plan)
        assert "b_spec" in str(ei.value) and "data" in str(ei.value)

    def test_plan_for_output_threads_axes(self):
        from repro.core.sharding import plan_for_output
        plan = plan_for_output(MESH, P(None, "model"),
                               b_dout_axes=("data",))
        assert plan.b_dout_axes == ("data",)
        assert hash(plan) == hash(plan)   # still lru-cache keyable

    def test_row_parallel_b_axes_derivation(self):
        from repro.launch import sharding as LS
        mcfg = __import__("repro.configs", fromlist=["get_config"]) \
            .get_config("qwen2-7b", smoke=True)
        # no FSDP axes on the debug mesh (fsdp prefers the absent 'pod',
        # and size-1 axes are dropped): the plan stays unchanged
        assert LS.row_parallel_b_axes(mcfg, FakeMesh(data=1, model=1)) == ()
        assert LS.row_parallel_b_axes(mcfg, FakeMesh(data=8, model=4)) == ()
        # a multi-pod mesh FSDP-shards d_model over pod (wo and w_down
        # agree: heads divide model=4, so wo keeps the plain fsdp role)
        pod_mesh = FakeMesh(pod=2, data=8, model=4)
        if mcfg.d_model % 2 == 0:
            assert LS.row_parallel_b_axes(mcfg, pod_mesh) == ("pod",)
        # heads do NOT divide model=3: wo degrades to fsdp_gather
        # (('pod','data')) while w_down stays fsdp (('pod',)) — the one
        # shared plan cannot declare both, so the declaration is dropped
        # rather than pinning either weight to a WRONG layout
        assert LS.row_parallel_b_axes(
            mcfg, FakeMesh(pod=2, data=8, model=3)) == ()

    def test_gsb_path_constrains_b_on_trivial_mesh(self):
        """The folded-gsB serving path applies constrain_b under a
        declared-FSDP plan; on a trivial mesh values are bitwise."""
        from repro.compat.mesh import make_mesh
        from repro.core import precompute_adapter_state
        from repro.core.sharding import plan_for_output
        cfg = DoRAConfig(rank=8, alpha=16, mode="eager")
        key = jax.random.PRNGKey(3)
        W = jax.random.normal(key, (128, 64))
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, 64))
        adp = init_dora_params(jax.random.fold_in(key, 2), W, cfg)
        adp["B"] = 0.2 * jax.random.normal(jax.random.fold_in(key, 3),
                                           adp["B"].shape)
        folded = precompute_adapter_state(W, adp, cfg, fold_gsb=True)
        mesh = make_mesh((1, 1), ("data", "model"))
        plan = plan_for_output(mesh, P(None, "model"),
                               b_dout_axes=("data",))
        y_c = jax.jit(lambda x: ad.dora_linear(
            x, W, folded, cfg, training=False, constrain=plan))(x)
        y_n = jax.jit(lambda x: ad.dora_linear(
            x, W, folded, cfg, training=False))(x)
        np.testing.assert_array_equal(np.asarray(y_c), np.asarray(y_n))


class TestDispatchWithSharding:
    @pytest.fixture(autouse=True)
    def _own_env(self, monkeypatch):
        monkeypatch.delenv("REPRO_FORCE_TIER", raising=False)
        monkeypatch.delenv("REPRO_DORA_MODE", raising=False)

    def test_expressible_plan_rides_kernel_plan(self):
        cfg = DoRAConfig(mode="interpret", rank=8)
        plan = ComposeSharding(MESH, P(None, "model"))
        kp = dp.plan_compose(cfg, training=True, rows=4096, d_out=512,
                             rank=8, sharding=plan)
        assert kp.matmul_fused and kp.sharding is plan

    def test_inexpressible_plan_falls_back_to_eager(self):
        cfg = DoRAConfig(mode="interpret", rank=8)
        plan = ComposeSharding(MESH, P(None, "model"))
        kp = dp.plan_compose(cfg, training=True, rows=4096, d_out=256,
                             rank=8, sharding=plan)   # 256/4 = 64 lanes
        assert kp.tier is dp.Tier.EAGER and kp.sharding is None

    def test_plan_dropped_when_not_mm_fused(self):
        cfg = DoRAConfig(mode="interpret", compose_matmul_fused=False)
        plan = ComposeSharding(MESH, P(None, "model"))
        kp = dp.plan_compose(cfg, training=True, rows=4096, d_out=512,
                             rank=8, sharding=plan)
        assert kp.fused and not kp.matmul_fused and kp.sharding is None

    def test_indivisible_rows_fall_back_to_eager(self):
        """Rows that do not divide the plan's row axes cannot run
        shard-local; the plan is inexpressible and dispatch drops cleanly
        to the constrained materialized path instead of silently running
        a global kernel on sharded operands."""
        cfg = DoRAConfig(mode="interpret", rank=8)
        plan = ComposeSharding(MESH, P(("data", "model"), None))  # 32-way
        kp = dp.plan_compose(cfg, training=True, rows=4104, d_out=512,
                             rank=8, sharding=plan)   # 4104 % 32 != 0
        assert kp.tier is dp.Tier.EAGER and kp.sharding is None
        kp = dp.plan_compose(cfg, training=True, rows=4096, d_out=512,
                             rank=8, sharding=plan)   # 4096 % 32 == 0
        assert kp.matmul_fused and kp.sharding is plan


class TestConfigBlockKnobs:
    def test_mm_block_rows_defaults_to_block_rows(self):
        assert DoRAConfig().resolve_mm_block_rows() == 256
        assert DoRAConfig(block_rows=128).resolve_mm_block_rows() == 128
        assert DoRAConfig(mm_block_rows=64).resolve_mm_block_rows() == 64

    def test_decode_shaped_grid_shrinks(self):
        cfg = DoRAConfig()
        assert cfg.resolve_mm_block_rows(rows=2) == 8    # sublane floor
        assert cfg.resolve_mm_block_rows(rows=21) == 24  # round up to 8
        assert cfg.resolve_mm_block_rows(rows=4096) == 256

    def test_max_rank_derived_from_configured_block(self):
        assert DoRAConfig().resolve_mm_fused_max_rank() == 512
        assert DoRAConfig(block_rows=128).resolve_mm_fused_max_rank() == 256
        # mm_block_rows overrides block_rows in the derivation
        assert DoRAConfig(block_rows=128, mm_block_rows=256) \
            .resolve_mm_fused_max_rank() == 512
        # explicit pin outranks both
        assert DoRAConfig(mm_block_rows=64, mm_fused_max_rank=384) \
            .resolve_mm_fused_max_rank() == 384

    def test_mm_block_rows_validated(self):
        with pytest.raises(ValueError, match="mm_block_rows"):
            DoRAConfig(mm_block_rows=0)


class TestLocalBlockShape:
    def test_sharded_blocks_derive_from_local_shard(self):
        bm, bn = ck.local_block_shape(4096, 1024, dout_shards=4,
                                      block_m=256, block_n=1024)
        assert (bm, bn) == (256, 256)   # n_local = 256
        bm, bn = ck.local_block_shape(64, 512, row_shards=4, dout_shards=2,
                                      block_m=256, block_n=1024)
        assert (bm, bn) == (16, 256)    # m_local = 16, n_local = 256

    def test_lane_violation_raises(self):
        with pytest.raises(ValueError, match="128-lane"):
            ck.local_block_shape(64, 256, dout_shards=4)


# ---------------------------------------------------------------------------
# Trivial one-device mesh: the unsharded path IS the plan's instance.
# ---------------------------------------------------------------------------

class TestTrivialMesh:
    # max rank pinned: the rows-aware bytes-model guard would otherwise
    # route these deliberately tiny shapes to the materialized path.
    CFG = DoRAConfig(rank=8, alpha=16, mode="interpret",
                     mm_fused_max_rank=128)

    def _layer(self, d_in=96, d_out=256, rows=(4, 8)):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(11), 3)
        x = jax.random.normal(k1, rows + (d_in,), jnp.float32)
        W = jax.random.normal(k2, (d_out, d_in), jnp.float32)
        adp = init_dora_params(k3, W, self.CFG)
        adp["B"] = 0.3 * jax.random.normal(k3, adp["B"].shape)
        return x, W, adp

    def test_one_device_plan_is_bitwise_the_unsharded_path(self):
        """A plan on a 1-device mesh must change nothing: same kernels,
        same tiles, bitwise-identical output and gradients."""
        x, W, adp = self._layer()
        mesh = make_mesh((1,), ("model",))
        plan = plan_for_output(mesh, P(None, None, "model"))
        kp = dp.plan_compose(self.CFG, training=True, rows=32, d_out=256,
                             rank=8, sharding=plan)
        assert kp.matmul_fused and kp.sharding is plan

        def f(c):
            return jax.jit(lambda x: ad.dora_linear(
                x, W, adp, self.CFG, training=True, constrain=c))(x)

        np.testing.assert_array_equal(np.asarray(f(plan)),
                                      np.asarray(f(None)))

        def make_loss(c):
            def loss(a):
                return jnp.sum(ad.dora_linear(
                    x, W, a, self.CFG, training=True, constrain=c) ** 2)
            return loss

        g_p = jax.jit(jax.grad(make_loss(plan)))(adp)
        g_n = jax.jit(jax.grad(make_loss(None)))(adp)
        for k in ("A", "B", "m"):
            np.testing.assert_allclose(
                np.asarray(g_p[k]), np.asarray(g_n[k]), rtol=1e-6,
                atol=1e-6, err_msg=k)

    def test_stacked_forwards_constrain(self):
        """dora_linear_stacked threads the plan into every slice."""
        mesh = make_mesh((1,), ("model",))
        plan = plan_for_output(mesh, P(None, "model"))
        key = jax.random.PRNGKey(5)
        W = jax.random.normal(key, (3, 128, 64))
        x = jax.random.normal(jax.random.fold_in(key, 1), (3, 16, 64))
        adp = init_dora_params(jax.random.fold_in(key, 2), W, self.CFG)
        y_p = ad.dora_linear_stacked(x, W, adp, self.CFG, constrain=plan)
        y_n = ad.dora_linear_stacked(x, W, adp, self.CFG)
        np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_n))

    def test_bare_callable_still_constrains_h_not_ylora(self):
        """A plain row-constraint callable (no .plan) routes through the
        factored path too — y_lora is never materialized just to be
        pinned (the deleted special case stays deleted)."""
        x, W, adp = self._layer()
        calls = []

        def cfn(t):
            calls.append(t.shape)
            return t

        y = ad.dora_linear(x, W, adp, self.CFG, training=True,
                           constrain=cfn)
        y_ref = ad.dora_linear(x, W, adp, self.CFG, training=True)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
        # constrained tensors: y_base [4,8,256] and the RANK-space h
        # [4,8,8] — never a [4,8,256] y_lora (y_base is the only full-width
        # constrained tensor).
        assert (4, 8, 8) in calls
        assert calls.count((4, 8, 256)) == 1


# ---------------------------------------------------------------------------
# Forced multi-device meshes (subprocess; 2- and 4-device).
# ---------------------------------------------------------------------------

def _run_subprocess(code: str, devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC
    env.pop("REPRO_FORCE_TIER", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


_SPMD_PARITY = """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    import repro.core.adapter as ad
    import repro.core.dispatch as dp
    from repro.compat.mesh import make_mesh
    from repro.core import DoRAConfig, init_dora_params, \\
        precompute_adapter_state
    from repro.kernels import ops, ref

    NDEV = {ndev}
    assert jax.device_count() == NDEV
    mesh = make_mesh((NDEV,), ("model",))
    d_in, d_out, rank = 96, 512, 8
    rows = (4, 8)
    M = 32
    # Pin the tile shapes so the sharded and unsharded programs tile
    # identically (block_n = the smallest local shard's width, block_m
    # = the smallest local row count): bitwise parity is then exact.
    # (mm_fused_max_rank pinned: the tiny block_m would otherwise derive
    # a sub-128 rank bound and disable the fusion we are testing.)
    cfg = DoRAConfig(rank=rank, alpha=16, mode="interpret",
                     block_cols=512 // NDEV, mm_block_rows=8,
                     mm_fused_max_rank=512)

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, rows + (d_in,), jnp.float32)
    W = jax.random.normal(k2, (d_out, d_in), jnp.float32)
    adp = init_dora_params(k3, W, cfg)
    adp["B"] = 0.3 * jax.random.normal(k3, adp["B"].shape)
    served = precompute_adapter_state(W, adp, cfg, act_dtype=jnp.float32)

    tp_plan = dp.ComposeSharding(mesh, P(None, None, "model"))
    sp_plan = dp.ComposeSharding(mesh, P(None, "model", None))

    # 1. the matmul-fused route is selected for the row-sharded d_out layer
    kp = dp.plan_compose(cfg, training=False, rows=M, d_out=d_out,
                         rank=rank, sharding=tp_plan)
    assert kp.matmul_fused and kp.sharding is tp_plan, kp
    assert kp.tier is dp.Tier.FUSED_FWD

    # 2. served logits: bitwise vs the unsharded reference, both layouts
    def logits(adapters, plan):
        return jax.jit(lambda x: ad.dora_linear(
            x, W, adapters, cfg, training=False, constrain=plan))(x)

    y_ref = logits(served, None)
    for name, plan in (("tp", tp_plan), ("sp", sp_plan)):
        y = logits(served, plan)
        assert bool(jnp.all(y == y_ref)), (
            name, float(jnp.max(jnp.abs(y - y_ref))))
    print("BITWISE_OK")

    # 3. training path (norm recomputed under GSPMD): tight allclose
    def train_out(plan):
        return jax.jit(lambda x: ad.dora_linear(
            x, W, adp, cfg, training=True, constrain=plan))(x)

    np.testing.assert_allclose(np.asarray(train_out(tp_plan)),
                               np.asarray(train_out(None)),
                               rtol=2e-6, atol=2e-6)
    print("TRAIN_ALLCLOSE_OK")

    # 4. jaxpr census: exactly ONE full-width dot_general (y_base) on the
    #    fused route; TWO (y_base + materialized y_lora) with fusion off.
    def count_full_dots(fn, *args):
        count = 0
        def walk(jaxpr):
            nonlocal count
            for eq in jaxpr.eqns:
                if eq.primitive.name == "dot_general":
                    for v in eq.outvars:
                        if tuple(v.aval.shape) in ((M, d_out),
                                                   rows + (d_out,)):
                            count += 1
                for sub in eq.params.values():
                    subs = sub if isinstance(sub, (list, tuple)) else [sub]
                    for s2 in subs:
                        if hasattr(s2, "jaxpr"):
                            walk(s2.jaxpr)
        walk(jax.make_jaxpr(fn)(*args).jaxpr)
        return count

    n_fused = count_full_dots(lambda x: ad.dora_linear(
        x, W, served, cfg, training=False, constrain=tp_plan), x)
    cfg_off = DoRAConfig(rank=rank, alpha=16, mode="interpret",
                         compose_matmul_fused=False)
    n_off = count_full_dots(lambda x: ad.dora_linear(
        x, W, served, cfg_off, training=False, constrain=tp_plan), x)
    assert n_fused == 1 and n_off == 2, (n_fused, n_off)
    print("JAXPR_OK")

    # 5. sharded VJP vs the fp64 eager oracle (all four cotangents,
    #    including the cross-shard psums of d_h / d_B / d_g).
    jax.config.update("jax_enable_x64", True)
    base = jax.random.normal(jax.random.fold_in(k1, 1), (M, d_out),
                             jnp.float32)
    h = 0.3 * jax.random.normal(jax.random.fold_in(k1, 2), (M, rank),
                                jnp.float32)
    B = 0.3 * jax.random.normal(jax.random.fold_in(k1, 3), (d_out, rank),
                                jnp.float32)
    g = 1.0 + 0.0015 * jax.random.normal(jax.random.fold_in(k1, 4),
                                         (d_out,), jnp.float32)
    plan2d = dp.ComposeSharding(mesh, P(None, "model"))
    s = 1.25

    def loss_k(b, hh, bb, gg):
        out = ops.fused_compose_mm(b, hh, bb, gg, s, interpret=True,
                                   block_m=8, block_n=128,
                                   sharding=plan2d)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    def loss64(b, hh, bb, gg):
        return jnp.sum(ref.ref_compose_mm_fp64(b, hh, bb, gg, s) ** 2)

    gk = jax.jit(jax.grad(loss_k, argnums=(0, 1, 2, 3)))(base, h, B, g)
    g64 = jax.grad(loss64, argnums=(0, 1, 2, 3))(
        base.astype(jnp.float64), h.astype(jnp.float64),
        B.astype(jnp.float64), g.astype(jnp.float64))
    for got, want, name in zip(gk, g64, ("d_base", "d_h", "d_B", "d_g")):
        scale = np.maximum(np.abs(np.asarray(want)), 1.0)
        err = np.abs(np.asarray(got, np.float64) - np.asarray(want)) / scale
        assert np.max(err) < 5e-5, (name, np.max(err))
    print("VJP_OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("ndev", [2, 4])
def test_spmd_matmul_fused_parity(ndev):
    """Acceptance: forced {2,4}-device CPU mesh — matmul-fused route
    selected for a row-sharded d_out layer, bitwise fp32 logits parity
    (both TP and SP layouts), no y_lora in the jaxpr, VJP vs fp64."""
    out = _run_subprocess(_SPMD_PARITY.format(ndev=ndev), ndev)
    for marker in ("BITWISE_OK", "TRAIN_ALLCLOSE_OK", "JAXPR_OK", "VJP_OK"):
        assert marker in out, out
