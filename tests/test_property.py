"""Property-based tests (hypothesis) on the system's core invariants.

Invariant 1 — the factored decomposition is *exact algebra*: for any W, A, B
and scaling s, the factored norm equals the dense norm (up to fp tolerance).

Invariant 2 — compose identity: Y_base + compose(Y_base, Y_lora, g, s)
            == g ⊙ (Y_base + s·Y_lora) for any g.

Invariant 3 — tier equivalence: eager and interpret-mode fused paths agree.

Invariant 4 — chunking invariance: any chunk budget gives the same norm.

Invariant 5 — speculative rewind is invisible (bitwise never-drafted).

Invariant 6 — fault containment under random FaultPlans.

Invariant 7 — paged block-pool conservation under any interleaving.

Invariant 8 — fleet churn: dynamic grouping serves any adapter churn
            through ONE decode executable, bitwise the static engine.

Invariant 9 — trace event conservation: over ANY random fault plan and
            preemption schedule, every submitted request's lifecycle
            trace has exactly one submitted and one terminal event (the
            terminal last, its reason a valid finish reason), ticks
            monotone along the request's own sequence, preempt/resume
            balanced, and token events conserved against the results.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; pip install -r "
           "requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.factored_norm as fn
from repro.core import DoRAConfig, compose_stable
from repro.kernels import ops as kops

jax.config.update("jax_enable_x64", True)

_DIMS = st.sampled_from([1, 2, 3, 5, 8, 16, 31, 64, 128])
_RANKS = st.sampled_from([1, 2, 4, 7, 16, 33])
_S = st.floats(min_value=0.0, max_value=16.0, allow_nan=False)
_SEED = st.integers(min_value=0, max_value=2**31 - 1)


def _mats(seed, d_out, d_in, r):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    W = jax.random.normal(k1, (d_out, d_in), jnp.float32)
    A = jax.random.normal(k2, (r, d_in), jnp.float32)
    B = jax.random.normal(k3, (d_out, r), jnp.float32)
    return W, A, B


@settings(max_examples=40, deadline=None)
@given(d_out=_DIMS, d_in=_DIMS, r=_RANKS, s=_S, seed=_SEED)
def test_factored_norm_equals_dense(d_out, d_in, r, s, seed):
    W, A, B = _mats(seed, d_out, d_in, r)
    got = fn.factored_norm(W, A, B, float(s))
    want = fn.norm_reference_fp64(W, A, B, float(s))
    scale = max(1.0, float(jnp.max(want)))
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want, np.float32) / scale,
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=40, deadline=None)
@given(d_out=_DIMS, d_in=_DIMS, r=_RANKS, s=_S, seed=_SEED,
       chunk_mb=st.sampled_from([1, 2, 256]))
def test_chunking_invariance(d_out, d_in, r, s, seed, chunk_mb):
    W, A, B = _mats(seed, d_out, d_in, r)
    full = fn.factored_norm(W, A, B, float(s), chunk_mb=None)
    chunked = fn.factored_norm(W, A, B, float(s), chunk_mb=chunk_mb)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(rows=st.sampled_from([1, 3, 17, 64]),
       n=st.sampled_from([8, 64, 256]),
       s=_S, seed=_SEED,
       gdev=st.floats(min_value=0.0, max_value=0.5, allow_nan=False))
def test_compose_identity(rows, n, s, seed, gdev):
    """Y_base + Δ == g ⊙ (Y_base + s·Y_lora)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    base = jax.random.normal(k1, (rows, n), jnp.float32)
    lora = jax.random.normal(k2, (rows, n), jnp.float32)
    g = 1.0 + gdev * jax.random.normal(k3, (n,), jnp.float32)
    delta = compose_stable(base, lora, g, float(s))
    left = base + delta
    right = g[None, :] * (base + float(s) * lora)
    np.testing.assert_allclose(np.asarray(left), np.asarray(right),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(rows=st.sampled_from([1, 5, 32, 100]),
       nmul=st.sampled_from([1, 2, 3]),
       s=_S, seed=_SEED)
def test_fused_interpret_equals_eager(rows, nmul, s, seed):
    """Tier equivalence under arbitrary row counts (pad/unpad path)."""
    n = 128 * nmul
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    base = jax.random.normal(k1, (rows, n), jnp.float32)
    lora = jax.random.normal(k2, (rows, n), jnp.float32)
    g = 1.0 + 0.01 * jax.random.normal(k3, (n,), jnp.float32)
    fused = kops.fused_compose(base, lora, g, float(s), interpret=True,
                               block_m=32, block_n=128)
    eager = compose_stable(base, lora, g, float(s))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(eager),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=_SEED, r=st.sampled_from([1, 4, 16]))
def test_norm_scale_homogeneity(seed, r):
    """||c·(W + sBA)|| = |c|·||W + sBA|| — catches accumulation-dtype bugs."""
    W, A, B = _mats(seed, 16, 32, r)
    base = fn.factored_norm(W, A, B, 1.0)
    scaled = fn.factored_norm(4.0 * W, 2.0 * A, 2.0 * B, 1.0)
    np.testing.assert_allclose(np.asarray(scaled), 4.0 * np.asarray(base),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=_SEED)
def test_dora_noop_at_init(seed):
    """B = 0 ⇒ the adapted layer equals the frozen layer exactly."""
    import repro.core.adapter as ad
    cfg = DoRAConfig(rank=4, alpha=8, mode="eager")
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (6, 24), jnp.float32)
    W = jax.random.normal(k2, (32, 24), jnp.float32)
    adapter = ad.init_dora_params(k3, W, cfg)
    y = ad.dora_linear(x, W, adapter, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ W.T),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Invariant 5 — speculative rewind: writing k draft tokens into a slot's
# per-row cache and rewinding that row's "len" is INVISIBLE — every later
# decode is bitwise identical to never having drafted. This is the cache
# contract the engine's speculative mode stands on, and it covers the
# per-row causal-frontier mask in models/layers.py: rows sit at DIFFERENT
# depths, so a frontier bug on any row breaks the bitwise claim. The
# interpret-tier leg runs automatically under REPRO_FORCE_TIER=interpret
# (scripts/run_tier1.sh second leg).
# ---------------------------------------------------------------------------

_REWIND_ML = 12          # cache rows; lens + k + 2 re-decodes must fit
_REWIND_SEED_LEN = 7     # seed tokens written per row before truncation


@functools.lru_cache(maxsize=1)
def _rewind_setup():
    from repro.configs import get_config
    from repro.models import forward

    mcfg = get_config("qwen2-7b", smoke=True)
    dcfg = DoRAConfig(rank=4, alpha=8.0, mode="eager")
    from repro.launch.train import build_state
    params, _, _ = build_state(mcfg, dcfg, 3)

    @jax.jit
    def step(cache, toks):
        logits, new_cache, _ = forward(mcfg, params, {}, dcfg,
                                       cache=cache, training=False,
                                       tokens=toks)
        return logits, new_cache

    return mcfg, step


@settings(max_examples=8, deadline=None)
@given(l0=st.integers(min_value=1, max_value=7),
       l1=st.integers(min_value=1, max_value=7),
       k=st.integers(min_value=1, max_value=3),
       seed=_SEED)
def test_rewind_is_bitwise_never_drafted(l0, l1, k, seed):
    from repro.models import init_cache

    mcfg, step = _rewind_setup()
    V = mcfg.vocab_size
    rng = np.random.default_rng(seed)
    # Rows at DIFFERENT causal frontiers: write _REWIND_SEED_LEN tokens
    # into both rows, then truncate "len" to (l0, l1) — positions beyond
    # each row's frontier hold live-but-dead K/V, exactly the state a
    # rewound draft leaves behind.
    cache = init_cache(mcfg, 2, _REWIND_ML, row_lens=True)
    seed_toks = rng.integers(0, V, (2, _REWIND_SEED_LEN), dtype=np.int32)
    _, cache = step(cache, jnp.asarray(seed_toks))
    lens = jnp.asarray(np.array([l0, l1], np.int32))
    cache = dict(cache, len=lens)

    t_next = jnp.asarray(rng.integers(0, V, (2, 1), dtype=np.int32))
    t_more = jnp.asarray(rng.integers(0, V, (2, 1), dtype=np.int32))
    # Path A — never drafted: two plain decode steps.
    la1, ca = step(cache, t_next)
    la2, ca = step(ca, t_more)
    # Path B — draft k tokens into both rows, rewind, re-decode.
    draft = jnp.asarray(rng.integers(0, V, (2, k), dtype=np.int32))
    _, drafted = step(cache, draft)
    assert np.array_equal(np.asarray(drafted["len"]), [l0 + k, l1 + k])
    rewound = dict(drafted, len=lens)
    lb1, cb = step(rewound, t_next)
    lb2, cb = step(cb, t_more)

    np.testing.assert_array_equal(np.asarray(la1), np.asarray(lb1))
    np.testing.assert_array_equal(np.asarray(la2), np.asarray(lb2))
    np.testing.assert_array_equal(np.asarray(ca["len"]),
                                  np.asarray(cb["len"]))


@settings(max_examples=6, deadline=None)
@given(l0=st.integers(min_value=1, max_value=6),
       l1=st.integers(min_value=1, max_value=6),
       k=st.integers(min_value=1, max_value=3),
       seed=_SEED)
def test_rewound_rows_verify_as_one_window(l0, l1, k, seed):
    """The verify shape: after a rewind, re-reading the SAME k+1 tokens
    as one batched window lands every row at the same frontier — and the
    window's first-position logits are bitwise the single-step decode's
    (the speculative acceptance rule compares exactly these)."""
    from repro.models import init_cache

    mcfg, step = _rewind_setup()
    V = mcfg.vocab_size
    rng = np.random.default_rng(seed)
    cache = init_cache(mcfg, 2, _REWIND_ML, row_lens=True)
    seed_toks = rng.integers(0, V, (2, _REWIND_SEED_LEN), dtype=np.int32)
    _, cache = step(cache, jnp.asarray(seed_toks))
    lens = jnp.asarray(np.array([l0, l1], np.int32))
    cache = dict(cache, len=lens)

    win = jnp.asarray(rng.integers(0, V, (2, k + 1), dtype=np.int32))
    # one-step decode of the window's first token (never drafted)
    l_one, _ = step(cache, win[:, :1])
    # draft the window tail, rewind, then verify the whole window at once
    _, drafted = step(cache, win[:, 1:])
    l_win, verified = step(dict(drafted, len=lens), win)
    np.testing.assert_array_equal(np.asarray(l_one),
                                  np.asarray(l_win[:, :1]))
    assert np.array_equal(np.asarray(verified["len"]),
                          [l0 + k + 1, l1 + k + 1])


# ---------------------------------------------------------------------------
# Invariant 6 — fault containment: under ANY seeded FaultPlan (random
# NaN injections, forced evictions, stale handles, slow ticks) plus an
# optional deadline, the engine (a) finishes every submitted request
# exactly once with a reason from FINISH_REASONS, (b) leaks no slots,
# (c) keeps unaffected requests' greedy streams BITWISE equal to the
# fault-free run, (d) hands affected requests a PREFIX of their clean
# stream (a fault may truncate, never corrupt), and (e) compiles
# nothing on any fault path.
# ---------------------------------------------------------------------------

_FAULT_ML = 12
_FAULT_REQS = [(5, 4), (6, 5), (4, 3), (5, 4)]   # (prompt_len, budget)


@functools.lru_cache(maxsize=1)
def _fault_setup():
    from repro.configs import get_config
    from repro.core import AdapterStateCache
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    mcfg = get_config("qwen2-7b", smoke=True)
    scfg = StepConfig(dora=DoRAConfig(rank=4, alpha=8.0, mode="eager"))
    params, _, _ = build_state(mcfg, scfg.dora, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg)
    _, ad, _ = build_state(mcfg, scfg.dora, 10)
    cache.register("t0", ad)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
               for P, _ in _FAULT_REQS]
    return mcfg, scfg, params, cache, prompts


def _fault_drive(plan, deadline):
    from repro.launch.engine import DecodeEngine

    mcfg, scfg, params, cache, prompts = _fault_setup()
    eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=_FAULT_ML,
                       adapter_cache=cache, fault_plan=plan)
    for i, (p, (_, g)) in enumerate(zip(prompts, _FAULT_REQS)):
        eng.submit(p, adapter="t0", max_new_tokens=g, key_id=i,
                   deadline_ticks=deadline if i == 3 else None)
    return eng.run(), eng


@functools.lru_cache(maxsize=1)
def _fault_clean_streams():
    results, _ = _fault_drive(None, None)
    return {r.request_id: tuple(int(t) for t in r.tokens)
            for r in results}


@settings(max_examples=6, deadline=None)
@given(seed=_SEED,
       n_nan=st.integers(min_value=0, max_value=2),
       n_evict=st.integers(min_value=0, max_value=1),
       n_stale=st.integers(min_value=0, max_value=1),
       n_slow=st.integers(min_value=0, max_value=1),
       deadline=st.sampled_from([None, 3]))
def test_fault_containment_under_random_plan(seed, n_nan, n_evict,
                                             n_stale, n_slow, deadline):
    from repro.launch.engine import FINISH_REASONS
    from repro.launch.faults import FaultPlan

    plan = FaultPlan.random(seed, steps=12, slots=2, n_nan=n_nan,
                            n_evict=n_evict, n_stale=n_stale,
                            n_slow=n_slow)
    clean = _fault_clean_streams()
    results, eng = _fault_drive(plan, deadline)
    # (a) exactly-once completion with a valid reason
    assert sorted(r.request_id for r in results) == [0, 1, 2, 3]
    assert all(r.finish_reason in FINISH_REASONS for r in results)
    # (b) no slot leaks: queue drained, every row free
    assert not eng.has_work()
    # (c)/(d) containment: unaffected streams bitwise, affected streams
    # a prefix — a fault truncates its own request, never rewrites it
    for r in results:
        got = tuple(int(t) for t in r.tokens)
        want = clean[r.request_id]
        affected = r.finish_reason in ("error", "error_numeric",
                                       "timeout")
        if affected:
            assert got == want[:len(got)], \
                (r.request_id, r.finish_reason, plan)
        else:
            assert got == want, (r.request_id, r.finish_reason, plan)
    # (e) the fault paths reuse the clean executables
    counts = eng.compile_counts()
    assert counts["prefill_into_slot"] == 1, counts
    assert counts["decode"] == {None: 1}, counts


# ---------------------------------------------------------------------------
# Invariant 7 — paged block-pool conservation: under ANY interleaving of
# admission (chunked prefill), decode, speculative rewind, deadline
# expiry, retirement and reclaim preemption, every block in the pool is
# owned by EXACTLY ONE of (the free list, one live slot) after EVERY
# engine tick — no leaks, no block aliased to two rows — the host block
# table mirrors each slot's ownership list exactly, and the pool drains
# to fully-free when the engine does.
# ---------------------------------------------------------------------------

_PAGED_ML = 12
_PAGED_BS = 4            # max_blocks = 3 per row


@functools.lru_cache(maxsize=1)
def _paged_setup():
    from repro.configs import get_config
    from repro.core import AdapterStateCache
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    mcfg = get_config("qwen2-7b", smoke=True)
    scfg = StepConfig(dora=DoRAConfig(rank=4, alpha=8.0, mode="eager"))
    params, _, _ = build_state(mcfg, scfg.dora, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg)
    _, ad, _ = build_state(mcfg, scfg.dora, 10)
    # Random-B adapter: speculative drafts diverge from the full path,
    # so some drafts are REJECTED and the rewind path (and its
    # _free_tail block release) actually runs.
    key = jax.random.PRNGKey(7)
    cnt = [0]

    def perturb(path, leaf):
        cnt[0] += 1
        if "'B'" in "/".join(str(p) for p in path):
            return 0.1 * jax.random.normal(
                jax.random.fold_in(key, cnt[0]), leaf.shape, leaf.dtype)
        return leaf

    cache.register("t0", jax.tree_util.tree_map_with_path(perturb, ad))
    return mcfg, scfg, params, cache


def _assert_block_conservation(eng, n_blocks):
    free = list(eng._free)
    owned = [b for bl in eng._blocks for b in bl]
    assert len(set(free)) == len(free), f"free list duplicates: {free}"
    assert len(set(owned)) == len(owned), \
        f"block aliased to two live slots: {eng._blocks}"
    assert not set(free) & set(owned), \
        f"block both free and owned: {free} vs {eng._blocks}"
    assert sorted(free + owned) == list(range(n_blocks)), \
        f"pool leak: free={free} owned={eng._blocks}"
    for i, bl in enumerate(eng._blocks):
        row = eng._pages_np[i]
        assert list(row[:len(bl)]) == bl, (i, bl, row)
        assert all(v == -1 for v in row[len(bl):]), (i, bl, row)


@settings(max_examples=5, deadline=None)
@given(seed=_SEED,
       n_blocks=st.sampled_from([3, 4, 6]),
       chunk=st.sampled_from([3, 5, 12]),
       spec_k=st.sampled_from([0, 2]),
       n_reqs=st.integers(min_value=3, max_value=6))
def test_paged_block_pool_conservation(seed, n_blocks, chunk, spec_k,
                                       n_reqs):
    from repro.launch.engine import DecodeEngine

    mcfg, scfg, params, cache = _paged_setup()
    rng = np.random.default_rng(seed)
    eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=_PAGED_ML,
                       adapter_cache=cache, paged=True,
                       block_size=_PAGED_BS, n_blocks=n_blocks,
                       prefill_chunk=chunk, speculative_k=spec_k)
    # Random arrivals, prompt lengths, budgets, priorities and deadlines:
    # a tight pool (n_blocks as low as one row's worth) forces head-of-
    # line deferral and reclaim preemption; priorities force displacement
    # mid-decode AND mid-prefill; deadlines force expiry in every phase.
    reqs = sorted(
        ({"at": int(rng.integers(0, 8)),
          "prompt": rng.integers(0, mcfg.vocab_size,
                                 int(rng.integers(2, 9)), dtype=np.int32),
          "budget": int(rng.integers(1, 4)),
          "priority": int(rng.integers(0, 2)),
          "deadline": (int(rng.integers(2, 6))
                       if rng.random() < 0.3 else None)}
         for _ in range(n_reqs)),
        key=lambda r: r["at"])
    i = tick = 0
    while i < len(reqs) or eng.has_work():
        while i < len(reqs) and reqs[i]["at"] <= tick:
            eng.submit(reqs[i]["prompt"], adapter="t0",
                       max_new_tokens=reqs[i]["budget"],
                       priority=reqs[i]["priority"],
                       deadline_ticks=reqs[i]["deadline"])
            i += 1
        eng.step()
        _assert_block_conservation(eng, n_blocks)
        tick += 1
        assert tick < 400, "engine failed to drain the trace"
    ps = eng.pool_stats()
    assert ps["used_blocks"] == 0 and ps["free_blocks"] == n_blocks, ps
    assert ps["per_slot_blocks"] == [0, 0], ps
    results = eng.pop_results()
    assert sorted(r.request_id for r in results) == list(range(n_reqs))


# ---------------------------------------------------------------------------
# Invariant 8 — fleet churn: with N adapters ≫ slots and ANY seeded
# interleaving of submits, engine ticks, adapter version bumps and cache
# drops, the DYNAMIC-grouping engine (a) streams every request bitwise
# identical to the static-signature engine over the same trace (which
# tests/test_engine.py pins to per-tenant-sequential serving), (b) keeps
# compile counts churn-invariant — exactly ONE decode executable and ONE
# stack-insert executable no matter which tenants come and go — and
# (c) finishes every submitted request exactly once, draining its fleet
# stack positions with the slot table. This is the PR-9 contract: tenant
# churn changes VALUES (stack rows, the per-row adapter index), never
# the compile signature.
# ---------------------------------------------------------------------------

_FLEET_ML = 14
_FLEET_SLOTS = 2


@functools.lru_cache(maxsize=1)
def _fleet_setup():
    from repro.configs import get_config
    from repro.launch.steps import StepConfig
    from repro.launch.train import build_state

    mcfg = get_config("qwen2-7b", smoke=True)
    scfg = StepConfig(dora=DoRAConfig(rank=4, alpha=8.0, mode="eager"))
    params, _, _ = build_state(mcfg, scfg.dora, 0)
    _, base, _ = build_state(mcfg, scfg.dora, 10)
    return mcfg, scfg, params, base


def _perturb_b(ad, seed, scale=0.1):
    """Replace every B leaf with seeded noise: seed-built trees have
    B == 0, so without this every tenant would stream identical tokens
    and a mis-indexed fleet stack could never be caught."""
    key = jax.random.PRNGKey(seed)
    cnt = [0]

    def go(path, leaf):
        cnt[0] += 1
        if "'B'" in "/".join(str(p) for p in path):
            return scale * jax.random.normal(
                jax.random.fold_in(key, cnt[0]), leaf.shape, leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(go, ad)


def _fleet_trace(seed, tenants, waves):
    """A deterministic churny fleet trace: per wave, a burst of submits
    (random tenant / prompt / budget), a random number of engine ticks,
    then adapter churn between waves (a version bump re-routing future
    submits, plus a cache drop making one tenant cold again)."""
    mcfg, *_ = _fleet_setup()
    rng = np.random.default_rng(seed)
    return [{"submits": [(rng.integers(0, mcfg.vocab_size,
                                       int(rng.integers(3, 7)),
                                       dtype=np.int32),
                          int(rng.integers(2, 5)),
                          int(rng.integers(tenants)))
                         for _ in range(int(rng.integers(2, 5)))],
             "ticks": int(rng.integers(1, 6)),
             "bump": int(rng.integers(tenants)),
             "drop": int(rng.integers(tenants))}
            for _ in range(waves)]


def _fleet_drive(trace, tenants, dynamic):
    """Replay a trace through a fresh engine + cache. The fleet is
    rebuilt from deterministic seeds, so the dynamic and static replays
    see bit-identical adapters at every point in the trace."""
    from repro.core import AdapterStateCache
    from repro.launch.engine import DecodeEngine

    mcfg, scfg, params, base = _fleet_setup()
    cache = AdapterStateCache.for_serving(mcfg, scfg)
    for t in range(tenants):
        cache.register(f"t{t}", _perturb_b(base, 40 + t))
    eng = DecodeEngine(mcfg, scfg, params, slots=_FLEET_SLOTS,
                       max_len=_FLEET_ML, adapter_cache=cache,
                       dynamic_grouping=dynamic)
    submitted, streams = [], {}

    def collect(results):
        for r in results:
            assert r.request_id not in streams, \
                f"request {r.request_id} finished twice"
            streams[r.request_id] = (tuple(int(t) for t in r.tokens),
                                     r.finish_reason)

    for w, wave in enumerate(trace):
        for p, g, t in wave["submits"]:
            submitted.append(
                eng.submit(p, adapter=f"t{t}", max_new_tokens=g))
        for _ in range(wave["ticks"]):
            if eng.has_work():
                eng.step()
        collect(eng.pop_results())
        # churn mid-flight: in-flight requests keep their pinned states;
        # the bump re-routes only FUTURE submits of that tenant, and the
        # drop makes one tenant cold (re-precomputed on next submit).
        cache.update(f"t{wave['bump']}", _perturb_b(base, 90 + w))
        cache.invalidate(f"t{wave['drop']}")
        if dynamic:
            counts = eng.compile_counts()
            assert counts["decode"] == {"dynamic": 1}, (w, counts)
            assert counts["adapter_insert"] <= 1, (w, counts)
    collect(eng.run())
    assert sorted(streams) == sorted(submitted), \
        "requests lost or double-finished under churn"
    assert not eng.has_work()
    if dynamic:
        counts = eng.compile_counts()
        assert counts["decode"] == {"dynamic": 1}, counts
        assert counts["adapter_insert"] == 1, counts
        assert counts["prefill_into_slot"] == 1, counts
        # fleet stack positions drain with the slot table
        assert len(eng._dyn_free) == eng.slots and not eng._dyn_pos
    return streams


@settings(max_examples=3, deadline=None)
@given(seed=_SEED,
       tenants=st.sampled_from([3, 5]),
       waves=st.integers(min_value=2, max_value=3))
def test_fleet_churn_dynamic_matches_static(seed, tenants, waves):
    """N adapters ≫ slots under a random churny trace: the dynamic
    engine's streams (tokens AND finish reasons) are bitwise the static
    engine's, with churn-invariant compile counts and exactly-once
    completion on both sides."""
    trace = _fleet_trace(seed, tenants, waves)
    dyn = _fleet_drive(trace, tenants, dynamic=True)
    sta = _fleet_drive(trace, tenants, dynamic=False)
    assert dyn == sta, \
        "dynamic-grouped streams diverged from the static engine"


# ---------------------------------------------------------------------------
# Invariant 9 — trace event conservation: observability is an append-only
# journal of what the engine ALREADY did, so whatever faults or
# preemptions a random schedule throws, the journal must balance —
# exactly one terminal per submitted request, monotone ticks per
# request, preempt/resume paired, token events equal to tokens returned.
# ---------------------------------------------------------------------------

def _obs_fault_drive(plan, deadline, priority):
    from repro.launch.engine import DecodeEngine
    from repro.obs import TraceRecorder

    mcfg, scfg, params, cache, prompts = _fault_setup()
    rec = TraceRecorder()
    eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=_FAULT_ML,
                       adapter_cache=cache, fault_plan=plan, trace=rec)
    for i in range(3):
        eng.submit(prompts[i], adapter="t0",
                   max_new_tokens=_FAULT_REQS[i][1], key_id=i,
                   deadline_ticks=deadline if i == 2 else None)
    for _ in range(2):          # let the slot table fill and decode
        if eng.has_work():
            eng.step()
    # the late arrival: priority>0 preempts a running row (slots full)
    eng.submit(prompts[3], adapter="t0",
               max_new_tokens=_FAULT_REQS[3][1], key_id=3,
               priority=priority)
    return eng.run(), eng, rec


@settings(max_examples=5, deadline=None)
@given(seed=_SEED,
       n_nan=st.integers(min_value=0, max_value=1),
       n_evict=st.integers(min_value=0, max_value=1),
       n_slow=st.integers(min_value=0, max_value=1),
       priority=st.sampled_from([0, 5]),
       deadline=st.sampled_from([None, 3]))
def test_trace_event_conservation(seed, n_nan, n_evict, n_slow,
                                  priority, deadline):
    from repro.launch.engine import FINISH_REASONS
    from repro.launch.faults import FaultPlan

    plan = FaultPlan.random(seed, steps=12, slots=2, n_nan=n_nan,
                            n_evict=n_evict, n_slow=n_slow)
    results, eng, rec = _obs_fault_drive(plan, deadline, priority)
    assert rec.dropped == 0
    by_rid = {r.request_id: r for r in results}

    # (a) exactly-once lifecycle per submitted request
    assert rec.request_ids() == sorted(by_rid) == [0, 1, 2, 3]
    n_pre_total = 0
    for rid, r in by_rid.items():
        evs = rec.events(request_id=rid)
        names = [e.name for e in evs]
        assert names.count("submitted") == 1, (rid, names)
        assert names.count("terminal") == 1, (rid, names)
        assert names[0] == "submitted" and names[-1] == "terminal", \
            (rid, names)
        term = evs[-1]
        assert term.data["reason"] in FINISH_REASONS
        assert term.data["reason"] == r.finish_reason, (rid, plan)

        # (b) ticks monotone along this request's own sequence
        ticks = [e.tick for e in evs]
        assert ticks == sorted(ticks), (rid, list(zip(names, ticks)))

        # (c) preempt/resume balance: every resume follows a preempt;
        # at most one preemption can end un-resumed (the victim timed
        # out or was quarantined while queued)
        n_pre = names.count("preempted")
        n_res = names.count("resumed")
        assert n_res <= n_pre <= n_res + 1, (rid, names)
        assert n_pre == r.preempted, (rid, plan)
        n_pre_total += n_pre
        # every seating is an admitted event: initial + one per resume;
        # a never-admitted request (queued timeout) has neither
        n_adm = names.count("admitted")
        if n_adm:
            assert n_adm == n_res + 1, (rid, names)
        else:
            assert n_pre == 0 and n_res == 0, (rid, names)

        # (d) token conservation: the journal saw every returned token
        n_tok = names.count("first_token") + names.count("token")
        assert n_tok == len(r.tokens), (rid, names, r.tokens)
        if len(r.tokens):
            assert names.count("first_token") == 1, (rid, names)

    # (e) the journal's totals tally with the engine's own counters
    st_ = eng.stats()
    assert n_pre_total == st_.preemptions
    assert len(rec.events("quarantined")) == st_.quarantined
    assert sum(1 for r in results if r.finish_reason == "timeout") \
        == st_.timeouts
