"""Sharding rules: divisibility fallback, role tables, tree congruence.

Pure-logic tests use a duck-typed FakeMesh (pick_axes/spec_for only read
``axis_names`` and ``shape``); tree-structure tests use a real 1-device
debug mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P
import pytest

from repro.configs import get_config
from repro.core import DoRAConfig
from repro.launch import sharding as S
from repro.launch.mesh import make_debug_mesh
from repro.models import adapter_shapes, param_shapes


class FakeMesh:
    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


PROD = FakeMesh(pod=2, data=16, model=16)
SINGLE = FakeMesh(data=16, model=16)


class TestPickAxes:
    def test_tp_divisible(self):
        assert S.pick_axes(4096, "tp", PROD, set()) == "model"

    def test_tp_not_divisible_replicates(self):
        assert S.pick_axes(40 * 64 + 8, "tp", PROD, set()) is None

    def test_fsdp_falls_back_progressively(self):
        # 60 % 32 != 0, 60 % 16 != 0, 60 % 2 == 0 -> pod only
        assert S.pick_axes(60, "expert", PROD, set()) == "pod"
        # 16 % 32 != 0, 16 % 16 == 0 -> data
        assert S.pick_axes(16, "expert", PROD, set()) == "data"
        # 64 divisible by 32 -> (pod, data)
        assert S.pick_axes(64, "expert", PROD, set()) == ("pod", "data")

    def test_used_axes_not_reused(self):
        used = {"model"}
        assert S.pick_axes(4096, "tp", PROD, used) is None

    def test_single_pod_mesh_drops_pod(self):
        assert S.pick_axes(60, "expert", SINGLE, set()) is None
        assert S.pick_axes(32, "expert", SINGLE, set()) == "data"


class TestSpecFor:
    def test_each_axis_used_once(self):
        spec = S.spec_for((64, 4096, 2048), ("expert", "tp", "fsdp"), PROD)
        assert spec == P(("pod", "data"), "model", None)

    def test_fallback_chain(self):
        # expert=60 takes pod; weight-fsdp is pod-only (H1.3) and pod is
        # taken -> D replicates
        spec = S.spec_for((60, 1408, 2048), ("expert", "tp", "fsdp"), PROD)
        assert spec == P("pod", "model", None)


class TestLeafRoles:
    def test_gqa_nontp_gets_gather_fsdp(self):
        # 28 heads / kv=4 — neither divides 16: d_out gather-FSDP (H2.2)
        mcfg = get_config("qwen2-7b")
        assert S.leaf_roles(mcfg, "wq", 2, PROD) == ("fsdp_gather", "repl")
        assert S.leaf_roles(mcfg, "wk", 2, PROD) == ("fsdp_gather", "repl")
        assert S.leaf_roles(mcfg, "wo", 2, PROD) == ("fsdp_gather", "repl")

    def test_heads_shard_when_divisible(self):
        mcfg = get_config("qwen3-32b")  # 64 heads, kv=8
        assert S.leaf_roles(mcfg, "wq", 2, PROD)[0] == "tp"
        assert S.leaf_roles(mcfg, "wk", 2, PROD)[0] == "fsdp_gather"
        assert S.leaf_roles(mcfg, "wo", 2, PROD) == ("fsdp", "tp")

    def test_moe_roles(self):
        mcfg = get_config("qwen2-moe-a2.7b")
        assert S.leaf_roles(mcfg, "gate", 3, PROD) == ("expert", "tp",
                                                       "fsdp")
        assert S.leaf_roles(mcfg, "down", 3, PROD) == ("expert", "fsdp",
                                                       "tp")

    def test_unknown_leaf_replicates(self):
        mcfg = get_config("qwen2-7b")
        assert S.leaf_roles(mcfg, "scale", 1, PROD) == ("repl",)


@pytest.mark.parametrize("arch", ["qwen3-32b", "jamba-v0.1-52b",
                                  "qwen2-moe-a2.7b", "falcon-mamba-7b"])
def test_param_sharding_tree_matches_shapes(arch):
    mcfg = get_config(arch)
    mesh = make_debug_mesh(1, 1)
    shapes = param_shapes(mcfg)
    shardings = S.param_sharding(mcfg, mesh)
    assert (jax.tree.structure(shapes)
            == jax.tree.structure(shardings))
    # every spec rank matches its leaf rank
    for sds, sh in zip(jax.tree.leaves(shapes), jax.tree.leaves(shardings)):
        assert len(sh.spec) <= len(sds.shape)


@pytest.mark.parametrize("arch", ["qwen3-32b", "falcon-mamba-7b"])
def test_adapter_sharding_congruent(arch):
    mcfg = get_config(arch)
    dcfg = DoRAConfig(rank=384)
    mesh = make_debug_mesh(1, 1)
    shapes = adapter_shapes(mcfg, dcfg)
    shardings = S.adapter_sharding(mcfg, dcfg, mesh)
    assert (jax.tree.structure(shapes)
            == jax.tree.structure(shardings))


def test_adapter_tp_congruence_rules():
    """B row-sharded iff W out-sharded; A col-sharded iff W in-sharded —
    whatever axis W's dim takes, the adapter dim takes the same one. On
    this tp=1 mesh qwen3-32b crosses the per-chip budget, so its fsdp
    role resolves to 'fsdp_data' (H3.5) and the fsdp dims land on
    ``data`` rather than replicating."""
    mcfg = get_config("qwen3-32b")
    dcfg = DoRAConfig(rank=384)
    mesh = FakeMeshAsReal()
    sh = S.adapter_sharding(mcfg, dcfg, mesh)
    unit = sh["stack"]["l0"]
    # wq [q_dim, D]: out TP -> B/m model-sharded; A d_in congruent with
    # W's d_in (data-FSDP for this over-budget model on tp=1)
    wq_roles = S.leaf_roles(mcfg, "wq", 2, mesh)
    assert wq_roles == ("tp", "fsdp_data")
    assert unit["mixer"]["wq"]["B"].spec == P(None, "model", None)
    assert unit["mixer"]["wq"]["m"].spec == P(None, "model")
    assert unit["mixer"]["wq"]["A"].spec == P(None, None, "data")
    # w_down [D, ff]: in TP -> A col-sharded over model; B congruent with
    # W's d_out fsdp axis
    assert unit["ffn"]["w_down"]["A"].spec == P(None, None, "model")
    assert unit["ffn"]["w_down"]["B"].spec == P(None, "data", None)


def test_serving_state_sharding_congruent():
    """serving=True emits the frozen-adapter cache leaves: g shards like m
    (congruent with W's d_out) and the folded gsB row-shards exactly like
    the raw B — the broadcast-free decode compose must consume a
    correctly-sharded cached B, not all-gather it per token."""
    mcfg = get_config("qwen3-32b")
    dcfg = DoRAConfig(rank=384)
    mesh = FakeMeshAsReal()
    sh = S.adapter_sharding(mcfg, dcfg, mesh, serving=True)
    unit = sh["stack"]["l0"]
    for leaf in (unit["mixer"]["wq"], unit["ffn"]["w_down"]):
        assert leaf["g"].spec == leaf["m"].spec
        assert leaf["gsB"].spec == leaf["B"].spec
    # wq is TP out-sharded on this mesh: the cached B lands model-sharded
    assert unit["mixer"]["wq"]["gsB"].spec == P(None, "model", None)
    # default (serving=False) trees stay exactly as before
    raw = S.adapter_sharding(mcfg, dcfg, mesh)
    assert "g" not in raw["stack"]["l0"]["mixer"]["wq"]
    assert "gsB" not in raw["stack"]["l0"]["mixer"]["wq"]


def test_boundary_constraint_carries_compose_plan():
    """make_boundary_constraint attaches the ComposeSharding plan the
    adapted linears use to pin the rank-space LoRA intermediate."""
    from repro.core.sharding import as_compose_sharding
    mesh = FakeMeshAsReal()
    cst = S.make_boundary_constraint(mesh, batch=256, seq=4096)
    plan = as_compose_sharding(cst)
    assert plan is not None and plan.mesh is mesh
    assert plan.out_spec == S.activation_spec(mesh, batch=256, seq=4096)
    assert plan.h_spec == P(*(tuple(plan.out_spec)[:-1] + (None,)))


def test_adapter_pod_fsdp_on_multipod_mesh():
    mcfg = get_config("qwen3-32b")
    dcfg = DoRAConfig(rank=384)
    roles = S.leaf_roles(mcfg, "wq", 2, PROD)
    assert roles == ("tp", "fsdp")
    # wq d_in -> pod on the multi-pod FakeMesh
    assert S.spec_for((8192, 5120), roles, PROD) == P("model", "pod")


def FakeMeshAsReal():
    """A real (1,1) mesh named like production but sized 1 — divisibility
    always passes, so the chosen axes reflect the pure role logic."""
    from repro.compat.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


class TestBatchAndCache:
    def test_batch_sharded_when_divisible(self):
        assert S.batch_spec(PROD, batch=256) == P(("pod", "data"), None)
        assert S.batch_spec(SINGLE, batch=256) == P("data", None)

    def test_batch_replicated_when_indivisible(self):
        # long_500k global_batch=1 does not divide the 32-way dp axes
        assert S.batch_spec(PROD, batch=1) == P(None, None)
        assert S.batch_spec(SINGLE, batch=1) == P(None, None)

    def test_activation_spec_sequence_parallel(self):
        assert S.activation_spec(SINGLE, batch=256, seq=4096) \
            == P("data", "model", None)
        # decode: seq 1 cannot shard; batch 128 divides 16
        assert S.activation_spec(SINGLE, batch=128, seq=1) \
            == P("data", None, None)
        # odd seq cannot shard over model
        assert S.activation_spec(SINGLE, batch=128, seq=4095) \
            == P("data", None, None)

    def test_cache_kv_seq_sharded_over_model(self):
        mcfg = get_config("qwen3-32b")
        mesh = FakeMeshAsReal()
        c = S.cache_sharding(mcfg, mesh, batch=128)
        kv = c["stack"]["l0"]["k"]
        assert kv.spec == P(None, "data", "model", None, None)

    def test_cache_mamba_di_sharded(self):
        mcfg = get_config("falcon-mamba-7b")
        mesh = FakeMeshAsReal()
        c = S.cache_sharding(mcfg, mesh, batch=128)
        assert c["stack"]["l0"]["h"].spec == P(None, "data", "model", None)
