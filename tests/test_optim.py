"""Optimizer: AdamW semantics, schedule, clipping, gradient compression."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (OptimizerConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress_bf16,
                         cosine_warmup_schedule, decompress_bf16,
                         global_norm, init_error_feedback,
                         int8_ef_compress, int8_ef_decompress)

CFG = OptimizerConfig(lr=1e-2, warmup_steps=5, total_steps=100,
                      weight_decay=0.0, clip_norm=None)


def _params():
    return {"A": jnp.ones((4, 3)), "B": jnp.zeros((2,)),
            "m": jnp.full((3,), 2.0)}


def test_adamw_moves_against_gradient():
    p = _params()
    g = jax.tree.map(jnp.ones_like, p)
    st = adamw_init(p)
    new_p, st, stats = adamw_update(g, st, p, CFG)
    for k in p:
        assert np.all(np.asarray(new_p[k]) <= np.asarray(p[k]))
    assert int(st["count"]) == 1
    assert float(stats["grad_norm"]) > 0


def test_adamw_converges_quadratic():
    """Minimize ||x - t||^2: AdamW should get close to t."""
    t = jnp.asarray([1.0, -2.0, 3.0])
    p = {"x": jnp.zeros(3)}
    st = adamw_init(p)
    cfg = OptimizerConfig(lr=5e-2, warmup_steps=0, total_steps=400,
                          weight_decay=0.0, clip_norm=None,
                          min_lr_ratio=1.0)
    for _ in range(400):
        g = {"x": 2 * (p["x"] - t)}
        p, st, _ = adamw_update(g, st, p, cfg)
    np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(t), atol=5e-2)


def test_weight_decay_skips_magnitude():
    """Default mask: decay A/B but never m."""
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=0, weight_decay=0.5,
                          clip_norm=None, min_lr_ratio=1.0)
    p = _params()
    g = jax.tree.map(jnp.zeros_like, p)  # zero grads: only decay acts
    st = adamw_init(p)
    new_p, _, _ = adamw_update(g, st, p, cfg)
    assert np.all(np.asarray(new_p["A"]) < np.asarray(p["A"]))  # decayed
    np.testing.assert_array_equal(np.asarray(new_p["m"]),
                                  np.asarray(p["m"]))  # not decayed


def test_schedule_warmup_and_decay():
    assert float(cosine_warmup_schedule(CFG, 0)) == 0.0
    assert float(cosine_warmup_schedule(CFG, 5)) == pytest.approx(CFG.lr)
    end = float(cosine_warmup_schedule(CFG, 100))
    assert end == pytest.approx(CFG.lr * CFG.min_lr_ratio, rel=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0)}  # norm 6
    clipped, norm = clip_by_global_norm(g, 1.5)
    assert float(norm) == pytest.approx(6.0)
    assert float(global_norm(clipped)) == pytest.approx(1.5, rel=1e-5)


def test_bf16_compression_roundtrip():
    g = {"a": jnp.asarray([1.0, 2.0, 3.0])}
    out = decompress_bf16(compress_bf16(g))
    np.testing.assert_allclose(np.asarray(out["a"]), [1.0, 2.0, 3.0],
                               rtol=1e-2)


def test_int8_ef_error_feedback_accumulates():
    """Error feedback: the sum of k quantized steps approaches the sum of
    the raw gradients (the residual re-injects what quantization lost)."""
    rng = np.random.default_rng(0)
    raw = [{"g": jnp.asarray(rng.normal(size=64) * 0.3)} for _ in range(50)]
    ef = init_error_feedback(raw[0])
    acc_q = np.zeros(64)
    acc_raw = np.zeros(64)
    for g in raw:
        q, scale, corrected = int8_ef_compress(g, ef)
        deq, ef = int8_ef_decompress(q, scale, corrected)
        acc_q += np.asarray(deq["g"])
        acc_raw += np.asarray(g["g"])
    # Without EF the per-step error is ~scale/2 ≈ 0.4%; with EF the
    # accumulated error stays bounded by ONE step's quantization error.
    err = np.abs(acc_q - acc_raw).max()
    one_step = float(scale["g"]) / 2
    assert err <= one_step * 1.5, (err, one_step)


def test_int8_payload_is_int8():
    g = {"g": jnp.ones((16,))}
    q, scale, _ = int8_ef_compress(g, init_error_feedback(g))
    assert q["g"].dtype == jnp.int8
