"""Per-architecture smoke tests (assignment requirement): instantiate a
REDUCED config of each family, run one forward and one train step on CPU,
assert output shapes and no NaNs. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import DoRAConfig
from repro.models import (adapter_shapes, cache_shapes, forward,
                          init_adapters, init_cache, init_params,
                          param_shapes)

DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")


def _setup(arch):
    mcfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, mcfg)
    adapters = init_adapters(jax.random.fold_in(key, 1), mcfg, params, DCFG)
    return mcfg, params, adapters


def _batch(mcfg, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(42)
    if mcfg.frontend:
        embeds = jax.random.normal(key, (B, S, mcfg.d_model), jnp.float32)
        return {"embeds": embeds}
    tokens = jax.random.randint(key, (B, S), 0, mcfg.vocab_size)
    return {"tokens": tokens}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    mcfg, params, adapters = _setup(arch)
    batch = _batch(mcfg)
    logits, cache, aux = forward(mcfg, params, adapters, DCFG,
                                 **batch, training=False)
    assert logits.shape == (2, 16, mcfg.vocab_size)
    assert cache is None
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_updates_adapters_only(arch):
    mcfg, params, adapters = _setup(arch)
    batch = _batch(mcfg)
    labels = jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                                mcfg.vocab_size)

    def loss_fn(ad):
        logits, _, aux = forward(mcfg, params, ad, DCFG, **batch,
                                 training=True)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        return nll + aux

    loss, grads = jax.value_and_grad(loss_fn)(adapters)
    assert np.isfinite(float(loss))
    # Every adapter A-grad finite; B starts at 0 so dA may be 0 but dB and dm
    # must be nonzero somewhere (B=0 → dA = 0 is expected at init for LoRA).
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in flat)
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    """Prefill then one decode step == forward over the full sequence."""
    mcfg, params, adapters = _setup(arch)
    B, S = 1, 12
    batch = _batch(mcfg, B=B, S=S)

    full_logits, _, _ = forward(mcfg, params, adapters, DCFG, **batch,
                                training=False)

    cache = init_cache(mcfg, B, max_len=S + 4)
    if "tokens" in batch:
        pre = {"tokens": batch["tokens"][:, :S - 1]}
        last = {"tokens": batch["tokens"][:, S - 1:]}
    else:
        pre = {"embeds": batch["embeds"][:, :S - 1]}
        last = {"embeds": batch["embeds"][:, S - 1:]}
    _, cache, _ = forward(mcfg, params, adapters, DCFG, **pre,
                          cache=cache, training=False)
    step_logits, cache, _ = forward(mcfg, params, adapters, DCFG, **last,
                                    cache=cache, training=False)
    assert int(cache["len"]) == S
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=2e-4, atol=2e-4)


def test_param_shapes_match_init():
    mcfg = get_config("jamba-v0.1-52b", smoke=True)
    shapes = param_shapes(mcfg)
    params = init_params(jax.random.PRNGKey(0), mcfg)
    a = jax.tree.map(lambda s: (s.shape, s.dtype), shapes)
    b = jax.tree.map(lambda x: (x.shape, x.dtype), params)
    assert a == b
    ash = adapter_shapes(mcfg, DCFG)
    ad = init_adapters(jax.random.PRNGKey(1), mcfg, params, DCFG)
    a = jax.tree.map(lambda s: (s.shape, s.dtype), ash)
    b = jax.tree.map(lambda x: (x.shape, x.dtype), ad)
    assert a == b
    csh = cache_shapes(mcfg, 2, 32)
    c = init_cache(mcfg, 2, 32)
    a = jax.tree.map(lambda s: (s.shape, s.dtype), csh)
    b = jax.tree.map(lambda x: (x.shape, x.dtype), c)
    assert a == b
