"""Per-kernel allclose sweeps: Pallas (interpret=True) vs. pure-jnp oracles.

Sweeps shapes (including non-divisible row counts), dtypes and ranks for
every kernel in repro.kernels, mirroring the paper's operator-level test
tier (§5.8 "operator tests within quantization-aware bounds").
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.dora_compose import compose_bwd_pallas
from repro.kernels.factored_norm import norm_terms_pallas
from repro.kernels.norm_assembly import assemble_norm_pallas

jax.config.update("jax_enable_x64", True)


def _tol(dtype):
    if dtype == jnp.float32:
        return dict(rtol=1e-5, atol=1e-5)
    return dict(rtol=2e-2, atol=2e-2)  # bf16/fp16 quantization-aware bounds


def _mk(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _g_near_unity(key, n):
    # g concentrates around 1 with std ~0.0015 (paper §3.1).
    return 1.0 + 0.0015 * jax.random.normal(key, (n,), jnp.float32)


COMPOSE_SHAPES = [
    (8, 128), (64, 256), (100, 384), (256, 1024), (17, 2048), (1024, 512),
]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


@pytest.mark.parametrize("shape", COMPOSE_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_compose_fwd_matches_ref(shape, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    m, n = shape
    base = _mk(k1, (m, n), dtype)
    lora = _mk(k2, (m, n), dtype, 0.1)
    g = _g_near_unity(k3, n)
    s = 0.5
    got = ops.fused_compose(base, lora, g, s, interpret=True,
                            block_m=64, block_n=256)
    want = ref.ref_compose(base, lora, g, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_compose_fwd_3d_input(dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    base = _mk(k1, (4, 33, 256), dtype)
    lora = _mk(k2, (4, 33, 256), dtype, 0.1)
    g = _g_near_unity(k3, 256)
    got = ops.fused_compose(base, lora, g, 2.0, interpret=True,
                            block_m=32, block_n=128)
    want = ref.ref_compose(base, lora, g, 2.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("save_inner", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_compose_grads_match_eager_autodiff(save_inner, dtype):
    """Fused custom-vjp cotangents == jax.grad through the eager form."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    m, n = 64, 512
    base = _mk(k1, (m, n), dtype)
    lora = _mk(k2, (m, n), dtype, 0.1)
    g = _g_near_unity(k3, n)
    s = 1.5

    def fused_loss(b, l, gg):
        out = ops.fused_compose(b, l, gg, s, save_inner=save_inner,
                                interpret=True, block_m=32, block_n=256)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    def eager_loss(b, l, gg):
        out = ref.ref_compose(b, l, gg, s)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    gf = jax.grad(fused_loss, argnums=(0, 1, 2))(base, lora, g)
    ge = jax.grad(eager_loss, argnums=(0, 1, 2))(base, lora, g)
    for got, want, name in zip(gf, ge, ("d_base", "d_lora", "d_g")):
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            err_msg=name, **_tol(dtype))


def test_compose_frozen_magnitude_skips_inner():
    """mag_grad=False → d_g is zero and inner is never saved."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    base = _mk(k1, (32, 256), jnp.float32)
    lora = _mk(k2, (32, 256), jnp.float32)
    g = _g_near_unity(k3, 256)

    def loss(b, l, gg):
        out = ops.fused_compose(b, l, gg, 1.0, mag_grad=False,
                                interpret=True, block_m=32, block_n=256)
        return jnp.sum(out ** 2)

    d_g = jax.grad(loss, argnums=2)(base, lora, g)
    assert np.all(np.asarray(d_g) == 0.0)


@pytest.mark.parametrize("dtype", DTYPES)
def test_compose_bwd_kernel_matches_ref(dtype):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(4), 4)
    m, n = 48, 384
    dy = _mk(k1, (m, n), dtype)
    base = _mk(k2, (m, n), dtype)
    lora = _mk(k3, (m, n), dtype)
    g = _g_near_unity(k4, n)
    s = 0.25
    gm1 = (g - 1.0).reshape(1, n)
    gs = (g * s).reshape(1, n)
    d_base, d_lora = compose_bwd_pallas(dy, gm1, gs, block_m=16,
                                        block_n=128, interpret=True)
    want_b, want_l, _ = ref.ref_compose_bwd(dy, base, lora, g, s)
    np.testing.assert_allclose(np.asarray(d_base, np.float32),
                               np.asarray(want_b, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(d_lora, np.float32),
                               np.asarray(want_l, np.float32), **_tol(dtype))


NORM_SHAPES = [
    # (d_out, d_in, r) — includes ragged r and d_in not divisible by block_k
    (128, 256, 8), (256, 512, 64), (384, 1000, 16), (512, 768, 384),
    (128, 4096, 7), (1024, 128, 128),
]


@pytest.mark.parametrize("shape", NORM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_norm_kernel_matches_dense_oracle(shape, dtype):
    d_out, d_in, r = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    W = _mk(k1, (d_out, d_in), dtype)
    A = _mk(k2, (r, d_in), dtype, 0.3)
    B = _mk(k3, (d_out, r), dtype, 0.3)
    s = 1.25
    got = ops.fused_norm(W, A, B, s, block_rows=128, block_k=256,
                         interpret=True)
    want = ref.ref_norm(W, A, B, s)
    # fp32 accumulation in both paths; inputs quantized to `dtype` first.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("r", [8, 64, 256])
def test_norm_terms_kernel_raw(r):
    d_out, d_in = 256, 512
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(6), 3)
    W = _mk(k1, (d_out, d_in), jnp.float32)
    A = _mk(k2, (r, d_in), jnp.float32)
    B = _mk(k3, (d_out, r), jnp.float32)
    base_sq, cross = norm_terms_pallas(W, A, B, block_rows=128, block_k=128,
                                       interpret=True)
    want_b, want_c = ref.ref_norm_terms(W, A, B)
    np.testing.assert_allclose(np.asarray(base_sq), np.asarray(want_b),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cross), np.asarray(want_c),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("s", [0.0, 0.1, 1.0, 13.0])
def test_assembly_kernel(s):
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    d = 512
    base = jnp.abs(jax.random.normal(k1, (d,), jnp.float32)) * 100
    cross = jax.random.normal(k2, (d,), jnp.float32)
    ba = jnp.abs(jax.random.normal(k3, (d,), jnp.float32))
    got = assemble_norm_pallas(base, cross, ba, s, interpret=True)
    want = ref.ref_assemble(base, cross, ba, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_assembly_kernel_nan_propagation():
    """max() must propagate NaNs (paper App. C / torch.clamp_min)."""
    d = 256
    base = jnp.full((d,), jnp.nan, jnp.float32)
    cross = jnp.zeros((d,), jnp.float32)
    got = assemble_norm_pallas(base, cross, cross, 1.0, interpret=True)
    assert np.all(np.isnan(np.asarray(got)))


def test_norm_kernel_with_base_cache():
    """Beyond-paper base_sq cache returns identical results."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(8), 3)
    W = _mk(k1, (256, 512), jnp.float32)
    A = _mk(k2, (32, 512), jnp.float32)
    B = _mk(k3, (256, 32), jnp.float32)
    base_sq = jnp.sum(W.astype(jnp.float32) ** 2, axis=1)
    got = ops.fused_norm(W, A, B, 2.0, interpret=True,
                         base_sq_cache=base_sq)
    want = ops.fused_norm(W, A, B, 2.0, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_compose_d_out_not_128_raises():
    base = jnp.zeros((8, 100), jnp.float32)
    with pytest.raises(ValueError, match="divisible by 128"):
        ops.fused_compose(base, base, jnp.ones((100,), jnp.float32), 1.0,
                          interpret=True)
