"""H3.2 — cached ||W||²_row (the paper's §2.3 future-work item):
correctness vs the uncached norm, constancy under training, and the
end-to-end step."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import DoRAConfig, dora_linear, init_dora_params
from repro.core.factored_norm import factored_norm
from repro.launch.steps import StepConfig, make_train_step
from repro.models import adapter_shapes, init_adapters, init_params
from repro.optim import OptimizerConfig, adamw_init

CFG = DoRAConfig(rank=8, alpha=16.0, mode="eager", cache_base_norm=True)


def test_init_includes_base_sq():
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (32, 64))
    ad = init_dora_params(jax.random.fold_in(key, 1), W, CFG)
    assert "base_sq" in ad
    np.testing.assert_allclose(
        np.asarray(ad["base_sq"]),
        np.sum(np.asarray(W, np.float64) ** 2, axis=1), rtol=1e-5)


def test_cached_norm_matches_uncached():
    key = jax.random.PRNGKey(1)
    W = jax.random.normal(key, (32, 64))
    ad = init_dora_params(jax.random.fold_in(key, 1), W, CFG)
    ad["B"] = 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                      ad["B"].shape)
    n_ref = factored_norm(W, ad["A"], ad["B"], CFG.scaling)
    n_cached = factored_norm(W, ad["A"], ad["B"], CFG.scaling,
                             base_sq_cache=ad["base_sq"])
    np.testing.assert_allclose(np.asarray(n_cached), np.asarray(n_ref),
                               rtol=1e-6)


def test_dora_linear_uses_cache_from_adapter_tree():
    """A poisoned cache must change the output — proves the cached path
    is live; a correct cache must match the uncached output."""
    key = jax.random.PRNGKey(2)
    W = jax.random.normal(key, (32, 64))
    x = jax.random.normal(jax.random.fold_in(key, 3), (4, 64))
    ad = init_dora_params(jax.random.fold_in(key, 1), W, CFG)
    ad["B"] = 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                      ad["B"].shape)
    y_cached = dora_linear(x, W, ad, CFG)
    ad_nc = {k: v for k, v in ad.items() if k != "base_sq"}
    y_ref = dora_linear(x, W, ad_nc, CFG)
    np.testing.assert_allclose(np.asarray(y_cached), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    ad_bad = dict(ad, base_sq=ad["base_sq"] * 4.0)
    y_bad = dora_linear(x, W, ad_bad, CFG)
    assert not np.allclose(np.asarray(y_bad), np.asarray(y_ref))


def test_train_step_keeps_base_sq_constant():
    mcfg = get_config("phi4-mini-3.8b", smoke=True)
    dcfg = DoRAConfig(rank=4, alpha=8.0, mode="eager",
                      cache_base_norm=True)
    scfg = StepConfig(dora=dcfg, optim=OptimizerConfig(weight_decay=0.1))
    key = jax.random.PRNGKey(0)
    params = init_params(key, mcfg)
    adapters = init_adapters(jax.random.fold_in(key, 1), mcfg, params,
                             dcfg)
    shapes = adapter_shapes(mcfg, dcfg)
    assert jax.tree.structure(shapes) == jax.tree.structure(
        jax.tree.map(lambda x: x, adapters))
    opt = adamw_init(adapters)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0,
                                mcfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0,
                                mcfg.vocab_size)
    step = jax.jit(make_train_step(mcfg, scfg, None, batch=2, seq=16))
    new_ad, _, m = step(params, adapters, opt,
                        {"tokens": tokens, "labels": labels})
    assert np.isfinite(float(m["loss"]))
    before = adapters["stack"]["l0"]["mixer"]["wq"]
    after = new_ad["stack"]["l0"]["mixer"]["wq"]
    np.testing.assert_array_equal(np.asarray(before["base_sq"]),
                                  np.asarray(after["base_sq"]))
    # trainable leaves did move
    assert not np.array_equal(np.asarray(before["A"]),
                              np.asarray(after["A"]))
