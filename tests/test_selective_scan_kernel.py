"""Pallas selective-scan kernel vs the numpy recurrence oracle
(interpret mode; shape/dtype sweep per the kernel-test requirement)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.selective_scan import selective_scan_pallas

_F32 = jnp.float32


def _inputs(key, B, S, di, n):
    ks = jax.random.split(key, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di), _F32))
    xi = jax.random.normal(ks[1], (B, S, di), _F32)
    Bm = jax.random.normal(ks[2], (B, S, n), _F32)
    Cm = jax.random.normal(ks[3], (B, S, n), _F32)
    A = -jnp.exp(0.5 * jax.random.normal(ks[4], (di, n), _F32))
    h0 = jax.random.normal(jax.random.fold_in(key, 9), (B, di, n), _F32)
    return dt, xi, Bm, Cm, A, h0


def _reference(dt, xi, Bm, Cm, A, h0):
    h = np.asarray(h0, np.float64)
    a_all = np.exp(np.asarray(dt)[..., None] * np.asarray(A))
    b_all = (np.asarray(dt) * np.asarray(xi))[..., None] \
        * np.asarray(Bm)[:, :, None, :]
    ys = []
    for t in range(dt.shape[1]):
        h = a_all[:, t] * h + b_all[:, t]
        ys.append(np.einsum("bdn,bn->bd", h, np.asarray(Cm)[:, t]))
    return np.stack(ys, axis=1), h


@pytest.mark.parametrize("B,S,di,n,block_di,chunk", [
    (1, 8, 128, 4, 128, 4),
    (2, 16, 256, 16, 128, 8),   # di tiled 2x
    (1, 32, 128, 8, 128, 32),   # single chunk
    (2, 12, 128, 16, 128, 4),   # 3 chunks
])
def test_kernel_matches_reference(B, S, di, n, block_di, chunk):
    dt, xi, Bm, Cm, A, h0 = _inputs(jax.random.PRNGKey(0), B, S, di, n)
    y, h_t = selective_scan_pallas(
        dt, dt * xi, Bm, Cm, jnp.transpose(A),
        jnp.transpose(h0, (0, 2, 1)),
        block_di=block_di, chunk=chunk, interpret=True)
    y_ref, h_ref = _reference(dt, xi, Bm, Cm, A, h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_t).transpose(0, 2, 1), h_ref,
                               rtol=2e-5, atol=2e-5)


def test_kernel_carries_state_across_chunks():
    """The VMEM scratch must carry h between sequential chunk steps —
    compare one 4-chunk kernel call against four chained 1-chunk calls."""
    B, S, di, n = 1, 16, 128, 4
    dt, xi, Bm, Cm, A, h0 = _inputs(jax.random.PRNGKey(1), B, S, di, n)
    A_t = jnp.transpose(A)
    h0_t = jnp.transpose(h0, (0, 2, 1))
    y_full, h_full = selective_scan_pallas(
        dt, dt * xi, Bm, Cm, A_t, h0_t, block_di=128, chunk=4,
        interpret=True)
    h = h0_t
    ys = []
    for c in range(4):
        sl = slice(4 * c, 4 * (c + 1))
        y_c, h = selective_scan_pallas(
            dt[:, sl], (dt * xi)[:, sl], Bm[:, sl], Cm[:, sl], A_t, h,
            block_di=128, chunk=4, interpret=True)
        ys.append(y_c)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, axis=1)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h),
                               rtol=1e-6, atol=1e-6)


def test_kernel_matches_fused_chunk_xla():
    """Kernel and the XLA fused_chunk path are the same schedule."""
    from repro.models.mamba import _ssm_scan_fused
    B, S, di, n = 2, 24, 128, 16
    dt, xi, Bm, Cm, A, h0 = _inputs(jax.random.PRNGKey(2), B, S, di, n)
    y_x, h_x = _ssm_scan_fused(dt, dt * xi, Bm, Cm, A, h0, 8)
    y_k, h_k = selective_scan_pallas(
        dt, dt * xi, Bm, Cm, jnp.transpose(A),
        jnp.transpose(h0, (0, 2, 1)), block_di=128, chunk=8,
        interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_x),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_k).transpose(0, 2, 1),
                               np.asarray(h_x), rtol=2e-5, atol=2e-5)
