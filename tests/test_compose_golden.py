"""Golden-value regression: the stable compose vs the fp64 oracle at
near-unity magnitude scales.

The paper's Fig. 1 result rests on one numerical fact: with g = 1 ± 2^-k
(DoRA's g concentrates inside the bf16 collapse zone), the naive form
``g*(s*lora + base) - base`` cancels catastrophically while the stable form
``(g-1)*base + g*s*lora`` keeps the correction exact — because (g - 1) is
representable exactly in fp32 for these g. This module locks that behavior
with exact golden values (scalar cases whose arithmetic is representable)
and with fp64-oracle error bounds across bf16/fp32 activations on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compose import (compose_naive, compose_reference_fp64,
                                compose_stable)

jax.config.update("jax_enable_x64", True)

# g offsets the paper measures: well inside bf16's 8-bit mantissa collapse
# zone (2^-9) and inside fp16's (2^-13).
G_OFFSETS = [2.0 ** -9, -(2.0 ** -9), 2.0 ** -13, -(2.0 ** -13)]
S = 1.25  # exactly representable scaling


def _mats(key, rows, d_out, dtype):
    kb, kl = jax.random.split(key)
    base = jax.random.normal(kb, (rows, d_out), jnp.float32).astype(dtype)
    lora = (0.05 * jax.random.normal(kl, (rows, d_out),
                                     jnp.float32)).astype(dtype)
    return base, lora


@pytest.mark.parametrize("off", G_OFFSETS)
def test_exact_golden_scalar_case(off):
    """base=1, lora=0, g=1+off: delta must be EXACTLY off (fp32), the
    correction the naive bf16 form collapses to 0 or 2^-8."""
    g = jnp.asarray([1.0 + off], jnp.float32)
    base = jnp.ones((1, 1), jnp.float32)
    lora = jnp.zeros((1, 1), jnp.float32)
    delta = compose_stable(base, lora, g, S)
    # Golden value: off is a power of two → (g-1)*1 is exact in fp32.
    assert float(delta[0, 0]) == off


@pytest.mark.parametrize("off", G_OFFSETS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stable_tracks_fp64_oracle(off, dtype, rng_key):
    rows, d_out = 64, 256
    base, lora = _mats(rng_key, rows, d_out, dtype)
    g = jnp.full((d_out,), 1.0 + off, jnp.float32)
    got = np.asarray(compose_stable(base, lora, g, S), np.float64)
    want = np.asarray(compose_reference_fp64(base, lora, g, S))
    # The compose itself runs in fp32; the only loss is the final cast to
    # the activation dtype. Bound = 1 ulp of the output dtype on the
    # correction's scale (|delta| ~ |off| + s*|lora|), NOT on |base| —
    # that looser bound is exactly what the naive form needs and the
    # stable form must beat.
    scale = np.abs(want) + np.abs(off)
    ulp = 1e-6 if dtype == jnp.float32 else 2.0 ** -8
    err = np.abs(got - want)
    assert np.max(err / np.maximum(scale, np.abs(off))) <= ulp, \
        f"stable compose drifted from fp64 oracle at g=1{off:+g}"


@pytest.mark.parametrize("off", [2.0 ** -9, -(2.0 ** -9)])
def test_naive_bf16_collapses_where_stable_survives(off, rng_key):
    """The regression this file exists for: at g = 1 ± 2^-9 in bf16, the
    naive form's relative error vs the oracle must be ~100% (g rounds to
    1.0 ± nothing after the multiply, the subtraction cancels), while the
    stable form stays within bf16 quantization of the same oracle."""
    rows, d_out = 64, 256
    base, lora = _mats(rng_key, rows, d_out, jnp.bfloat16)
    lora = jnp.zeros_like(lora)  # isolate the (g-1)*base correction
    g = jnp.full((d_out,), 1.0 + off, jnp.float32)
    want = np.asarray(compose_reference_fp64(base, lora, g, S))
    stable = np.asarray(compose_stable(base, lora, g, S), np.float64)
    naive = np.asarray(compose_naive(base, lora, g, S), np.float64)
    denom = np.linalg.norm(want)
    rel_stable = np.linalg.norm(stable - want) / denom
    rel_naive = np.linalg.norm(naive - want) / denom
    assert rel_stable < 0.01, rel_stable
    assert rel_naive > 0.5, (
        "naive bf16 compose unexpectedly survived the collapse zone — "
        "did someone change its evaluation dtype?")


def test_cosine_vs_oracle_above_paper_threshold(rng_key):
    """Paper's headline equivalence metric: cosine similarity of the stable
    fp32 compose vs the fp64 oracle > 0.9999 at every measured g offset."""
    rows, d_out = 128, 512
    base, lora = _mats(rng_key, rows, d_out, jnp.float32)
    for off in G_OFFSETS:
        g = jnp.full((d_out,), 1.0 + off, jnp.float32)
        got = np.asarray(compose_stable(base, lora, g, S),
                         np.float64).ravel()
        want = np.asarray(compose_reference_fp64(base, lora, g, S)).ravel()
        cos = got @ want / (np.linalg.norm(got) * np.linalg.norm(want))
        assert cos > 0.9999, (off, cos)
