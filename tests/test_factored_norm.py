"""Factored-norm correctness: algebra, chunking, baselines, sharding."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.factored_norm as fn

jax.config.update("jax_enable_x64", True)


def _mats(key, d_out, d_in, r, dtype=jnp.float32, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    W = (jax.random.normal(k1, (d_out, d_in), jnp.float32)).astype(dtype)
    A = (scale * jax.random.normal(k2, (r, d_in), jnp.float32)).astype(dtype)
    B = (scale * jax.random.normal(k3, (d_out, r), jnp.float32)).astype(dtype)
    return W, A, B


@pytest.mark.parametrize("shape", [(64, 128, 4), (128, 96, 16),
                                   (32, 4096, 384), (256, 256, 768)])
@pytest.mark.parametrize("s", [0.0, 0.25, 1.0, 8.0])
def test_factored_equals_dense_fp64(shape, s):
    """The factored decomposition is exact algebra: vs fp64 dense oracle."""
    d_out, d_in, r = shape
    W, A, B = _mats(jax.random.PRNGKey(0), d_out, d_in, r)
    got = fn.factored_norm(W, A, B, s)
    want = fn.norm_reference_fp64(W, A, B, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_chunked_matches_unchunked():
    W, A, B = _mats(jax.random.PRNGKey(1), 128, 8192, 64)
    full = fn.factored_norm(W, A, B, 2.0, chunk_mb=None)
    # budget forcing ~8 chunks: cs = 1MB/(128*4) = 2048
    chunked = fn.factored_norm(W, A, B, 2.0, chunk_mb=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-4)


def test_chunk_size_alignment():
    """cs = min(d_in, budget // (d_out*4)), aligned to 64 (Alg. 1)."""
    assert fn.chunk_size(8192, 8192, 256) == 8192  # 256MB spans full d_in
    cs = fn.chunk_size(8192, 28672, 256)
    assert cs % 64 == 0 and cs == (256 * 2**20) // (8192 * 4)
    assert fn.chunk_size(128, 100, None) == 100


def test_baselines_agree_with_factored():
    """PEFT-eye and dense-BA baselines compute the same norm."""
    W, A, B = _mats(jax.random.PRNGKey(2), 96, 192, 24)
    s = 1.7
    factored = fn.factored_norm(W, A, B, s)
    peft = fn.norm_peft_eye(W, A, B, s)
    dense = fn.norm_dense_ba(W, A, B, s)
    np.testing.assert_allclose(np.asarray(factored), np.asarray(peft),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(factored), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_norm_is_detached():
    """DoRA §4.3: no gradient flows through the norm to W, A or B."""
    W, A, B = _mats(jax.random.PRNGKey(3), 32, 64, 8)

    def loss(a, b):
        return jnp.sum(fn.factored_norm(W, a, b, 1.0))

    ga, gb = jax.grad(loss, argnums=(0, 1))(A, B)
    assert float(jnp.abs(ga).max()) == 0.0
    assert float(jnp.abs(gb).max()) == 0.0


def test_s_zero_fast_path():
    W, A, B = _mats(jax.random.PRNGKey(4), 64, 128, 8)
    got = fn.factored_norm(W, A, B, 0.0)
    want = jnp.linalg.norm(W.astype(jnp.float32), axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_bf16_inputs_fp32_accumulation():
    """Accumulation must be fp32 even for bf16 inputs (paper §2.2): the
    result matches the fp32 norm of the *quantized* matrices closely."""
    W, A, B = _mats(jax.random.PRNGKey(5), 128, 2048, 32, dtype=jnp.bfloat16)
    got = fn.factored_norm(W, A, B, 1.0)
    assert got.dtype == jnp.float32
    want = fn.norm_reference_fp64(W, A, B, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-3)


def test_base_sq_cache_path():
    W, A, B = _mats(jax.random.PRNGKey(6), 64, 256, 16)
    cache = jnp.sum(W.astype(jnp.float32) ** 2, axis=1)
    got = fn.factored_norm(W, A, B, 1.5, base_sq_cache=cache)
    want = fn.factored_norm(W, A, B, 1.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_eps_policy():
    assert fn.dtype_eps(jnp.bfloat16) == 1e-6
    assert fn.dtype_eps(jnp.float16) == 1e-6
    assert fn.dtype_eps(jnp.float32) == 1e-12
    assert fn.dtype_eps(jnp.float64) == 1e-12


_SHARDED_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat.mesh import make_mesh, shard_map
    from repro.core import factored_norm as fn

    mesh = make_mesh((8,), ("model",))
    d_out, d_in, r, s = 64, 512, 16, 1.3
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    W = jax.random.normal(k1, (d_out, d_in), jnp.float32)
    A = jax.random.normal(k2, (r, d_in), jnp.float32)
    B = jax.random.normal(k3, (d_out, r), jnp.float32)

    fun = shard_map(
        lambda w, a, b: fn.factored_norm_sharded(w, a, b, s,
                                                 axis_name="model"),
        mesh=mesh,
        in_specs=(P(None, "model"), P(None, "model"), P(None, None)),
        out_specs=P(None),
    )
    got = jax.jit(fun)(W, A, B)
    want = fn.factored_norm(W, A, B, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    got0 = jax.jit(shard_map(
        lambda w, a, b: fn.factored_norm_sharded(w, a, b, 0.0,
                                                 axis_name="model"),
        mesh=mesh,
        in_specs=(P(None, "model"), P(None, "model"), P(None, None)),
        out_specs=P(None)))(W, A, B)
    np.testing.assert_allclose(np.asarray(got0),
                               np.asarray(fn.factored_norm(W, A, B, 0.0)),
                               rtol=1e-5, atol=1e-4)
    print("SHARDED_OK")
""")


def test_sharded_factored_norm_subprocess():
    """The psum-based sharded norm (8 fake devices, d_in sharded 8-way)
    matches the single-device factored norm. Run in a subprocess so the
    device-count flag doesn't leak into this test session."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    # Inherit the parent env (JAX_PLATFORMS etc. — a stripped env can send
    # the TPU plugin off to poll cloud metadata) and pin the CPU backend.
    env = dict(os.environ, PYTHONPATH=src, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)  # the program sets its own device count
    res = subprocess.run([sys.executable, "-c", _SHARDED_PROG],
                         capture_output=True, text=True, timeout=300,
                         env=env)
    assert "SHARDED_OK" in res.stdout, res.stderr[-2000:]
