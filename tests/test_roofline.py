"""Roofline analyzer: HLO parsing, trip-count multipliers, dot flops,
collective traffic factors — validated against hand-built HLO snippets and
a real compiled module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.roofline import HW, analyze_hlo_text, model_flops, \
    roofline_terms
from repro.roofline.analysis import _shape_bytes_and_dims

HLO_DOT = """
ENTRY %main (p0: f32[8,16], p1: f32[32,16]) -> f32[8,32] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %p1 = f32[32,16]{1,0} parameter(1)
  ROOT %dot.1 = f32[8,32]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={1}
}
"""

HLO_WHILE = """
%body (param: (s32[], f32[8,16], f32[16,16])) -> (s32[], f32[8,16], f32[16,16]) {
  %param = (s32[], f32[8,16]{1,0}, f32[16,16]{1,0}) parameter(0)
  %gte0 = f32[8,16]{1,0} get-tuple-element(%param), index=1
  %gte1 = f32[16,16]{1,0} get-tuple-element(%param), index=2
  %dot.2 = f32[8,16]{1,0} dot(%gte0, %gte1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,16]{1,0}, f32[16,16]{1,0}) tuple(%gte0, %dot.2, %gte1)
}

%cond (param.1: (s32[], f32[8,16], f32[16,16])) -> pred[] {
  %param.1 = (s32[], f32[8,16]{1,0}, f32[16,16]{1,0}) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (arg: (s32[], f32[8,16], f32[16,16])) -> (s32[], f32[8,16], f32[16,16]) {
  %arg = (s32[], f32[8,16]{1,0}, f32[16,16]{1,0}) parameter(0)
  ROOT %while.1 = (s32[], f32[8,16]{1,0}, f32[16,16]{1,0}) while(%arg), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
}
"""

HLO_COLLECTIVE = """
ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %ar = f32[128]{0} all-reduce(%p), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %ag = f32[512]{0} all-gather(%ar), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
}
"""


def test_shape_parsing():
    assert _shape_bytes_and_dims("f32[8,16]{1,0}") == (512, [8, 16])
    assert _shape_bytes_and_dims("bf16[4]") == (8, [4])
    b, dims = _shape_bytes_and_dims("(s32[], f32[8,16], bf16[2,2])")
    assert b == 4 + 512 + 8
    assert dims == []  # first entry s32[] is scalar


def test_dot_flops_counted():
    ana = analyze_hlo_text(HLO_DOT)
    assert ana.flops == 2 * 8 * 32 * 16


def test_while_trip_count_multiplies():
    ana = analyze_hlo_text(HLO_WHILE)
    assert ana.flops == 12 * 2 * 8 * 16 * 16


def test_collective_traffic_factors():
    ana = analyze_hlo_text(HLO_COLLECTIVE)
    # all-reduce 512B x 2(n-1)/n with n=4 -> 768; all-gather shard 512B x
    # (n-1) = 1536
    assert ana.by_collective["all-reduce"] == pytest.approx(768.0)
    assert ana.by_collective["all-gather"] == pytest.approx(1536.0)
    assert ana.link_bytes == pytest.approx(768.0 + 1536.0)


def test_roofline_terms_dominance():
    ana = analyze_hlo_text(HLO_DOT)
    terms = roofline_terms(ana, HW(peak_flops=1.0, hbm_bw=1e30,
                                   link_bw=1e30))
    assert terms["dominant"] == "compute"
    assert terms["roofline_fraction"] == 1.0


def test_model_flops_train_vs_serve():
    from repro.configs import get_config
    mcfg = get_config("qwen2-7b")
    t = model_flops(mcfg, tokens=100, kind="train")
    s = model_flops(mcfg, tokens=100, kind="serve")
    assert t == pytest.approx(3 * s)


def test_moe_active_params_used():
    from repro.configs import get_config
    moe = get_config("llama4-scout-17b-a16e")
    assert moe.count_active_params() < 0.45 * moe.count_params()


def test_against_real_compiled_module():
    """End-to-end: a jitted scan matmul must yield flops ~= trip x 2MNK
    (XLA's own cost_analysis misses the trip count; ours must not)."""
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    ana = analyze_hlo_text(compiled.as_text())
    want = 5 * 2 * 8 * 64 * 64
    assert ana.flops == pytest.approx(want, rel=0.05)
