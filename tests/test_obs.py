"""Observability layer (PR 10): the FREE-and-INVARIANT contract.

The load-bearing assertions: threading a ``TraceRecorder`` through
``DecodeEngine(trace=...)`` leaves token streams BITWISE identical,
``EngineStats`` identical, and ``compile_counts()`` identical to the
untraced run — over clean, faulty, speculative and preemptive
schedules — and every recorded event is built from host scalars only
(JSON-serializable without any numpy/jax coercion), which is the
observable face of the zero-device-fetch guarantee.

Plus the plumbing underneath: ring bounding/overflow accounting,
histogram bucket edges, exporter round-trips (JSONL, Chrome
trace_event, Prometheus text, JSON), derived lifecycle latencies, and
the adapter-cache spill/reload event hook.
"""
from __future__ import annotations

import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import AdapterStateCache, DoRAConfig
from repro.launch.engine import FINISH_REASONS, DecodeEngine
from repro.launch.steps import StepConfig
from repro.launch.train import build_state
from repro.obs import (AUX_EVENTS, EVENT_NAMES, LIFECYCLE_EVENTS,
                       SECONDS_BUCKETS, TICK_BUCKETS, Counter, Gauge,
                       Histogram, MetricsRegistry, TraceRecorder,
                       engine_metrics, latency_metrics,
                       lifecycle_latencies, monotonic, parse_prometheus,
                       percentile)

DCFG = DoRAConfig(rank=4, alpha=8.0, mode="eager")
ARCH = "qwen2-7b"
ML = 14


class _FakeClock:
    """Deterministic monotone clock for exporter/latency tests."""

    def __init__(self, dt: float = 0.5):
        self.t = 0.0
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


# ---------------------------------------------------------------------------
# Ring buffer
# ---------------------------------------------------------------------------

class TestRing:
    def test_bounding_and_overflow_accounting(self):
        rec = TraceRecorder(capacity=4, clock=_FakeClock())
        for i in range(10):
            rec.emit("token", tick=i, request_id=0, token=i)
        assert len(rec) == 4
        assert rec.emitted == 10
        assert rec.dropped == 6
        # oldest dropped first: the survivors are the LAST four
        assert [e.tick for e in rec] == [6, 7, 8, 9]

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError):
            TraceRecorder(capacity=0)

    def test_filters_and_request_ids(self):
        rec = TraceRecorder(clock=_FakeClock())
        rec.emit("submitted", tick=0, request_id=1)
        rec.emit("submitted", tick=0, request_id=2)
        rec.emit("terminal", tick=3, request_id=1, reason="length")
        rec.emit("fault", tick=2, kind="nan")
        assert rec.request_ids() == [1, 2]
        assert len(rec.events("submitted")) == 2
        assert len(rec.events(request_id=1)) == 2
        assert rec.events("terminal", request_id=1)[0].data["reason"] \
            == "length"
        assert rec.events("terminal", request_id=2) == []

    def test_t_wall_is_monotone(self):
        rec = TraceRecorder(clock=_FakeClock())
        for i in range(5):
            rec.emit("token", tick=i)
        ws = [e.t_wall for e in rec]
        assert ws == sorted(ws) and ws[0] >= 0.0

    def test_taxonomy_is_closed(self):
        # terminal's reason field mirrors the engine's finish reasons —
        # the docs table is generated from these tuples.
        assert set(LIFECYCLE_EVENTS) & set(AUX_EVENTS) == set()
        assert EVENT_NAMES == LIFECYCLE_EVENTS + AUX_EVENTS
        assert "terminal" in LIFECYCLE_EVENTS
        assert len(FINISH_REASONS) == 6


# ---------------------------------------------------------------------------
# Metrics primitives
# ---------------------------------------------------------------------------

class TestPrimitives:
    def test_histogram_bucket_edges_are_inclusive_upper(self):
        h = Histogram(buckets=(1, 2, 4))
        for v in (1, 1.5, 4, 5):
            h.observe(v)
        assert h.cumulative() == [(1.0, 1), (2.0, 2), (4.0, 3),
                                  (math.inf, 4)]
        assert h.count == 4 and h.sum == pytest.approx(11.5)

    def test_histogram_rejects_unsorted_or_empty_edges(self):
        with pytest.raises(ValueError):
            Histogram(buckets=(2, 1))
        with pytest.raises(ValueError):
            Histogram(buckets=())

    def test_counter_rejects_negative(self):
        c = Counter()
        c.inc(2)
        with pytest.raises(ValueError):
            c.inc(-1)
        assert c.value == 2

    def test_percentile_nearest_rank(self):
        xs = [1, 2, 3, 4]
        assert percentile(xs, 50) == 2
        assert percentile(xs, 100) == 4
        assert percentile(xs, 0) == 1
        assert percentile([], 50) == 0.0
        with pytest.raises(ValueError):
            percentile(xs, 101)

    def test_registry_kind_collision(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")

    def test_registry_labels_are_distinct_samples(self):
        reg = MetricsRegistry()
        reg.counter("n", labels={"k": "a"}).inc(1)
        reg.counter("n", labels={"k": "b"}).inc(2)
        assert reg.counter("n", labels={"k": "a"}).value == 1
        assert reg.counter("n", labels={"k": "b"}).value == 2


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

class TestExporters:
    def _registry(self):
        reg = MetricsRegistry()
        reg.counter("reqs_total", "requests").inc(7)
        reg.gauge("occupancy", "busy slots").set(1.5)
        h = reg.histogram("wait_ticks", "queue wait",
                          buckets=(1, 2, 4))
        for v in (1, 3, 9):
            h.observe(v)
        reg.counter("finished_total", labels={"reason": "eos"}).inc(2)
        return reg

    def test_prometheus_round_trip(self, tmp_path):
        reg = self._registry()
        path = str(tmp_path / "m.prom")
        text = reg.to_prometheus(path)
        assert open(path).read() == text
        parsed = parse_prometheus(text)
        assert parsed["repro_reqs_total"] == 7
        assert parsed["repro_occupancy"] == 1.5
        assert parsed['repro_finished_total{reason="eos"}'] == 2
        assert parsed['repro_wait_ticks_bucket{le="1"}'] == 1
        assert parsed['repro_wait_ticks_bucket{le="4"}'] == 2
        assert parsed['repro_wait_ticks_bucket{le="+Inf"}'] == 3
        assert parsed["repro_wait_ticks_sum"] == 13
        assert parsed["repro_wait_ticks_count"] == 3
        # HELP/TYPE lines present (text exposition v0.0.4)
        assert "# TYPE repro_wait_ticks histogram" in text
        assert "# HELP repro_reqs_total requests" in text

    def test_json_snapshot(self, tmp_path):
        reg = self._registry()
        path = str(tmp_path / "m.json")
        snap = reg.to_json(path)
        assert json.load(open(path)) == json.loads(json.dumps(snap))
        assert snap["reqs_total"]["kind"] == "counter"
        assert snap["wait_ticks"]["samples"][0]["count"] == 3
        assert snap["wait_ticks"]["samples"][0]["buckets"][-1] == \
            ["inf", 3]

    def test_jsonl_round_trip(self, tmp_path):
        rec = TraceRecorder(clock=_FakeClock())
        rec.emit("submitted", tick=0, request_id=0, prompt_len=5)
        rec.emit("terminal", tick=4, request_id=0, slot=1,
                 reason="length", n_tokens=4)
        path = str(tmp_path / "t.jsonl")
        text = rec.to_jsonl(path)
        assert open(path).read() == text
        parsed = [json.loads(line) for line in text.splitlines()]
        assert parsed == [e.as_dict() for e in rec]
        assert parsed[1]["data"]["reason"] == "length"

    def test_chrome_trace_spans(self, tmp_path):
        # one full lifecycle with a preemption, as the engine emits it:
        # every seating emits "admitted"; re-admissions add "resumed".
        rec = TraceRecorder(clock=_FakeClock())
        rec.emit("submitted", tick=0, request_id=0, prompt_len=5)
        rec.emit("queued", tick=0, request_id=0, depth=1)
        rec.emit("admitted", tick=0, request_id=0, slot=0, prompt_len=5)
        rec.emit("first_token", tick=1, request_id=0, slot=0, token=7)
        rec.emit("token", tick=2, request_id=0, slot=0, token=9)
        rec.emit("preempted", tick=3, request_id=0, slot=0,
                 n_generated=2)
        rec.emit("admitted", tick=5, request_id=0, slot=1, prompt_len=7)
        rec.emit("resumed", tick=5, request_id=0, slot=1, attempt=1)
        rec.emit("terminal", tick=7, request_id=0, slot=1,
                 reason="length", n_tokens=4)
        rec.emit("fault", tick=6, kind="nan")
        path = str(tmp_path / "t.json")
        doc = rec.to_chrome_trace(path)
        assert json.load(open(path)) == json.loads(json.dumps(doc))
        evs = doc["traceEvents"]
        assert all(e["ph"] in ("M", "X", "i") for e in evs)
        spans = [e for e in evs if e["ph"] == "X"]
        assert all(e["dur"] >= 0.0 for e in spans)
        # two queue-wait spans (initial + post-preemption re-queue) and
        # two residency spans (slot 0 then slot 1)
        queue = [e for e in spans if e["name"].startswith("queued")]
        resid = [e for e in spans if e["name"] == "r0"]
        assert len(queue) == 2 and len(resid) == 2
        assert sorted(e["tid"] for e in resid) == [0, 1]
        assert queue[0]["args"]["ticks"] == 0
        assert queue[1]["args"]["ticks"] == 2     # preempted@3 -> admitted@5
        # the fault instant lands on the engine track (above all slots)
        inst = [e for e in evs if e["ph"] == "i" and e["name"] == "fault"]
        assert inst and inst[0]["tid"] > max(e["tid"] for e in resid)
        assert doc["otherData"]["emitted"] == 10

    def test_chrome_trace_closes_open_spans(self):
        rec = TraceRecorder(clock=_FakeClock())
        rec.emit("submitted", tick=0, request_id=0)
        rec.emit("admitted", tick=0, request_id=0, slot=0)
        rec.emit("token", tick=1, request_id=0, slot=0, token=3)
        doc = rec.to_chrome_trace()
        open_spans = [e for e in doc["traceEvents"]
                      if e["ph"] == "X" and e["name"].endswith("(open)")]
        assert len(open_spans) == 1 and open_spans[0]["dur"] >= 0.0


# ---------------------------------------------------------------------------
# Derived latencies
# ---------------------------------------------------------------------------

class TestLatencies:
    def _rec(self):
        rec = TraceRecorder(clock=_FakeClock(dt=1.0))
        rec.emit("submitted", tick=0, request_id=0)
        rec.emit("admitted", tick=2, request_id=0, slot=0)
        rec.emit("first_token", tick=3, request_id=0, slot=0, token=1)
        rec.emit("token", tick=4, request_id=0, slot=0, token=2)
        rec.emit("token", tick=6, request_id=0, slot=0, token=3)
        rec.emit("terminal", tick=6, request_id=0, slot=0,
                 reason="length", n_tokens=3)
        # a queued-timeout request: submitted but never admitted
        rec.emit("submitted", tick=1, request_id=1)
        rec.emit("terminal", tick=5, request_id=1, reason="timeout",
                 queued=True)
        return rec

    def test_tick_domain_deltas(self):
        lat = lifecycle_latencies(self._rec())
        r0 = lat[0]
        assert r0["queue_wait_ticks"] == 2
        assert r0["ttft_ticks"] == 3
        assert r0["admit_to_retire_ticks"] == 4
        assert r0["itl_ticks"] == [1, 2]
        assert r0["reason"] == "length"
        # wall deltas exist and are positive (fake clock: 1s/event)
        assert r0["ttft_s"] == pytest.approx(2.0)
        r1 = lat[1]
        assert r1["admitted_tick"] is None
        assert r1["queue_wait_ticks"] is None
        assert r1["ttft_ticks"] is None and r1["itl_ticks"] == []
        assert r1["reason"] == "timeout"

    def test_latency_metrics_fill(self):
        reg = latency_metrics(self._rec())
        text = reg.to_prometheus()
        parsed = parse_prometheus(text)
        assert parsed["repro_ttft_ticks_count"] == 1
        assert parsed["repro_itl_ticks_count"] == 2
        assert parsed['repro_requests_finished_total{reason="length"}'] \
            == 1
        assert parsed['repro_requests_finished_total{reason="timeout"}'] \
            == 1
        assert parsed["repro_trace_events_emitted_total"] == 8
        assert parsed["repro_trace_events_dropped_total"] == 0


# ---------------------------------------------------------------------------
# Engine integration: the invariance contract
# ---------------------------------------------------------------------------

_REQS = [(5, 4), (6, 5), (4, 3), (5, 4)]     # (prompt_len, budget)


def _setup():
    mcfg = get_config(ARCH, smoke=True)
    scfg = StepConfig(dora=DCFG)
    params, _, _ = build_state(mcfg, DCFG, 0)
    cache = AdapterStateCache.for_serving(mcfg, scfg)
    _, ad, _ = build_state(mcfg, DCFG, 10)
    cache.register("t0", ad)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, mcfg.vocab_size, P, dtype=np.int32)
               for P, _ in _REQS]
    return mcfg, scfg, params, cache, prompts


def _drive(trace=None, *, plan=None, deadline=None, speculative_k=0,
           paged=False):
    mcfg, scfg, params, cache, prompts = _setup()
    eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=ML,
                       adapter_cache=cache, fault_plan=plan,
                       speculative_k=speculative_k, paged=paged,
                       trace=trace)
    for i, (p, (_, g)) in enumerate(zip(prompts, _REQS)):
        eng.submit(p, adapter="t0", max_new_tokens=g, key_id=i,
                   deadline_ticks=deadline if i == 3 else None)
    return eng.run(), eng


def _streams(results):
    return {r.request_id: (tuple(int(t) for t in r.tokens),
                           r.finish_reason) for r in results}


class TestInvariance:
    """ACCEPTANCE: tracing on == tracing off, bitwise."""

    @pytest.mark.parametrize("variant", ["clean", "faulty", "spec"])
    def test_tracing_changes_nothing(self, variant):
        from repro.launch.faults import FaultPlan
        kw = {}
        if variant == "faulty":
            kw = dict(plan=FaultPlan.parse("nan@3"), deadline=3)
        elif variant == "spec":
            kw = dict(speculative_k=2)
        off_res, off_eng = _drive(None, **kw)
        rec = TraceRecorder()
        on_res, on_eng = _drive(rec, **kw)
        assert _streams(on_res) == _streams(off_res)
        assert on_eng.stats().as_dict() == off_eng.stats().as_dict()
        assert on_eng.compile_counts() == off_eng.compile_counts()
        assert len(rec) > 0 and rec.dropped == 0

    def test_events_are_host_scalars_only(self):
        """The zero-device-fetch face: every recorded field must already
        be a host scalar — json.dumps with no default= coercion proves
        no numpy/jax value ever reached the emit path."""
        rec = TraceRecorder()
        _drive(rec, speculative_k=2)
        for e in rec:
            json.dumps(e.as_dict())        # raises on np.*/jax.Array
            assert e.name in EVENT_NAMES, e


class TestLifecycleEvents:
    def test_conservation_and_order(self):
        rec = TraceRecorder()
        results, _ = _drive(rec)
        assert rec.request_ids() == [0, 1, 2, 3]
        for rid in rec.request_ids():
            evs = rec.events(request_id=rid)
            # exactly one submitted and one terminal per request
            assert sum(e.name == "submitted" for e in evs) == 1
            assert sum(e.name == "terminal" for e in evs) == 1
            assert evs[0].name == "submitted"
            assert evs[-1].name == "terminal"
            assert evs[-1].data["reason"] in FINISH_REASONS
            # ticks monotone along each request's own event sequence
            ticks = [e.tick for e in evs]
            assert ticks == sorted(ticks), (rid, ticks)
            # exactly one first_token, before any plain token
            names = [e.name for e in evs]
            assert names.count("first_token") == 1
            assert "token" not in names[:names.index("first_token")]
        # token events tally with the engine's own accounting
        for r in results:
            n_tok = len(rec.events("first_token", r.request_id)) \
                + len(rec.events("token", r.request_id))
            assert n_tok == len(r.tokens)

    def test_preemption_emits_preempt_resume_pair(self):
        mcfg, scfg, params, cache, prompts = _setup()
        rec = TraceRecorder()
        eng = DecodeEngine(mcfg, scfg, params, slots=1, max_len=ML,
                           adapter_cache=cache, trace=rec)
        eng.submit(prompts[0], adapter="t0", max_new_tokens=8)
        for _ in range(2):
            eng.step()
        eng.submit(prompts[1][:4], adapter="t0", max_new_tokens=2,
                   priority=5)
        results = {r.request_id: r for r in eng.run()}
        assert results[0].preempted == 1
        pre = rec.events("preempted", 0)
        res = rec.events("resumed", 0)
        assert len(pre) == 1 and len(res) == 1
        assert res[0].data["attempt"] == 1
        assert pre[0].tick <= res[0].tick
        # the victim re-seats: two admitted events, one per residency
        assert len(rec.events("admitted", 0)) == 2
        # the timeline stays well-formed through the preemption
        doc = rec.to_chrome_trace()
        r0_spans = [e for e in doc["traceEvents"]
                    if e["ph"] == "X" and e["name"] == "r0"]
        assert len(r0_spans) == 2

    def test_quarantine_trace_sequence(self):
        from repro.launch.faults import FaultPlan
        rec = TraceRecorder()
        results, eng = _drive(rec, plan=FaultPlan.parse("nan@3"))
        poisoned = [r.request_id for r in results
                    if r.finish_reason == "error_numeric"]
        assert poisoned, "nan@3 quarantined nothing"
        assert len(rec.events("fault")) == 1
        assert rec.events("fault")[0].data["kind"] == "nan"
        for rid in poisoned:
            q = rec.events("quarantined", rid)
            t = rec.events("terminal", rid)
            assert len(q) == 1 and len(t) == 1
            assert t[0].data["reason"] == "error_numeric"
            assert q[0].tick == t[0].tick

    def test_chunk_prefill_events_cover_the_prompt(self):
        rec = TraceRecorder()
        results, eng = _drive(rec, paged=True)
        assert _streams(results) == _streams(_drive(None, paged=True)[0])
        for rid, (P, _) in enumerate(_REQS):
            chunks = rec.events("chunk_prefill", rid)
            assert chunks, f"r{rid}: no chunk events"
            assert sum(c.data["chunk_len"] for c in chunks) == P
            assert chunks[-1].data["final"] is True
            assert all(not c.data["final"] for c in chunks[:-1])


class TestCacheEvents:
    def test_traced_engine_claims_the_hook(self):
        mcfg, scfg, params, cache, _ = _setup()
        assert cache.on_event is None
        rec = TraceRecorder()
        eng = DecodeEngine(mcfg, scfg, params, slots=2, max_len=ML,
                           adapter_cache=cache, trace=rec)
        hook = cache.on_event
        assert hook is not None
        DecodeEngine(mcfg, scfg, params, slots=2, max_len=ML,
                     adapter_cache=cache)
        assert cache.on_event is hook, \
            "an untraced engine must not strip another engine's hook"
        del eng

    def test_spill_reload_emit_events(self):
        """Unit-level: drive the tiered cache through a spill and a
        reload with the hook wired straight to a recorder."""
        from repro.core import init_dora_params, precompute_adapter_state
        d_out, d_in = 16, 12

        def pre(params, adapters):
            return precompute_adapter_state(params, adapters, DCFG,
                                            act_dtype=jnp.float32,
                                            fold_gsb=True)

        def tenant(seed):
            key = jax.random.PRNGKey(seed)
            W = jax.random.normal(key, (d_out, d_in), jnp.float32)
            return init_dora_params(jax.random.fold_in(key, 1), W, DCFG)

        W = jax.random.normal(jax.random.PRNGKey(9), (d_out, d_in),
                              jnp.float32)
        state_bytes = 4 * (DCFG.rank * d_in + d_out * DCFG.rank + d_out
                           + d_out + d_out * DCFG.rank)
        cache = AdapterStateCache(pre, act_dtype=jnp.float32,
                                  fold_gsb=True, max_bytes=state_bytes,
                                  host_max_bytes=10 * state_bytes)
        rec = TraceRecorder(clock=_FakeClock())
        cache.on_event = lambda kind, key: rec.emit(
            kind, tick=0, adapter=key.adapter_id, version=key.version)
        hs = [cache.register(f"t{i}", tenant(i)) for i in range(2)]
        cache.get_state(W, hs[0])
        cache.get_state(W, hs[1])          # evicts + spills t0
        cache.get_state(W, hs[0])          # reloads t0 (spills t1)
        spills = rec.events("spill")
        reloads = rec.events("reload")
        assert [e.data["adapter"] for e in spills] == ["t0", "t1"]
        assert [e.data["adapter"] for e in reloads] == ["t0"]
        st = cache.stats()
        assert st.spills == len(spills) and st.reloads == len(reloads)


class TestEngineMetrics:
    def test_snapshot_wraps_all_stat_surfaces(self):
        rec = TraceRecorder()
        results, eng = _drive(rec, paged=True)
        reg = engine_metrics(eng, rec)
        parsed = parse_prometheus(reg.to_prometheus())
        st = eng.stats()
        assert parsed["repro_engine_retired_total"] == st.retired
        assert parsed["repro_engine_slots"] == 2
        assert parsed["repro_engine_generated_tokens_total"] == \
            st.generated_tokens
        assert parsed["repro_engine_mean_occupancy"] == \
            pytest.approx(st.mean_occupancy)
        assert parsed["repro_adapter_cache_entries"] == 1
        # compile counts carried as labelled counters
        assert parsed['repro_compiles_total{fn="prefill_chunk",sig=""}'] \
            == eng.compile_counts()["prefill_chunk"]
        # paged pool gauges present (pool drained after run)
        assert parsed["repro_pool_used_blocks"] == 0
        assert parsed['repro_pool_slot_blocks{slot="0"}'] == 0
        # derived latency histograms folded in from the trace
        assert parsed["repro_ttft_ticks_count"] == len(results)
        assert parsed['repro_requests_finished_total{reason="length"}'] \
            == len(results)

    def test_snapshot_is_json_exportable(self, tmp_path):
        rec = TraceRecorder()
        _, eng = _drive(rec)
        path = str(tmp_path / "m.json")
        snap = engine_metrics(eng, rec).to_json(path)
        assert json.load(open(path)) == json.loads(json.dumps(snap))
        assert snap["engine_retired_total"]["samples"][0]["value"] == 4

    def test_monotonic_clock_is_perf_counter(self):
        import time
        assert monotonic is time.perf_counter
        a, b = monotonic(), monotonic()
        assert b >= a
