"""MoE dispatch: capacity semantics, chunk-local (H2.4) equivalence,
dtype discipline (H2.1), router behaviour."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as MOE
from repro.models.config import ModelConfig

MCFG = ModelConfig(
    name="moe-test", family="moe",
    num_layers=2, d_model=32, num_heads=4, num_kv_heads=4,
    d_ff=16, vocab_size=64,
    moe=True, num_experts=4, top_k=2, moe_d_ff=16,
    capacity_factor=8.0,  # ample capacity: no drops -> exact checks
    dtype=jnp.float32)


def _params(key, mcfg=MCFG):
    D, E, F = mcfg.d_model, mcfg.num_experts, mcfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (E, D)) * 0.1,
        "gate": jax.random.normal(ks[1], (E, F, D)) * 0.1,
        "up": jax.random.normal(ks[2], (E, F, D)) * 0.1,
        "down": jax.random.normal(ks[3], (E, D, F)) * 0.1,
    }


def _dense_reference(x, p, mcfg=MCFG):
    """Every token through its top-k experts, no capacity limit."""
    gate_w, gate_i, _ = MOE.router_topk(x, p["router"], mcfg)
    B, S, D = x.shape
    y = np.zeros((B, S, D), np.float32)
    xn = np.asarray(x)
    for b in range(B):
        for s in range(S):
            for j in range(mcfg.top_k):
                e = int(gate_i[b, s, j])
                h = xn[b, s] @ np.asarray(p["gate"][e]).T
                u = xn[b, s] @ np.asarray(p["up"][e]).T
                act = h / (1 + np.exp(-h)) * u          # silu(h) * u
                y[b, s] += float(gate_w[b, s, j]) * (
                    act @ np.asarray(p["down"][e]).T)
    return y


def test_moe_matches_dense_reference():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, MCFG.d_model))
    p = _params(key)
    y, _ = MOE.moe_ffn(x, p, None, MCFG, None, training=False)
    np.testing.assert_allclose(np.asarray(y), _dense_reference(x, p),
                               rtol=2e-4, atol=2e-4)


def test_chunk_local_matches_global_with_ample_capacity():
    """H2.4: with capacity >= every chunk's worst case, chunk-local
    dispatch computes exactly the same output as global dispatch."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16,
                                                       MCFG.d_model))
    p = _params(key)
    y_global, _ = MOE.moe_ffn(x, p, None, MCFG, None, training=False)
    mc = dataclasses.replace(MCFG, moe_seq_chunks=4)
    y_chunk, _ = MOE.moe_ffn(x, p, None, mc, None, training=False)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_global),
                               rtol=2e-4, atol=2e-4)


def test_chunk_local_shape_guard():
    """Indivisible seq falls back to global dispatch."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 10, MCFG.d_model))
    mc = dataclasses.replace(MCFG, moe_seq_chunks=4)  # 10 % 4 != 0
    y, _ = MOE.moe_ffn(x, _params(key), None, mc, None, training=False)
    assert y.shape == (1, 10, MCFG.d_model)


def test_dispatch_dtype_follows_activation():
    """H2.1: bf16 activations keep the dispatch buffers bf16."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (1, 8, MCFG.d_model)).astype(jnp.bfloat16)
    p = jax.tree.map(lambda a: a.astype(jnp.bfloat16), _params(key))
    y, _ = MOE.moe_ffn(x, p, None, MCFG, None, training=False)
    assert y.dtype == jnp.bfloat16


def test_capacity_drops_tokens():
    """With capacity 1 and concentrated routing, overflow tokens drop
    (GShard semantics): the output is finite and not all tokens equal."""
    mc = dataclasses.replace(MCFG, capacity_factor=0.01)
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (1, 16, MCFG.d_model))
    y, _ = MOE.moe_ffn(x, _params(key), None, mc, None, training=False)
    assert np.isfinite(np.asarray(y)).all()
    # at least one dropped token produces a zero row
    norms = np.linalg.norm(np.asarray(y)[0], axis=-1)
    assert (norms < 1e-6).any()


def test_router_aux_loss_positive_when_enabled():
    mc = dataclasses.replace(MCFG, router_aux_coef=0.01)
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 8, MCFG.d_model))
    _, aux = MOE.moe_ffn(x, _params(key), None, mc, None, training=True)
    assert float(aux) > 0.0
